from repro.optim.adamw import (AdamWConfig, adamw_update, init_opt_state,
                               abstract_opt_state, schedule_lr, global_norm,
                               clip_by_global_norm)
from repro.optim.compression import (CompressionConfig, compress,
                                     init_error_state, wire_bytes)

__all__ = ["AdamWConfig", "adamw_update", "init_opt_state",
           "abstract_opt_state", "schedule_lr", "global_norm",
           "clip_by_global_norm", "CompressionConfig", "compress",
           "init_error_state", "wire_bytes"]
