"""AdamW + LR schedules + global-norm clipping, as pure pytree transforms.

Minimal optax-like surface (optax is not available offline).  Moment
dtypes are configurable: bf16 moments halve optimizer HBM — required to
fit llama3-405b training on a single 256-chip v5e pod (see configs).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0
    moment_dtype: str = "float32"       # "bfloat16" halves optimizer HBM
    schedule: str = "cosine"            # cosine | linear | constant
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def schedule_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (s + 1.0) / max(cfg.warmup_steps, 1))
    frac = jnp.clip((s - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1.0 - frac
    else:
        decay = jnp.ones_like(frac)
    decay = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * decay
    return cfg.lr * warm * decay


def global_norm(tree: Params) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Params, max_norm: float
                        ) -> tuple[Params, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def init_opt_state(cfg: AdamWConfig, params: Params) -> dict:
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def abstract_opt_state(cfg: AdamWConfig, params: Params) -> dict:
    mdt = jnp.dtype(cfg.moment_dtype)
    sds = lambda p: jax.ShapeDtypeStruct(p.shape, mdt)
    return {"mu": jax.tree.map(sds, params),
            "nu": jax.tree.map(sds, params),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def _is_matrix(path: tuple) -> bool:
    return True   # decay applied uniformly; norms are 1-d and tiny anyway


def adamw_update(cfg: AdamWConfig, grads: Params, opt_state: dict,
                 params: Params) -> tuple[Params, dict, dict]:
    """Returns (new_params, new_opt_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip_norm)
    step = opt_state["step"] + 1
    lr = schedule_lr(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = m.astype(jnp.float32) * b1 + gf * (1 - b1)
        vf = v.astype(jnp.float32) * b2 + jnp.square(gf) * (1 - b2)
        mhat = mf / bc1
        vhat = vf / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (delta + cfg.weight_decay * pf)
        return pf.astype(p.dtype), mf.astype(mdt), vf.astype(mdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["mu"])
    flat_v = jax.tree.leaves(opt_state["nu"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_state = {"mu": new_m, "nu": new_v, "step": step}
    return new_p, new_state, {"lr": lr, "grad_norm": gnorm}
