"""Gradient compression for the slow (pod-crossing) axis.

Two schemes, both with error feedback so compression noise does not bias
the optimizer (Karimireddy et al., arXiv:1901.09847):

* ``topk``  — keep the k largest-magnitude entries per leaf (as a dense
  mask — TPU collectives are dense, so the win is modeled for the DCI
  byte accounting and the EF dynamics are exact);
* ``int8``  — per-leaf symmetric int8 quantization, dequantized after the
  all-reduce (4x fewer bytes on the wire).

``compress/decompress`` are pure pytree transforms; the trainer composes
them around the cross-pod gradient reduction (DESIGN.md §6).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    scheme: str = "none"          # none | topk | int8
    topk_ratio: float = 0.01      # fraction of entries kept
    ef: bool = True               # error feedback


def init_error_state(params: Params) -> Params:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _topk_mask(g: jax.Array, ratio: float) -> jax.Array:
    flat = jnp.abs(g.reshape(-1))
    k = max(1, int(flat.size * ratio))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(g) >= thresh).astype(g.dtype)


def compress(cfg: CompressionConfig, grads: Params, error: Params
             ) -> tuple[Params, Params, dict]:
    """Returns (wire_grads, new_error, metrics).

    wire_grads is what crosses the slow axis; new_error holds the residual
    to be re-injected next step (error feedback)."""
    if cfg.scheme == "none":
        return grads, error, {"compression_ratio": 1.0}

    def one(g, e):
        gf = g.astype(jnp.float32) + (e if cfg.ef else 0.0)
        if cfg.scheme == "topk":
            mask = _topk_mask(gf, cfg.topk_ratio)
            wire = gf * mask
            resid = gf - wire
            return wire.astype(g.dtype), resid
        if cfg.scheme == "int8":
            scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
            q = jnp.clip(jnp.round(gf / scale), -127, 127)
            wire = q * scale
            resid = gf - wire
            return wire.astype(g.dtype), resid
        raise ValueError(cfg.scheme)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    wire = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_err = jax.tree.unflatten(treedef, [o[1] for o in outs])
    ratio = cfg.topk_ratio if cfg.scheme == "topk" else 0.25
    return wire, new_err, {"compression_ratio": ratio}


def wire_bytes(cfg: CompressionConfig, grads: Params) -> float:
    """Bytes a cross-pod all-reduce of these grads would move per device
    under the configured scheme (for the roofline DCI term)."""
    import math
    total = sum(math.prod(g.shape) * g.dtype.itemsize
                for g in jax.tree.leaves(grads))
    if cfg.scheme == "topk":
        # index+value pairs: 4B index + 2B value per kept entry
        kept = total * cfg.topk_ratio / 2      # entries (bf16 grads)
        return kept * 6
    if cfg.scheme == "int8":
        return total / 2                        # bf16 -> int8
    return float(total)
