"""Cluster pool launcher: ``python -m repro.launch.cluster ...``

Runs a tenant mix through the multi-machine ``ClusterPool`` —
demand-aware routing over N per-machine schedulers — and reports
placement, per-job latency, rebalances, and aggregate throughput as
JSON.  ``--compare`` reruns the same mix under round-robin routing and
on a single machine, so one invocation shows what demand-aware routing
and the extra machines each buy.

``--check-parity`` preflights the layering claim behind the whole
design: a 1-machine cluster must reproduce the single-machine pool
bit-for-bit (the ``cluster-1m`` leg of ``check_parity``).

``--trace-out`` writes the run as a Perfetto timeline with one process
lane per machine plus route->launch flow arrows (open at
https://ui.perfetto.dev).
"""

from __future__ import annotations

import argparse
import json

from repro.cluster import ClusterPool, RouterConfig
from repro.core import StrategyConfig
from repro.hw import ClusterSpec
from repro.multitenant import PlanCache, PoolConfig
from repro.obs import RecordingSink, configure_logging, \
    export_cluster_trace, get_logger
from repro.service.spec import submit_spec
from repro.launch.pool import mix_specs

logger = get_logger(__name__)

DEFAULT_JOBS = ("resnet50,dcgan,resnet50,dcgan,"
                "resnet50,dcgan,resnet50,dcgan")


def run_mix(specs, *, n_machines: int, policy: str, rebalance: bool,
            split: bool, max_active: int, feedback: str | None,
            seed: int, sink=None) -> tuple:
    """One cluster run of the mix; returns (pool, result)."""
    strat = StrategyConfig(feedback=feedback or "off",
                           **({"sink": sink} if sink is not None else {}))
    pool = ClusterPool(
        ClusterSpec.homogeneous(n_machines),
        config=PoolConfig(max_active=max_active, strategy=strat),
        router=RouterConfig(policy=policy, rebalance=rebalance,
                            split=split),
        plan_cache=PlanCache(), seed=seed)
    for spec in specs:
        submit_spec(pool, spec)
    return pool, pool.run()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--machines", type=int, default=2,
                    help="number of (homogeneous KNL-like) machines")
    ap.add_argument("--jobs", default=DEFAULT_JOBS,
                    help="comma-separated paper models, one job each")
    ap.add_argument("--policy", choices=("demand", "round_robin"),
                    default="demand",
                    help="routing policy: 'demand' bin-packs by "
                         "planstore-re-estimated core-seconds against "
                         "per-machine free capacity; 'round_robin' is "
                         "the arrival-index baseline")
    ap.add_argument("--no-rebalance", action="store_true",
                    help="disable the cross-machine admission-level "
                         "eviction (deadline-critical waiters stay put)")
    ap.add_argument("--split", action="store_true",
                    help="arm MovePrice-gated cross-machine splits of "
                         "multi-component graphs (off by default, like "
                         "every priced move)")
    ap.add_argument("--max-active", type=int, default=3,
                    help="per-machine co-run admission cap")
    ap.add_argument("--arrival-gap", type=float, default=0.0)
    ap.add_argument("--deadlines", default=None,
                    help="comma-separated per-job latency budgets in "
                         "seconds (empty entry = best-effort)")
    ap.add_argument("--feedback", choices=("off", "ewma"), default="off")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scale", type=int, default=1)
    ap.add_argument("--compare", action="store_true",
                    help="also run the round-robin and single-machine "
                         "baselines on the same mix and report ratios")
    ap.add_argument("--check-parity", action="store_true",
                    help="preflight: a 1-machine cluster must reproduce "
                         "the single-machine pool bit-for-bit on this "
                         "mix's models (the cluster-1m parity leg)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the run as Perfetto JSON: one process "
                         "lane per machine, route->launch flow arrows")
    ap.add_argument("--log-level", default="warning",
                    choices=("debug", "info", "warning", "error"))
    args = ap.parse_args()
    configure_logging(args.log_level)

    models = [m.strip() for m in args.jobs.split(",") if m.strip()]
    if not models:
        raise SystemExit("--jobs must name at least one model")
    if args.machines < 1:
        raise SystemExit("--machines must be >= 1")
    budgets: list[float | None] = [None] * len(models)
    if args.deadlines:
        entries = args.deadlines.split(",")
        if len(entries) != len(models):
            raise SystemExit("--deadlines length must match --jobs")
        budgets = [float(e) if e.strip() else None for e in entries]

    parity = None
    if args.check_parity:
        from repro.multitenant import check_parity
        report = check_parity(models, seed=args.seed, scale=args.scale)
        if not report["ok"]:
            for model, rec in report["models"].items():
                for d in rec["divergences"][:10]:
                    logger.error("parity divergence [%s]: %s", model, d)
            raise SystemExit("cluster-1m parity check FAILED")
        parity = {m: rec["ok"] for m, rec in report["models"].items()}

    feedback = args.feedback if args.feedback != "off" else None
    specs = mix_specs(models, [1.0] * len(models), budgets,
                      arrival_gap=args.arrival_gap, scale=args.scale)
    sink = RecordingSink() if args.trace_out else None
    pool, res = run_mix(specs, n_machines=args.machines,
                        policy=args.policy,
                        rebalance=not args.no_rebalance, split=args.split,
                        max_active=args.max_active, feedback=feedback,
                        seed=args.seed, sink=sink)
    if sink is not None:
        trace = export_cluster_trace(res, args.trace_out, sink.events)
        logger.info("wrote %d trace events to %s",
                    len(trace["traceEvents"]), args.trace_out)

    report = {
        "machines": args.machines,
        "policy": args.policy,
        "jobs": [{
            "name": cj.name,
            "machine": cj.machine,
            "split": cj.split,
            "moves": cj.moves,
            "latency_s": cj.latency,
            **({"deadline_s": cj.deadline,
                "deadline_met": (cj.finish_time is not None
                                 and cj.finish_time <= cj.deadline)}
               if cj.deadline is not None else {}),
        } for cj in res.cluster_jobs],
        "machine_makespans_s": [r.makespan for r in res.machines],
        "machine_ops": [r.total_ops for r in res.machines],
        "cluster_makespan_s": res.makespan,
        "aggregate_throughput_ops_s": res.aggregate_throughput,
        "rebalances": res.n_rebalances,
        "splits": res.n_splits,
        "demand_index": res.demand_index_stats,
        **({"parity_check": parity} if parity is not None else {}),
        **({"trace_out": args.trace_out,
            "trace_decision_events": len(sink.events)}
           if sink is not None else {}),
        "metrics": res.metrics,
    }
    if args.compare:
        _, rr = run_mix(specs, n_machines=args.machines,
                        policy="round_robin",
                        rebalance=not args.no_rebalance,
                        split=args.split, max_active=args.max_active,
                        feedback=feedback, seed=args.seed)
        _, single = run_mix(specs, n_machines=1, policy=args.policy,
                            rebalance=False, split=False,
                            max_active=args.max_active,
                            feedback=feedback, seed=args.seed)
        report["round_robin_throughput_ops_s"] = rr.aggregate_throughput
        report["single_machine_throughput_ops_s"] = \
            single.aggregate_throughput
        report["throughput_vs_round_robin"] = (
            res.aggregate_throughput / rr.aggregate_throughput
            if rr.aggregate_throughput else None)
        report["throughput_vs_single_machine"] = (
            res.aggregate_throughput / single.aggregate_throughput
            if single.aggregate_throughput else None)
    print(json.dumps(report, indent=1))


if __name__ == "__main__":
    main()
