"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (importing this module never
touches jax device state).  Single pod: 16x16 = 256 chips (v5e pod);
multi-pod: 2 pods = 512 chips with the ``pod`` axis outermost — only
data-parallel gradient reduction crosses it (DESIGN.md §6).
"""

from __future__ import annotations

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    """``jax.sharding.AxisType`` only exists in newer jax; older releases
    default every axis to Auto anyway, so omit the kwarg there."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (tests, examples, elastic re-meshes)."""
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def use_mesh(mesh):
    """Context manager activating ``mesh``: ``jax.sharding.set_mesh`` on
    newer jax, the ``Mesh`` object's own context manager on older."""
    setter = getattr(jax.sharding, "set_mesh", None)
    if setter is not None:
        return setter(mesh)
    return mesh


def pod_size(mesh) -> int:
    """Devices per pod (for DCI vs ICI classification in hw.hlo)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for name, s in sizes.items():
        if name != "pod":
            n *= s
    return n
