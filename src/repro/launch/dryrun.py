import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# isort: split  — the two lines above MUST run before any jax import
# (jax locks the device count at first initialization).

import argparse        # noqa: E402
import dataclasses     # noqa: E402
import json            # noqa: E402
import time            # noqa: E402
import traceback       # noqa: E402

import jax             # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCH_IDS, SHAPES, ShapeSpec, get_config, \
    skip_reason  # noqa: E402
from repro.hw import V5E, parse_collectives, dominant_term  # noqa: E402
from repro.launch.mesh import make_production_mesh, pod_size, use_mesh  # noqa: E402
from repro.models import zoo  # noqa: E402
from repro.models.common import ModelConfig, ShardingPlan, default_plan  # noqa: E402
from repro.optim import AdamWConfig  # noqa: E402
from repro.serving.kvcache import cache_shardings  # noqa: E402
from repro.sharding import named_sharding_tree  # noqa: E402
from repro.train import (TrainConfig, abstract_state, make_serve_step,
                         make_train_step, state_specs)  # noqa: E402

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh(es); record memory analysis, cost analysis, and the
collective-byte breakdown the roofline reads (EXPERIMENTS.md §Dry-run).

The per-arch TrainConfigs below are the MEMORY-term decisions of the perf
pass (microbatch count, remat policy, accumulator/moment dtypes) — see
EXPERIMENTS.md §Perf for the iteration log that produced them.
"""

TRAIN_CFGS: dict[str, TrainConfig] = {
    "llama3-405b": TrainConfig(
        microbatches=16, remat=True, remat_policy="nothing",
        accum_dtype="bfloat16",
        optimizer=AdamWConfig(moment_dtype="bfloat16")),
    "llama4-scout-17b-a16e": TrainConfig(
        microbatches=8, remat=True, remat_policy="nothing",
        accum_dtype="bfloat16",
        optimizer=AdamWConfig(moment_dtype="bfloat16")),
    "mixtral-8x7b": TrainConfig(
        microbatches=8, remat=True, remat_policy="nothing",
        optimizer=AdamWConfig(moment_dtype="bfloat16")),
    "granite-3-8b": TrainConfig(microbatches=4, remat=True,
                                remat_policy="nothing"),
    "codeqwen1.5-7b": TrainConfig(microbatches=4, remat=True,
                                  remat_policy="nothing"),
    "llama-3.2-vision-11b": TrainConfig(microbatches=8, remat=True,
                                        remat_policy="nothing"),
    "olmo-1b": TrainConfig(microbatches=2, remat=True, remat_policy="dots"),
    "rwkv6-1.6b": TrainConfig(microbatches=2, remat=True,
                              remat_policy="nothing"),
    "recurrentgemma-2b": TrainConfig(microbatches=2, remat=True,
                                     remat_policy="nothing"),
    "whisper-small": TrainConfig(microbatches=2, remat=True,
                                 remat_policy="dots"),
}


def train_config_for(arch: str) -> TrainConfig:
    return TRAIN_CFGS.get(arch, TrainConfig(microbatches=4))


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Global-shape SDS for every model input of this cell."""
    gb, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        out = {"tokens": jax.ShapeDtypeStruct((gb, s), jnp.int32),
               "targets": jax.ShapeDtypeStruct((gb, s), jnp.int32)}
    elif shape.kind == "prefill":
        out = {"tokens": jax.ShapeDtypeStruct((gb, s), jnp.int32)}
    else:                                  # decode: one new token
        out = {"token": jax.ShapeDtypeStruct((gb,), jnp.int32),
               "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    if zoo.needs_frontend(cfg) and shape.kind != "decode":
        out["frontend"] = jax.ShapeDtypeStruct(
            (gb, cfg.n_frontend_tokens, cfg.d_model), cfg.activation_dtype)
    if zoo.needs_frontend(cfg) and shape.kind == "decode":
        # decode reads the frontend through the prefilled cross-kv cache
        pass
    return out


def plan_for_shape(cfg: ModelConfig, shape: ShapeSpec,
                   mesh) -> ShardingPlan:
    """Paper-faithful baseline plan adapted to the cell's batch:
    long_500k (batch 1) cannot batch-shard, so the data axis joins the
    kv/sequence sharding group instead of idling."""
    plan = default_plan()
    # sequence parallelism for deep*wide models (train): layer-boundary
    # carries otherwise exceed HBM (DESIGN.md §6; found via the 405b cell)
    if shape.kind == "train" and cfg.d_model >= 8192:
        plan.seq_axes = ("model",)
    # expert parallelism requires experts % axis == 0 (mixtral E=8 on a
    # 16-wide axis): fall back to TP inside the expert FFN
    model_size = mesh.shape.get("model", 1)
    if cfg.moe_experts and cfg.moe_experts % model_size:
        plan.rules["expert"] = ()
    axes = set(mesh.axis_names)
    if "pod" in axes:
        # pod axis extends data parallelism (gradient reduction crosses it)
        plan.batch_axes = ("pod", "data")
        plan.rules["embed"] = ("data",)     # FSDP stays in-pod
    if shape.global_batch < mesh.shape.get("data", 1):
        plan.batch_axes = tuple(a for a in plan.batch_axes if a != "data"
                                and a != "pod")
        plan.rules["kv"] = tuple(
            a for a in ("pod", "data", "model") if a in axes)
    return plan


# ---------------------------------------------------------------------------
# lowering one cell
# ---------------------------------------------------------------------------

def _batch_shardings(cfg, shape, plan, mesh, specs):
    batch = tuple(plan.batch_axes) or None
    if isinstance(batch, tuple) and len(batch) == 1:
        batch = batch[0]

    def leaf(sds):
        if len(sds.shape) == 0:
            return NamedSharding(mesh, P())
        parts = [batch] + [None] * (len(sds.shape) - 1)
        return NamedSharding(mesh, P(*parts))

    return jax.tree.map(leaf, specs)


def _cost_dict(compiled) -> dict:
    """compiled.cost_analysis() normalized: older jax returns a
    per-device list, newer a single dict."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def lower_cell(cfg: ModelConfig, shape: ShapeSpec, mesh, *,
               plan: ShardingPlan | None = None,
               tcfg: TrainConfig | None = None,
               unroll: bool = False,
               micro_override: int | None = None,
               compile_only_text: bool = False) -> dict:
    """Lower + compile one (arch x shape x mesh) cell; return the record
    EXPERIMENTS.md consumes.

    ``unroll=True`` fully unrolls layer/chunk scans so cost_analysis and
    the collective parse see every iteration (XLA counts while bodies
    once); used by the single-pod COST compile.  ``micro_override``
    forces the microbatch count (cost compiles use 1 and reconstruct)."""
    t0 = time.time()
    plan = plan or plan_for_shape(cfg, shape, mesh)
    tcfg = tcfg or train_config_for(cfg.arch_id)
    if micro_override is not None:
        tcfg = dataclasses.replace(tcfg, microbatches=micro_override)
    cfg = dataclasses.replace(cfg, batch_axes=tuple(plan.batch_axes),
                              seq_axes=tuple(plan.seq_axes),
                              scan_unroll=unroll)
    specs_in = input_specs(cfg, shape)
    n_dev = mesh.devices.size

    with use_mesh(mesh):
        if shape.kind == "train":
            step = make_train_step(cfg, tcfg,
                                   batch_axes=tuple(plan.batch_axes))
            st_abs = abstract_state(cfg, tcfg)
            st_sh = named_sharding_tree(plan, mesh, state_specs(cfg, tcfg))
            b_sh = _batch_shardings(cfg, shape, plan, mesh, specs_in)
            jitted = jax.jit(step, in_shardings=(st_sh, b_sh),
                             out_shardings=(st_sh, None),
                             donate_argnums=0)
            lowered = jitted.lower(st_abs, specs_in)
        elif shape.kind == "prefill":
            max_len = zoo.cache_max_len(cfg, shape.seq_len)
            params_abs = zoo.abstract(cfg)
            p_sh = named_sharding_tree(plan, mesh, zoo.specs(cfg))
            b_sh = _batch_shardings(cfg, shape, plan, mesh, specs_in)

            def prefill_step(params, batch):
                return zoo.prefill(cfg, params, batch, max_len)

            jitted = jax.jit(prefill_step, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(params_abs, specs_in)
        else:                               # decode
            max_len = zoo.cache_max_len(cfg, shape.seq_len)
            params_abs = zoo.abstract(cfg)
            p_sh = named_sharding_tree(plan, mesh, zoo.specs(cfg))
            cache_abs = zoo.abstract_cache(cfg, shape.global_batch, max_len)
            model_degree = mesh.shape.get("model", 1)
            c_sh, kv_strategy = cache_shardings(
                cfg, plan, mesh, cache_abs, model_degree=model_degree)
            tok_sh = _batch_shardings(cfg, shape, plan, mesh,
                                      {"token": specs_in["token"]})["token"]
            step = make_serve_step(cfg)
            jitted = jax.jit(step,
                             in_shardings=(p_sh, c_sh, tok_sh, None),
                             out_shardings=(None, c_sh),
                             donate_argnums=1)
            lowered = jitted.lower(params_abs, cache_abs,
                                   specs_in["token"], specs_in["pos"])

        compiled = lowered.compile()

    # ---- analyses -----------------------------------------------------
    cost = _cost_dict(compiled)
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    stats = parse_collectives(hlo, pod_size=pod_size(mesh))

    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    compute_s = V5E.compute_time(flops_dev)
    memory_s = V5E.memory_time(bytes_dev)
    collective_s = V5E.collective_time(stats.ici_link_bytes,
                                       stats.dci_link_bytes)

    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    n_active = cfg.active_param_count()
    model_flops = (6 if shape.kind == "train" else 2) * n_active * tokens
    hlo_flops_global = flops_dev * n_dev

    record = {
        "arch": cfg.arch_id, "shape": shape.name, "kind": shape.kind,
        "unrolled": unroll, "microbatches": (tcfg.microbatches
                                             if shape.kind == "train" else 0),
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "devices": n_dev,
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "collectives": {
            "full_tensor_bytes": stats.raw_operand_bytes,
            "ici_link_bytes": stats.ici_link_bytes,
            "dci_link_bytes": stats.dci_link_bytes,
            "by_kind": {k: {"count": c, "link_bytes": b}
                        for k, (c, b) in stats.by_kind().items()},
        },
        "roofline": {
            "compute_s": compute_s, "memory_s": memory_s,
            "collective_s": collective_s,
            "dominant": dominant_term(compute_s, memory_s, collective_s),
            "step_s_overlapped": max(compute_s, memory_s, collective_s),
        },
        "model_flops_global": model_flops,
        "hlo_flops_global": hlo_flops_global,
        "useful_flops_ratio": (model_flops / hlo_flops_global
                               if hlo_flops_global else None),
        "memory_analysis": {
            k: int(getattr(mem, k))
            for k in ("temp_size_in_bytes", "argument_size_in_bytes",
                      "output_size_in_bytes", "alias_size_in_bytes",
                      "generated_code_size_in_bytes")
            if hasattr(mem, k)
        },
        "compile_seconds": time.time() - t0,
    }
    if shape.kind == "decode":
        record["kv_strategy"] = kv_strategy
    if compile_only_text:
        record["hlo_lines"] = len(hlo.splitlines())
    return record


# ---------------------------------------------------------------------------
# cost reconstruction: COST = m * (C_m1 - C_opt) + C_opt
# ---------------------------------------------------------------------------

def optimizer_cost(cfg: ModelConfig, mesh, plan: ShardingPlan,
                   tcfg: TrainConfig) -> dict:
    """Lower the AdamW update alone (elementwise, no while loops) to
    separate the per-step optimizer cost from the per-microbatch cost."""
    from repro.optim import adamw_update, abstract_opt_state

    params_abs = zoo.abstract(cfg)
    opt_abs = abstract_opt_state(tcfg.optimizer, params_abs)
    acc_dt = jnp.dtype(tcfg.accum_dtype)
    grads_abs = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, acc_dt), params_abs)
    pspecs = zoo.specs(cfg)
    p_sh = named_sharding_tree(plan, mesh, pspecs)
    o_sh = {"mu": p_sh, "nu": p_sh,
            "step": NamedSharding(mesh, P())}

    def opt_only(params, opt, grads):
        new_p, new_o, m = adamw_update(tcfg.optimizer, grads, opt, params)
        return new_p, new_o, m["grad_norm"]

    with use_mesh(mesh):
        jitted = jax.jit(opt_only, in_shardings=(p_sh, o_sh, p_sh),
                         out_shardings=(p_sh, o_sh, None),
                         donate_argnums=(0, 1))
        compiled = jitted.lower(params_abs, opt_abs, grads_abs).compile()
    cost = _cost_dict(compiled)
    stats = parse_collectives(compiled.as_text(), pod_size=pod_size(mesh))
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "ici": stats.ici_link_bytes, "dci": stats.dci_link_bytes}


def reconstruct_train_cost(c1: dict, opt: dict, m: int) -> dict:
    """Combine the m=1 unrolled cost compile with the optimizer-only cost:
    per-step = m * (C_m1 - C_opt) + C_opt (clamped at C_m1)."""
    out = {}
    for key, c1k in (("flops", "flops_per_device"),
                     ("bytes", "bytes_per_device")):
        base = max(c1[c1k] - opt[key], 0.0)
        out[c1k] = m * base + opt[key]
    ici1 = c1["collectives"]["ici_link_bytes"]
    dci1 = c1["collectives"]["dci_link_bytes"]
    out["ici_link_bytes"] = m * max(ici1 - opt["ici"], 0.0) + opt["ici"]
    out["dci_link_bytes"] = m * max(dci1 - opt["dci"], 0.0) + opt["dci"]
    return out


def depth_plan(cfg: ModelConfig) -> tuple[int, int, float, float] | None:
    """(L_a, L_b, units_per_layer_a..) for cost extrapolation, or None for a
    direct unrolled compile.  Returns (La, Lb, units_a, units_b, units_full)
    in scan-unit space (layers, or super-blocks for patterned archs)."""
    if cfg.family == "hybrid":
        per = len(cfg.block_pattern)
        ns, tail = cfg.n_layers // per, cfg.n_layers % per
        # tail rglru layers counted as fractional super-blocks
        return (2 * per, 4 * per, 2.0, 4.0, ns + tail / per)
    if cfg.family == "vlm":
        per = cfg.cross_attn_every
        return (2 * per, 4 * per, 2.0, 4.0, cfg.n_layers / per)
    if cfg.family == "ssm":
        # each layer unrolls S/chunk WKV bodies: keep depths small
        return (4, 8, 4.0, 8.0, float(cfg.n_layers))
    if cfg.n_layers > 16:
        return (8, 16, 8.0, 16.0, float(cfg.n_layers))
    return None


def _extract(rec: dict) -> dict:
    return {"flops_per_device": rec["flops_per_device"],
            "bytes_per_device": rec["bytes_per_device"],
            "ici_link_bytes": rec["collectives"]["ici_link_bytes"],
            "dci_link_bytes": rec["collectives"]["dci_link_bytes"]}


def extrapolated_cost(cfg: ModelConfig, shape: ShapeSpec, mesh, plan,
                      tcfg) -> tuple[dict, dict, float]:
    """Unrolled cost compiles at two reduced depths, linear extrapolation
    to the full depth (costs are exactly per-layer-linear; XLA while-body
    once-counting and full-depth unroll RAM blowups are both avoided).
    Returns (metrics, collectives_record_of_Lb, compile_seconds).

    For train cells the compile uses ONE microbatch at the PER-MICRO
    global batch (B/m); measure_cell multiplies back."""
    la, lb, ua, ub, uf = depth_plan(cfg)
    micro = 1 if shape.kind == "train" else None
    if shape.kind == "train":
        shape = shape.scaled(batch=shape.global_batch // tcfg.microbatches)
    ca = lower_cell(dataclasses.replace(cfg, n_layers=la), shape, mesh,
                    plan=plan, tcfg=tcfg, unroll=True, micro_override=micro)
    jax.clear_caches()
    cb = lower_cell(dataclasses.replace(cfg, n_layers=lb), shape, mesh,
                    plan=plan, tcfg=tcfg, unroll=True, micro_override=micro)
    jax.clear_caches()
    a, b = _extract(ca), _extract(cb)
    out = {}
    for key in a:
        per_unit = (b[key] - a[key]) / (ub - ua)
        out[key] = max(a[key] + per_unit * (uf - ua), 0.0)
    return out, cb["collectives"], \
        ca["compile_seconds"] + cb["compile_seconds"]


def measure_cell(cfg: ModelConfig, shape: ShapeSpec, mesh, *,
                 mesh_name: str, plan: ShardingPlan | None = None,
                 tcfg: TrainConfig | None = None,
                 with_cost: bool = True) -> dict:
    """Full measurement: rolled compile (memory/fits/compile-proof on any
    mesh) + — single-pod only — unrolled cost compile with microbatch
    reconstruction feeding the roofline terms."""
    plan = plan or plan_for_shape(cfg, shape, mesh)
    tcfg = tcfg or train_config_for(cfg.arch_id)
    rec = lower_cell(cfg, shape, mesh, plan=plan, tcfg=tcfg)
    rec["mesh_name"] = mesh_name
    per_dev, fits = hbm_check(rec)
    rec["hbm_bytes_per_device_est"] = per_dev
    rec["fits_hbm"] = fits
    if not with_cost:
        rec["roofline"]["note"] = "rolled-scan costs (undercounted); "             "single-pod cost compile carries the roofline"
        return rec

    jax.clear_caches()
    dp = depth_plan(cfg)
    cost_compile_s = 0.0
    if dp is not None:
        c1, coll_rec, cost_compile_s = extrapolated_cost(
            cfg, shape, mesh, plan, tcfg)
        rec["cost_extrapolated_from"] = dp[:2]
    elif shape.kind == "train":
        shape_micro = shape.scaled(
            batch=shape.global_batch // tcfg.microbatches)
        cost_rec = lower_cell(cfg, shape_micro, mesh, plan=plan, tcfg=tcfg,
                              unroll=True, micro_override=1)
        c1 = _extract(cost_rec)
        coll_rec = cost_rec["collectives"]
        cost_compile_s = cost_rec["compile_seconds"]
    else:
        cost_rec = lower_cell(cfg, shape, mesh, plan=plan, tcfg=tcfg,
                              unroll=True)
        c1 = _extract(cost_rec)
        coll_rec = cost_rec["collectives"]
        cost_compile_s = cost_rec["compile_seconds"]

    if shape.kind == "train":
        opt = optimizer_cost(cfg, mesh, plan, tcfg)
        rec["optimizer_cost"] = opt
        m = tcfg.microbatches
        fixed = {}
        for key, okey in (("flops_per_device", "flops"),
                          ("bytes_per_device", "bytes"),
                          ("ici_link_bytes", "ici"),
                          ("dci_link_bytes", "dci")):
            base = max(c1[key] - opt[okey], 0.0)
            fixed[key] = m * base + opt[okey]
    else:
        fixed = c1

    rec["flops_per_device"] = fixed["flops_per_device"]
    rec["bytes_per_device"] = fixed["bytes_per_device"]
    rec["collectives"] = coll_rec
    rec["collectives"]["ici_link_bytes_step"] = fixed["ici_link_bytes"]
    rec["collectives"]["dci_link_bytes_step"] = fixed["dci_link_bytes"]
    compute_s = V5E.compute_time(fixed["flops_per_device"])
    # two memory accountings (EXPERIMENTS.md §Roofline):
    #  * memory_s_hlo — the spec formula HLO_bytes/(chips*bw).  The CPU
    #    backend's cost analysis counts every unfused elementwise
    #    operand, so this is a severe UPPER bound (5-10x on TPU, where
    #    fusion keeps those values in registers/VMEM).
    #  * memory_s — buffer-traffic estimate from the rolled compile's
    #    real buffer assignment: (args + outputs + 3*temp)/bw (the x3
    #    models fwd+bwd+remat re-traffic).  Used for dominance.
    ma = rec.get("memory_analysis", {})
    traffic = (ma.get("argument_size_in_bytes", 0)
               + ma.get("output_size_in_bytes", 0)
               + 3 * ma.get("temp_size_in_bytes", 0))
    memory_s_hlo = V5E.memory_time(fixed["bytes_per_device"])
    memory_s = V5E.memory_time(traffic)
    collective_s = V5E.collective_time(fixed["ici_link_bytes"],
                                       fixed["dci_link_bytes"])
    n_dev = mesh.devices.size
    hlo_flops_global = fixed["flops_per_device"] * n_dev
    rec["hlo_flops_global"] = hlo_flops_global
    rec["useful_flops_ratio"] = (rec["model_flops_global"] / hlo_flops_global
                                 if hlo_flops_global else None)
    rec["roofline"] = {
        "compute_s": compute_s, "memory_s": memory_s,
        "memory_s_hlo": memory_s_hlo,
        "collective_s": collective_s,
        "dominant": dominant_term(compute_s, memory_s, collective_s),
        "step_s_overlapped": max(compute_s, memory_s, collective_s),
    }
    rec["cost_compile_seconds"] = cost_compile_s
    return rec


# ---------------------------------------------------------------------------

def hbm_check(record: dict) -> tuple[float, bool]:
    # memory_analysis is PER-DEVICE (the SPMD module is the per-device
    # program; verified empirically — see EXPERIMENTS.md §Dry-run notes)
    ma = record.get("memory_analysis", {})
    per_dev = (ma.get("argument_size_in_bytes", 0)
               + ma.get("temp_size_in_bytes", 0)
               + ma.get("output_size_in_bytes", 0)
               - ma.get("alias_size_in_bytes", 0))
    return per_dev, per_dev <= V5E.hbm_bytes


def run_cells(archs, shapes, meshes, out_path, *, verbose=True):
    results = []
    for mesh_name in meshes:
        mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
        for arch in archs:
            cfg = get_config(arch)
            for shape_name in shapes:
                shape = SHAPES[shape_name]
                reason = skip_reason(cfg, shape)
                if reason:
                    results.append({"arch": arch, "shape": shape_name,
                                    "mesh": mesh_name, "skipped": reason})
                    if verbose:
                        print(f"[skip] {mesh_name:6s} {arch:24s} "
                              f"{shape_name:12s} {reason}")
                    continue
                try:
                    rec = measure_cell(cfg, shape, mesh,
                                       mesh_name=mesh_name,
                                       with_cost=(mesh_name == "single"))
                    results.append(rec)
                    if verbose:
                        r = rec["roofline"]
                        print(f"[ok]   {mesh_name:6s} {arch:24s} "
                              f"{shape_name:12s} compile={rec['compile_seconds']:6.1f}s "
                              f"dom={r['dominant']:10s} "
                              f"step={r['step_s_overlapped']*1e3:9.3f}ms "
                              f"hbm/dev={rec['hbm_bytes_per_device_est']/2**30:6.2f}GiB "
                              f"fits={rec['fits_hbm']}")
                except Exception as e:  # noqa: BLE001 — record and continue
                    results.append({"arch": arch, "shape": shape_name,
                                    "mesh": mesh_name, "error": str(e),
                                    "traceback": traceback.format_exc()})
                    if verbose:
                        print(f"[FAIL] {mesh_name:6s} {arch:24s} "
                              f"{shape_name:12s} {e}")
                # free compilation caches between cells
                jax.clear_caches()
    if out_path:
        os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(results, f, indent=1)
        if verbose:
            print(f"wrote {out_path} ({len(results)} records)")
    return results


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all",
                    help=f"arch id or 'all' ({', '.join(ARCH_IDS)})")
    ap.add_argument("--shape", default="all",
                    help=f"shape or 'all' ({', '.join(SHAPES)})")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun.json")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])
    run_cells(archs, shapes, meshes, args.out)


if __name__ == "__main__":
    main()
