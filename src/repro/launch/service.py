"""Service CLI: ``python -m repro.launch.service <command> ...``

The client side of the pool daemon (``repro.service.PoolDaemon``).  One
daemon owns one ``RuntimePool`` + worker set per ``--state-dir``; every
other command talks to it through the file inbox (one JSON command file
in ``<state_dir>/inbox/``, one JSON reply in ``<state_dir>/outbox/``).

Commands:

* ``start``  — run the daemon loop in the foreground.  ``--once`` exits
  after the first ``drain`` completes (submit-all-then-drain mode);
  ``--crash-after-steps N`` hard-kills the process after N decision
  instants (the recovery tests' kill switch).
* ``submit`` — submit one job, either from ``--spec '<json>'`` (the
  ``JobSpec`` wire dict) or from flags mirroring ``repro.launch.pool``.
* ``cancel`` / ``status`` / ``drain`` / ``stop`` — the obvious verbs.
* ``smoke``  — self-contained CI choreography (no running daemon
  needed): enqueue submit/status/cancel/drain through the REAL file
  protocol, run a ``--once`` daemon over the inbox, and assert the
  drained metrics are bit-for-bit an equivalent direct
  ``RuntimePool.run``.

Restart the daemon after a kill with the same ``--state-dir`` and it
recovers its world from the job store (see ``repro.service.jobstore``).
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import time

from repro.core import SimMachine
from repro.core.runtime import RuntimeConfig
from repro.core.strategy import StrategyConfig
from repro.multitenant.plancache import atomic_write_text
from repro.multitenant.pool import PoolConfig, RuntimePool
from repro.service import JobSpec, PoolDaemon, submit_spec


# ---------------------------------------------------------------------------
# file-protocol client
# ---------------------------------------------------------------------------

def enqueue_command(state_dir: str | pathlib.Path, cmd: dict,
                    seq: int | None = None) -> pathlib.Path:
    """Drop one command file into the daemon inbox (atomic write, so the
    daemon never reads a partial command); returns the reply path the
    daemon will write.  ``seq`` pins the processing order (the daemon
    reads in filename order) — defaults to a wall-clock ticket."""
    state_dir = pathlib.Path(state_dir)
    inbox = state_dir / "inbox"
    inbox.mkdir(parents=True, exist_ok=True)
    ticket = seq if seq is not None else time.time_ns()
    name = f"{ticket:020d}-{os.getpid()}-{cmd['op']}.json"
    atomic_write_text(inbox / name, json.dumps(cmd))
    return state_dir / "outbox" / name


def read_reply(reply_path: pathlib.Path, *, timeout: float = 30.0,
               poll: float = 0.05) -> dict:
    """Wait for (and consume) the daemon's reply file."""
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if reply_path.exists():
            reply = json.loads(reply_path.read_text())
            reply_path.unlink()
            return reply
        time.sleep(poll)
    raise SystemExit(f"no daemon reply at {reply_path} "
                     f"within {timeout:.0f}s — is the daemon running?")


def send_command(state_dir: str | pathlib.Path, cmd: dict, *,
                 timeout: float = 30.0) -> dict:
    return read_reply(enqueue_command(state_dir, cmd), timeout=timeout)


# ---------------------------------------------------------------------------
# subcommands
# ---------------------------------------------------------------------------

def _daemon_config(args: argparse.Namespace) -> PoolConfig:
    return PoolConfig(
        max_active=args.max_active,
        runtime=RuntimeConfig(
            strategy=StrategyConfig(feedback=args.feedback)))


def cmd_start(args: argparse.Namespace) -> None:
    daemon = PoolDaemon(args.state_dir,
                        config=_daemon_config(args),
                        machine=SimMachine(seed=args.seed),
                        checkpoint_every=args.checkpoint_every,
                        max_workers=args.max_workers,
                        payload_feedback=args.payload_feedback)
    daemon.serve(poll_interval=args.poll_interval, once=args.once,
                 crash_after_steps=args.crash_after_steps)


def _spec_from_args(args: argparse.Namespace) -> JobSpec:
    if args.spec:
        return JobSpec.from_dict(json.loads(args.spec))
    return JobSpec(workload=args.workload, name=args.name,
                   scale=args.scale, priority=args.priority,
                   submit_time=args.submit_time, deadline=args.deadline,
                   latency_budget=args.latency_budget,
                   demand_hint=args.demand_hint)


def cmd_submit(args: argparse.Namespace) -> None:
    spec = _spec_from_args(args)
    print(json.dumps(send_command(
        args.state_dir, {"op": "submit", "spec": spec.to_dict()},
        timeout=args.timeout)))


def cmd_verb(args: argparse.Namespace) -> None:
    cmd: dict = {"op": args.verb}
    if args.verb == "cancel":
        cmd["job"] = args.job
    print(json.dumps(send_command(args.state_dir, cmd,
                                  timeout=args.timeout), indent=1))


def cmd_smoke(args: argparse.Namespace) -> None:
    """CI fast-lane choreography over the real file protocol.

    All commands are enqueued first (filename order = processing
    order), then one ``--once`` daemon run consumes them: 3 submits,
    status, cancel the still-queued third job, drain, exit.  The
    drained metrics must be bit-for-bit an equivalent direct
    ``RuntimePool.run`` with the same submissions and the same
    pre-run cancellation (``max_active=2`` keeps the cancelled job
    queued on both paths, so the ledgers agree exactly)."""
    import tempfile
    with tempfile.TemporaryDirectory() as td:
        state_dir = pathlib.Path(td)
        specs = [JobSpec(workload="resnet50", name="resnet50-0"),
                 JobSpec(workload="dcgan", name="dcgan-1"),
                 JobSpec(workload="dcgan", name="dcgan-2")]
        replies = [enqueue_command(
            state_dir, {"op": "submit", "spec": s.to_dict()}, seq=i)
            for i, s in enumerate(specs)]
        replies.append(enqueue_command(state_dir, {"op": "status"}, seq=3))
        replies.append(enqueue_command(
            state_dir, {"op": "cancel", "job": "job-2"}, seq=4))
        replies.append(enqueue_command(state_dir, {"op": "drain"}, seq=5))

        config = PoolConfig(max_active=2)
        daemon = PoolDaemon(state_dir, config=config,
                            machine=SimMachine(seed=args.seed))
        daemon.serve(once=True)

        out = [read_reply(p, timeout=1.0) for p in replies]
        assert all(r["ok"] for r in out), out
        sub, status, cancel, drain = out[:3], out[3], out[4], out[5]
        assert [r["job"] for r in sub] == ["job-0", "job-1", "job-2"]
        states = {j["id"]: j["state"] for j in status["jobs"]}
        assert states == {"job-0": "admitted", "job-1": "admitted",
                          "job-2": "queued"}, states

        # the reference: same submissions, same pre-run cancel, one
        # direct library run
        pool = RuntimePool(machine=SimMachine(seed=args.seed),
                           config=PoolConfig(max_active=2))
        jobs = [submit_spec(pool, s) for s in specs]
        assert pool.cancel(jobs[2].jid)
        ref = pool.run()
        if drain["metrics"] != ref.metrics:
            diff = {k: (drain["metrics"].get(k), ref.metrics.get(k))
                    for k in set(drain["metrics"]) | set(ref.metrics)
                    if drain["metrics"].get(k) != ref.metrics.get(k)}
            raise SystemExit(f"daemon smoke: drained metrics diverge "
                             f"from direct RuntimePool.run: {diff}")
        print(json.dumps({"ok": True, "makespan": drain["makespan"],
                          "cancelled": cancel["ok"],
                          "jobs": len(sub),
                          "metrics_match": True}))


def main() -> None:
    ap = argparse.ArgumentParser(prog="repro.launch.service")
    sub = ap.add_subparsers(dest="command", required=True)

    sp = sub.add_parser("start", help="run the pool daemon (foreground)")
    sp.add_argument("--state-dir", required=True)
    sp.add_argument("--once", action="store_true",
                    help="exit after the first drain completes")
    sp.add_argument("--poll-interval", type=float, default=0.05)
    sp.add_argument("--checkpoint-every", type=int, default=1)
    sp.add_argument("--max-active", type=int, default=3)
    sp.add_argument("--max-workers", type=int, default=2)
    sp.add_argument("--feedback", choices=("off", "ewma"), default="off")
    sp.add_argument("--payload-feedback", action="store_true",
                    help="report real payload wall times through the "
                         "jobs' plan stores")
    sp.add_argument("--seed", type=int, default=0)
    sp.add_argument("--crash-after-steps", type=int, default=None,
                    help="hard-kill (os._exit) after N decision instants "
                         "— crash-recovery testing only")
    sp.set_defaults(fn=cmd_start)

    sp = sub.add_parser("submit", help="submit one job")
    sp.add_argument("--state-dir", required=True)
    sp.add_argument("--timeout", type=float, default=30.0)
    sp.add_argument("--spec", default=None,
                    help="JobSpec wire dict as JSON (overrides the "
                         "individual flags)")
    sp.add_argument("--workload", default="resnet50")
    sp.add_argument("--name", default=None)
    sp.add_argument("--scale", type=int, default=1)
    sp.add_argument("--priority", type=float, default=1.0)
    sp.add_argument("--submit-time", type=float, default=0.0)
    sp.add_argument("--deadline", type=float, default=None)
    sp.add_argument("--latency-budget", type=float, default=None)
    sp.add_argument("--demand-hint", type=float, default=None)
    sp.set_defaults(fn=cmd_submit)

    for verb in ("cancel", "status", "drain", "stop"):
        sp = sub.add_parser(verb)
        sp.add_argument("--state-dir", required=True)
        sp.add_argument("--timeout", type=float, default=30.0)
        if verb == "cancel":
            sp.add_argument("--job", required=True,
                            help="client-facing job id (job-N)")
        sp.set_defaults(fn=cmd_verb, verb=verb)

    sp = sub.add_parser("smoke",
                        help="CI fast-lane: file-protocol round trip + "
                             "metrics parity vs a direct pool run")
    sp.add_argument("--seed", type=int, default=0)
    sp.set_defaults(fn=cmd_smoke)

    args = ap.parse_args()
    args.fn(args)


if __name__ == "__main__":
    main()
