"""Serving launcher: ``python -m repro.launch.serve --arch <id> ...``

Loads (or random-initializes) a model and drives the batched serving
engine over a synthetic request stream, reporting throughput and slot
utilization.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import zoo
from repro.serving import Request, ServeEngine
from repro.train import CheckpointManager


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b", choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore params from a training checkpoint")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.ckpt_dir:
        restored = CheckpointManager(args.ckpt_dir).restore()
        if restored is None:
            raise SystemExit(f"no checkpoint in {args.ckpt_dir}")
        params = restored[0]["params"]
        params = jax.tree.map(jax.numpy.asarray, params)
        print(f"restored params from step {restored[2]}")
    else:
        params = zoo.init(cfg, jax.random.PRNGKey(0))

    engine = ServeEngine(cfg, params, n_slots=args.slots,
                         max_len=args.max_len)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        plen = int(rng.integers(args.prompt_len // 2, args.prompt_len + 1))
        engine.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab, size=plen).astype(np.int32),
            max_new_tokens=args.max_new))

    t0 = time.perf_counter()
    done = engine.run()
    wall = time.perf_counter() - t0
    total_tokens = sum(len(r.output) for r in done)
    print(json.dumps({
        "arch": cfg.arch_id,
        "requests": len(done),
        "generated_tokens": total_tokens,
        "tokens_per_s": total_tokens / wall,
        "mean_slot_utilization": engine.mean_slot_utilization,
        "waves": len(engine.stats),
    }, indent=1))


if __name__ == "__main__":
    main()
