"""Training launcher: ``python -m repro.launch.train --arch <id> ...``

Runs real steps on the available devices (CPU smoke scale by default,
TPU pods unchanged — the mesh adapts to jax.device_count()).  Wires every
substrate piece: data pipeline + prefetch, sharded train step, async
checkpointing, heartbeat, straggler monitor, recovery loop, and — when
--autotune is set — the paper-technique shard-degree autotuner before the
steady-state phase (DESIGN.md §4).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.data import DataConfig, Prefetcher, make_source
from repro.launch.mesh import make_mesh, use_mesh
from repro.models import zoo
from repro.models.common import default_plan, replicated_plan
from repro.optim import AdamWConfig
from repro.sharding import named_sharding_tree
from repro.train import (CheckpointManager, Heartbeat, StragglerMonitor,
                         TrainConfig, init_state, make_train_step,
                         run_with_recovery, state_specs)


def build(args):
    cfg = get_config(args.arch, smoke=args.smoke)
    if args.seq:
        pass  # seq comes from data config
    tcfg = TrainConfig(
        microbatches=args.microbatches,
        remat=not args.no_remat,
        optimizer=AdamWConfig(lr=args.lr, total_steps=args.steps,
                              warmup_steps=max(args.steps // 20, 1)))

    n_dev = jax.device_count()
    if n_dev >= 4:
        mesh = make_mesh((2, n_dev // 2), ("data", "model"))
        plan = default_plan()
    else:
        mesh = make_mesh((n_dev, 1), ("data", "model")) if n_dev > 1 \
            else make_mesh((1,), ("data",))
        plan = replicated_plan()
        plan.batch_axes = ("data",) if n_dev > 1 else ()
    cfg = dataclasses.replace(cfg, batch_axes=tuple(plan.batch_axes))
    return cfg, tcfg, mesh, plan


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b", choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg, tcfg, mesh, plan = build(args)
    print(f"arch={cfg.arch_id} params={cfg.param_count():,} "
          f"devices={jax.device_count()} mesh={dict(mesh.shape)}")

    dcfg = DataConfig(seq_len=args.seq, global_batch=args.batch,
                      vocab=cfg.vocab,
                      frontend_tokens=cfg.n_frontend_tokens
                      if zoo.needs_frontend(cfg) else 0,
                      d_model=cfg.d_model)
    source = make_source(dcfg)
    prefetch = Prefetcher(source)

    manager = CheckpointManager(args.ckpt_dir)
    heartbeat = Heartbeat(os.path.join(args.ckpt_dir, "heartbeat.json"))
    monitor = StragglerMonitor()

    with use_mesh(mesh):
        state = init_state(cfg, tcfg, jax.random.PRNGKey(0))
        if len(mesh.devices.ravel()) > 1:
            st_sh = named_sharding_tree(plan, mesh, state_specs(cfg, tcfg))
            state = jax.tree.map(jax.device_put, state, st_sh)
        step_fn = jax.jit(make_train_step(
            cfg, tcfg, batch_axes=tuple(plan.batch_axes) or None))

        start = 0
        if args.resume:
            restored = manager.restore()
            if restored:
                state, extra, start = restored
                print(f"resumed from step {start}")

        times: list[float] = []

        def wrapped(state, batch, step):
            t0 = time.perf_counter()
            jb = {k: jnp.asarray(v) for k, v in batch.items()}
            state, metrics = step_fn(state, jb)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            times.append(dt)
            heartbeat.beat(step)
            monitor.observe({"host0": dt})
            return state, metrics

        def on_metrics(step, metrics):
            if step % 10 == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"dt {times[-1]*1e3:.0f}ms")

        state, stats = run_with_recovery(
            wrapped, state, n_steps=args.steps,
            save_every=args.save_every, manager=manager,
            data_prefetch=prefetch, on_metrics=on_metrics)
        manager.save(args.steps, state, extra={"final": True}, block=True)

    prefetch.close()
    print(json.dumps({
        "steps": args.steps,
        "mean_step_ms": 1e3 * sum(times) / max(len(times), 1),
        "failures": stats.failures, "restores": stats.restores,
    }))


if __name__ == "__main__":
    main()
