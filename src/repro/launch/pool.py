"""Multi-tenant pool launcher: ``python -m repro.launch.pool ...``

Builds a tenant mix of paper step graphs (and optionally serving waves),
runs it through the ``RuntimePool`` co-scheduler and through the serial
one-graph-at-a-time baseline, and reports aggregate throughput, per-job
latency, fairness, and plan-cache amortization as JSON.
"""

from __future__ import annotations

import argparse
import json

from repro.core import SimMachine, build_paper_graph
from repro.multitenant import PoolConfig, RuntimePool


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", default="resnet50,dcgan,resnet50,dcgan",
                    help="comma-separated paper models, one job each")
    ap.add_argument("--priorities", default=None,
                    help="comma-separated weights (default: all 1.0)")
    ap.add_argument("--max-active", type=int, default=3)
    ap.add_argument("--arrival-gap", type=float, default=0.0,
                    help="seconds between successive job arrivals")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scale", type=int, default=1,
                    help="layer-count multiplier for every job graph")
    ap.add_argument("--check-parity", action="store_true",
                    help="preflight: verify a single-job pool reproduces "
                         "the single-graph scheduler bit-for-bit on this "
                         "tenant mix's models (fails fast on divergence)")
    args = ap.parse_args()

    models = [m.strip() for m in args.jobs.split(",") if m.strip()]
    if not models:
        raise SystemExit("--jobs must name at least one model")
    prios = ([float(p) for p in args.priorities.split(",")]
             if args.priorities else [1.0] * len(models))
    if len(prios) != len(models):
        raise SystemExit("--priorities length must match --jobs")

    parity = None
    if args.check_parity:
        from repro.multitenant import check_parity
        report = check_parity(models, seed=args.seed, scale=args.scale)
        if not report["ok"]:
            for model, rec in report["models"].items():
                for d in rec["divergences"][:10]:
                    print(f"parity divergence [{model}]: {d}")
            raise SystemExit("pool-vs-corun parity check FAILED")
        parity = {m: rec["ok"] for m, rec in report["models"].items()}

    pool = RuntimePool(machine=SimMachine(seed=args.seed),
                       config=PoolConfig(max_active=args.max_active))
    for i, (model, prio) in enumerate(zip(models, prios)):
        pool.submit(build_paper_graph(model, scale=args.scale),
                    priority=prio, name=f"{model}-{i}",
                    submit_time=i * args.arrival_gap)
    res = pool.run()
    serial = pool.run_serial()

    print(json.dumps({
        "jobs": [{
            "name": j.name,
            "priority": j.priority,
            "queue_wait_s": j.queue_wait,
            "latency_s": j.latency,
            "serial_latency_s": serial.job_latencies[j.jid],
            "service_core_s": j.service,
            "demand_core_s": j.demand,
        } for j in res.jobs],
        "pool_makespan_s": res.makespan,
        "serial_makespan_s": serial.makespan,
        "aggregate_speedup": serial.makespan / res.makespan,
        "pool_throughput_ops_s": res.aggregate_throughput,
        "serial_throughput_ops_s": serial.aggregate_throughput,
        "fairness_jain": res.fairness,
        "slowdown_fairness_jain": res.slowdown_fairness(
            serial.job_makespans),
        "plan_cache": res.cache_stats,
        "serial_profiling_probes": serial.profiling_probes,
        **({"parity_check": parity} if parity is not None else {}),
    }, indent=1))


if __name__ == "__main__":
    main()
