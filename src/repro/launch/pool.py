"""Multi-tenant pool launcher: ``python -m repro.launch.pool ...``

Builds a tenant mix of paper step graphs (and optionally serving waves),
runs it through the ``RuntimePool`` co-scheduler and through the serial
one-graph-at-a-time baseline, and reports aggregate throughput, per-job
latency, fairness, and plan-cache amortization as JSON.

Deadline/SLO knobs: ``--deadlines`` gives each job an absolute deadline
(submit time + per-job budget) and ``--preempt`` arms checkpoint-free
op preemption, so a tenant that runs out of slack can revoke the
longest-remaining running op (see ``repro.core.strategy.PreemptionPolicy``).
The preemption-economics knobs (``--max-victims``, ``--evict-admitted``,
``--migrate``) arm the priced moves on top of that — multi-victim revoke,
free admission-level eviction, and width migration; each implies
``--preempt``.

Closed-loop knobs: ``--feedback ewma`` arms the adaptive plan store
(observed service EWMA-corrects every prediction — candidate ranking,
admission demand, deadline slack; see ``repro.core.planstore``), and
``--plan-cache-path`` persists the cross-job curve cache across launcher
invocations (loaded before the run if the file exists, dumped after), so
profiling probes paid today are still amortized tomorrow.

Observability knobs: ``--trace-out trace.json`` records every scheduling
decision (see ``repro.obs.trace``) and writes the run as a Chrome-trace/
Perfetto JSON timeline — open it at https://ui.perfetto.dev;
``--log-level`` configures the shared ``repro`` logger.

Dynamic control flow: ``--dynamic`` switches the tenant mix to dynamic
graphs — ``--jobs`` entries become ``rnn`` (data-dependent while loop,
``repro.core.graph.build_recurrent_step_graph``) and ``wave``
(early-exit serving pipeline, ``build_early_exit_wave``) instead of
paper models.  Region expansion and resolution instants land in the
decision-event stream, so ``--dynamic --trace-out trace.json`` shows
every loop iteration materializing on the Perfetto timeline.
``--trip-count-feedback`` arms the pool-wide EWMA trip-count estimator
(implies ``--feedback ewma``): unresolved loops are priced at learned
trip counts instead of their build-time priors.
"""

from __future__ import annotations

import argparse
import json
import pathlib

from repro.core import SimMachine
from repro.multitenant import (PlanCache, PoolConfig, PreemptionPolicy,
                               RuntimePool)
from repro.obs import (RecordingSink, configure_logging, export_pool_trace,
                       get_logger)
from repro.service.spec import DYNAMIC_WORKLOADS, JobSpec, submit_spec

logger = get_logger(__name__)


def mix_specs(models: list[str], prios: list[float],
              budgets: list[float | None], *, arrival_gap: float = 0.0,
              dynamic: bool = False, scale: int = 1) -> list[JobSpec]:
    """The tenant mix as ``JobSpec``s — this launcher is a thin parser
    over the wire schema (the daemon inbox and ``ServeEngine`` consume
    the same schema).  Dynamic-mix trips/depths vary with the job index
    so a ``--trip-count-feedback`` run has a distribution to learn."""
    specs = []
    for i, (model, prio, budget) in enumerate(zip(models, prios, budgets)):
        common = dict(name=f"{model}-{i}", priority=prio,
                      submit_time=i * arrival_gap, latency_budget=budget)
        if not dynamic:
            specs.append(JobSpec(workload=model, scale=scale, **common))
        elif model == "rnn":
            specs.append(JobSpec(workload="rnn", trips=4 + (i % 3),
                                 max_trips=8, **common))
        elif model == "wave":
            specs.append(JobSpec(workload="wave", depth=1 + (i % 3),
                                 max_depth=6, accept=(i % 2 == 0),
                                 **common))
        else:
            raise SystemExit(
                f"--dynamic jobs must be {'|'.join(DYNAMIC_WORKLOADS)}, "
                f"got {model!r}")
    return specs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", default=None,
                    help="comma-separated paper models, one job each "
                         "(with --dynamic: rnn|wave entries instead; "
                         "default resnet50,dcgan,resnet50,dcgan or "
                         "rnn,wave,rnn,wave)")
    ap.add_argument("--priorities", default=None,
                    help="comma-separated weights (default: all 1.0)")
    ap.add_argument("--max-active", type=int, default=3)
    ap.add_argument("--arrival-gap", type=float, default=0.0,
                    help="seconds between successive job arrivals")
    ap.add_argument("--deadlines", default=None,
                    help="comma-separated per-job latency budgets in "
                         "seconds (deadline = submit time + budget; empty "
                         "entry = best-effort job)")
    ap.add_argument("--preempt", action="store_true",
                    help="enable deadline-driven checkpoint-free "
                         "preemption (off: no launch is ever revoked; "
                         "note --deadlines alone already reorders "
                         "admission/fair-share — only a run with neither "
                         "flag is bit-for-bit the PR-2 pool)")
    ap.add_argument("--max-victims", type=int, default=1,
                    help="preemption economics: >1 lets the deadline path "
                         "revoke a SET of runners (cheapest summed restart "
                         "waste first, affinity-aware) when one victim "
                         "cannot seat the overdue op's preferred width — "
                         "only when the priced SLO gain exceeds the "
                         "summed waste (implies --preempt)")
    ap.add_argument("--evict-admitted", action="store_true",
                    help="preemption economics: return an admitted job "
                         "with no launched ops to the queue when that "
                         "unblocks an overdue deadlined waiter — the free "
                         "move, zero restart waste (implies --preempt)")
    ap.add_argument("--migrate", action="store_true",
                    help="preemption economics: relaunch a running op at "
                         "a different width when predicted-remaining-time "
                         "gain strictly exceeds the re-billed restart "
                         "waste (implies --preempt)")
    ap.add_argument("--reservation-window", type=float, default=0.0,
                    help="hold the last active slot for a higher-priority "
                         "deadlined arrival due within this many seconds")
    ap.add_argument("--topology", choices=("flat", "quadrant"),
                    default="flat",
                    help="thread placement: 'flat' is the paper's 68-core "
                         "pool; 'quadrant' books concrete core sets "
                         "(empty quadrant first, quadrant-local packing, "
                         "bounded spill) with per-quadrant bandwidth "
                         "contention and tenant-to-quadrant affinity")
    ap.add_argument("--dynamic", action="store_true",
                    help="tenant mix of DYNAMIC graphs (data-dependent "
                         "while loops + early-exit branches): --jobs "
                         "entries become rnn|wave; region expansion and "
                         "resolution instants appear in --trace-out "
                         "timelines as decision events")
    ap.add_argument("--trip-count-feedback", action="store_true",
                    help="arm the pool-wide EWMA trip-count estimator "
                         "(implies --feedback ewma): unresolved regions "
                         "are priced at learned trip counts instead of "
                         "build-time priors")
    ap.add_argument("--feedback", choices=("off", "ewma"), default="off",
                    help="closed-loop plan store: 'off' freezes every "
                         "prediction at profiling time (bit-for-bit the "
                         "pre-feedback pool); 'ewma' blends observed "
                         "service back into predictions, re-estimating "
                         "demand and deadline slack online")
    ap.add_argument("--plan-cache-path", default=None,
                    help="JSON file to persist the cross-job plan cache "
                         "across invocations: loaded before the run when "
                         "it exists (corrupted/mismatched files degrade "
                         "to an empty cache with a warning), dumped "
                         "after the run")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scale", type=int, default=1,
                    help="layer-count multiplier for every job graph")
    ap.add_argument("--check-parity", action="store_true",
                    help="preflight: verify a single-job pool reproduces "
                         "the single-graph scheduler bit-for-bit on this "
                         "tenant mix's models (fails fast on divergence)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="record every scheduling decision and write the "
                         "run as Chrome-trace/Perfetto JSON (open at "
                         "https://ui.perfetto.dev); tracing never changes "
                         "the schedule — the traced timeline is "
                         "bit-for-bit the untraced one")
    ap.add_argument("--log-level", default="warning",
                    choices=("debug", "info", "warning", "error"),
                    help="level for the shared 'repro' logger")
    args = ap.parse_args()
    configure_logging(args.log_level)
    if args.trip_count_feedback:
        args.feedback = "ewma"

    jobs = args.jobs or ("rnn,wave,rnn,wave" if args.dynamic
                         else "resnet50,dcgan,resnet50,dcgan")
    models = [m.strip() for m in jobs.split(",") if m.strip()]
    if not models:
        raise SystemExit("--jobs must name at least one model")
    prios = ([float(p) for p in args.priorities.split(",")]
             if args.priorities else [1.0] * len(models))
    if len(prios) != len(models):
        raise SystemExit("--priorities length must match --jobs")
    budgets: list[float | None] = [None] * len(models)
    if args.deadlines:
        entries = args.deadlines.split(",")
        if len(entries) != len(models):
            raise SystemExit("--deadlines length must match --jobs")
        budgets = [float(e) if e.strip() else None for e in entries]

    parity = None
    if args.check_parity:
        if args.dynamic:
            # check_parity covers the dynamic machinery via its
            # zero-region legs on the paper zoo; a mix of genuinely
            # dynamic graphs has no single-graph golden to diff against
            raise SystemExit("--check-parity runs on the paper-model "
                             "mix; drop --dynamic for the preflight")
        from repro.multitenant import check_parity
        report = check_parity(models, seed=args.seed, scale=args.scale)
        if not report["ok"]:
            for model, rec in report["models"].items():
                for d in rec["divergences"][:10]:
                    logger.error("parity divergence [%s]: %s", model, d)
            raise SystemExit("pool-vs-corun parity check FAILED")
        parity = {m: rec["ok"] for m, rec in report["models"].items()}

    cache_path = (pathlib.Path(args.plan_cache_path)
                  if args.plan_cache_path else None)
    plan_cache = (PlanCache.load(cache_path)
                  if cache_path is not None and cache_path.exists()
                  else PlanCache())
    sink = RecordingSink() if args.trace_out else None
    pool = RuntimePool(
        machine=SimMachine(seed=args.seed),
        plan_cache=plan_cache,
        config=PoolConfig(
            max_active=args.max_active,
            reservation_window=args.reservation_window,
            topology=(args.topology if args.topology != "flat" else None),
            feedback=(args.feedback if args.feedback != "off" else None),
            sink=sink,
            preemption=(PreemptionPolicy(
                enabled=True,
                max_victims=max(1, args.max_victims),
                evict_admitted=args.evict_admitted,
                migration=args.migrate)
                if (args.preempt or args.max_victims > 1
                    or args.evict_admitted or args.migrate) else None)))
    for spec in mix_specs(models, prios, budgets,
                          arrival_gap=args.arrival_gap,
                          dynamic=args.dynamic, scale=args.scale):
        submit_spec(pool, spec)
    res = pool.run()
    serial = pool.run_serial()
    if cache_path is not None:
        plan_cache.dump(cache_path)
    if sink is not None:
        trace = export_pool_trace(res, args.trace_out, sink.events)
        logger.info("wrote %d trace events (%d decision events) to %s",
                    len(trace["traceEvents"]), len(sink.events),
                    args.trace_out)

    print(json.dumps({
        "jobs": [{
            "name": j.name,
            "priority": j.priority,
            "queue_wait_s": j.queue_wait,
            "latency_s": j.latency,
            "run_latency_s": j.run_latency,
            "serial_latency_s": serial.job_latencies[j.jid],
            "service_core_s": j.service,
            # the demand the admission tier priced the job at; under
            # --feedback ewma the live Job.demand is REMAINING demand
            # (0 once finished), which is not what this field reports
            "demand_core_s": (j.admitted_demand
                              if j.admitted_demand is not None
                              else j.demand),
            "preemptions": j.preemptions,      # launches revoked FROM j
            "evictions": j.evictions,          # admission-level bounces
            "migrations": j.migrations,        # priced width re-seats
            **({"deadline_s": j.deadline,
                "deadline_met": (j.latency is not None
                                 and j.finish_time <= j.deadline)}
               if j.deadline is not None else {}),
        } for j in res.jobs],
        "topology": args.topology,
        "pool_makespan_s": res.makespan,
        "serial_makespan_s": serial.makespan,
        "aggregate_speedup": serial.makespan / res.makespan,
        "pool_throughput_ops_s": res.aggregate_throughput,
        "serial_throughput_ops_s": serial.aggregate_throughput,
        "fairness_jain": res.fairness,
        # e2e divides submit-to-finish by the solo makespan (charges the
        # scheduler for admission queueing); sched divides admit-to-finish
        # (isolates the core scheduler from pure queue wait)
        "slowdown_fairness_e2e_jain": res.slowdown_fairness(
            serial.job_makespans),
        "slowdown_fairness_sched_jain": res.slowdown_fairness(
            serial.job_makespans, include_queue_wait=False),
        "preemptions": res.n_preemptions,
        "evictions": res.n_evictions,
        "migrations": res.n_migrations,
        **({"region_expands": res.n_region_expands,
            "region_resolves": res.n_region_resolves}
           if args.dynamic else {}),
        **({"trip_counts": {str(k): v for k, v
                            in sorted(pool.trip_counts.values.items(),
                                      key=str)},
            "trip_count_stats": pool.trip_counts.stats()}
           if args.trip_count_feedback and pool.trip_counts is not None
           else {}),
        "feedback": args.feedback,
        **({"feedback_stats": res.feedback_stats}
           if res.feedback_stats is not None else {}),
        "plan_cache": res.cache_stats,
        **({"plan_cache_path": str(cache_path)}
           if cache_path is not None else {}),
        "serial_profiling_probes": serial.profiling_probes,
        **({"parity_check": parity} if parity is not None else {}),
        **({"trace_out": args.trace_out,
            "trace_decision_events": len(sink.events)}
           if sink is not None else {}),
        "metrics": res.metrics,
    }, indent=1))


if __name__ == "__main__":
    main()
