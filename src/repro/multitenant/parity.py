"""Differential parity: a single-job pool must reproduce CorunScheduler.

Both schedulers are thin adapters over ``repro.core.strategy.StrategyCore``
(since the extraction), so a pool containing exactly one job must produce
a BIT-IDENTICAL ``ScheduleResult`` timeline — same makespan, same per-op
launch times, thread counts, affinity variants, and hyper-thread flags —
as the single-graph scheduler run on the same machine.  This module is the
executable form of that claim, shared by three consumers:

* ``tests/test_strategy_differential.py`` — the differential suite over
  the model zoo plus committed golden timelines;
* ``benchmarks/run.py --check-parity`` — perf runs double as regression
  checks on the bench mix;
* ``python -m repro.launch.pool --check-parity`` — CLI preflight.

The closed-loop plan store has its own parity obligation: a
``feedback="ewma"`` scheduler fed a ZERO-ERROR observation stream (every
observation exactly matches its prediction) must reproduce the
``feedback="off"`` timeline bitwise, because a ratio-1.0 observation may
not move any correction off 1.0 and a 1.0 correction may not change any
prediction.  ``check_parity`` runs that leg too (``zero_error=True``
flips the correction table into treat-every-observation-as-exact mode),
so accidental drift in the blend math fails the same smoke as a
strategy-rule drift.  Scope: the lock covers the PREDICTION path — the
configurations it runs are single-tenant and cap-free.  A multi-tenant
pool with a demand cap may legitimately diverge even on a zero-error
trace, because ``feedback="ewma"`` prices admission at REMAINING demand
(completed ops drop out), which is a deliberate semantic of the mode,
not blend drift.

Divergence reports name the first mismatching record field-by-field so a
strategy-rule drift between the adapters is diagnosable from CI output
alone.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable

from repro.core.graph import DynamicOpGraph, OpGraph, build_paper_graph
from repro.core.runtime import ConcurrencyRuntime, RuntimeConfig
from repro.core.simmachine import SimMachine
from repro.core.strategy import PreemptionPolicy, ScheduleResult
from repro.multitenant.pool import PoolConfig, RuntimePool
from repro.obs.trace import RecordingSink

# the fields of one timeline row, in report order
_ROW_FIELDS = ("uid", "op_class", "threads", "variant", "hyper",
               "start", "finish", "predicted")


def corun_timeline(graph: OpGraph, machine: SimMachine | None = None,
                   config: RuntimeConfig | None = None, *,
                   zero_error: bool = False) -> ScheduleResult:
    """Profile + schedule one graph with the single-graph scheduler.

    ``zero_error=True`` (meaningful only with ``config.feedback="ewma"``)
    flips the runtime's correction table into the parity mode where every
    observation is treated as exactly matching its prediction — the
    resulting timeline must be bitwise the ``feedback="off"`` one."""
    rt = ConcurrencyRuntime(machine=machine or SimMachine(), config=config)
    rt.profile(graph)
    if zero_error:
        corrections = getattr(rt.planstore, "corrections", None)
        if corrections is not None:
            corrections.zero_error = True
    return rt.execute_step(graph)


def pool_timeline(graph: OpGraph, machine: SimMachine | None = None,
                  config: RuntimeConfig | None = None, *,
                  pool_config: PoolConfig | None = None,
                  zero_error: bool = False) -> ScheduleResult:
    """The same graph as the ONLY tenant of a RuntimePool.

    ``pool_config`` overrides the default single-tenant pool setup, so
    differential tests can vouch for POOL-level knobs too (e.g. a
    preemption-enabled pool with no deadlines must still reproduce the
    single-graph scheduler bit-for-bit).  It is exclusive with ``config``
    — silently preferring one would let a parity test vouch for a
    configuration it never ran.  ``zero_error`` mirrors
    ``corun_timeline``: with ``feedback="ewma"`` the pool's shared
    correction table treats every observation as exact."""
    if pool_config is not None and config is not None:
        raise ValueError("pass either config or pool_config, not both "
                         "(set pool_config.runtime instead)")
    if pool_config is None:
        pool_config = PoolConfig(max_active=1,
                                 runtime=config or RuntimeConfig())
    pool = RuntimePool(machine=machine or SimMachine(), config=pool_config)
    if zero_error and pool.corrections is not None:
        pool.corrections.zero_error = True
    job = pool.submit(graph)
    res = pool.run()
    return res.per_job_schedule(job.jid)


def cluster_timeline(graph: OpGraph, machine: SimMachine | None = None,
                     config: RuntimeConfig | None = None) -> ScheduleResult:
    """The same graph as the only tenant of a ONE-MACHINE ClusterPool.

    The cluster layer (router, demand index, rebalance check, shared jid
    space) must be bit-for-bit inert when there is nothing to route
    between: a 1-machine cluster IS the single-machine pool."""
    # function-local for the same layering reason as service_timeline:
    # the cluster package imports multitenant modules
    from repro.cluster import ClusterPool
    from repro.hw.spec import ClusterSpec

    machine = machine or SimMachine()
    pool = ClusterPool(ClusterSpec(machines=(machine.spec,)),
                       config=PoolConfig(max_active=1,
                                         runtime=config or RuntimeConfig()),
                       machines=[machine])
    job = pool.submit(graph)
    return pool.run().per_job_schedule(job.jid)


def service_timeline(model: str, machine: SimMachine | None = None,
                     config: RuntimeConfig | None = None, *,
                     scale: int = 1) -> ScheduleResult:
    """The same graph as the only tenant of a ``--once`` pool DAEMON
    (submit-all-then-drain through ``repro.service.PoolDaemon``, state
    dir discarded).  The daemon wraps the pool in checkpointing, a job
    store, and the payload-observer seam — all of which must be
    bit-for-bit inert on the scheduling timeline."""
    import tempfile

    # function-local: parity is imported by the multitenant package
    # __init__, and the service package imports multitenant modules
    from repro.service import JobSpec, PoolDaemon
    with tempfile.TemporaryDirectory() as td:
        daemon = PoolDaemon(
            td, config=PoolConfig(max_active=1,
                                  runtime=config or RuntimeConfig()),
            machine=machine or SimMachine())
        daemon.submit(JobSpec(workload=model, scale=scale))
        res = daemon.drain()
        daemon.close()
        return res.per_job_schedule(daemon.pool.jobs[0].jid)


def timeline_rows(result: ScheduleResult) -> list[dict]:
    """JSON-serializable per-op launch records (golden-fixture format).

    Floats are kept at full precision — ``json`` round-trips Python floats
    exactly — so fixture comparisons are bit-exact, not approximate."""
    return [{"uid": r.op.uid, "op_class": r.op.op_class,
             "threads": r.threads, "variant": r.variant, "hyper": r.hyper,
             "start": r.start, "finish": r.finish, "predicted": r.predicted}
            for r in result.records]


def compare_timelines(a: list[dict], b: list[dict], *,
                      label_a: str = "corun",
                      label_b: str = "pool") -> list[str]:
    """Field-by-field divergences between two timelines (empty = parity)."""
    divergences: list[str] = []
    if len(a) != len(b):
        divergences.append(
            f"record count: {label_a}={len(a)} {label_b}={len(b)}")
    for i, (ra, rb) in enumerate(zip(a, b)):
        for f in _ROW_FIELDS:
            if ra.get(f) != rb.get(f):
                divergences.append(
                    f"record {i} field {f!r}: {label_a}={ra.get(f)!r} "
                    f"{label_b}={rb.get(f)!r}")
    return divergences


def check_parity(models: Iterable[str] = ("resnet50", "dcgan"), *,
                 seed: int = 0, scale: int = 1,
                 config: RuntimeConfig | None = None) -> dict:
    """Pool-vs-corun parity over paper-zoo models, plus the closed-loop
    zero-error leg and the trace-inertness leg.

    Per model, NINE pool/corun timelines must agree bitwise with the
    single-graph ``feedback="off"`` reference: the single-job pool (the
    strategy-core differential), a single-job pool with a live
    ``RecordingSink`` (the observability lock — tracing must be
    bit-for-bit inert, and a traced run that records ZERO events is
    itself flagged, so the leg can't pass vacuously with a disconnected
    sink), a preemption-ENABLED pool with the economics knobs at their
    off defaults and no deadlines (the preemption-economics surface must
    be inert unless armed AND triggered), both schedulers re-run with
    ``feedback="ewma"`` on a zero-error observation stream (the
    blend-math lock — an exact observation may not move any prediction),
    both schedulers run on the same ops wrapped in a ``DynamicOpGraph``
    with ZERO regions (the dynamic-control-flow lock — the region
    machinery must be bit-for-bit inert on static graphs), a
    submit-all-then-drain run through the pool DAEMON (the service lock
    — checkpointing, the job store, and the payload-observer seam must
    not perturb the timeline), and a ONE-MACHINE ClusterPool run (the
    cluster lock — routing, demand pricing, and the rebalance check must
    be inert with nothing to route between).

    Returns ``{"ok": bool, "models": {name: {"ok", "makespan",
    "divergences"}}}``.  Uses equal-seeded machines (the sim machine is a
    deterministic function of its seed, so equal seeds mean an identical
    timing function).  ``scale``/``config`` must match the run being
    vouched for — parity on a scale-1 graph says nothing about a
    divergence only reachable with a larger ready frontier."""
    report: dict = {"ok": True, "models": {}}
    base = config or RuntimeConfig()
    fb = dataclasses.replace(
        base, strategy=dataclasses.replace(base.strategy, feedback="ewma"))
    for model in dict.fromkeys(models):        # dedupe, keep order
        graph = build_paper_graph(model, scale=scale)
        # the same ops as a region-free dynamic graph: the trivial fixed
        # point of the frontier contract, must schedule bit-identically
        dyn = DynamicOpGraph(name=graph.name, ops=dict(graph.ops))
        single = corun_timeline(graph, SimMachine(seed=seed), config)
        ref = timeline_rows(single)
        sink = RecordingSink()
        legs = {
            "pool": pool_timeline(graph, SimMachine(seed=seed), config),
            "pool-traced": pool_timeline(
                graph, SimMachine(seed=seed),
                pool_config=PoolConfig(max_active=1, runtime=base,
                                       sink=sink)),
            # preemption armed, economics knobs at their OFF defaults, no
            # deadlines: the whole preemption-economics surface must be
            # inert — bit-for-bit the plain pool (the PR-6 behavior lock)
            "pool-preempt": pool_timeline(
                graph, SimMachine(seed=seed),
                pool_config=PoolConfig(
                    max_active=1, runtime=base,
                    preemption=PreemptionPolicy(enabled=True))),
            "corun-ewma0": corun_timeline(graph, SimMachine(seed=seed),
                                          fb, zero_error=True),
            "pool-ewma0": pool_timeline(graph, SimMachine(seed=seed), fb,
                                        zero_error=True),
            "corun-dyn0": corun_timeline(dyn, SimMachine(seed=seed),
                                         config),
            "pool-dyn0": pool_timeline(dyn, SimMachine(seed=seed), config),
            # submit-all-then-drain through the pool DAEMON: the service
            # layer (job store, per-instant checkpointing, observer seam)
            # must reproduce the library pool bit-for-bit
            "service-once": service_timeline(
                model, SimMachine(seed=seed), config, scale=scale),
            # a 1-machine cluster IS the pool: the placement layer must
            # add nothing to the timeline until there is a second machine
            "cluster-1m": cluster_timeline(graph, SimMachine(seed=seed),
                                           config),
        }
        divs: list[str] = []
        if not sink.events:
            divs.append("pool-traced: RecordingSink recorded 0 events — "
                        "the trace seam is disconnected")
        for label, res in legs.items():
            d = compare_timelines(ref, timeline_rows(res), label_b=label)
            if single.makespan != res.makespan:
                d.insert(0, f"makespan: corun={single.makespan!r} "
                            f"{label}={res.makespan!r}")
            divs.extend(d)
        report["models"][model] = {
            "ok": not divs,
            "makespan": single.makespan,
            "divergences": divs,
        }
        if divs:
            report["ok"] = False
    return report
