"""Differential parity: a single-job pool must reproduce CorunScheduler.

Both schedulers are thin adapters over ``repro.core.strategy.StrategyCore``
(since the extraction), so a pool containing exactly one job must produce
a BIT-IDENTICAL ``ScheduleResult`` timeline — same makespan, same per-op
launch times, thread counts, affinity variants, and hyper-thread flags —
as the single-graph scheduler run on the same machine.  This module is the
executable form of that claim, shared by three consumers:

* ``tests/test_strategy_differential.py`` — the differential suite over
  the model zoo plus committed golden timelines;
* ``benchmarks/run.py --check-parity`` — perf runs double as regression
  checks on the bench mix;
* ``python -m repro.launch.pool --check-parity`` — CLI preflight.

Divergence reports name the first mismatching record field-by-field so a
strategy-rule drift between the adapters is diagnosable from CI output
alone.
"""

from __future__ import annotations

from typing import Iterable

from repro.core.graph import OpGraph, build_paper_graph
from repro.core.runtime import ConcurrencyRuntime, RuntimeConfig
from repro.core.simmachine import SimMachine
from repro.core.strategy import ScheduleResult
from repro.multitenant.pool import PoolConfig, RuntimePool

# the fields of one timeline row, in report order
_ROW_FIELDS = ("uid", "op_class", "threads", "variant", "hyper",
               "start", "finish", "predicted")


def corun_timeline(graph: OpGraph, machine: SimMachine | None = None,
                   config: RuntimeConfig | None = None) -> ScheduleResult:
    """Profile + schedule one graph with the single-graph scheduler."""
    rt = ConcurrencyRuntime(machine=machine or SimMachine(), config=config)
    rt.profile(graph)
    return rt.execute_step(graph)


def pool_timeline(graph: OpGraph, machine: SimMachine | None = None,
                  config: RuntimeConfig | None = None, *,
                  pool_config: PoolConfig | None = None) -> ScheduleResult:
    """The same graph as the ONLY tenant of a RuntimePool.

    ``pool_config`` overrides the default single-tenant pool setup, so
    differential tests can vouch for POOL-level knobs too (e.g. a
    preemption-enabled pool with no deadlines must still reproduce the
    single-graph scheduler bit-for-bit).  It is exclusive with ``config``
    — silently preferring one would let a parity test vouch for a
    configuration it never ran."""
    if pool_config is not None and config is not None:
        raise ValueError("pass either config or pool_config, not both "
                         "(set pool_config.runtime instead)")
    if pool_config is None:
        pool_config = PoolConfig(max_active=1,
                                 runtime=config or RuntimeConfig())
    pool = RuntimePool(machine=machine or SimMachine(), config=pool_config)
    job = pool.submit(graph)
    res = pool.run()
    return res.per_job_schedule(job.jid)


def timeline_rows(result: ScheduleResult) -> list[dict]:
    """JSON-serializable per-op launch records (golden-fixture format).

    Floats are kept at full precision — ``json`` round-trips Python floats
    exactly — so fixture comparisons are bit-exact, not approximate."""
    return [{"uid": r.op.uid, "op_class": r.op.op_class,
             "threads": r.threads, "variant": r.variant, "hyper": r.hyper,
             "start": r.start, "finish": r.finish, "predicted": r.predicted}
            for r in result.records]


def compare_timelines(a: list[dict], b: list[dict], *,
                      label_a: str = "corun",
                      label_b: str = "pool") -> list[str]:
    """Field-by-field divergences between two timelines (empty = parity)."""
    divergences: list[str] = []
    if len(a) != len(b):
        divergences.append(
            f"record count: {label_a}={len(a)} {label_b}={len(b)}")
    for i, (ra, rb) in enumerate(zip(a, b)):
        for f in _ROW_FIELDS:
            if ra.get(f) != rb.get(f):
                divergences.append(
                    f"record {i} field {f!r}: {label_a}={ra.get(f)!r} "
                    f"{label_b}={rb.get(f)!r}")
    return divergences


def check_parity(models: Iterable[str] = ("resnet50", "dcgan"), *,
                 seed: int = 0, scale: int = 1,
                 config: RuntimeConfig | None = None) -> dict:
    """Pool-vs-corun parity over paper-zoo models.

    Returns ``{"ok": bool, "models": {name: {"ok", "makespan",
    "divergences"}}}``.  Uses two equal-seeded machines (the sim machine
    is a deterministic function of its seed, so equal seeds mean an
    identical timing function).  ``scale``/``config`` must match the run
    being vouched for — parity on a scale-1 graph says nothing about a
    divergence only reachable with a larger ready frontier."""
    report: dict = {"ok": True, "models": {}}
    for model in dict.fromkeys(models):        # dedupe, keep order
        graph = build_paper_graph(model, scale=scale)
        single = corun_timeline(graph, SimMachine(seed=seed), config)
        pooled = pool_timeline(graph, SimMachine(seed=seed), config)
        divs = compare_timelines(timeline_rows(single), timeline_rows(pooled))
        if single.makespan != pooled.makespan:
            divs.insert(0, f"makespan: corun={single.makespan!r} "
                           f"pool={pooled.makespan!r}")
        report["models"][model] = {
            "ok": not divs,
            "makespan": single.makespan,
            "divergences": divs,
        }
        if divs:
            report["ok"] = False
    return report
