"""Multi-tenant runtime pool: many op graphs co-scheduled on one machine.

Layers (each builds on ``repro.core``, none of core depends back):

  job        -- Job + JobQueue admission controller (priority, demand cap,
                weighted-fair-share accounting in perfmodel core-seconds;
                deadlines: EDF within a priority level, dynamic
                slack-scaled priority, slot reservation, per-node
                critical-path pricing for deadline slack)
  plancache  -- cross-job curve cache (keyed by the op's full analytic
                profile) so one tenant's profiling probes amortize over
                every tenant; persists across process restarts as
                versioned JSON (dump/load, LRU + stats preserved)
  pool       -- PoolScheduler: thin multi-job adapter over the shared
                ``repro.core.strategy.StrategyCore`` (job-aware Strategy-2
                clamp, cross-job interference blacklist, weighted fair
                share, deadline-driven checkpoint-free preemption via
                ``PreemptionPolicy`` — off by default) + RuntimePool
                driver and serial baseline
  parity     -- differential check that a single-job pool reproduces
                CorunScheduler timelines bit-for-bit
"""

from repro.core.strategy import PreemptionPolicy
from repro.multitenant.job import (Job, JobQueue, downstream_critical_path,
                                   fairness_index)
from repro.multitenant.parity import (check_parity, compare_timelines,
                                      corun_timeline, pool_timeline,
                                      timeline_rows)
from repro.multitenant.plancache import PlanCache
from repro.multitenant.pool import (PoolConfig, PoolResult, PoolScheduler,
                                    RuntimePool, SerialResult)

__all__ = [
    "Job", "JobQueue", "downstream_critical_path", "fairness_index",
    "PlanCache", "PreemptionPolicy",
    "PoolConfig", "PoolResult", "PoolScheduler", "RuntimePool",
    "SerialResult",
    "check_parity", "compare_timelines", "corun_timeline", "pool_timeline",
    "timeline_rows",
]
