"""Multi-tenant runtime pool: many op graphs co-scheduled on one machine.

Layers (each builds on ``repro.core``, none of core depends back):

  job        -- Job + JobQueue admission controller (priority, demand cap,
                weighted-fair-share accounting in perfmodel core-seconds)
  plancache  -- cross-job curve cache (keyed by the op's full analytic
                profile) so one tenant's profiling probes amortize over
                every tenant
  pool       -- PoolScheduler (Strategies 3/4 over a multi-job frontier,
                job-aware Strategy-2 clamp, cross-job interference
                blacklist) + RuntimePool driver and serial baseline
"""

from repro.multitenant.job import Job, JobQueue, fairness_index
from repro.multitenant.plancache import PlanCache
from repro.multitenant.pool import (PoolConfig, PoolResult, PoolScheduler,
                                    RuntimePool, SerialResult)

__all__ = [
    "Job", "JobQueue", "fairness_index",
    "PlanCache",
    "PoolConfig", "PoolResult", "PoolScheduler", "RuntimePool",
    "SerialResult",
]
