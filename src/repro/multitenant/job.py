"""Jobs and admission control for the multi-tenant runtime pool.

A *job* is one tenant's workload: an ``OpGraph`` (a training step, a
serving wave) plus — once admitted — the frozen ``ConcurrencyPlan`` the
paper's Strategies 1-2 produce for it.  ``JobQueue`` is the admission
controller: jobs wait in priority order and are admitted while the pool
has an active-job slot and the job's *predicted demand* (core-seconds,
the ``perfmodel`` cost currency) fits under the outstanding-demand cap.

Fair sharing uses the classic weighted virtual-time rule: each job
accrues ``service`` (core-seconds actually granted) and the scheduler
always prefers the job with the smallest ``service / priority``.  Charging
service at launch time (not completion) makes the share responsive within
a single scheduling instant.
"""

from __future__ import annotations

import bisect
import dataclasses
import itertools

from repro.core.concurrency import ConcurrencyController, ConcurrencyPlan
from repro.core.graph import OpGraph


@dataclasses.dataclass
class Job:
    """One admitted-or-waiting tenant workload."""

    jid: int
    name: str
    graph: OpGraph
    priority: float = 1.0             # weight in the fair-share rule
    submit_time: float = 0.0
    # filled at profiling/admission time
    plan: ConcurrencyPlan | None = None
    controller: ConcurrencyController | None = None
    demand: float = 0.0               # predicted core-seconds (perfmodel)
    # accounting, maintained by the pool
    admit_time: float | None = None
    finish_time: float | None = None
    service: float = 0.0              # core-seconds granted so far
    ops_done: int = 0

    @property
    def done(self) -> bool:
        return self.finish_time is not None

    @property
    def latency(self) -> float:
        """Submit-to-finish (includes queueing) — the per-tenant SLO view."""
        assert self.finish_time is not None
        return self.finish_time - self.submit_time

    @property
    def queue_wait(self) -> float:
        assert self.admit_time is not None
        return self.admit_time - self.submit_time

    @property
    def virtual_time(self) -> float:
        """Weighted service — the fair-share ordering key (smaller = owed)."""
        return self.service / max(self.priority, 1e-9)


class JobQueue:
    """Priority-ordered admission controller with a demand cap.

    ``max_active`` bounds concurrently admitted jobs (each admitted graph's
    ready frontier competes for cores — too many tenants and the Strategy-3
    horizon guard starts rejecting everything).  ``max_outstanding_demand``
    optionally bounds the summed predicted core-seconds of active jobs; a
    job whose demand would overflow the cap waits, but is never skipped in
    favor of a lower-priority job (no starvation by overtaking).  The cap
    is deliberately waived when the pool is idle: a job bigger than the
    cap must still run eventually, alone — otherwise it would deadlock
    the queue."""

    def __init__(self, max_active: int = 3,
                 max_outstanding_demand: float | None = None):
        self.max_active = max_active
        self.max_outstanding_demand = max_outstanding_demand
        # kept sorted by (-priority, submit_time, seq): strict priority,
        # FIFO within a level (seq is unique, so Jobs are never compared)
        self._waiting: list[tuple[float, float, int, Job]] = []
        self._seq = itertools.count()
        self.submitted: list[Job] = []

    def submit(self, job: Job) -> None:
        bisect.insort(self._waiting,
                      (-job.priority, job.submit_time, next(self._seq), job))
        self.submitted.append(job)

    def __len__(self) -> int:
        return len(self._waiting)

    def peek(self) -> Job | None:
        return self._waiting[0][3] if self._waiting else None

    def next_arrival(self, now: float) -> float | None:
        """Earliest submit_time strictly in the future, or None."""
        future = [j.submit_time for _, _, _, j in self._waiting
                  if j.submit_time > now]
        return min(future) if future else None

    def pop_admissible(self, active: list[Job],
                       now: float = float("inf")) -> Job | None:
        """Next job to admit given the currently active set, or None.

        Highest priority among jobs that have already arrived
        (``submit_time <= now``); within a priority level, FIFO.  The
        demand cap never lets a lower-priority job overtake one that is
        merely too big — the big job waits, everything behind it waits too
        (strict priority, no starvation by overtaking)."""
        if len(active) >= self.max_active:
            return None
        for i, (_, _, _, job) in enumerate(self._waiting):
            if job.submit_time > now:
                continue
            if self.max_outstanding_demand is not None and active:
                outstanding = sum(j.demand for j in active)
                if outstanding + job.demand > self.max_outstanding_demand:
                    return None
            self._waiting.pop(i)
            return job
        return None


def jain(values: list[float]) -> float:
    """Jain's fairness index: 1.0 = all equal, 1/n = one takes all.
    Empty or all-zero inputs count as perfectly fair (nothing competed)."""
    if not values:
        return 1.0
    s = sum(values)
    sq = sum(x * x for x in values)
    if sq == 0:
        return 1.0
    return (s * s) / (len(values) * sq)


def fairness_index(jobs: list[Job]) -> float:
    """Jain's fairness index over priority-normalized service.

    1.0 = every job got service exactly proportional to its priority;
    1/n = one job got everything.  Computed over jobs that were admitted.

    Caveat: in a run-to-completion pool, final service converges to each
    job's own demand whatever the scheduler did, so this index mostly
    reflects demand/priority skew of the MIX.  To judge the SCHEDULER,
    use ``PoolResult.slowdown_fairness`` (per-job latency relative to
    running alone), where a starved tenant shows up as a large slowdown."""
    return jain([j.service / max(j.priority, 1e-9)
                 for j in jobs if j.admit_time is not None])
