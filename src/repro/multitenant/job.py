"""Jobs and admission control for the multi-tenant runtime pool.

A *job* is one tenant's workload: an ``OpGraph`` (a training step, a
serving wave) plus — once admitted — the frozen ``ConcurrencyPlan`` the
paper's Strategies 1-2 produce for it.  ``JobQueue`` is the admission
controller: jobs wait in priority order and are admitted while the pool
has an active-job slot and the job's *predicted demand* (core-seconds,
the ``perfmodel`` cost currency) fits under the outstanding-demand cap.

Fair sharing uses the classic weighted virtual-time rule: each job
accrues ``service`` (core-seconds actually granted) and the scheduler
always prefers the job with the smallest ``service / priority``.  Charging
service at launch time (not completion) makes the share responsive within
a single scheduling instant.

Deadlines (SLOs) ride on top of the static weights: a job may carry a
``deadline`` (absolute time its owner wants it finished by), which makes
its priority DYNAMIC — ``effective_priority`` scales the static weight up
as slack runs out — and makes the queue order earliest-deadline-first
within a priority level.  ``downstream_critical_path`` prices how much
predicted work still separates each node from the job's completion, which
is what turns a deadline into per-node slack the pool's preemption path
can act on (see ``repro.core.strategy.PreemptionPolicy``).
"""

from __future__ import annotations

import bisect
import dataclasses
import itertools

from repro.core.concurrency import ConcurrencyController, ConcurrencyPlan
from repro.core.graph import OpGraph
from repro.core.planstore import PlanStore, critical_path_from


@dataclasses.dataclass
class Job:
    """One admitted-or-waiting tenant workload."""

    jid: int
    name: str
    graph: OpGraph
    priority: float = 1.0             # weight in the fair-share rule
    submit_time: float = 0.0
    deadline: float | None = None     # absolute SLO target (None = best-effort)
    # filled at profiling/admission time
    plan: ConcurrencyPlan | None = None
    controller: ConcurrencyController | None = None
    # the job's closed-loop plan store (repro.core.planstore): every
    # prediction the pool consumes for this job flows through it, and
    # under feedback="ewma" the pool's observations flow back — demand
    # and cp below are DERIVED from it and re-derived on completions
    store: PlanStore | None = None
    demand: float = 0.0               # predicted core-seconds (perfmodel);
    #                                   under feedback="ewma" this is the
    #                                   REMAINING corrected demand, updated
    #                                   as ops complete
    # uid -> predicted critical path from that node to job completion,
    # inclusive (filled at profiling time; prices deadline slack per node;
    # re-derived from observations under feedback="ewma")
    cp: dict[int, float] = dataclasses.field(default_factory=dict)
    # demand in force when the job was admitted (reporting: under
    # feedback="ewma" the live ``demand`` decays to 0 as ops complete,
    # so "what was this tenant priced at" needs its own field)
    admitted_demand: float | None = None
    # accounting, maintained by the pool
    admit_time: float | None = None
    finish_time: float | None = None
    service: float = 0.0              # core-seconds granted so far
    ops_done: int = 0
    preemptions: int = 0              # launches revoked from this job
    # admission-level evictions: times this job was returned to the queue
    # while admitted with no launched ops (the FREE preemption-economics
    # move — zero restart waste; see PreemptionPolicy.evict_admitted)
    evictions: int = 0
    # width migrations: launches of this job revoked and immediately
    # relaunched at a different width (PreemptionPolicy.migration);
    # counted separately from ``preemptions`` (which includes them at the
    # sim level) so reporting can tell an SLO revoke from a priced re-seat
    migrations: int = 0
    # the queue-order ticket assigned at FIRST submit and reused on every
    # readmit, so an evicted job re-enters under its ORIGINAL submit order
    queue_seq: int | None = None
    # quadrant of the job's most recent placed launch (topology="quadrant"
    # only) — the pool's tenant-to-quadrant affinity hint
    last_quadrant: int | None = None
    # set by RuntimePool.cancel: the job left the pool before finishing
    # (finish_time stays None — a cancelled job has no latency)
    cancelled: bool = False

    @property
    def done(self) -> bool:
        return self.finish_time is not None

    @property
    def latency(self) -> float | None:
        """Submit-to-finish (includes queueing) — the per-tenant SLO view.
        ``None`` until the job finishes (a rejected or still-queued tenant
        has no latency yet; callers reporting on unfinished jobs should
        use ``waiting_time(now)``)."""
        if self.finish_time is None:
            return None
        return self.finish_time - self.submit_time

    @property
    def run_latency(self) -> float | None:
        """Admit-to-finish — what the SCHEDULER did to the job, with the
        admission queue factored out.  ``None`` until finished."""
        if self.finish_time is None or self.admit_time is None:
            return None
        return self.finish_time - self.admit_time

    @property
    def queue_wait(self) -> float | None:
        """Submit-to-admit, or ``None`` for a never-admitted job (deadline
        rejection and reporting paths must not crash on those)."""
        if self.admit_time is None:
            return None
        return self.admit_time - self.submit_time

    def waiting_time(self, now: float) -> float:
        """Queue wait as of ``now``: submit-to-admit once admitted,
        submit-to-now while still waiting."""
        until = self.admit_time if self.admit_time is not None else now
        return max(0.0, until - self.submit_time)

    def slack(self, now: float) -> float | None:
        """Raw deadline slack (no remaining-work estimate): time left
        until the deadline, or ``None`` for best-effort jobs."""
        if self.deadline is None:
            return None
        return self.deadline - now

    def effective_priority(self, now: float) -> float:
        """Dynamic priority = f(deadline slack).

        Best-effort jobs keep their static weight.  A deadlined job's
        weight scales linearly from 1x at submit to 2x at (and past) the
        deadline, so a tenant running out of slack is progressively
        preferred by the fair-share order without ever dominating a
        static-priority tier above it."""
        if self.deadline is None:
            return self.priority
        budget = max(self.deadline - self.submit_time, 1e-12)
        frac = (self.deadline - now) / budget       # 1 at submit, 0 at SLO
        return self.priority * (2.0 - min(max(frac, 0.0), 1.0))

    @property
    def virtual_time(self) -> float:
        """Weighted service — the fair-share ordering key (smaller = owed)."""
        return self.service / max(self.priority, 1e-9)

    def virtual_time_at(self, now: float) -> float:
        """Fair-share key under the dynamic (slack-scaled) priority.
        Identical to ``virtual_time`` for best-effort jobs, so schedulers
        that never set deadlines are bit-for-bit unchanged."""
        return self.service / max(self.effective_priority(now), 1e-9)


def downstream_critical_path(graph: OpGraph,
                             plan: ConcurrencyPlan) -> dict[int, float]:
    """uid -> predicted time from starting that node to finishing the job
    (the node's own frozen-plan prediction plus the longest consumer
    chain).  This is the remaining-work estimate that converts a job
    deadline into per-node slack: a ready node with
    ``deadline - now - cp[uid] <= 0`` cannot make its SLO even if granted
    cores immediately, which is the pool's preemption trigger.

    This is the FROZEN-plan view (the pre-feedback behavior, kept for
    callers without a store); the pool derives ``Job.cp`` through
    ``PlanStore.remaining_critical_path``, which additionally applies
    observation corrections and drops completed nodes under
    ``feedback="ewma"``."""
    pred = {uid: plan.per_instance[op.size_key].predicted_time
            for uid, op in graph.ops.items()}
    return critical_path_from(graph, pred)


class JobQueue:
    """Priority-ordered admission controller with a demand cap.

    ``max_active`` bounds concurrently admitted jobs (each admitted graph's
    ready frontier competes for cores — too many tenants and the Strategy-3
    horizon guard starts rejecting everything).  ``max_outstanding_demand``
    optionally bounds the summed predicted core-seconds of active jobs; a
    job whose demand would overflow the cap waits, but is never skipped in
    favor of a lower-priority job (no starvation by overtaking).  The cap
    is deliberately waived when the pool is idle: a job bigger than the
    cap must still run eventually, alone — otherwise it would deadlock
    the queue.

    Deadline awareness: within a priority level the queue is earliest-
    deadline-first (best-effort jobs sort after any deadlined peer), and a
    positive ``reservation_window`` holds the LAST active slot open for a
    strictly-higher-priority deadlined arrival due within the window, so
    an imminent SLO tenant doesn't find the pool freshly packed with
    best-effort work."""

    def __init__(self, max_active: int = 3,
                 max_outstanding_demand: float | None = None,
                 reservation_window: float = 0.0):
        self.max_active = max_active
        self.max_outstanding_demand = max_outstanding_demand
        self.reservation_window = reservation_window
        # kept sorted by (-priority, deadline, submit_time, seq): strict
        # priority, EDF within a level (no deadline = +inf, so best-effort
        # jobs keep FIFO among themselves), FIFO as the final tie-break
        # (seq is unique, so Jobs are never compared)
        self._waiting: list[tuple[float, float, float, int, Job]] = []
        self._seq = itertools.count()
        self.submitted: list[Job] = []

    def submit(self, job: Job) -> None:
        if job.queue_seq is None:
            job.queue_seq = next(self._seq)
        self._enqueue(job)
        self.submitted.append(job)

    def readmit(self, job: Job) -> None:
        """Return an EVICTED job to the queue (admission-level preemption,
        see ``PreemptionPolicy.evict_admitted``).  The job keeps its
        original ``submit_time`` and ``queue_seq``, so it re-enters under
        exactly its original submit order — eviction defers the tenant, it
        never demotes it.  Not appended to ``submitted`` again: it is the
        same submission, bounced back."""
        self._enqueue(job)

    def _enqueue(self, job: Job) -> None:
        deadline = job.deadline if job.deadline is not None else float("inf")
        bisect.insort(self._waiting,
                      (-job.priority, deadline, job.submit_time,
                       job.queue_seq, job))

    def remove(self, jid: int) -> bool:
        """Drop one WAITING job from the queue (job cancellation).
        Returns False when the jid is not waiting (already admitted,
        finished, or unknown) — the caller decides what that means."""
        for i, (*_, job) in enumerate(self._waiting):
            if job.jid == jid:
                del self._waiting[i]
                return True
        return False

    def __len__(self) -> int:
        return len(self._waiting)

    def peek(self) -> Job | None:
        return self._waiting[0][4] if self._waiting else None

    def waiting_jobs(self) -> list[Job]:
        """Snapshot of queued jobs in admission order (the pool's
        feedback path re-derives their demand/cp before admission checks
        so the cap prices tenants at TODAY's estimates)."""
        return [job for *_, job in self._waiting]

    def next_arrival(self, now: float) -> float | None:
        """Earliest submit_time strictly in the future, or None."""
        future = [j.submit_time for *_, j in self._waiting
                  if j.submit_time > now]
        return min(future) if future else None

    def next_admissible_arrival(self, active: list[Job],
                                now: float) -> float | None:
        """Earliest strictly-future arrival instant at which some waiting
        job would actually be admitted, or None.  The pool's wakeup time:
        the EARLIEST arrival may be inadmissible (demand cap, reservation)
        while a later one within the same op's runtime is not — that later
        arrival still deserves its scheduling instant."""
        future = sorted({j.submit_time for *_, j in self._waiting
                         if j.submit_time > now})
        for t in future:
            if self.admissible_at(active, t):
                return t
        return None

    def _admissible_index(self, active: list[Job],
                          now: float) -> tuple[int | None, str]:
        """``(index, cause)``: the waiting-list index of the job
        ``pop_admissible`` would hand out (cause ``"ok"``), or ``None``
        with WHY nothing is admissible — ``"empty"`` / ``"not_arrived"``
        (nothing to decide yet), ``"max_active"`` / ``"demand_cap"`` /
        ``"reserved"`` (an arrived tenant was actually blocked; these are
        the causes the admission trace reports).  One predicate for both
        popping and the pool's arrival-wakeup check, so a wakeup can
        never disagree with the admission it is waking up for."""
        if not self._waiting:
            return None, "empty"
        if len(active) >= self.max_active:
            return None, "max_active"
        for i, (*_, job) in enumerate(self._waiting):
            if job.submit_time > now:
                continue
            if self.max_outstanding_demand is not None and active:
                outstanding = sum(j.demand for j in active)
                if outstanding + job.demand > self.max_outstanding_demand:
                    return None, "demand_cap"
            if (self.reservation_window > 0.0
                    and len(active) == self.max_active - 1
                    and self._imminent_urgent_arrival(job, now)):
                return None, "reserved"
            return i, "ok"
        return None, "not_arrived"

    def block_cause(self, active: list[Job], now: float) -> str | None:
        """Why no waiting job is admissible right now (see
        ``_admissible_index`` for the vocabulary), or ``None`` when one
        IS admissible — the pool's admission decision trace reads this."""
        i, cause = self._admissible_index(active, now)
        return None if i is not None else cause

    def _imminent_urgent_arrival(self, job: Job, now: float) -> bool:
        """Is a strictly-higher-priority deadlined job due within the
        reservation window?  If so, the last slot is held for it."""
        horizon = now + self.reservation_window
        return any(h.priority > job.priority and h.deadline is not None
                   and now < h.submit_time <= horizon
                   for *_, h in self._waiting)

    def peek_admissible(self, active: list[Job],
                        now: float = float("inf")) -> Job | None:
        """The job ``pop_admissible`` WOULD hand out, without removing it
        — the eviction path's what-if probe: 'if the active set were
        ``active``, who would be admitted?'."""
        i, _ = self._admissible_index(active, now)
        return self._waiting[i][4] if i is not None else None

    def pop_admissible(self, active: list[Job],
                       now: float = float("inf")) -> Job | None:
        """Next job to admit given the currently active set, or None.

        Highest priority among jobs that have already arrived
        (``submit_time <= now``); within a priority level, earliest
        deadline first, then FIFO.  The demand cap never lets a lower-
        priority job overtake one that is merely too big — the big job
        waits, everything behind it waits too (strict priority, no
        starvation by overtaking)."""
        i, _ = self._admissible_index(active, now)
        if i is None:
            return None
        return self._waiting.pop(i)[4]

    def admissible_at(self, active: list[Job], t: float) -> bool:
        """Would ``pop_admissible(active, now=t)`` hand out a job?  The
        pool's arrival-wakeup predicate: waking the scheduling loop for an
        arrival that the demand cap (or a reservation) would bounce is a
        spurious scheduling instant."""
        return self._admissible_index(active, t)[0] is not None


def jain(values: list[float]) -> float:
    """Jain's fairness index: 1.0 = all equal, 1/n = one takes all.
    Empty or all-zero inputs count as perfectly fair (nothing competed)."""
    if not values:
        return 1.0
    s = sum(values)
    sq = sum(x * x for x in values)
    if sq == 0:
        return 1.0
    return (s * s) / (len(values) * sq)


def fairness_index(jobs: list[Job]) -> float:
    """Jain's fairness index over priority-normalized service.

    1.0 = every job got service exactly proportional to its priority;
    1/n = one job got everything.  Computed over jobs that were admitted.

    Caveat: in a run-to-completion pool, final service converges to each
    job's own demand whatever the scheduler did, so this index mostly
    reflects demand/priority skew of the MIX.  To judge the SCHEDULER,
    use ``PoolResult.slowdown_fairness`` (per-job latency relative to
    running alone), where a starved tenant shows up as a large slowdown."""
    return jain([j.service / max(j.priority, 1e-9)
                 for j in jobs if j.admit_time is not None])
