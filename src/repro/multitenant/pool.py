"""Runtime pool: co-schedule many op graphs on one simulated machine.

This generalizes ``repro.core.scheduler.CorunScheduler`` from *one step
graph* to *many tenants*: the paper's Strategy-3 candidate selection draws
ready ops from every admitted job's frontier, the Strategy-2 clamp applies
each op's **own job's** frozen plan, Strategy 4's hyper-thread lane picks
the globally smallest ready op, and the interference blacklist spans
co-runners from different jobs (a class pair that thrashes MCDRAM thrashes
it regardless of which tenant launched each side).

Cross-job decisions need a currency; following value-function schedulers
(Steiner et al.) we use the ``perfmodel`` predictions already frozen in
each job's plan: a job's *demand* is its predicted core-seconds, its
*service* the core-seconds actually granted, and the pool always prefers
the job with the smallest priority-weighted service (weighted fair share).
Service is charged at launch so the share is responsive within one
scheduling instant; hyper-thread launches are charged at the machine's
hyper-thread efficiency (they borrow spare lanes, not whole cores).

``RuntimePool`` is the driver: submit jobs (graph + priority + arrival
time), run, get a ``PoolResult`` with per-job latency, fairness, and
plan-cache amortization stats.  ``RuntimePool.run_serial`` replays the
same job mix one graph at a time — the baseline the multitenant
benchmarks compare against.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools

from repro.core.concurrency import OpPlan
from repro.core.graph import Op, OpGraph
from repro.core.interference import InterferenceRecorder
from repro.core.runtime import ConcurrencyRuntime, RuntimeConfig
from repro.core.scheduler import (ScheduledOp, ScheduleResult, free_cores,
                                  pick_admissible, remaining_horizon)
from repro.core.simmachine import Placement, SimMachine
from repro.multitenant.job import Job, JobQueue, fairness_index, jain
from repro.multitenant.plancache import PlanCache

NodeKey = tuple[int, int]           # (jid, uid)


@dataclasses.dataclass
class PoolConfig:
    """Pool-level knobs (admission + fallback), composed with the per-job
    ``RuntimeConfig`` so every profiling/strategy knob lives in exactly
    one place and the pool's delegated runtimes see the same settings."""

    max_active: int = 3             # admission: concurrent tenants
    max_outstanding_demand: float | None = None   # admission: core-seconds
    min_fallback_cores: int = 4
    fallback_slack: float = 1.25
    runtime: RuntimeConfig = dataclasses.field(default_factory=RuntimeConfig)


class _PoolSim:
    """Discrete-event state over many graphs — the multi-tenant EventSim.

    Same launch/complete/event conventions as ``core.scheduler.EventSim``
    but nodes are ``(jid, uid)`` and each job keeps its own pending/ready
    frontier so per-job dependency tracking never crosses tenants."""

    def __init__(self) -> None:
        self.clock = 0.0
        self.graphs: dict[int, OpGraph] = {}
        self.pending: dict[int, dict[int, int]] = {}
        self.ready: dict[int, list[int]] = {}       # jid -> ready uids
        self.heap: list[tuple[float, int, NodeKey]] = []
        self.running: dict[NodeKey, ScheduledOp] = {}
        self.records: dict[int, list[ScheduledOp]] = {}
        self.events: list[tuple[float, int]] = []
        self._seq = itertools.count()

    def admit(self, job: Job) -> None:
        g = job.graph
        self.graphs[job.jid] = g
        self.pending[job.jid] = {u: len(op.deps) for u, op in g.ops.items()}
        self.ready[job.jid] = sorted(g.sources())
        self.records[job.jid] = []

    def op(self, key: NodeKey) -> Op:
        return self.graphs[key[0]].ops[key[1]]

    def ready_keys(self) -> list[NodeKey]:
        return [(jid, uid) for jid, uids in self.ready.items()
                for uid in uids]

    def launch(self, key: NodeKey, sched: ScheduledOp) -> None:
        self.ready[key[0]].remove(key[1])
        self.running[key] = sched
        heapq.heappush(self.heap, (sched.finish, next(self._seq), key))
        self.events.append((self.clock, len(self.running)))

    def complete_next(self) -> tuple[int, ScheduledOp]:
        finish, _, key = heapq.heappop(self.heap)
        self.clock = finish
        jid, uid = key
        sched = self.running.pop(key)
        self.records[jid].append(sched)
        for c in self.graphs[jid].consumers(uid):
            self.pending[jid][c] -= 1
            if self.pending[jid][c] == 0:
                self.ready[jid].append(c)
        self.events.append((self.clock, len(self.running)))
        return jid, sched

    def job_done(self, jid: int) -> bool:
        return (not self.ready[jid]
                and not any(k[0] == jid for k in self.running))

    @property
    def any_ready(self) -> bool:
        return any(self.ready.values())


@dataclasses.dataclass
class PoolResult:
    makespan: float
    jobs: list[Job]
    records: dict[int, list[ScheduledOp]]      # jid -> per-op records
    events: list[tuple[float, int]]            # (time, #co-running)
    cache_stats: dict[str, float]

    @property
    def total_ops(self) -> int:
        return sum(len(r) for r in self.records.values())

    @property
    def aggregate_throughput(self) -> float:
        """Ops completed per second across all tenants."""
        return self.total_ops / max(self.makespan, 1e-12)

    @property
    def fairness(self) -> float:
        return fairness_index(self.jobs)

    def slowdown_fairness(self, solo_makespans: dict[int, float]) -> float:
        """Jain index over per-job slowdown (pool latency / makespan the
        job would have alone).  Unlike cumulative-service ``fairness``,
        this measures what the scheduler DID: a tenant starved for most of
        the run carries a large slowdown and drags the index toward 1/n."""
        return jain([j.latency / max(solo_makespans[j.jid], 1e-12)
                     for j in self.jobs
                     if j.done and j.jid in solo_makespans])

    @property
    def mean_latency(self) -> float:
        done = [j for j in self.jobs if j.done]
        return sum(j.latency for j in done) / max(len(done), 1)

    def per_job_schedule(self, jid: int) -> ScheduleResult:
        """One job's records in the single-graph result type (global
        timestamps), so existing analysis/plot helpers apply unchanged.
        The events timeline is rebuilt from THIS job's records — the
        pool-wide timeline would misreport the job's own concurrency."""
        recs = self.records[jid]
        deltas = sorted([(r.start, 1) for r in recs]
                        + [(r.finish, -1) for r in recs])
        events: list[tuple[float, int]] = []
        n = 0
        for t, d in deltas:
            n += d
            events.append((t, n))
        return ScheduleResult(
            makespan=max((r.finish for r in recs), default=0.0),
            records=recs, events=events)


class PoolScheduler:
    """Strategy 3/4 admission generalized to a multi-job ready frontier."""

    def __init__(self, machine: SimMachine, config: PoolConfig, *,
                 recorder: InterferenceRecorder):
        self.machine = machine
        self.config = config
        self.recorder = recorder
        self.cores = machine.spec.cores

    # ---- shared helpers (job-aware versions of CorunScheduler's) -------
    def _free_cores(self, sim: _PoolSim) -> int:
        return free_cores(sim.running.values(), self.cores)

    def _instance_plan(self, job: Job, op: Op) -> OpPlan:
        assert job.plan is not None and job.controller is not None
        base = job.plan.plan_for(op, strategy2=self.config.runtime.strategy2)
        curve = job.controller.store.curve(op)
        return OpPlan(base.threads, base.variant,
                      curve.predict(base.threads, base.variant))

    def _duration(self, op: Op, plan: OpPlan, hyper: bool,
                  sim: _PoolSim) -> float:
        pl = Placement(plan.threads, cache_sharing=plan.variant,
                       hyper_thread=hyper)
        share = self.machine.corun_bw_share(
            plan.threads, (r.threads for r in sim.running.values()))
        return self.machine.op_time(op, pl, bw_share=share)

    def _launch(self, sim: _PoolSim, job: Job, uid: int, plan: OpPlan,
                hyper: bool) -> None:
        op = sim.graphs[job.jid].ops[uid]
        dur = self._duration(op, plan, hyper, sim)
        sched = ScheduledOp(op=op, threads=plan.threads, variant=plan.variant,
                            hyper=hyper, start=sim.clock,
                            finish=sim.clock + dur,
                            predicted=plan.predicted_time)
        # cross-job interference bookkeeping, same class-pair key as the
        # single-graph scheduler (the machine doesn't care who launched)
        for other in sim.running.values():
            self.recorder.record(op.op_class, other.op.op_class,
                                 plan.predicted_time, dur)
        sim.launch((job.jid, uid), sched)
        # weighted fair share: charge core-seconds at launch time
        eff = (self.machine.spec.hyper_thread_efficiency if hyper else 1.0)
        job.service += plan.threads * dur * eff

    def _jobs_by_share(self, active: list[Job], sim: _PoolSim) -> list[Job]:
        """Jobs owed service first; only jobs with ready ops."""
        return sorted((j for j in active if sim.ready[j.jid]),
                      key=lambda j: (j.virtual_time, j.jid))

    # ---- Strategy 3 across jobs ---------------------------------------
    def try_corun(self, sim: _PoolSim, active: list[Job]) -> bool:
        free = self._free_cores(sim)
        if free <= 0 or not sim.any_ready:
            return False
        running_classes = [r.op.op_class for r in sim.running.values()]
        horizon = remaining_horizon(sim.running.values(), sim.clock)
        for job in self._jobs_by_share(active, sim):
            assert job.controller is not None and job.plan is not None
            order = sorted(
                sim.ready[job.jid],
                key=lambda u: -self._instance_plan(
                    job, sim.graphs[job.jid].ops[u]).predicted_time)
            for uid in order:
                op = sim.graphs[job.jid].ops[uid]
                if not self.recorder.compatible(op.op_class, running_classes):
                    continue
                cands = job.controller.candidates_for(
                    op, self.config.runtime.candidates)
                pick = pick_admissible(cands, free, horizon)
                if pick is None:
                    continue
                pick = job.plan.clamp(op, pick)     # job-aware S2 clamp
                if pick.threads > free:
                    continue
                self._launch(sim, job, uid, pick, hyper=False)
                return True
        return False

    # ---- fallback: biggest ready op, most-owed job first ----------------
    def run_biggest(self, sim: _PoolSim, active: list[Job]) -> bool:
        free = self._free_cores(sim)
        if free <= 0 or not sim.any_ready:
            return False
        if sim.running and free < self.config.min_fallback_cores:
            return False
        horizon = (remaining_horizon(sim.running.values(), sim.clock)
                   if sim.running else float("inf"))
        # unlike the single-graph fallback there are other tenants to try:
        # if the most-owed job's biggest op would outlast the running set,
        # a later job's op may still fit — don't idle the cores over it
        for job in self._jobs_by_share(active, sim):
            uid = max(sim.ready[job.jid],
                      key=lambda u: self._instance_plan(
                          job, sim.graphs[job.jid].ops[u]).predicted_time)
            op = sim.graphs[job.jid].ops[uid]
            plan = self._instance_plan(job, op)
            if plan.threads > free:
                assert job.controller is not None
                plan = OpPlan(free, plan.variant,
                              job.controller.store.curve(op).predict(
                                  free, plan.variant))
            if plan.predicted_time > horizon * self.config.fallback_slack:
                continue
            self._launch(sim, job, uid, plan, hyper=False)
            return True
        return False

    # ---- Strategy 4 across jobs ---------------------------------------
    def try_hyper(self, sim: _PoolSim, active: list[Job]) -> bool:
        if not self.config.runtime.enable_s4 or not sim.any_ready:
            return False
        if self._free_cores(sim) > 0:
            return False
        ht_running = sum(1 for r in sim.running.values() if r.hyper)
        if ht_running >= self.config.runtime.max_ht_corunners:
            return False
        running_classes = [r.op.op_class for r in sim.running.values()]
        by_jid = {j.jid: j for j in active}

        def serial_time(key: NodeKey) -> tuple[float, float, int, int]:
            job = by_jid[key[0]]
            assert job.controller is not None
            op = sim.op(key)
            return (job.controller.store.curve(op).predict(1, False),
                    job.virtual_time, key[0], key[1])

        for key in sorted(sim.ready_keys(), key=serial_time):
            job = by_jid[key[0]]
            op = sim.op(key)
            if not self.recorder.compatible(op.op_class, running_classes):
                continue
            inst = self._instance_plan(job, op)
            plan = OpPlan(min(inst.threads, self.cores), inst.variant,
                          inst.predicted_time)
            self._launch(sim, job, key[1], plan, hyper=True)
            return True
        return False


@dataclasses.dataclass
class SerialResult:
    """The run-one-graph-at-a-time baseline over the same job mix."""

    makespan: float
    job_makespans: dict[int, float]
    job_latencies: dict[int, float]
    total_ops: int
    profiling_probes: int

    @property
    def aggregate_throughput(self) -> float:
        return self.total_ops / max(self.makespan, 1e-12)


class RuntimePool:
    """Admission + pool scheduling driver (the multi-tenant Fig-2 loop)."""

    def __init__(self, machine: SimMachine | None = None,
                 config: PoolConfig | None = None,
                 plan_cache: PlanCache | None = None):
        self.machine = machine or SimMachine()
        self.config = config or PoolConfig()
        self.plan_cache = plan_cache if plan_cache is not None else PlanCache()
        self.recorder = InterferenceRecorder(
            threshold=self.config.runtime.interference_threshold)
        self.queue = JobQueue(
            max_active=self.config.max_active,
            max_outstanding_demand=self.config.max_outstanding_demand)
        self.scheduler = PoolScheduler(self.machine, self.config,
                                       recorder=self.recorder)
        self.jobs: list[Job] = []
        self._jid = itertools.count()

    # ---- profiling (amortized through the shared PlanCache) ------------
    def _profile_job(self, job: Job, cache: PlanCache | None) -> None:
        # one profiling pipeline for both the pool and the per-step
        # runtime: delegate to ConcurrencyRuntime.profile (which also
        # binds the cache to this machine)
        rt = ConcurrencyRuntime(machine=self.machine,
                                config=self.config.runtime,
                                plan_cache=cache)
        rt.profile(job.graph)
        assert rt.controller is not None and rt.plan is not None
        job.controller = rt.controller
        job.plan = rt.plan
        # predicted demand in core-seconds — the admission/fair-share
        # currency (perfmodel predictions, not measurements)
        demand = 0.0
        for op in job.graph.ops.values():
            p = job.plan.per_instance[op.size_key]
            demand += p.predicted_time * p.threads
        job.demand = demand

    # ---- public API -----------------------------------------------------
    def submit(self, graph: OpGraph, *, priority: float = 1.0,
               name: str | None = None, submit_time: float = 0.0) -> Job:
        job = Job(jid=next(self._jid), name=name or graph.name, graph=graph,
                  priority=priority, submit_time=submit_time)
        self._profile_job(job, self.plan_cache)
        self.jobs.append(job)
        self.queue.submit(job)
        return job

    def _admit(self, sim: _PoolSim, active: list[Job]) -> None:
        while True:
            job = self.queue.pop_admissible(active, now=sim.clock)
            if job is None:
                return
            job.admit_time = sim.clock
            sim.admit(job)
            if not sim.ready[job.jid]:      # zero-op graph: done on arrival
                job.finish_time = sim.clock
                continue
            active.append(job)

    def run(self) -> PoolResult:
        sim = _PoolSim()
        active: list[Job] = []
        sched = self.scheduler
        self._admit(sim, active)
        while active or len(self.queue):
            if not active:
                # idle until the next tenant arrives
                nxt = self.queue.next_arrival(sim.clock)
                assert nxt is not None, "queued jobs but none admissible"
                sim.clock = nxt
                self._admit(sim, active)
                continue
            launched = True
            while launched:
                launched = False
                # same strategy gating as CorunScheduler.run: S3 off means
                # serial launches only (the serial baseline honors the
                # flag too, so comparisons stay apples-to-apples)
                if self.config.runtime.enable_s3:
                    if sim.running:
                        launched = sched.try_corun(sim, active)
                        if not launched:
                            launched = sched.run_biggest(sim, active)
                    else:
                        launched = sched.run_biggest(sim, active)
                elif not sim.running:
                    launched = sched.run_biggest(sim, active)
                if not launched:
                    launched = sched.try_hyper(sim, active)
            if sim.running:
                # a tenant arriving before the next op completes must not
                # wait out that op: advance to the arrival, admit, and
                # go back to launching on whatever cores are idle
                nxt = (self.queue.next_arrival(sim.clock)
                       if len(self.queue) else None)
                if (nxt is not None and nxt < sim.heap[0][0]
                        and len(active) < self.config.max_active):
                    sim.clock = nxt
                    self._admit(sim, active)
                    continue
                jid, _ = sim.complete_next()
                job = next(j for j in active if j.jid == jid)
                job.ops_done += 1
                if sim.job_done(jid):
                    job.finish_time = sim.clock
                    active.remove(job)
                self._admit(sim, active)
        return PoolResult(makespan=sim.clock, jobs=list(self.jobs),
                          records=sim.records, events=sim.events,
                          cache_stats=self.plan_cache.stats())

    # ---- baseline -------------------------------------------------------
    def run_serial(self, *, share_cache: bool = False) -> SerialResult:
        """The same job mix, one graph at a time (fresh jobs, fresh
        profiling): the single-tenant status quo the pool competes with.

        The baseline is deliberately priority-BLIND: it executes in plain
        arrival order (FIFO), because the status quo it models — a
        runtime that owns the whole machine per job — has no admission
        tier at all.  Priority-aware queueing is itself a pool feature,
        so latency comparisons against this baseline credit the pool for
        both co-scheduling and priority scheduling.

        ``share_cache=False`` means each job pays its own profiling probes
        — isolating both pool advantages (co-scheduling AND probe
        amortization) in the benchmark comparison."""
        cache = PlanCache() if share_cache else None
        clock = 0.0
        job_makespans: dict[int, float] = {}
        job_latencies: dict[int, float] = {}
        total_ops = 0
        probes = 0
        for job in sorted(self.jobs, key=lambda j: (j.submit_time, j.jid)):
            rt = ConcurrencyRuntime(machine=self.machine,
                                    config=self.config.runtime,
                                    plan_cache=cache)
            rt.profile(job.graph)
            assert rt.store is not None
            probes += rt.store.total_probes
            res = rt.execute_step(job.graph)
            clock = max(clock, job.submit_time) + res.makespan
            job_makespans[job.jid] = res.makespan
            job_latencies[job.jid] = clock - job.submit_time
            total_ops += len(res.records)
        return SerialResult(makespan=clock, job_makespans=job_makespans,
                            job_latencies=job_latencies, total_ops=total_ops,
                            profiling_probes=probes)
