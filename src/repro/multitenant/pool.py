"""Runtime pool: co-schedule many op graphs on one simulated machine.

This generalizes the paper's runtime from *one step graph* to *many
tenants*.  The Strategy-2/3/4 decision RULES are not re-implemented here:
they live once in ``repro.core.strategy.StrategyCore`` (shared with the
single-graph ``CorunScheduler``), and ``PoolScheduler`` is the multi-job
adapter over them — its ``_PoolAdapter`` injects the job-aware pieces:
the candidate source draws ready ops from every admitted job's frontier
(tenants ordered by weighted fair share), the Strategy-2 clamp applies
each op's **own job's** frozen plan, Strategy 4's hyper-thread lane picks
the globally smallest ready op, and the interference blacklist spans
co-runners from different jobs (a class pair that thrashes MCDRAM thrashes
it regardless of which tenant launched each side).  A single-job pool
therefore reproduces ``CorunScheduler`` timelines exactly — enforced by
``repro.multitenant.parity`` and ``tests/test_strategy_differential.py``.

Cross-job decisions need a currency; following value-function schedulers
(Steiner et al.) we use the ``perfmodel`` predictions behind each job's
plan: a job's *demand* is its predicted core-seconds, its *service* the
core-seconds actually granted, and the pool always prefers the job with
the smallest priority-weighted service (weighted fair share).  Service is
charged at launch so the share is responsive within one scheduling
instant; hyper-thread launches are charged at the machine's hyper-thread
efficiency (they borrow spare lanes, not whole cores).

Every prediction flows through each job's closed-loop ``PlanStore``
(``repro.core.planstore``): with ``feedback="off"`` (default) that is
the frozen profiling-time plan, bit-for-bit the pre-feedback pool; with
``feedback="ewma"`` the pool's launch/finish/revoke observations blend
back into one pool-wide ``CorrectionTable`` and ``Job.demand``/``Job.cp``
are re-derived as ops complete (and re-priced for waiting jobs before
every admission decision), so the admission cap and deadline slack track
observed reality when profiles mispredict.

Deadlines ride on top of fair share: a job may carry an absolute
``deadline``, priced into per-node slack via its frozen-plan critical
path (``Job.cp``).  The pool adds slack-expiry wakeups to the event loop
and, when ``PoolConfig.preemption`` is enabled, the shared core's
``try_preempt`` path may revoke the longest-remaining running op for an
overdue tenant (``_PoolSim.revoke``: the victim node returns to its ready
frontier, its partial run is recorded in ``preempted``, and its service
is re-billed at the machine's restart-waste factor).  With preemption off
AND no deadlines — the defaults — every timeline is bit-for-bit the PR-2
pool's; deadlines alone already reorder scheduling (EDF admission, the
slack-scaled fair-share key), preemption additionally revokes.

``RuntimePool`` is the driver: submit jobs (graph + priority + arrival
time + optional deadline), run, get a ``PoolResult`` with per-job
latency, fairness, preemption, and plan-cache amortization stats.
``RuntimePool.run_serial`` replays the same job mix one graph at a time —
the baseline the multitenant benchmarks compare against.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Iterator, Mapping, Sequence

from repro.core.concurrency import OpPlan
from repro.core.graph import Op, OpGraph, RegionEvent
from repro.core.interference import InterferenceRecorder
from repro.core.perfmodel import cross_graph_key
from repro.core.planstore import (OBS_FINISH, OBS_REVOKE, CorrectionTable,
                                  OpObservation, TripCountEstimator,
                                  make_plan_store)
from repro.obs.metrics import pool_metrics
from repro.obs.trace import (FAM_ADMISSION, FAM_PLANSTORE, FAM_PREEMPTION,
                             FAM_REGION, FAM_STRATEGY, NULL_SINK, TraceEvent,
                             TraceSink)
from repro.core.runtime import ConcurrencyRuntime, RuntimeConfig
from repro.core.simmachine import SimMachine
from repro.core.strategy import (CONFIG_SCHEMA_VERSION, PreemptionPolicy,
                                 ScheduledOp, ScheduleResult,
                                 StrategyAdapter, StrategyConfig,
                                 StrategyCore, _check_config_dict,
                                 fold_deprecated_strategy_kwargs)
from repro.multitenant.job import Job, JobQueue, fairness_index, jain
from repro.multitenant.plancache import PlanCache

NodeKey = tuple[int, int]           # (jid, uid)


@dataclasses.dataclass(init=False)
class PoolConfig:
    """Pool-level knobs (admission + reservation), composed with the
    per-job ``RuntimeConfig`` so every profiling/strategy knob lives in
    exactly one place and the pool's delegated runtimes see the same
    settings.

    Strategy-owned knobs (preemption, topology, feedback, fallback
    floors, sink) are NOT re-declared here: set them on
    ``runtime.strategy``, or pass ``strategy=StrategyConfig(...)`` to
    give the POOL a deliberately different policy than its per-job
    runtimes (``strategy=None`` inherits ``runtime``'s).  The old flat
    kwargs (``PoolConfig(preemption=..., feedback="ewma")``) keep
    working with a DeprecationWarning — non-None ones fold onto the
    pool's strategy view, preserving the old override-only-when-set
    semantics."""

    max_active: int = 3             # admission: concurrent tenants
    max_outstanding_demand: float | None = None   # admission: core-seconds
    # hold the last active slot for a strictly-higher-priority deadlined
    # arrival due within this many seconds (0 = no reservation)
    reservation_window: float = 0.0
    runtime: RuntimeConfig = dataclasses.field(default_factory=RuntimeConfig)
    # pool-level strategy override; None = inherit runtime.strategy
    strategy: StrategyConfig | None = None

    def __init__(self, max_active: int = 3,
                 max_outstanding_demand: float | None = None,
                 reservation_window: float = 0.0,
                 runtime: RuntimeConfig | None = None,
                 strategy: StrategyConfig | None = None, **deprecated):
        self.max_active = max_active
        self.max_outstanding_demand = max_outstanding_demand
        self.reservation_window = reservation_window
        self.runtime = runtime if runtime is not None else RuntimeConfig()
        unknown = sorted(set(deprecated)
                         - {f.name for f in
                            dataclasses.fields(StrategyConfig)})
        if unknown:
            raise TypeError(
                f"PoolConfig() got unexpected keyword arguments {unknown}")
        # the old flat kwargs defaulted to None = "inherit from runtime":
        # only explicitly-set ones override, so drop Nones before folding
        overrides = {k: v for k, v in deprecated.items() if v is not None}
        if overrides:
            base = (strategy if strategy is not None
                    else self.runtime.strategy_config())
            strategy = fold_deprecated_strategy_kwargs(
                type(self).__name__, base, overrides)
        self.strategy = strategy

    def strategy_config(self) -> StrategyConfig:
        """Same StrategyConfig RuntimeConfig.strategy_config returns —
        one shared core, one knob set, no drift: a single-job pool stays
        bit-identical to CorunScheduler for ANY RuntimeConfig.  A
        pool-level ``strategy`` applies only when explicitly set."""
        if self.strategy is not None:
            return self.strategy
        return self.runtime.strategy_config()

    def to_dict(self) -> dict:
        """Versioned JSON form — what the service daemon persists in its
        job store and what the CLI accepts, one serialization for all
        three layers."""
        return {"schema": CONFIG_SCHEMA_VERSION,
                "max_active": self.max_active,
                "max_outstanding_demand": self.max_outstanding_demand,
                "reservation_window": self.reservation_window,
                "runtime": self.runtime.to_dict(),
                "strategy": (None if self.strategy is None
                             else self.strategy.to_dict())}

    @classmethod
    def from_dict(cls, d) -> "PoolConfig":
        d = dict(d)
        rt, strat = d.pop("runtime", None), d.pop("strategy", None)
        kw = _check_config_dict(
            cls.__name__, d,
            {"max_active", "max_outstanding_demand", "reservation_window"})
        if rt is not None:
            kw["runtime"] = RuntimeConfig.from_dict(rt)
        if strat is not None:
            kw["strategy"] = StrategyConfig.from_dict(strat)
        return cls(**kw)


class PoolObserver:
    """Execution-backend hook points on the pool's discrete-event loop.

    The service daemon attaches one to mirror sim decisions onto REAL
    payload execution: a launch submits the op's payload to the shared
    worker set, a revoke cancels the payload future before it starts,
    and a completion collects it.  Every method is a no-op by default
    and the pool never behaves differently for having an observer — the
    sim timeline stays bit-for-bit the unobserved one (the observer
    sees decisions; it does not make them)."""

    def on_launch(self, key: NodeKey, sched: ScheduledOp) -> None:
        pass

    def on_revoke(self, key: NodeKey, sched: ScheduledOp) -> None:
        pass

    def on_complete(self, key: NodeKey, sched: ScheduledOp) -> None:
        pass


class _PoolSim:
    """Discrete-event state over many graphs — the multi-tenant EventSim.

    Same launch/complete/event conventions as ``core.scheduler.EventSim``
    but nodes are ``(jid, uid)`` and each job keeps its own pending/ready
    frontier so per-job dependency tracking never crosses tenants."""

    def __init__(self) -> None:
        self.clock = 0.0
        self.observer: PoolObserver | None = None
        self.graphs: dict[int, OpGraph] = {}
        self.jobs: dict[int, Job] = {}              # jid -> admitted job
        self.pending: dict[int, dict[int, int]] = {}
        self.ready: dict[int, list[int]] = {}       # jid -> ready uids
        self.heap: list[tuple[float, int, NodeKey]] = []
        self.running: dict[NodeKey, ScheduledOp] = {}
        self.records: dict[int, list[ScheduledOp]] = {}
        # jid -> completed uids (maintained incrementally: the feedback
        # path re-derives remaining demand/critical-paths on every
        # completion and must not rebuild this set from records each time)
        self.completed: dict[int, set[int]] = {}
        # jid -> partial runs cut short by preemption (finish = revoke
        # time); kept OUT of ``records`` so "every op exactly once"
        # invariants keep holding on the completed timeline
        self.preempted: dict[int, list[ScheduledOp]] = {}
        self.events: list[tuple[float, int]] = []
        # (jid, RegionEvent) shape changes not yet reported to the pool
        # driver (which traces them, feeds trip-count learning, and
        # re-prices the job); empty for every static graph
        self.region_events: list[tuple[int, RegionEvent]] = []
        self._seq = itertools.count()
        self._live_seq: dict[NodeKey, int] = {}     # key -> heap entry seq
        self._cancelled: set[int] = set()           # revoked heap seqs

    def admit(self, job: Job) -> None:
        g = job.graph
        self.graphs[job.jid] = g
        self.jobs[job.jid] = job
        # restore dynamic graphs to their initial shape (entry-free
        # regions expand now, BEFORE the frontier is derived); a no-op []
        # on static graphs
        for ev in g.reset():
            self.region_events.append((job.jid, ev))
        self.pending[job.jid] = {u: len(op.deps) for u, op in g.ops.items()}
        self.ready[job.jid] = sorted(g.sources())
        self.records[job.jid] = []
        self.completed[job.jid] = set()
        self.preempted[job.jid] = []

    def op(self, key: NodeKey) -> Op:
        return self.graphs[key[0]].ops[key[1]]

    def ready_keys(self) -> list[NodeKey]:
        return [(jid, uid) for jid, uids in self.ready.items()
                for uid in uids]

    def launch(self, key: NodeKey, sched: ScheduledOp) -> None:
        self.ready[key[0]].remove(key[1])
        self.running[key] = sched
        seq = next(self._seq)
        self._live_seq[key] = seq
        heapq.heappush(self.heap, (sched.finish, seq, key))
        self.events.append((self.clock, len(self.running)))
        if self.observer is not None:
            self.observer.on_launch(key, sched)

    def revoke(self, key: NodeKey) -> ScheduledOp:
        """Preempt a running launch: the node goes back to its job's ready
        frontier (exactly once — it is no longer running, so no other path
        can return it again) and the heap entry is lazily cancelled.
        Under quadrant topology the victim's core set is released at this
        instant by construction: placement derives occupancy from the
        running set, which no longer contains the victim (its partial
        record in ``preempted`` keeps the cores for occupancy audits)."""
        sched = self.running.pop(key)
        self._cancelled.add(self._live_seq.pop(key))
        self.ready[key[0]].append(key[1])
        self.preempted[key[0]].append(
            dataclasses.replace(sched, finish=self.clock))
        self.jobs[key[0]].preemptions += 1
        self.events.append((self.clock, len(self.running)))
        if self.observer is not None:
            self.observer.on_revoke(key, sched)
        return sched

    def next_finish(self) -> float | None:
        """Earliest live completion time (revoked heap entries skipped)."""
        while self.heap and self.heap[0][1] in self._cancelled:
            self._cancelled.discard(self.heap[0][1])
            heapq.heappop(self.heap)
        return self.heap[0][0] if self.heap else None

    def complete_next(self) -> tuple[int, ScheduledOp]:
        # prune revoked entries unconditionally — the heap head must be a
        # LIVE launch before popping (an assert would be stripped by -O)
        if self.next_finish() is None:
            raise RuntimeError("complete_next on an empty/revoked heap")
        finish, _, key = heapq.heappop(self.heap)
        self.clock = finish
        jid, uid = key
        self._live_seq.pop(key, None)
        sched = self.running.pop(key)
        self.records[jid].append(sched)
        self.completed[jid].add(uid)
        for c in self.graphs[jid].consumers(uid):
            self.pending[jid][c] -= 1
            if self.pending[jid][c] == 0:
                self.ready[jid].append(c)
        # dynamic graphs may materialize ops at this instant (next loop
        # iteration, taken branch, region exit); absorb them into the
        # job's frontier — their gate deps are already complete, so no
        # consumer decrement will ever arrive for those edges
        for ev in self.graphs[jid].advance(uid, self.completed[jid]):
            self.region_events.append((jid, ev))
            for u in ev.new_uids:
                op = self.graphs[jid].ops[u]
                n = sum(1 for d in op.deps
                        if d not in self.completed[jid])
                self.pending[jid][u] = n
                if n == 0:
                    self.ready[jid].append(u)
        self.events.append((self.clock, len(self.running)))
        if self.observer is not None:
            self.observer.on_complete(key, sched)
        return jid, sched

    def drop_job(self, jid: int) -> list[ScheduledOp]:
        """Remove one tenant from the event loop (job cancellation).

        Running launches are lazily cancelled exactly like ``revoke`` —
        the observer's ``on_revoke`` fires so a payload backend cancels
        the futures — but they do NOT count as preemptions or return to
        a ready frontier: the tenant is leaving, not restarting.  The
        job's completed records/partials stay behind for accounting (the
        work really ran); only its scheduling state goes.  Launch-time
        service charges stay on the cancelled tenant's ledger — the pool
        priced those cores out to it, and a cancel does not retroactively
        make them free."""
        dropped = []
        for key in [k for k in self.running if k[0] == jid]:
            sched = self.running.pop(key)
            self._cancelled.add(self._live_seq.pop(key))
            dropped.append(sched)
            if self.observer is not None:
                self.observer.on_revoke(key, sched)
        for d in (self.graphs, self.jobs, self.pending, self.ready):
            d.pop(jid, None)
        self.events.append((self.clock, len(self.running)))
        return dropped

    def job_done(self, jid: int) -> bool:
        return (not self.ready[jid]
                and not any(k[0] == jid for k in self.running))

    @property
    def any_ready(self) -> bool:
        return any(self.ready.values())


@dataclasses.dataclass
class PoolResult:
    makespan: float
    jobs: list[Job]
    records: dict[int, list[ScheduledOp]]      # jid -> per-op records
    events: list[tuple[float, int]]            # (time, #co-running)
    cache_stats: dict[str, float]
    # jid -> partial runs cut short by preemption (finish = revoke time)
    preempted: dict[int, list[ScheduledOp]] = dataclasses.field(
        default_factory=dict)
    # CorrectionTable.stats() of the pool's shared EWMA state (None when
    # the pool ran with feedback="off")
    feedback_stats: dict[str, float] | None = None
    # dynamic-control-flow shape changes during the run (0 on every
    # static mix): while-iterations materialized / regions resolved
    n_region_expands: int = 0
    n_region_resolves: int = 0
    # flat metric snapshot of the run (repro.obs.metrics.pool_metrics):
    # the one accounting surface benches/CLI consume instead of each
    # re-deriving its own sums from records
    metrics: dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def total_ops(self) -> int:
        return sum(len(r) for r in self.records.values())

    @property
    def n_preemptions(self) -> int:
        return sum(len(r) for r in self.preempted.values())

    @property
    def n_evictions(self) -> int:
        """Admission-level evictions across all jobs (free moves — the
        evicted tenant had no launched ops, so no restart waste)."""
        return sum(j.evictions for j in self.jobs)

    @property
    def n_migrations(self) -> int:
        """Width migrations across all jobs (these launches also appear in
        ``preempted`` as partial records — the sim bills them the same
        way — but they were immediately relaunched at a new width)."""
        return sum(j.migrations for j in self.jobs)

    @property
    def aggregate_throughput(self) -> float:
        """Ops completed per second across all tenants."""
        return self.total_ops / max(self.makespan, 1e-12)

    @property
    def fairness(self) -> float:
        return fairness_index(self.jobs)

    def slowdowns(self, solo_makespans: dict[int, float], *,
                  include_queue_wait: bool = True) -> list[float]:
        """Per-finished-job slowdown vs running alone.

        ``include_queue_wait=True`` divides submit-to-finish latency by the
        solo makespan — the tenant's end-to-end view, which charges the
        scheduler for admission delay too.  ``False`` divides admit-to-
        finish, isolating what the SCHEDULER did to the job once it was
        actually inside the pool: a job that merely sat in the admission
        queue is not evidence of unfair scheduling."""
        out = []
        for j in self.jobs:
            if not j.done or j.jid not in solo_makespans:
                continue
            lat = j.latency if include_queue_wait else j.run_latency
            if lat is None:
                continue
            out.append(lat / max(solo_makespans[j.jid], 1e-12))
        return out

    def slowdown_fairness(self, solo_makespans: dict[int, float], *,
                          include_queue_wait: bool = True) -> float:
        """Jain index over per-job slowdown (pool latency / makespan the
        job would have alone).  Unlike cumulative-service ``fairness``,
        this measures what the scheduler DID: a tenant starved for most of
        the run carries a large slowdown and drags the index toward 1/n.
        Report the queue-inclusive and admit-to-finish variants side by
        side (``include_queue_wait``): a gap between them localizes the
        unfairness to the admission tier rather than the core scheduler."""
        return jain(self.slowdowns(solo_makespans,
                                   include_queue_wait=include_queue_wait))

    @property
    def mean_latency(self) -> float:
        """Mean submit-to-finish latency over FINISHED jobs, or NaN when
        no job finished — a run where nothing completed must not report
        the same 0.0 as a perfect one (NaN also poisons any aggregate
        a bench builds from it, so the failure can't hide)."""
        lats = [j.latency for j in self.jobs if j.latency is not None]
        if not lats:
            return float("nan")
        return sum(lats) / len(lats)

    def per_job_schedule(self, jid: int) -> ScheduleResult:
        """One job's records in the single-graph result type (global
        timestamps), so existing analysis/plot helpers apply unchanged.
        The events timeline is rebuilt from THIS job's records — the
        pool-wide timeline would misreport the job's own concurrency."""
        recs = self.records[jid]
        deltas = sorted([(r.start, 1) for r in recs]
                        + [(r.finish, -1) for r in recs])
        events: list[tuple[float, int]] = []
        n = 0
        for t, d in deltas:
            n += d
            events.append((t, n))
        return ScheduleResult(
            makespan=max((r.finish for r in recs), default=0.0),
            records=recs, events=events)


class _PoolAdapter(StrategyAdapter):
    """Multi-job view for ``StrategyCore``: node keys are ``(jid, uid)``,
    the candidate source yields one ready group per admitted job —
    most-owed tenant first (weighted fair share) — and every plan lookup
    resolves against the node's OWN job's frozen plan/controller (the
    job-aware Strategy-2 clamp).  ``charge`` implements launch-time
    fair-share accounting; hyper-thread launches are charged at the
    machine's hyper-thread efficiency (they borrow spare lanes, not whole
    cores)."""

    def __init__(self, sim: _PoolSim, machine: SimMachine, *,
                 strategy2: bool, sink: TraceSink = NULL_SINK):
        self.sim = sim
        self.machine = machine
        self.strategy2 = strategy2
        self.sink = sink

    @property
    def clock(self) -> float:
        return self.sim.clock

    @property
    def running(self) -> Mapping[NodeKey, ScheduledOp]:
        return self.sim.running

    def _job(self, key: NodeKey) -> Job:
        return self.sim.jobs[key[0]]

    def ready_groups(self) -> list[Sequence[NodeKey]]:
        # jobs owed service first; only jobs with ready ops (a job with a
        # non-empty frontier is necessarily still active).  The ordering
        # key uses the DYNAMIC (slack-scaled) priority, so a tenant whose
        # deadline is approaching drifts toward the front of the line;
        # for deadline-free jobs this is exactly the old static key.
        now = self.sim.clock
        jobs = sorted((j for j in self.sim.jobs.values()
                       if self.sim.ready[j.jid]),
                      key=lambda j: (j.virtual_time_at(now), j.jid))
        return [[(j.jid, u) for u in self.sim.ready[j.jid]] for j in jobs]

    def op(self, key: NodeKey) -> Op:
        return self.sim.op(key)

    def instance_plan(self, key: NodeKey) -> OpPlan:
        job = self._job(key)
        assert job.plan is not None and job.store is not None
        op = self.sim.op(key)
        # the store re-prices the frozen plan's width (corrected under
        # feedback="ewma", verbatim curve prediction under "off")
        return job.store.replan(op, job.plan.plan_for(
            op, strategy2=self.strategy2))

    def candidates_for(self, key: NodeKey, k: int) -> list[OpPlan]:
        job = self._job(key)
        assert job.store is not None
        return job.store.candidates(self.sim.op(key), k)

    def clamp(self, key: NodeKey, proposal: OpPlan) -> OpPlan:
        job = self._job(key)
        assert job.plan is not None
        return job.plan.clamp(self.sim.op(key), proposal)   # job-aware S2

    def predict(self, key: NodeKey, threads: int, variant: bool) -> float:
        job = self._job(key)
        assert job.store is not None
        return job.store.predict(self.sim.op(key), threads, variant)

    def commit(self, key: NodeKey, sched: ScheduledOp) -> None:
        self.sim.launch(key, sched)

    def charge(self, key: NodeKey, sched: ScheduledOp) -> None:
        # weighted fair share: charge core-seconds at launch time
        eff = (self.machine.spec.hyper_thread_efficiency
               if sched.hyper else 1.0)
        job = self._job(key)
        amount = sched.threads * sched.duration * eff
        job.service += amount
        if self.sink.enabled:
            self.sink.emit(TraceEvent(
                ts=self.sim.clock, family=FAM_STRATEGY, kind="charge",
                key=key, data={"jid": job.jid, "job": job.name,
                               "priority": job.priority, "amount": amount,
                               "service": job.service}))
        if sched.cores:
            # tenant-to-quadrant affinity: remember where the job landed
            # (the primary quadrant — placement fills it first) so its
            # next launches prefer the quadrant its working set warms
            job.last_quadrant = self.machine.spec.quadrant_of_core(
                sched.cores[0])

    def placement_hint(self, key: NodeKey) -> int | None:
        return self._job(key).last_quadrant

    # ---- closed-loop observation ----------------------------------------
    def observe(self, key: NodeKey, sched: ScheduledOp, kind: str,
                elapsed: float) -> None:
        """Forward the event to the job's plan store and — when the store
        is adaptive — re-derive the aggregates the pool caches on the Job:
        remaining demand (the admission/fair-share currency tightens or
        relaxes as observations land and ops complete) and per-node
        critical paths (so deadline slack prices REMAINING work at
        observed speeds, not the frozen profiling-time guess — the
        ROADMAP's stale-``Job.cp`` item)."""
        job = self._job(key)
        assert job.store is not None
        job.store.observe(OpObservation(
            op=sched.op, threads=sched.threads, variant=sched.variant,
            hyper=sched.hyper, predicted=sched.predicted,
            observed=elapsed, kind=kind))
        if self.sink.enabled:
            corrections = getattr(job.store, "corrections", None)
            self.sink.emit(TraceEvent(
                ts=self.sim.clock, family=FAM_PLANSTORE, kind=kind,
                key=key,
                data={"op_class": sched.op.op_class,
                      "size_key": sched.op.size_key,
                      "threads": sched.threads, "variant": sched.variant,
                      "hyper": sched.hyper, "predicted": sched.predicted,
                      "observed": elapsed,
                      "correction": (corrections.factor(
                          cross_graph_key(sched.op), sched.threads,
                          sched.variant)
                          if corrections is not None else 1.0)}))
        if job.store.adaptive and kind in (OBS_FINISH, OBS_REVOKE):
            assert job.plan is not None
            done = self.sim.completed[key[0]]
            job.demand = job.store.remaining_demand(job.graph, job.plan,
                                                    done)
            job.cp = job.store.remaining_critical_path(job.graph, job.plan,
                                                       done)

    # ---- deadlines / preemption ----------------------------------------
    def deadline_slack(self, key: NodeKey) -> float | None:
        job = self._job(key)
        if job.deadline is None:
            return None
        # time to the SLO minus the node's predicted downstream critical
        # path: <= 0 means this tenant misses its deadline even if granted
        # cores right now — the preemption trigger
        return job.deadline - self.sim.clock - job.cp.get(key[1], 0.0)

    def revoke(self, key: NodeKey) -> ScheduledOp:
        return self.sim.revoke(key)

    def refund(self, key: NodeKey, sched: ScheduledOp,
               elapsed: float) -> None:
        # reverse the launch-time charge; bill the discarded partial run at
        # the machine's restart-waste factor instead (the victim occupied
        # cores, but the scheduler chose to throw that work away)
        eff = (self.machine.spec.hyper_thread_efficiency
               if sched.hyper else 1.0)
        job = self._job(key)
        refund = sched.threads * sched.duration * eff
        waste = (sched.threads * elapsed * eff
                 * self.machine.spec.restart_waste)
        job.service -= refund
        job.service += waste
        if self.sink.enabled:
            self.sink.emit(TraceEvent(
                ts=self.sim.clock, family=FAM_STRATEGY, kind="refund",
                key=key, data={"jid": job.jid, "job": job.name,
                               "priority": job.priority, "refund": refund,
                               "waste": waste, "elapsed": elapsed}))

    def migrated(self, key: NodeKey, revoked: ScheduledOp) -> None:
        # the sim-level revoke already counted a preemption; migrations
        # get their own per-job counter so reporting can tell a priced
        # width re-seat from an SLO revoke
        self._job(key).migrations += 1


class PoolScheduler:
    """Thin multi-job adapter over ``StrategyCore`` (Strategies 3/4 across
    every admitted job's ready frontier, job-aware S2 clamp, cross-job
    interference blacklist, weighted fair share)."""

    def __init__(self, machine: SimMachine, config: PoolConfig, *,
                 recorder: InterferenceRecorder):
        self.machine = machine
        self.config = config
        self.recorder = recorder
        self.core = StrategyCore(machine, config.strategy_config(),
                                 recorder=recorder)
        self.cores = self.core.cores

    def adapter(self, sim: _PoolSim) -> _PoolAdapter:
        return _PoolAdapter(sim, self.machine,
                            strategy2=self.config.runtime.strategy2,
                            sink=self.core.sink)

    # Strategy entry points kept as the public seam (delegating to the
    # shared core); ``active`` is accepted for compatibility but the ready
    # frontier is derived from the sim's admitted jobs.
    def try_corun(self, sim: _PoolSim,
                  active: list[Job] | None = None) -> bool:
        return self.core.try_corun(self.adapter(sim))

    def run_biggest(self, sim: _PoolSim,
                    active: list[Job] | None = None) -> bool:
        return self.core.run_biggest(self.adapter(sim))

    def try_hyper(self, sim: _PoolSim,
                  active: list[Job] | None = None) -> bool:
        return self.core.try_hyper(self.adapter(sim))


@dataclasses.dataclass
class SerialResult:
    """The run-one-graph-at-a-time baseline over the same job mix."""

    makespan: float
    job_makespans: dict[int, float]
    job_latencies: dict[int, float]
    total_ops: int
    profiling_probes: int

    @property
    def aggregate_throughput(self) -> float:
        return self.total_ops / max(self.makespan, 1e-12)


class RuntimePool:
    """Admission + pool scheduling driver (the multi-tenant Fig-2 loop)."""

    def __init__(self, machine: SimMachine | None = None,
                 config: PoolConfig | None = None,
                 plan_cache: PlanCache | None = None,
                 profile_machine: SimMachine | None = None,
                 corrections: CorrectionTable | None = None,
                 trip_counts: TripCountEstimator | None = None,
                 jid_counter: Iterator[int] | None = None):
        self.machine = machine or SimMachine()
        self.config = config or PoolConfig()
        # profiling may run on a DIFFERENT timing context than execution
        # (stale curves, a drifted machine) — the misprediction scenario
        # the feedback="ewma" store exists to correct.  Default: profile
        # where you execute, the paper's setup.
        self.profile_machine = profile_machine or self.machine
        self.plan_cache = plan_cache if plan_cache is not None else PlanCache()
        self.recorder = InterferenceRecorder(
            threshold=self.config.runtime.interference_threshold)
        self.queue = JobQueue(
            max_active=self.config.max_active,
            max_outstanding_demand=self.config.max_outstanding_demand,
            reservation_window=self.config.reservation_window)
        self.scheduler = PoolScheduler(self.machine, self.config,
                                       recorder=self.recorder)
        # ONE correction table spans every tenant (keyed by the same
        # cross_graph_key the PlanCache shares curves under): an op class
        # one tenant's observations re-estimated is re-estimated for all
        strat = self.config.strategy_config()
        self.feedback = strat.feedback
        self.sink = strat.sink
        self._preemption = strat.preemption
        # seeded tables let a service daemon restart into the learned
        # state it persisted (see repro.service) instead of cold tables
        self.corrections = (
            (corrections if corrections is not None else CorrectionTable())
            if self.feedback != "off" else None)
        # ONE trip-count estimator spans every tenant too (keyed by
        # region key): the second tenant running the same loop starts
        # with the learned trip count instead of its build-time prior
        self.trip_counts = (
            (trip_counts if trip_counts is not None
             else TripCountEstimator())
            if self.feedback != "off" else None)
        # (corrections.observed, trip_counts.observed) at last refresh
        self._refreshed_at = (0, 0)
        # region shape-change counters of the CURRENT run (reset by run())
        self._region_counts = {"expand": 0, "resolve": 0}
        self.jobs: list[Job] = []
        # a ClusterPool passes ONE shared counter to all member pools so
        # jids stay globally unique and a rebalanced job's new jid can
        # never collide with any machine's existing jobs
        self._jid = jid_counter if jid_counter is not None \
            else itertools.count()
        # execution-backend hooks mirrored onto the sim at begin();
        # None = pure simulation, zero overhead
        self.observer: PoolObserver | None = None
        # live lifecycle state (begin()/step()/result()); run() is the
        # one-shot convenience over these
        self._sim: _PoolSim | None = None
        self._active: list[Job] = []
        self._adapter: _PoolAdapter | None = None

    # ---- profiling (amortized through the shared PlanCache) ------------
    def _profile_job(self, job: Job, cache: PlanCache | None) -> None:
        # one profiling pipeline for both the pool and the per-step
        # runtime: delegate to ConcurrencyRuntime.profile (which also
        # binds the cache to this machine)
        rt = ConcurrencyRuntime(machine=self.profile_machine,
                                config=self.config.runtime,
                                plan_cache=cache)
        rt.profile(job.graph)
        assert rt.controller is not None and rt.plan is not None
        job.controller = rt.controller
        job.plan = rt.plan
        # the job's closed-loop plan store: frozen curves under
        # feedback="off", the pool-wide EWMA corrections under "ewma"
        job.store = make_plan_store(self.feedback, rt.controller,
                                    corrections=self.corrections,
                                    trip_counts=self.trip_counts)
        # predicted demand in core-seconds — the admission/fair-share
        # currency — and the per-node remaining-work estimate that prices
        # deadline slack, both DERIVED from the store (so a warm
        # correction table already informs admission of a new tenant)
        job.demand = job.store.remaining_demand(job.graph, job.plan)
        job.cp = job.store.remaining_critical_path(job.graph, job.plan)

    # ---- public API -----------------------------------------------------
    def submit(self, graph: OpGraph, *, priority: float = 1.0,
               name: str | None = None, submit_time: float = 0.0,
               deadline: float | None = None) -> Job:
        """``deadline`` is an ABSOLUTE time (same clock as submit_time);
        serving layers usually compute it as submit_time + latency target
        (see ``ServeEngine.submit_waves_to_pool``)."""
        job = Job(jid=next(self._jid), name=name or graph.name, graph=graph,
                  priority=priority, submit_time=submit_time,
                  deadline=deadline)
        traced = self.sink.enabled
        before = self.plan_cache.stats() if traced else None
        self._profile_job(job, self.plan_cache)
        if traced:
            after = self.plan_cache.stats()
            self.sink.emit(TraceEvent(
                ts=submit_time, family=FAM_PLANSTORE, kind="profile",
                key=job.jid,
                data={"job": job.name, "n_ops": len(graph.ops),
                      "demand": job.demand, "priority": priority,
                      "probes": after["probes_spent"]
                      - before["probes_spent"],
                      "cache_hits": after["hits"] - before["hits"]}))
        self.jobs.append(job)
        self.queue.submit(job)
        # mid-lifecycle submission (the service daemon's path): give the
        # arrival its admission decision at the CURRENT instant, exactly
        # as begin()'s initial pass would have — step()'s idle branch only
        # handles strictly-future arrivals
        if self._sim is not None:
            self._admit(self._sim, self._active)
        return job

    def _refresh_waiting_estimates(self) -> None:
        """Under ``feedback="ewma"``, re-derive every WAITING job's demand
        and critical paths from the shared correction table before an
        admission decision: a job profiled (and priced) before any
        observations landed would otherwise enter admission — and the
        deadline-slack check — with stale submit-time estimates, which is
        exactly the frozen-plan staleness the feedback loop exists to
        fix.  (Active jobs are refreshed by the observe path as their own
        ops complete.)  A no-op with feedback off or nothing observed
        yet, so the default pool is bit-for-bit unchanged; skipped when
        no NEW observation landed since the last refresh (a waiting job's
        estimates can only change through the correction table or —
        since regions resolve at runtime — the trip-count estimator, so
        region-resolution instants count as observations here too)."""
        if self.corrections is None:
            return
        stamp = (self.corrections.observed,
                 self.trip_counts.observed if self.trip_counts else 0)
        if stamp == (0, 0) or stamp == self._refreshed_at:
            return
        self._refreshed_at = stamp
        for job in self.queue.waiting_jobs():
            if job.store is not None and job.plan is not None:
                job.demand = job.store.remaining_demand(job.graph, job.plan)
                job.cp = job.store.remaining_critical_path(job.graph,
                                                           job.plan)

    # ---- admission-level eviction (preemption economics) ----------------
    def _root_slack(self, job: Job, now: float) -> float | None:
        """Whole-job deadline slack: time to the SLO minus the job's
        longest remaining critical path (None = best-effort)."""
        if job.deadline is None:
            return None
        return job.deadline - now - max(job.cp.values(), default=0.0)

    def _try_evict(self, sim: _PoolSim, active: list[Job]) -> bool:
        """The FREE preemption-economics move: return one admitted job
        with NO launched ops to the queue when that unblocks the admission
        of an overdue deadlined waiter.

        Zero restart waste by construction — the victim has no running
        launches, no completed records, and no revoked partials, so there
        is no work to discard and nothing to re-bill; it re-enters the
        queue under its original submit order (``JobQueue.readmit``).
        Tried by ``_admit`` BEFORE the running-work preemption path can
        act for the waiter: a free move always beats a priced one.  The
        victim must be strictly less late than the waiter (best-effort, or
        more slack), so eviction chains terminate and never ping-pong."""
        pol = self._preemption
        if not (pol.enabled and pol.evict_admitted) or not len(self.queue):
            return False
        now = sim.clock
        idle = [j for j in active
                if not sim.records[j.jid] and not sim.preempted[j.jid]
                and not any(k[0] == j.jid for k in sim.running)]
        # least-urgent victim first (lowest dynamic priority, then the
        # most recently admitted — it has the least claim on its slot)
        idle.sort(key=lambda j: (j.effective_priority(now),
                                 -(j.admit_time or 0.0), -j.jid))
        for victim in idle:
            rest = [j for j in active if j.jid != victim.jid]
            waiter = self.queue.peek_admissible(rest, now)
            if waiter is None:
                continue           # evicting this one unblocks nothing
            ws = self._root_slack(waiter, now)
            if ws is None or ws > 0.0:
                continue           # only an overdue SLO tenant justifies it
            vs = self._root_slack(victim, now)
            if vs is not None and vs <= ws:
                continue           # never bounce a tenant just as late
            if self.sink.enabled:
                self.sink.emit(TraceEvent(
                    ts=now, family=FAM_PREEMPTION, kind="evict",
                    key=victim.jid,
                    data={"job": victim.name, "waiter_jid": waiter.jid,
                          "waiter": waiter.name, "waiter_slack": ws,
                          "victim_slack": vs,
                          "queue_depth": len(self.queue)}))
            active.remove(victim)
            for d in (sim.graphs, sim.jobs, sim.pending, sim.ready,
                      sim.records, sim.completed, sim.preempted):
                d.pop(victim.jid, None)
            victim.admit_time = None
            victim.admitted_demand = None
            victim.evictions += 1
            self.queue.readmit(victim)
            return True
        return False

    # ---- dynamic control flow -------------------------------------------
    def _handle_region_events(self, sim: _PoolSim) -> None:
        """Drain the sim's pending region shape changes: trace each one
        (FAM_REGION), feed resolutions into the store's trip-count
        learning, and re-derive the affected job's ``demand``/``cp`` from
        its NEW shape — a loop exiting early frees reserved demand (the
        next ``_admit`` can wake blocked arrivals), a loop overrunning
        its estimate shrinks slack (the next slack-expiry wakeup can
        trigger the priced preemption/eviction moves).  Re-derivation
        runs for frozen stores too: the shape changed even if no
        prediction did.  A no-op on every static mix."""
        while sim.region_events:
            jid, ev = sim.region_events.pop(0)
            self._region_counts[ev.kind] += 1
            job = sim.jobs.get(jid)
            if job is None:
                continue
            if (ev.kind == "resolve" and ev.outcome is not None
                    and job.store is not None):
                job.store.observe_region(ev.region, ev.outcome)
            if self.sink.enabled:
                self.sink.emit(TraceEvent(
                    ts=sim.clock, family=FAM_REGION, kind=ev.kind,
                    key=(jid, ev.region.rid),
                    data={"job": job.name, "region": ev.region.kind,
                          "region_key": str(ev.region.key),
                          "new_ops": len(ev.new_uids),
                          **({"outcome": ev.outcome}
                             if ev.outcome is not None else {}),
                          **({"trips": ev.region.trips_started}
                             if ev.region.kind == "while" else {})}))
            if job.store is not None and job.plan is not None:
                done = sim.completed.get(jid, set())
                job.demand = job.store.remaining_demand(
                    job.graph, job.plan, done)
                job.cp = job.store.remaining_critical_path(
                    job.graph, job.plan, done)

    def _admit(self, sim: _PoolSim, active: list[Job]) -> None:
        self._refresh_waiting_estimates()
        traced = self.sink.enabled
        while True:
            job = self.queue.pop_admissible(active, now=sim.clock)
            if job is None:
                if self._try_evict(sim, active):
                    continue
                if traced:
                    # only arrived-but-blocked tenants are admission
                    # DECISIONS; an empty queue or not-yet-arrived jobs
                    # leave nothing to decide
                    cause = self.queue.block_cause(active, sim.clock)
                    if cause in ("max_active", "demand_cap", "reserved"):
                        self.sink.emit(TraceEvent(
                            ts=sim.clock, family=FAM_ADMISSION,
                            kind=("reserve" if cause == "reserved"
                                  else "defer"),
                            data={"cause": cause,
                                  "queue_depth": len(self.queue),
                                  "n_active": len(active),
                                  "outstanding": sum(j.demand
                                                     for j in active)}))
                return
            job.admit_time = sim.clock
            job.admitted_demand = job.demand
            if traced:
                self.sink.emit(TraceEvent(
                    ts=sim.clock, family=FAM_ADMISSION, kind="admit",
                    key=job.jid,
                    data={"job": job.name, "priority": job.priority,
                          "demand": job.demand, "deadline": job.deadline,
                          "queue_wait": sim.clock - job.submit_time,
                          "queue_depth": len(self.queue),
                          "n_active": len(active),
                          "outstanding": sum(j.demand for j in active)}))
            sim.admit(job)
            # entry-free regions expanded during admit: trace them and
            # re-price the job off its materialized shape
            self._handle_region_events(sim)
            if not sim.ready[job.jid]:      # zero-op graph: done on arrival
                job.finish_time = sim.clock
                continue
            active.append(job)

    def _next_slack_expiry(self, sim: _PoolSim) -> float | None:
        """Earliest strictly-future instant at which some admitted ready
        node's deadline slack reaches zero — an extra scheduling instant
        for the preemption path (slack goes negative BETWEEN completions;
        waiting for the next op boundary is exactly the head-of-line delay
        preemption exists to cut)."""
        expiry = None
        for jid, uids in sim.ready.items():
            job = sim.jobs[jid]
            if job.deadline is None:
                continue
            for uid in uids:
                t = job.deadline - job.cp.get(uid, 0.0)
                if t > sim.clock and (expiry is None or t < expiry):
                    expiry = t
        if self._preemption.enabled and self._preemption.evict_admitted:
            # with admission-level eviction armed, a QUEUED deadlined
            # tenant going overdue is a scheduling instant too — that is
            # the moment _try_evict may bounce an idle admitted job for
            # it.  A tenant already overdue at arrival expires the moment
            # it arrives (max with submit_time).
            for job in self.queue.waiting_jobs():
                if job.deadline is None:
                    continue
                t = max(job.submit_time,
                        job.deadline - max(job.cp.values(), default=0.0))
                if t > sim.clock and (expiry is None or t < expiry):
                    expiry = t
        return expiry

    def _next_decision_instant(self, sim: _PoolSim, active: list[Job],
                               horizon: float) -> float | None:
        """Earliest scheduling instant strictly before ``horizon`` (the
        next live completion): the next ADMISSIBLE arrival (an arrival
        the admission tier would bounce is not a decision) and — when
        preemption is armed — the next slack expiry, folded into ONE
        min so no wakeup source can mask an earlier one.  Returns None
        when the next completion is the next decision."""
        wake = None
        if len(self.queue):
            arr = self.queue.next_admissible_arrival(active, sim.clock)
            if arr is not None and arr < horizon:
                wake = arr
        if self._preemption.enabled:
            exp = self._next_slack_expiry(sim)
            if (exp is not None and exp < horizon
                    and (wake is None or exp < wake)):
                wake = exp
        return wake

    # ---- lifecycle: begin / step / result -------------------------------
    # run() used to be one monolithic while-loop; the service daemon needs
    # to pump the SAME loop one decision instant at a time (checkpointing
    # between instants, accepting submissions/cancels while work is in
    # flight), so the loop body lives in step() and run() is the one-shot
    # composition.  run() remains bit-for-bit the old loop: begin() is the
    # old prologue, step() the old body, result() the old epilogue.

    def begin(self, *, clock: float = 0.0) -> None:
        """Start a pool lifecycle: fresh event sim (optionally resuming at
        a checkpointed ``clock`` — the daemon's crash-recovery path),
        frozen interference blacklist, initial admission pass."""
        sim = _PoolSim()
        sim.clock = clock
        sim.observer = self.observer
        self._sim = sim
        self._active = []
        # ONE launch fixpoint loop for both schedulers: the shared core's
        # drain handles S3/fallback/S4 gating (S3 off means serial
        # launches only; the serial baseline honors the flag too, so
        # comparisons stay apples-to-apples)
        self._adapter = self.scheduler.adapter(sim)
        self._region_counts = {"expand": 0, "resolve": 0}
        # freeze the cross-job interference blacklist for this pool run
        # (pairs recorded during the run bite on the next one)
        self.scheduler.core.begin_run()
        self._admit(sim, self._active)

    def step(self) -> bool:
        """Advance the pool by ONE decision instant (the old run() loop
        body, verbatim).  Returns False — without advancing anything —
        once no admitted or queued work remains; new submissions make it
        return True again, which is how the daemon idles."""
        assert self._sim is not None, "step() before begin()"
        sim, active, adapter = self._sim, self._active, self._adapter
        if not active and not len(self.queue):
            return False
        if not active:
            # idle until the next tenant arrives
            nxt = self.queue.next_arrival(sim.clock)
            assert nxt is not None, "queued jobs but none admissible"
            sim.clock = nxt
            self._admit(sim, active)
            return True
        self.scheduler.core.drain(adapter)
        if sim.running:
            nxt_fin = sim.next_finish()
            assert nxt_fin is not None
            # a tenant arriving before the next op completes must not
            # wait out that op: advance to the arrival, admit, and go
            # back to launching on whatever cores are idle.  Only wake
            # for arrivals the admission tier would actually accept —
            # an arrival the demand cap bounces is not a scheduling
            # instant (it used to wake on max_active alone), but a
            # LATER admissible arrival behind it still gets its own
            # instant (next_admissible_arrival scans past the blocked
            # one).  Slack expiries (preemption armed) fold into the
            # same min — see _next_decision_instant.
            wake = self._next_decision_instant(sim, active, nxt_fin)
            if wake is not None:
                sim.clock = wake
                self._admit(sim, active)
                return True
            jid, sched = sim.complete_next()
            # close the loop: the completion's observed service flows
            # back through the job's plan store (no-op under
            # feedback="off"; under "ewma" it also re-derives the
            # job's remaining demand and critical paths, so the
            # admission check below sees the tightened values)
            adapter.observe((jid, sched.op.uid), sched, OBS_FINISH,
                            sched.duration)
            # region shape changes at this completion: trace, learn
            # trip counts, re-price the job's demand/slack (early
            # exit frees demand -> the _admit below can wake blocked
            # arrivals; overrun shrinks slack -> the next decision
            # instant can trigger preemption/eviction)
            self._handle_region_events(sim)
            job = next(j for j in active if j.jid == jid)
            job.ops_done += 1
            if sim.job_done(jid):
                job.finish_time = sim.clock
                active.remove(job)
            self._admit(sim, active)
        return True

    def result(self) -> PoolResult:
        """Snapshot the lifecycle's result — callable mid-run (the daemon
        reports drained metrics from the same call)."""
        sim = self._sim
        assert sim is not None, "result() before begin()"
        result = PoolResult(makespan=sim.clock, jobs=list(self.jobs),
                            records=sim.records, events=sim.events,
                            cache_stats=self.plan_cache.stats(),
                            preempted=sim.preempted,
                            feedback_stats=(self.corrections.stats()
                                            if self.corrections else None),
                            n_region_expands=self._region_counts["expand"],
                            n_region_resolves=self._region_counts["resolve"])
        # the standard metric snapshot rides on EVERY result (tracing not
        # required): benches and the CLI read one accounting surface
        result.metrics = pool_metrics(
            result, spec=self.machine.spec,
            cache_stats=result.cache_stats,
            corrections=self.corrections).snapshot()
        return result

    def run(self) -> PoolResult:
        self.begin()
        while self.step():
            pass
        result = self.result()
        # one-shot mode: leave the pool "not begun" again, so a later
        # submit() queues normally instead of admitting into a dead sim
        self._sim = None
        self._adapter = None
        self._active = []
        return result

    @property
    def clock(self) -> float:
        """Current sim time of the live lifecycle (0.0 before begin())."""
        return self._sim.clock if self._sim is not None else 0.0

    # ---- cluster rebalance hook -----------------------------------------
    def withdraw(self, jid: int) -> Job | None:
        """Take a job BACK from this pool so a cluster layer can reroute
        it to another machine — the admission-level-eviction move, made
        cross-machine.  Only free moves are allowed: the job must be
        waiting in the queue, or admitted with NO launched work (no
        records, no running ops, no revoked partials), so withdrawing it
        discards nothing and re-bills nothing.  A job with started work
        returns None — moving IT would cost restart waste, and pricing
        that is the (off-by-default) split/migration path's business, not
        this one's.  The withdrawn job leaves this pool's ledger entirely
        (``jobs``, queue, sim); the caller owns resubmission."""
        job = next((j for j in self.jobs if j.jid == jid), None)
        if job is None or job.cancelled or job.done:
            return None
        if self.queue.remove(jid):
            pass
        elif (self._sim is not None and jid in self._sim.jobs
              and not self._sim.records[jid]
              and not self._sim.preempted[jid]
              and not any(k[0] == jid for k in self._sim.running)):
            sim = self._sim
            self._active[:] = [j for j in self._active if j.jid != jid]
            for d in (sim.graphs, sim.jobs, sim.pending, sim.ready,
                      sim.records, sim.completed, sim.preempted):
                d.pop(jid, None)
            job.admit_time = None
            job.admitted_demand = None
            job.evictions += 1
            # the freed slot/demand gets its admission decision NOW,
            # exactly like cancel()'s admitted branch
            self._admit(sim, self._active)
        else:
            return None
        self.jobs.remove(job)
        return job

    # ---- cancellation ---------------------------------------------------
    def cancel(self, jid: int) -> bool:
        """Cancel a job wherever it lives: waiting in the admission queue,
        admitted but launch-free, or with running launches (those are
        revoked through the observer seam, so a payload backend cancels
        the futures).  Returns True when the job was live and is now
        cancelled; unknown, finished, or already-cancelled jobs return
        False.  Completed work stays in the records (it really ran) and
        launch-time service charges stay on the tenant's ledger."""
        job = next((j for j in self.jobs if j.jid == jid), None)
        if job is None or job.cancelled or job.done:
            return False
        where = None
        if self.queue.remove(jid):
            where = "queued"
        elif self._sim is not None and jid in self._sim.jobs:
            self._sim.drop_job(jid)
            self._active[:] = [j for j in self._active if j.jid != jid]
            where = "admitted"
            # the freed slot (and freed demand) gets its admission
            # decision NOW — step()'s idle branch only handles
            # strictly-future arrivals
            self._admit(self._sim, self._active)
        if where is None:
            return False
        job.cancelled = True
        if self.sink.enabled:
            now = self._sim.clock if self._sim is not None else 0.0
            self.sink.emit(TraceEvent(
                ts=now, family=FAM_ADMISSION, kind="cancel", key=jid,
                data={"job": job.name, "where": where,
                      "ops_done": job.ops_done}))
        return True

    # ---- baseline -------------------------------------------------------
    def run_serial(self, *, share_cache: bool = False) -> SerialResult:
        """The same job mix, one graph at a time (fresh jobs, fresh
        profiling): the single-tenant status quo the pool competes with.

        The baseline is deliberately priority-BLIND: it executes in plain
        arrival order (FIFO), because the status quo it models — a
        runtime that owns the whole machine per job — has no admission
        tier at all.  Priority-aware queueing is itself a pool feature,
        so latency comparisons against this baseline credit the pool for
        both co-scheduling and priority scheduling.

        ``share_cache=False`` means each job pays its own profiling probes
        — isolating both pool advantages (co-scheduling AND probe
        amortization) in the benchmark comparison."""
        cache = PlanCache() if share_cache else None
        clock = 0.0
        job_makespans: dict[int, float] = {}
        job_latencies: dict[int, float] = {}
        total_ops = 0
        probes = 0
        for job in sorted(self.jobs, key=lambda j: (j.submit_time, j.jid)):
            rt = ConcurrencyRuntime(machine=self.machine,
                                    config=self.config.runtime,
                                    plan_cache=cache)
            rt.profile(job.graph)
            assert rt.store is not None
            probes += rt.store.total_probes
            res = rt.execute_step(job.graph)
            clock = max(clock, job.submit_time) + res.makespan
            job_makespans[job.jid] = res.makespan
            job_latencies[job.jid] = clock - job.submit_time
            total_ops += len(res.records)
        return SerialResult(makespan=clock, job_makespans=job_makespans,
                            job_latencies=job_latencies, total_ops=total_ops,
                            profiling_probes=probes)
