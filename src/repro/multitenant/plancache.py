"""Cross-job plan cache: amortize profiling probes across tenants.

The paper amortizes its profiling steps across the *steps of one job*
(§IV-A: the same step graph repeats for thousands of iterations).  A
multi-tenant pool adds a second amortization axis: distinct jobs share op
classes and input sizes — a ResNet step and an Inception step both spend
most of their time in ``Conv2DBackpropFilter`` at Table-II sizes — so a
curve one tenant paid hill-climb probes for is valid for every other
tenant on the same machine (the curve measures the machine, not the job).
Entries are keyed by ``repro.core.perfmodel.cross_graph_key`` — the op's
full analytic profile, not just the paper's ``(op_class, input_shape)``
unit — because across independently-built graphs the same class+shape can
hide different cost parameters (e.g. transformer depth lives in flops).

``PlanCache`` implements the ``repro.core.perfmodel.CurveCache`` protocol
consulted by ``HillClimbProfiler.profile_graph``; it additionally keeps
hit/probe accounting so benchmarks can report how many probes the pool
saved versus profiling every job in isolation.

Persistence: the cache is the curve BACKEND of the closed-loop plan API
(``repro.core.planstore``), and curves measure the machine — so they are
worth keeping across process restarts.  ``dump(path)``/``load(path)``
serialize the full cache state (curves, LRU recency order, hit/probe/
eviction accounting, per-entry machine-fingerprint namespaces) as
versioned JSON.  A corrupted, truncated, or version-mismatched file
degrades to an empty cache with a warning — a cold cache re-measures, a
wrong curve would mis-schedule silently, so load NEVER guesses.

Machine binding: curves measure a (machine, probe-interval) context, so
every lookup/insert is namespaced under the fingerprint most recently
passed to ``bind_machine``.  One cache (and one cache FILE) can therefore
serve a heterogeneous cluster: machine A's curves can never answer
machine B's lookups, while two pools on identical machines share hits.
Binding used to be whole-cache (first binder wins, mismatch raised) —
that made cross-machine sharing impossible and, worse, was only compared
at dump/load, so lookups themselves were never actually guarded.
"""

from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import tempfile
from typing import Hashable

from repro.core.perfmodel import CurveModel
from repro.obs.log import get_logger

logger = get_logger(__name__)

# bump whenever the on-disk layout changes; load() refuses other versions
# (v2 added per-entry fingerprint namespaces; v1 files are still read,
# with their entries placed under the file's whole-cache fingerprint)
SCHEMA_VERSION = 2
_LEGACY_SCHEMA_VERSIONS = (1,)


def atomic_write_text(path: str | pathlib.Path, text: str) -> None:
    """Write-temp-then-rename so readers NEVER see a partial file.

    A crash mid-write used to truncate the target in place: ``load``
    would then degrade to an empty cache, silently discarding every
    probe already paid for.  Writing to a tempfile in the same directory
    and ``os.replace``-ing it over the target is atomic on POSIX — a
    crash leaves either the old complete file or the new complete file,
    and a stray ``.tmp`` is ignored by every loader.  The service-daemon
    job store persists through the same helper."""
    path = pathlib.Path(path)
    fd, tmp = tempfile.mkstemp(dir=path.parent, prefix=path.name + ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            f.write(text)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        # never leave the tempfile behind on failure; the target is
        # untouched either way
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def _freeze(x):
    """JSON arrays -> tuples, recursively (cache keys are tuples —
    ``cross_graph_key`` — and JSON round-trips them as lists)."""
    if isinstance(x, list):
        return tuple(_freeze(v) for v in x)
    return x


def _curve_to_json(curve: CurveModel) -> dict:
    return {
        # bool dict keys become "true"/"false" strings explicitly (json
        # would coerce them anyway, but implicitly — be deliberate)
        "samples": {str(v).lower(): [[t, y] for t, y in pts]
                    for v, pts in curve.samples.items()},
        "case_lists": {str(v).lower(): list(cases)
                       for v, cases in curve.case_lists.items()},
        "probes": curve.probes,
    }


def _curve_from_json(d: dict) -> CurveModel:
    return CurveModel(
        samples={k == "true": [(int(t), float(y)) for t, y in pts]
                 for k, pts in d["samples"].items()},
        case_lists={k == "true": [int(t) for t in cases]
                    for k, cases in d["case_lists"].items()},
        probes=int(d["probes"]),
    )


@dataclasses.dataclass
class PlanCache:
    """Shared cross_graph_key(op) -> CurveModel store with accounting.

    Key with ``repro.core.perfmodel.cross_graph_key`` (the op's full
    analytic profile), NOT ``op.size_key`` — see the module docstring.

    ``max_entries`` bounds the cache (the ROADMAP's "unbounded today"
    item): beyond the bound the least-recently-USED curve is evicted —
    dict insertion order doubles as the LRU list, with every hit
    reinserting its key at the back.  An evicted curve is simply
    re-measured on its next miss, so eviction never changes results,
    only probe counts (``evictions`` tracks how often that price was
    paid)."""

    curves: dict[Hashable, CurveModel] = dataclasses.field(
        default_factory=dict)
    max_entries: int | None = None   # None = unbounded (the old behavior)
    hits: int = 0
    misses: int = 0
    probes_saved: int = 0       # probes a hit avoided re-paying
    evictions: int = 0          # LRU evictions (bounded caches only)
    probes_evicted: int = 0     # probes paid for curves later evicted
    # the profiling context (machine fingerprint + probe interval) whose
    # namespace lookups/inserts currently resolve under; None = the bare
    # un-namespaced keyspace (direct CurveCache use outside a runtime)
    machine_fingerprint: Hashable | None = None
    # repr of the fingerprint this cache was last PERSISTED under (a
    # loaded cache can't reconstruct live tuples — spec objects don't
    # survive JSON — so namespaces are canonical reprs on disk)
    loaded_fingerprint: str | None = None

    def bind_machine(self, fingerprint: Hashable) -> None:
        """Select the profiling context (timing function + probe protocol
        — see ConcurrencyRuntime.profile) whose curve namespace subsequent
        lookups and inserts resolve under.  Curves measure a machine
        through a probe grid, so every entry is keyed by the context it
        was measured in: one cache can serve a whole heterogeneous
        cluster (each machine's runtime re-binds before profiling) and a
        lookup can never be answered by another machine's curve."""
        self.machine_fingerprint = fingerprint

    def _nskey(self, key: Hashable) -> tuple:
        """Internal storage key: ``(namespace, key)`` where the namespace
        is the bound context's canonical repr (``None`` when unbound).
        Reprs, not live tuples, so that an entry persisted to JSON and
        reloaded answers the same machine's lookups again.  Every entry
        is wrapped — even unbound ones — so dump/load never has to guess
        whether a tuple-shaped raw key is itself a namespace."""
        fp = self.machine_fingerprint
        return (repr(fp) if fp is not None else None, key)

    def warm_keys(self, fingerprint: Hashable) -> frozenset:
        """Raw keys already cached under ``fingerprint``'s namespace —
        the curves a job routed to that machine would NOT re-pay probes
        for.  Read-only: consulted by the cluster router for cache
        affinity, so it must not perturb hit/miss accounting."""
        ns = repr(fingerprint)
        return frozenset(k for n, k in self.curves if n == ns)

    # ---- CurveCache protocol -----------------------------------------
    def lookup(self, key: Hashable) -> CurveModel | None:
        skey = self._nskey(key)
        curve = self.curves.get(skey)
        if curve is None:
            self.misses += 1
            return None
        self.hits += 1
        self.probes_saved += curve.probes
        # refresh LRU position: pop + reinsert moves the key to the back
        del self.curves[skey]
        self.curves[skey] = curve
        return curve

    def insert(self, key: Hashable, curve: CurveModel) -> None:
        skey = self._nskey(key)
        self.curves.pop(skey, None)       # reinsertion refreshes recency
        self.curves[skey] = curve
        if self.max_entries is not None:
            while len(self.curves) > self.max_entries:
                oldest = next(iter(self.curves))
                # the evicted curve's probes were really measured; keep
                # them in probes_spent so eviction (which forces a future
                # re-measure) can never make the cache LOOK cheaper
                self.probes_evicted += self.curves[oldest].probes
                del self.curves[oldest]
                self.evictions += 1

    # ---- persistence --------------------------------------------------
    def dump(self, path: str | pathlib.Path) -> None:
        """Serialize the full cache state as versioned JSON.

        Entries are written in dict order = LRU order (oldest first), so
        a load re-inserts them in the same order and recency survives the
        round trip.  Floats round-trip exactly through ``json`` (Python
        serializes shortest-repr doubles), so a reloaded curve predicts
        bit-identical times."""
        fp = (repr(self.machine_fingerprint)
              if self.machine_fingerprint is not None
              else self.loaded_fingerprint)
        payload = {
            "schema": SCHEMA_VERSION,
            "machine_fingerprint": fp,
            "max_entries": self.max_entries,
            "stats": {
                "hits": self.hits, "misses": self.misses,
                "probes_saved": self.probes_saved,
                "evictions": self.evictions,
                "probes_evicted": self.probes_evicted,
            },
            # json serializes tuples as arrays recursively; _freeze on
            # load restores them (non-tuple keys pass through untouched).
            # each entry records its fingerprint namespace so one file
            # can carry a whole heterogeneous cluster's curves
            "entries": [{"ns": ns, "key": k, "curve": _curve_to_json(c)}
                        for (ns, k), c in self.curves.items()],
        }
        # atomic: a crash mid-dump must leave the previous good cache,
        # not a truncated file that load() degrades to empty
        atomic_write_text(path, json.dumps(payload))

    @classmethod
    def load(cls, path: str | pathlib.Path) -> "PlanCache":
        """Deserialize a cache ``dump`` wrote.

        Any failure — unreadable file, malformed JSON, wrong schema
        version, mangled entries — degrades to an EMPTY cache with a
        warning rather than raising: persistence is an optimization, and
        a cold cache merely re-measures, while crashing the launcher (or
        worse, half-loading curves) would cost more than it saves."""
        try:
            payload = json.loads(pathlib.Path(path).read_text())
            if not isinstance(payload, dict):
                raise ValueError("top-level JSON is not an object")
            schema = payload.get("schema")
            if schema != SCHEMA_VERSION and schema not in _LEGACY_SCHEMA_VERSIONS:
                raise ValueError(
                    f"schema version {schema!r} != {SCHEMA_VERSION}")
            stats = payload["stats"]
            cache = cls(
                max_entries=payload["max_entries"],
                hits=int(stats["hits"]), misses=int(stats["misses"]),
                probes_saved=int(stats["probes_saved"]),
                evictions=int(stats["evictions"]),
                probes_evicted=int(stats["probes_evicted"]),
                loaded_fingerprint=payload["machine_fingerprint"],
            )
            for entry in payload["entries"]:
                # v1 entries carried no namespace: they were measured
                # under the file's whole-cache fingerprint, so that is
                # the namespace they belong to
                ns = (entry["ns"] if schema == SCHEMA_VERSION
                      else payload["machine_fingerprint"])
                cache.curves[(ns, _freeze(entry["key"]))] = _curve_from_json(
                    entry["curve"])
            return cache
        except Exception as e:  # noqa: BLE001 - degrade, never crash
            logger.warning(
                "PlanCache.load(%s): %r — falling back to an "
                "empty cache (curves will be re-measured)", path, e)
            return cls()

    # ---- accounting ---------------------------------------------------
    @property
    def probes_spent(self) -> int:
        """Probes actually measured: every resident curve's cost plus the
        cost of curves measured and later evicted (an evicted curve that
        re-misses is re-measured, and both payments count)."""
        return (sum(c.probes for c in self.curves.values())
                + self.probes_evicted)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict[str, float]:
        return {
            "curves": len(self.curves),
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hit_rate,
            "probes_spent": self.probes_spent,
            "probes_saved": self.probes_saved,
            "evictions": self.evictions,
        }
