"""Cross-job plan cache: amortize profiling probes across tenants.

The paper amortizes its profiling steps across the *steps of one job*
(§IV-A: the same step graph repeats for thousands of iterations).  A
multi-tenant pool adds a second amortization axis: distinct jobs share op
classes and input sizes — a ResNet step and an Inception step both spend
most of their time in ``Conv2DBackpropFilter`` at Table-II sizes — so a
curve one tenant paid hill-climb probes for is valid for every other
tenant on the same machine (the curve measures the machine, not the job).
Entries are keyed by ``repro.core.perfmodel.cross_graph_key`` — the op's
full analytic profile, not just the paper's ``(op_class, input_shape)``
unit — because across independently-built graphs the same class+shape can
hide different cost parameters (e.g. transformer depth lives in flops).

``PlanCache`` implements the ``repro.core.perfmodel.CurveCache`` protocol
consulted by ``HillClimbProfiler.profile_graph``; it additionally keeps
hit/probe accounting so benchmarks can report how many probes the pool
saved versus profiling every job in isolation.
"""

from __future__ import annotations

import dataclasses
from typing import Hashable

from repro.core.perfmodel import CurveModel


@dataclasses.dataclass
class PlanCache:
    """Shared cross_graph_key(op) -> CurveModel store with accounting.

    Key with ``repro.core.perfmodel.cross_graph_key`` (the op's full
    analytic profile), NOT ``op.size_key`` — see the module docstring.

    ``max_entries`` bounds the cache (the ROADMAP's "unbounded today"
    item): beyond the bound the least-recently-USED curve is evicted —
    dict insertion order doubles as the LRU list, with every hit
    reinserting its key at the back.  An evicted curve is simply
    re-measured on its next miss, so eviction never changes results,
    only probe counts (``evictions`` tracks how often that price was
    paid)."""

    curves: dict[Hashable, CurveModel] = dataclasses.field(
        default_factory=dict)
    max_entries: int | None = None   # None = unbounded (the old behavior)
    hits: int = 0
    misses: int = 0
    probes_saved: int = 0       # probes a hit avoided re-paying
    evictions: int = 0          # LRU evictions (bounded caches only)
    probes_evicted: int = 0     # probes paid for curves later evicted
    machine_fingerprint: Hashable | None = None

    def bind_machine(self, fingerprint: Hashable) -> None:
        """Pin the cache to one profiling context (timing function +
        probe protocol — see ConcurrencyRuntime.profile).  Curves measure
        a machine through a probe grid; sharing one cache across different
        machines or probe intervals would serve wrong curves with no
        error, so the first binder wins and any different context is
        rejected."""
        if self.machine_fingerprint is None:
            self.machine_fingerprint = fingerprint
        elif self.machine_fingerprint != fingerprint:
            raise ValueError(
                "PlanCache is bound to a different machine/profiling "
                f"context ({self.machine_fingerprint!r} != {fingerprint!r});"
                " use one cache per machine and probe interval")

    # ---- CurveCache protocol -----------------------------------------
    def lookup(self, key: Hashable) -> CurveModel | None:
        curve = self.curves.get(key)
        if curve is None:
            self.misses += 1
            return None
        self.hits += 1
        self.probes_saved += curve.probes
        # refresh LRU position: pop + reinsert moves the key to the back
        del self.curves[key]
        self.curves[key] = curve
        return curve

    def insert(self, key: Hashable, curve: CurveModel) -> None:
        self.curves.pop(key, None)        # reinsertion refreshes recency
        self.curves[key] = curve
        if self.max_entries is not None:
            while len(self.curves) > self.max_entries:
                oldest = next(iter(self.curves))
                # the evicted curve's probes were really measured; keep
                # them in probes_spent so eviction (which forces a future
                # re-measure) can never make the cache LOOK cheaper
                self.probes_evicted += self.curves[oldest].probes
                del self.curves[oldest]
                self.evictions += 1

    # ---- accounting ---------------------------------------------------
    @property
    def probes_spent(self) -> int:
        """Probes actually measured: every resident curve's cost plus the
        cost of curves measured and later evicted (an evicted curve that
        re-misses is re-measured, and both payments count)."""
        return (sum(c.probes for c in self.curves.values())
                + self.probes_evicted)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict[str, float]:
        return {
            "curves": len(self.curves),
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hit_rate,
            "probes_spent": self.probes_spent,
            "probes_saved": self.probes_saved,
            "evictions": self.evictions,
        }
