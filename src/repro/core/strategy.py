"""Shared strategy core — Strategies 2-4 in ONE place (paper §III-D).

The paper's co-run decision rules used to exist twice: once in
``repro.core.scheduler.CorunScheduler`` (one step graph) and once in
``repro.multitenant.pool.PoolScheduler`` (many tenant graphs), differing
only in job plumbing — a drift hazard the ROADMAP flagged explicitly.
``StrategyCore`` owns the rules once:

* **Strategy 3 admission fixpoint** — ``try_corun`` admits a ready op into
  idle cores when a top-k candidate fits AND won't outlast the running set
  (``free_cores`` / ``remaining_horizon`` / ``pick_admissible``), with the
  ``run_biggest`` fallback (most time-consuming ready op at its frozen
  plan, throughput-guarded when others run);
* **Strategy 4** — ``try_hyper`` runs the smallest ready ops on the
  hyper-thread lane once physical cores are exhausted;
* **Strategy 2 interaction** — every S3 proposal passes through the
  adapter's ``clamp`` (per-class hysteresis guard);
* the **launch drain loop** (``drain``) that fixpoints S3/fallback/S4 at
  one scheduling instant, including the S3-off serial gating;
* the **deadline path** (``try_preempt``, gated by
  ``StrategyConfig.preemption`` — OFF by default): an overdue op (adapter
  reports non-positive deadline slack) launches with the throughput guard
  waived, squeezed to a bounded-loss width if need be, or by revoking the
  longest-remaining running op (checkpoint-free, work-conserving — the
  victim returns to its ready frontier via ``StrategyAdapter.revoke``).

What *varies* between the single-graph scheduler and the multi-tenant pool
is injected through ``StrategyAdapter``:

* **candidate source** — ``ready_groups()`` yields ordered groups of ready
  node keys (one global group for a single graph; one group per tenant,
  ordered by weighted fair share, for the pool);
* **plan/controller lookup** — ``instance_plan`` / ``candidates_for`` /
  ``clamp`` / ``predict`` resolve against the node's own job's frozen plan;
* **bandwidth-share policy** — ``StrategyCore(bw_share=...)``, defaulting
  to the machine's ``corun_bw_share`` contention rule;
* **interference blacklist** — the injected ``InterferenceRecorder`` spans
  whatever co-runs: within one graph or across tenants;
* **accounting** — ``charge`` (weighted-fair-share service for the pool,
  a no-op for a single graph).

Node keys are opaque to the core (``int`` uid for a single graph,
``(jid, uid)`` for the pool).  Because both schedulers execute the same
core, a single-job pool reproduces the single-graph scheduler's timeline
bit-for-bit — locked down by ``tests/test_strategy_differential.py``.
"""

from __future__ import annotations

import abc
import dataclasses
import warnings
from typing import Callable, Hashable, Iterable, Mapping, Sequence

from repro.core.concurrency import OpPlan
from repro.core.graph import Op
from repro.core.interference import InterferenceRecorder, _pair_key
from repro.core.placement import (REL_ANY, REL_CROSS, REL_LOCAL,
                                  place, placement_relation, quadrants_of)
from repro.core.planstore import (OBS_LAUNCH, OBS_REVOKE, MovePrice,
                                  claim_price, migration_price, restart_cost)
from repro.core.simmachine import Placement, SimMachine
from repro.obs.trace import (FAM_PLACEMENT, FAM_PREEMPTION, FAM_STRATEGY,
                             NullSink, TraceEvent, TraceSink)

NodeKey = Hashable            # int (uid) or (jid, uid) — opaque to the core


@dataclasses.dataclass
class ScheduledOp:
    op: Op
    threads: int
    variant: bool
    hyper: bool
    start: float
    finish: float
    predicted: float
    # concrete core ids under topology="quadrant"; empty for flat topology
    # and for hyper-thread-lane launches (they borrow busy cores' spare HW
    # threads machine-wide rather than booking physical cores)
    cores: tuple[int, ...] = ()

    @property
    def duration(self) -> float:
        return self.finish - self.start


@dataclasses.dataclass
class ScheduleResult:
    makespan: float
    records: list[ScheduledOp]
    events: list[tuple[float, int]]      # (time, #co-running) — paper Fig 4
    profiling_probes: int = 0

    @property
    def mean_corunning(self) -> float:
        if not self.events:
            return 0.0
        return sum(n for _, n in self.events) / len(self.events)

    def per_class_time(self) -> dict[str, float]:
        out: dict[str, float] = {}
        for r in self.records:
            out[r.op.op_class] = out.get(r.op.op_class, 0.0) + r.duration
        return out


def free_cores(running: Iterable[ScheduledOp], total_cores: int) -> int:
    """Physical cores not occupied by non-hyper-thread runners."""
    used = sum(r.threads for r in running if not r.hyper)
    return max(0, total_cores - used)


def remaining_horizon(running: Iterable[ScheduledOp], clock: float) -> float:
    """Longest remaining time among running ops — Strategy 3's throughput
    guard: a new co-runner must not outlast everything already running."""
    return max((r.finish - clock for r in running), default=float("inf"))


def pick_admissible(cands: list[OpPlan], free: int,
                    horizon: float) -> OpPlan | None:
    """Strategy 3's admission rule: admissible = fits the idle cores AND
    won't outlast the running set; among admissible candidates pick the
    FEWEST threads (the paper deliberately leaves cores free for more
    co-runners)."""
    adm = [c for c in cands
           if c.threads <= free and c.predicted_time <= horizon]
    return min(adm, key=lambda c: c.threads) if adm else None


# On-disk schema version shared by every config ``to_dict``/``from_dict``
# pair (StrategyConfig here, RuntimeConfig/PoolConfig in repro.core.runtime
# and repro.multitenant.pool).  The pool daemon persists configs with this
# schema and the CLI accepts them, so all three layers share ONE
# serialization; bump on any layout change — ``from_dict`` refuses other
# versions, so a stale daemon store can never half-load into live knobs.
CONFIG_SCHEMA_VERSION = 1


def _check_config_dict(cls_name: str, d: dict, known: set[str], *,
                       versioned: bool = True) -> dict:
    """Shared ``from_dict`` validation: schema version checked (when the
    dict is a top-level versioned document) and unknown keys REJECTED —
    a typo'd or future-schema knob must fail loudly, not be silently
    dropped into a config that then schedules differently."""
    d = dict(d)
    if versioned:
        schema = d.pop("schema", None)
        if schema != CONFIG_SCHEMA_VERSION:
            raise ValueError(
                f"{cls_name}.from_dict: schema version {schema!r} != "
                f"{CONFIG_SCHEMA_VERSION}")
    unknown = sorted(set(d) - known)
    if unknown:
        raise ValueError(f"{cls_name}.from_dict: unknown keys {unknown}")
    return d


def fold_deprecated_strategy_kwargs(cls_name: str, strategy: "StrategyConfig",
                                    kwargs: dict) -> "StrategyConfig":
    """Back-compat shim for the config redesign: ``RuntimeConfig`` and
    ``PoolConfig`` used to re-declare strategy-owned knobs (topology,
    feedback, preemption, fallback floors, ...) as their own constructor
    kwargs.  Those spellings keep working — folded onto the composed
    ``StrategyConfig`` with a DeprecationWarning naming the keys — so
    existing callers and benchmarks run unchanged while new code passes
    ``strategy=StrategyConfig(...)``.  Overrides apply ON TOP of an
    explicitly passed strategy, which keeps ``dataclasses.replace(cfg,
    feedback="ewma")`` working (replace re-passes the old ``strategy``
    field plus the deprecated key)."""
    if not kwargs:
        return strategy
    known = {f.name for f in dataclasses.fields(StrategyConfig)}
    unknown = sorted(set(kwargs) - known)
    if unknown:
        raise TypeError(
            f"{cls_name}() got unexpected keyword arguments {unknown}")
    warnings.warn(
        f"{cls_name}({', '.join(sorted(kwargs))}) is deprecated: these "
        f"knobs live on StrategyConfig — pass "
        f"strategy=StrategyConfig(...) instead",
        DeprecationWarning, stacklevel=3)
    return dataclasses.replace(strategy, **kwargs)


@dataclasses.dataclass(frozen=True)
class PreemptionPolicy:
    """Checkpoint-free preemption knobs (off by default, so every scheduler
    built on the core — and the differential/golden suites — behaves
    exactly as before unless a pool opts in).

    When a ready op belongs to a tenant whose deadline slack has run out
    (``StrategyAdapter.deadline_slack`` <= 0) and nothing else launched at
    this instant, the core may claim cores for it: first by launching into
    idle cores with the Strategy-3 throughput guard waived (a deadline
    outranks makespan), and failing that by CANCELLING the running op with
    the largest remaining time.  Preemption is work-conserving: the victim
    node returns to its job's ready frontier (it restarts from scratch —
    checkpoint-free) and its partial service is charged back at the
    machine's restart-waste factor.
    """

    enabled: bool = False
    # a victim must have at least this many times the urgent op's predicted
    # time still remaining — never axe an op that would have finished before
    # the waiter anyway (the revoked partial work is pure waste)
    min_victim_advantage: float = 1.0
    # ---- preemption economics (all OFF by default, so an enabled-but-
    # otherwise-default policy behaves exactly as before) ----
    # >1 arms multi-victim preemption: when one victim's cores cannot seat
    # the overdue op's PREFERRED width, assemble a victim set (cheapest
    # summed restart waste first, affinity-aware under quadrant topology)
    # and revoke it atomically — but only when the priced SLO gain exceeds
    # the summed waste (see repro.core.planstore.claim_price)
    max_victims: int = 1
    # admission-level eviction (pool tier, see RuntimePool): before any
    # running work is revoked for an overdue waiter blocked in the
    # admission queue, an admitted job with NO launched ops may be
    # returned to the queue — a free move, zero restart waste
    evict_admitted: bool = False
    # width migration: a drain step that relaunches a running op at a
    # different width when (predicted relaunch time + re-billed restart
    # waste) strictly undercuts finishing at the current width (see
    # repro.core.planstore.migration_price) — the move that un-sticks an
    # op squeezed at claim time or priced wrong by a stale curve
    migration: bool = False

    def to_dict(self) -> dict:
        """JSON form (nested inside a versioned StrategyConfig document,
        so it carries no schema key of its own)."""
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping) -> "PreemptionPolicy":
        return cls(**_check_config_dict(
            cls.__name__, dict(d),
            {f.name for f in dataclasses.fields(cls)}, versioned=False))


@dataclasses.dataclass(frozen=True)
class StrategyConfig:
    """The strategy knobs shared by every scheduler built on the core."""

    enable_s3: bool = True
    enable_s4: bool = True
    candidates: int = 3              # Strategy 3 top-k
    max_ht_corunners: int = 2        # Strategy 4 hyper-thread lane width
    min_fallback_cores: int = 4      # don't squeeze the fallback op
    fallback_slack: float = 1.25     # horizon slack for the fallback launch
    preemption: PreemptionPolicy = PreemptionPolicy()
    # "flat": the paper's 68-core pool — no placement, global bw shares,
    # bit-for-bit the pre-topology scheduler (locked by the differential/
    # golden suites).  "quadrant": placement is a scheduling decision —
    # every non-hyper launch books a concrete core set (empty quadrant
    # first, then quadrant-local packing, then bounded spill), bw shares
    # are computed from actual quadrant co-residents, and interference is
    # recorded per placement relation (local vs cross-quadrant).
    topology: str = "flat"
    # closed-loop plan feedback ("off" | "ewma", see repro.core.planstore).
    # "off" keeps every prediction frozen at profiling time — bit-for-bit
    # the pre-feedback schedulers (the golden/differential lock).  "ewma"
    # blends observed service back into the plan store: candidate ranking,
    # admission horizons, Job.demand, and deadline slack all track
    # observed reality when profiles mispredict.
    feedback: str = "off"
    # decision-trace sink (repro.obs.trace).  The default NullSink keeps
    # every emit site dormant — the traced and untraced schedulers are
    # bit-for-bit identical (locked by the traced parity leg); all
    # NullSink instances compare equal so config equality is unaffected.
    sink: TraceSink = dataclasses.field(default_factory=NullSink)

    # the knobs excluded from serialization: a sink is a live process
    # object (a deserialized config starts with the inert NullSink and a
    # daemon attaches its own sink explicitly)
    _UNSERIALIZED = frozenset({"sink", "preemption"})

    def to_dict(self) -> dict:
        """Versioned JSON form — the ONE serialization of strategy knobs
        shared by the CLI (``--config``), the daemon's persisted store,
        and ``RuntimeConfig``/``PoolConfig`` round-trips."""
        d: dict = {"schema": CONFIG_SCHEMA_VERSION}
        for f in dataclasses.fields(self):
            if f.name not in self._UNSERIALIZED:
                d[f.name] = getattr(self, f.name)
        d["preemption"] = self.preemption.to_dict()
        return d

    @classmethod
    def from_dict(cls, d: Mapping) -> "StrategyConfig":
        d = dict(d)
        pre = d.pop("preemption", None)
        kw = _check_config_dict(
            cls.__name__, d,
            {f.name for f in dataclasses.fields(cls)} - cls._UNSERIALIZED)
        if pre is not None:
            kw["preemption"] = PreemptionPolicy.from_dict(pre)
        return cls(**kw)


class StrategyAdapter(abc.ABC):
    """The seam a scheduler implements to drive ``StrategyCore``.

    An adapter is a *view* over one scheduler's discrete-event state plus
    its plan/controller lookups; the core never touches sims or jobs
    directly.  ``repro.core.scheduler`` adapts one ``_EventSim``;
    ``repro.multitenant.pool`` adapts a ``_PoolSim`` with job-aware
    lookups and fair-share ordering."""

    # ---- sim view -----------------------------------------------------
    @property
    @abc.abstractmethod
    def clock(self) -> float: ...

    @property
    @abc.abstractmethod
    def running(self) -> Mapping[NodeKey, ScheduledOp]: ...

    @abc.abstractmethod
    def ready_groups(self) -> list[Sequence[NodeKey]]:
        """Ordered candidate groups of ready node keys.  The core tries
        groups in order (pool: most-owed tenant first) and, inside a
        group, orders ops itself per strategy.  Group order is the
        injected scheduling POLICY (fair share); in-group rule is the
        paper's MECHANISM."""

    @abc.abstractmethod
    def op(self, key: NodeKey) -> Op: ...

    # ---- plan / controller lookup --------------------------------------
    @abc.abstractmethod
    def instance_plan(self, key: NodeKey) -> OpPlan:
        """The node's frozen S1/S2 plan with an instance-specific
        predicted time (re-predicted from the node's own curve)."""

    @abc.abstractmethod
    def candidates_for(self, key: NodeKey, k: int) -> list[OpPlan]:
        """Strategy 3's top-k candidate configurations for the node."""

    @abc.abstractmethod
    def clamp(self, key: NodeKey, proposal: OpPlan) -> OpPlan:
        """Strategy 2 hysteresis guard over an S3 proposal."""

    @abc.abstractmethod
    def predict(self, key: NodeKey, threads: int, variant: bool) -> float:
        """Curve prediction for an arbitrary thread count (fallback clamp
        to idle cores)."""

    def serial_time(self, key: NodeKey) -> float:
        """Strategy 4's 'smallest op' metric: serial-execution time."""
        return self.predict(key, 1, False)

    # ---- commit --------------------------------------------------------
    @abc.abstractmethod
    def commit(self, key: NodeKey, sched: ScheduledOp) -> None:
        """Remove the node from the ready frontier and register the launch
        with the event sim."""

    def charge(self, key: NodeKey, sched: ScheduledOp) -> None:
        """Post-launch accounting hook (pool: weighted fair share)."""

    def observe(self, key: NodeKey, sched: ScheduledOp, kind: str,
                elapsed: float) -> None:
        """Report an execution event to the scheduler's plan store — the
        closed-loop seam (see ``repro.core.planstore``).  The core calls
        it on every launch (``OBS_LAUNCH``, elapsed 0) and preemption
        revoke (``OBS_REVOKE``, elapsed = discarded partial run); the
        schedulers' event loops call it on every completion
        (``OBS_FINISH``, elapsed = service time).  The default is a
        no-op, so adapters without a store — and every
        ``feedback="off"`` scheduler — behave exactly as before."""

    def placement_hint(self, key: NodeKey) -> int | None:
        """Preferred quadrant for the node's launch under
        ``topology="quadrant"`` (pool: the tenant's last-used quadrant, so
        a job's ops keep landing where its working set already lives).
        ``None`` means no affinity; flat topology never consults this."""
        return None

    # ---- deadlines / preemption (optional) -----------------------------
    def deadline_slack(self, key: NodeKey) -> float | None:
        """Deadline slack of the node's tenant at this instant: time left
        until the deadline minus the node's predicted downstream critical
        path.  ``None`` means no deadline (the default — single-graph
        scheduling has no SLOs, so preemption can never trigger there)."""
        return None

    def revoke(self, key: NodeKey) -> ScheduledOp:
        """Cancel a running launch: remove it from the event sim and return
        the node to its ready frontier (checkpoint-free — it will restart
        from scratch).  Only adapters that opt into preemption implement
        this; the core never calls it unless the policy is enabled."""
        raise NotImplementedError(
            f"{type(self).__name__} does not support preemption")

    def refund(self, key: NodeKey, sched: ScheduledOp,
               elapsed: float) -> None:
        """Accounting reversal for a revoked launch: un-charge the launch-
        time service and bill the wasted partial run instead (pool: at the
        machine's restart-waste factor)."""

    def migrated(self, key: NodeKey, revoked: ScheduledOp) -> None:
        """Bookkeeping hook for a width migration (the revoke/refund pair
        already handled the accounting; this only lets the adapter count
        the move separately from SLO preemptions — pool: Job.migrations)."""


class StrategyCore:
    """Strategies 2-4 over any ``StrategyAdapter``.

    ``bw_share`` is the injected contention policy ``(threads,
    co_running_threads) -> share``; it defaults to the machine's
    ``corun_bw_share`` so every scheduler divides MCDRAM identically.
    """

    def __init__(self, machine: SimMachine,
                 config: StrategyConfig | None = None, *,
                 recorder: InterferenceRecorder | None = None,
                 total_cores: int | None = None,
                 bw_share: Callable[[int, Iterable[int]], float] | None = None):
        self.machine = machine
        self.config = config or StrategyConfig()
        self.recorder = (recorder if recorder is not None
                         else InterferenceRecorder())
        self.cores = total_cores or machine.spec.cores
        self.bw_share = bw_share or machine.corun_bw_share
        self.sink = self.config.sink
        self._blacklist: frozenset[tuple[str, str]] | None = None

    def _emit(self, family: str, kind: str, key: NodeKey,
              clock: float, **data) -> None:
        """Build and emit one decision event.  Callers guard on
        ``self.sink.enabled`` FIRST so the default NullSink path never
        constructs event payloads (tracing must cost one attribute read
        when off)."""
        self.sink.emit(TraceEvent(ts=clock, family=family, kind=kind,
                                  key=key, data=data))

    # ------------------------------------------------------------------
    def begin_run(self) -> None:
        """Freeze the interference blacklist for one scheduling run.

        The paper avoids recorded-interference pairs "in the future
        training steps": the snapshot taken here is what every launch
        path of THIS run enforces, while observations recorded during the
        run accumulate in the recorder and only bite on the next
        ``begin_run``.  Live-consulting the recorder instead would let
        ordinary modeled contention (every co-run observation exceeds the
        solo prediction by construction) serialize the machine mid-run."""
        self._blacklist = self.recorder.blacklist()

    def _blacklisted_pair(self, a: str, b: str, relation: str) -> bool:
        if self._blacklist is None:        # no snapshot: live recorder view
            return self.recorder.blacklisted(a, b, relation)
        return _pair_key(a, b) + (relation,) in self._blacklist

    def _compat_relations(self, hyper: bool) -> tuple[str, ...]:
        """Which blacklist relations make a pair HARD-incompatible.

        Flat topology has one bucket.  Under quadrant topology an "any"
        entry (pre-seeded or carried over from a flat run) and a "local"
        entry (the pair interferes even placed in disjoint quadrants —
        a true global-bandwidth conflict) always forbid the co-run; a
        "cross"-only entry does NOT — the pair is re-admitted as long as
        placement keeps their quadrants disjoint (see
        ``_placement_avoid``).  A hyper-thread launch rides busy cores
        machine-wide, so every co-run it joins IS a cross-quadrant one
        and the cross relation turns hard for it."""
        if self.config.topology != "quadrant":
            return (REL_ANY,)
        return (REL_ANY, REL_LOCAL, REL_CROSS) if hyper \
            else (REL_ANY, REL_LOCAL)

    def _compatible(self, op_class: str, running_classes: list[str],
                    hyper: bool = False) -> bool:
        rels = self._compat_relations(hyper)
        return not any(self._blacklisted_pair(op_class, r, rel)
                       for r in running_classes for rel in rels)

    def _placement_avoid(self, op_class: str,
                         adapter: StrategyAdapter) -> frozenset[int] | None:
        """Quadrants the launch must stay out of: those occupied by
        runners whose class pair is blacklisted under the CROSS relation
        (they may still co-run quadrant-LOCALLY — the whole point of
        splitting the recorder key).  ``None`` = no feasible placement at
        all: a cross-blacklisted co-runner with no placement (hyper lane)
        rides every quadrant, so no core set can dodge it."""
        if self.config.topology != "quadrant":
            return frozenset()
        avoid: set[int] = set()
        for r in adapter.running.values():
            if self._blacklisted_pair(op_class, r.op.op_class, REL_CROSS):
                if not r.cores:
                    return None
                avoid |= quadrants_of(self.machine.spec, r.cores)
        return frozenset(avoid)

    def _place(self, adapter: StrategyAdapter, key: NodeKey, plan: OpPlan,
               avoid: frozenset[int]) -> tuple[int, ...] | None:
        """Concrete core set for a non-hyper launch (empty tuple under
        flat topology — placement stays out of the flat scheduler
        entirely, preserving bit-for-bit parity)."""
        if self.config.topology != "quadrant":
            return ()
        busy = frozenset(c for r in adapter.running.values()
                         for c in r.cores)
        return place(self.machine.spec, plan.threads, busy,
                     cache_sharing=plan.variant,
                     prefer=adapter.placement_hint(key), avoid=avoid)

    def free(self, adapter: StrategyAdapter) -> int:
        return free_cores(adapter.running.values(), self.cores)

    def _share(self, plan: OpPlan, adapter: StrategyAdapter,
               cores: tuple[int, ...] = ()) -> float:
        """Modeled bandwidth share of the launch against what's running."""
        if cores:
            # topology-aware contention: share computed from the actual
            # quadrant co-residents, not the flat global pool
            return self.machine.quadrant_bw_share(
                cores, [(r.threads, r.cores)
                        for r in adapter.running.values()])
        return self.bw_share(
            plan.threads, (r.threads for r in adapter.running.values()))

    def _duration(self, op: Op, plan: OpPlan, hyper: bool,
                  share: float) -> float:
        pl = Placement(plan.threads, cache_sharing=plan.variant,
                       hyper_thread=hyper)
        return self.machine.op_time(op, pl, bw_share=share)

    def launch(self, adapter: StrategyAdapter, key: NodeKey, plan: OpPlan,
               hyper: bool, cores: tuple[int, ...] = (), *,
               path: str = "s3_admit") -> ScheduledOp:
        op = adapter.op(key)
        share = self._share(plan, adapter, cores)
        dur = self._duration(op, plan, hyper, share)
        sched = ScheduledOp(op=op, threads=plan.threads, variant=plan.variant,
                            hyper=hyper, start=adapter.clock,
                            finish=adapter.clock + dur,
                            predicted=plan.predicted_time, cores=cores)
        if self.sink.enabled:
            self._emit(FAM_STRATEGY, path, key, adapter.clock,
                       op_class=op.op_class, threads=plan.threads,
                       variant=plan.variant, hyper=hyper,
                       predicted=plan.predicted_time, bw_share=share,
                       finish=sched.finish, cores=cores,
                       co_running=len(adapter.running))
            if self.config.topology == "quadrant" and cores:
                quads = quadrants_of(self.machine.spec, cores)
                self._emit(FAM_PLACEMENT,
                           "spill" if len(quads) > 1 else "book",
                           key, adapter.clock, quadrants=sorted(quads),
                           spill=len(quads) > 1, width=len(cores),
                           prefer=adapter.placement_hint(key))
        # interference bookkeeping: observed co-run duration vs solo model,
        # keyed by class pair (the machine doesn't care who launched what)
        # plus, under quadrant topology, the pair's placement relation —
        # a cross-quadrant slowdown must not blacklist quadrant-local
        # co-runs of the same classes
        quadrant = self.config.topology == "quadrant"
        for other in adapter.running.values():
            rel = (placement_relation(self.machine.spec, cores, other.cores)
                   if quadrant else REL_ANY)
            self.recorder.record(op.op_class, other.op.op_class,
                                 plan.predicted_time, dur, relation=rel)
        adapter.commit(key, sched)
        adapter.charge(key, sched)
        adapter.observe(key, sched, OBS_LAUNCH, 0.0)
        return sched

    # ---- Strategy 3 ----------------------------------------------------
    def try_corun(self, adapter: StrategyAdapter) -> bool:
        """Admit one ready op into idle cores.  True if launched."""
        free = self.free(adapter)
        if free <= 0:
            return False
        running = adapter.running
        running_classes = [r.op.op_class for r in running.values()]
        horizon = remaining_horizon(running.values(), adapter.clock)
        for group in adapter.ready_groups():
            # examine ready ops, most expensive first (they gate the
            # critical path)
            order = sorted(
                group,
                key=lambda k: -adapter.instance_plan(k).predicted_time)
            for key in order:
                op = adapter.op(key)
                traced = self.sink.enabled
                if not self._compatible(op.op_class, running_classes):
                    if traced:
                        self._emit(FAM_STRATEGY, "reject", key,
                                   adapter.clock, cause="blacklist",
                                   op_class=op.op_class)
                    continue
                avoid = self._placement_avoid(op.op_class, adapter)
                if avoid is None:
                    if traced:
                        self._emit(FAM_STRATEGY, "reject", key,
                                   adapter.clock, cause="no_feasible_quadrant",
                                   op_class=op.op_class)
                    continue
                cands = adapter.candidates_for(key, self.config.candidates)
                pick = pick_admissible(cands, free, horizon)
                if pick is None:
                    if traced:
                        self._emit(FAM_STRATEGY, "reject", key,
                                   adapter.clock, cause="no_admissible",
                                   op_class=op.op_class, free=free,
                                   horizon=horizon,
                                   candidates=[(c.threads, c.predicted_time)
                                               for c in cands])
                    continue
                proposal = pick
                pick = adapter.clamp(key, pick)
                if traced and (pick.threads != proposal.threads
                               or pick.variant != proposal.variant):
                    self._emit(FAM_STRATEGY, "s2_clamp", key, adapter.clock,
                               op_class=op.op_class,
                               from_threads=proposal.threads,
                               to_threads=pick.threads,
                               from_variant=proposal.variant,
                               to_variant=pick.variant)
                if pick.threads > free:
                    if traced:
                        self._emit(FAM_STRATEGY, "reject", key,
                                   adapter.clock, cause="clamp_overflow",
                                   op_class=op.op_class,
                                   threads=pick.threads, free=free)
                    continue
                cores = self._place(adapter, key, pick, avoid)
                if cores is None:
                    if traced:
                        self._emit(FAM_STRATEGY, "reject", key,
                                   adapter.clock, cause="no_placement",
                                   op_class=op.op_class,
                                   threads=pick.threads,
                                   avoid=sorted(avoid))
                    continue
                self.launch(adapter, key, pick, hyper=False, cores=cores)
                return True
        return False

    # ---- fallback ------------------------------------------------------
    def run_biggest(self, adapter: StrategyAdapter) -> bool:
        """Fallback: most time-consuming ready op at its frozen plan.

        When other ops are running, the clamped-to-idle-cores launch must
        still respect the throughput guard (with a little slack for
        contention): squeezing a big op into a few leftover cores makes it
        outlast everything and hurts throughput — better to wait.  With
        several groups (pool tenants), a later group's op may still fit
        when the most-owed group's biggest would outlast the running set —
        don't idle the cores over it.

        The fallback launches NEXT TO running ops, so it must honor the
        interference blacklist like every other launch path — this used to
        be the forked schedulers' silent gap: only ``try_corun`` and
        ``try_hyper`` checked compatibility, letting a blacklisted pair
        co-launch through the fallback."""
        free = self.free(adapter)
        if free <= 0:
            return False
        running = adapter.running
        if running and free < self.config.min_fallback_cores:
            return False
        running_classes = [r.op.op_class for r in running.values()]
        horizon = (remaining_horizon(running.values(), adapter.clock)
                   if running else float("inf"))
        traced = self.sink.enabled
        for group in adapter.ready_groups():
            cand = [k for k in group if self._compatible(
                adapter.op(k).op_class, running_classes)]
            if not cand:
                continue
            # biggest first; on a PLACEMENT failure (quadrant topology
            # only — flat placement cannot fail, so flat stays bit-for-bit
            # the single-candidate fallback) try the next-biggest op in
            # the SAME group instead of skipping the whole group.  A
            # horizon failure still skips to the next group: every smaller
            # op in this group outlasts the running set even harder at the
            # same clamped width, and a later group's op may still fit.
            order = sorted(
                cand,
                key=lambda k: -adapter.instance_plan(k).predicted_time)
            for key in order:
                plan = adapter.instance_plan(key)
                if plan.threads > free:
                    plan = OpPlan(free, plan.variant,
                                  adapter.predict(key, free, plan.variant))
                if plan.predicted_time > horizon * self.config.fallback_slack:
                    if traced:
                        self._emit(FAM_STRATEGY, "reject", key, adapter.clock,
                                   cause="fallback_outlasts_horizon",
                                   op_class=adapter.op(key).op_class,
                                   predicted=plan.predicted_time,
                                   horizon=horizon,
                                   slack=self.config.fallback_slack)
                    break
                avoid = self._placement_avoid(adapter.op(key).op_class,
                                              adapter)
                if avoid is None:
                    if traced:
                        self._emit(FAM_STRATEGY, "reject", key, adapter.clock,
                                   cause="no_feasible_quadrant",
                                   op_class=adapter.op(key).op_class)
                    continue
                cores = self._place(adapter, key, plan, avoid)
                if cores is None:
                    if traced:
                        self._emit(FAM_STRATEGY, "reject", key, adapter.clock,
                                   cause="no_placement",
                                   op_class=adapter.op(key).op_class,
                                   threads=plan.threads, avoid=sorted(avoid))
                    continue
                self.launch(adapter, key, plan, hyper=False, cores=cores,
                            path="fallback")
                return True
        return False

    # ---- Strategy 4 ----------------------------------------------------
    def try_hyper(self, adapter: StrategyAdapter) -> bool:
        """Free physical cores exhausted — run the smallest ready ops on
        the hyper-thread lane."""
        if not self.config.enable_s4:
            return False
        if self.free(adapter) > 0:
            return False
        running = adapter.running
        if sum(1 for r in running.values()
               if r.hyper) >= self.config.max_ht_corunners:
            return False
        running_classes = [r.op.op_class for r in running.values()]
        # smallest = shortest serial-execution time; ties resolve by group
        # order (fair share), then readiness order within the group
        keyed = [(adapter.serial_time(k), gi, i, k)
                 for gi, group in enumerate(adapter.ready_groups())
                 for i, k in enumerate(group)]
        for _, _, _, key in sorted(keyed, key=lambda t: t[:3]):
            op = adapter.op(key)
            # a hyper launch borrows busy cores machine-wide: every co-run
            # it joins is cross-quadrant, so the cross relation is hard
            if not self._compatible(op.op_class, running_classes,
                                    hyper=True):
                continue
            inst = adapter.instance_plan(key)
            threads = min(inst.threads, self.cores)
            if threads == inst.threads:
                plan = inst
            else:
                # clamped width => re-predict at the clamped width (same
                # rule as the run_biggest clamp); keeping the unclamped
                # width's predicted_time would mis-price the launch
                plan = OpPlan(threads, inst.variant,
                              adapter.predict(key, threads, inst.variant))
            self.launch(adapter, key, plan, hyper=True, path="s4_hyper")
            return True
        return False

    # ---- deadline-driven preemption ------------------------------------
    def _overdue_by_urgency(self, adapter: StrategyAdapter
                            ) -> list[NodeKey]:
        """Ready ops with non-positive deadline slack, most urgent first
        (earliest-deadline-first among tenants that are already late).
        The deadline path tries them ALL in order: one op being stuck
        (blacklisted against a running class, no viable victim) must not
        deny a less-urgent-but-claimable tenant its launch."""
        overdue: list[tuple[float, int, NodeKey]] = []
        for gi, group in enumerate(adapter.ready_groups()):
            for key in group:
                s = adapter.deadline_slack(key)
                if s is not None and s <= 0.0:
                    overdue.append((s, gi, key))
        overdue.sort(key=lambda t: t[:2])
        return [key for _, _, key in overdue]

    def try_preempt(self, adapter: StrategyAdapter) -> bool:
        """Deadline path, tried before normal S3 admission each drain
        iteration so an overdue op gets its PREFERRED width instead of
        being squeezed into whatever S3 happens to leave idle.

        If a ready op's tenant has run out of deadline slack, claim cores
        for it: (1) if a candidate fits the idle cores, launch it with the
        throughput guard waived (an op that outlasts the running set is a
        makespan concern; a blown SLO is worse); (2) otherwise cancel the
        running op with the largest remaining time and launch into the
        reclaimed cores.  Work-conserving: the victim returns to its ready
        frontier and the adapter's ``refund`` re-prices its partial run at
        the restart-waste factor.  Victims must predate this scheduling
        instant (an op relaunched at the same clock is never re-revoked, so
        one instant cannot ping-pong) and must be strictly less urgent than
        the waiter."""
        pol = self.config.preemption
        if not pol.enabled:
            return False
        for key in self._overdue_by_urgency(adapter):
            if self._try_claim(adapter, key):
                return True
        return False

    def _try_claim(self, adapter: StrategyAdapter, key: NodeKey) -> bool:
        """Claim cores for ONE overdue ready op (see ``try_preempt``)."""
        pol = self.config.preemption
        op = adapter.op(key)
        cands = adapter.candidates_for(key, self.config.candidates)
        if not cands:
            return False
        running = adapter.running
        free = self.free(adapter)
        floor = self.config.min_fallback_cores
        need = min(c.threads for c in cands)
        pred = min(c.predicted_time for c in cands if c.threads == need)
        # S3 off = serial execution: the deadline path must not introduce
        # co-running — it may only act on an idle machine or by REPLACING
        # the sole runner (one revoke), never by launching alongside it
        serial = not self.config.enable_s3
        if serial and running and (
                len(running) > 1 or next(iter(running.values())).hyper):
            return False
        must_preempt = serial and bool(running)
        # otherwise idle cores suffice when the preferred width fits OR a
        # squeezed launch loses at most ~2x width (bounded time penalty
        # beats the waste of revoking someone's partial work)
        traced = self.sink.enabled
        waiter_slack = adapter.deadline_slack(key)
        victim_keys: list[NodeKey] = []
        prefer: OpPlan | None = None       # multi-victim: seat this width
        price: MovePrice | None = None
        n_eligible = 0
        if must_preempt or (free < need
                            and free < max(floor, (need + 1) // 2)):
            # pick the victim(s) BEFORE revoking so a failed fit leaves
            # the running set untouched
            slack = waiter_slack
            eligible: list[tuple[NodeKey, ScheduledOp, int, float]] = []
            for idx, (vk, r) in enumerate(running.items()):
                if r.hyper or r.start >= adapter.clock:
                    continue
                vs = adapter.deadline_slack(vk)
                if vs is not None and (slack is None or vs <= slack):
                    continue               # never rob a tenant just as late
                remaining = r.finish - adapter.clock
                if remaining <= pred * pol.min_victim_advantage:
                    continue               # it finishes before the waiter
                eligible.append((vk, r, idx, remaining))
            n_eligible = len(eligible)
            victim_key = None
            if eligible:
                # largest remaining time; ties break on the scheduler-
                # meaningful key — fewest threads revoked (cheapest claim),
                # then the earliest-launched runner (stable launch order) —
                # never on the opaque NodeKey
                victim_key = max(
                    eligible,
                    key=lambda e: (e[3], -e[1].threads, -e[2]))[0]
                if (not must_preempt
                        and free + running[victim_key].threads < floor):
                    victim_key = None      # revoking gains too little
            if victim_key is not None:
                victim_keys = [victim_key]
            if pol.max_victims > 1 and not serial and eligible:
                mv = self._assemble_victim_set(adapter, key, eligible,
                                               free, victim_key)
                if mv is not None:
                    victim_keys, prefer, price = mv
            if not victim_keys and (must_preempt or free < floor):
                if traced:
                    self._emit(FAM_PREEMPTION, "no_victim", key,
                               adapter.clock, op_class=op.op_class,
                               waiter_slack=waiter_slack, free=free,
                               need=need, n_candidates=n_eligible)
                return False               # nothing useful to claim now
        rest = [r.op.op_class for vk, r in running.items()
                if vk not in victim_keys]
        if not self._compatible(op.op_class, rest):
            if traced:
                self._emit(FAM_PREEMPTION, "incompatible", key,
                           adapter.clock, op_class=op.op_class,
                           waiter_slack=waiter_slack)
            return False
        if victim_keys:
            for vk in victim_keys:
                revoked = adapter.revoke(vk)
                elapsed = adapter.clock - revoked.start
                if traced:
                    self._emit(FAM_PREEMPTION, "revoke", key, adapter.clock,
                               op_class=op.op_class,
                               waiter_slack=waiter_slack,
                               waiter_pred=pred, victim=vk,
                               victim_class=revoked.op.op_class,
                               victim_threads=revoked.threads,
                               victim_remaining=(revoked.finish
                                                 - adapter.clock),
                               victim_elapsed=elapsed,
                               n_candidates=n_eligible,
                               set_size=len(victim_keys))
                adapter.refund(vk, revoked, elapsed)
                adapter.observe(vk, revoked, OBS_REVOKE, elapsed)
            free = self.free(adapter)
            if traced and prefer is not None:
                self._emit(FAM_PREEMPTION, "multi_revoke", key,
                           adapter.clock, op_class=op.op_class,
                           waiter_slack=waiter_slack,
                           victims=list(victim_keys),
                           prefer_threads=prefer.threads,
                           gain=price.gain, waste=price.cost)
        elif traced:
            # the throughput guard is waived: the overdue op launches into
            # idle cores even though it may outlast the running set
            self._emit(FAM_PREEMPTION, "waive", key, adapter.clock,
                       op_class=op.op_class, waiter_slack=waiter_slack,
                       free=free, need=need)
        # multi-victim claims launch at the preferred width the set was
        # priced to seat; otherwise fewest-thread admissible candidate,
        # horizon deliberately waived; clamp to the claimed cores when the
        # preferred width is unreachable
        if prefer is not None:
            pick = prefer
        else:
            pick = pick_admissible(cands, free, float("inf"))
            if pick is None:
                pick = min(cands, key=lambda c: c.threads)
        pick = adapter.clamp(key, pick)
        if pick.threads > free:
            if traced:
                self._emit(FAM_PREEMPTION, "squeeze", key, adapter.clock,
                           op_class=op.op_class, from_threads=pick.threads,
                           to_threads=free, waiter_slack=waiter_slack)
            pick = OpPlan(free, pick.variant,
                          adapter.predict(key, free, pick.variant))
        # quadrant placement for the claimed launch: the cross-relation
        # avoid set is ADVISORY here — a blown SLO outranks re-observing a
        # cross-quadrant slowdown, and the victim is already revoked, so
        # when avoidance leaves too few cores the launch lands anyway
        avoid = self._placement_avoid(op.op_class, adapter) or frozenset()
        cores = self._place(adapter, key, pick, avoid)
        if cores is None:
            if traced and avoid:
                self._emit(FAM_PLACEMENT, "avoid_override", key,
                           adapter.clock, op_class=op.op_class,
                           avoid=sorted(avoid), width=pick.threads)
            cores = self._place(adapter, key, pick, frozenset())
        self.launch(adapter, key, pick, hyper=False, cores=cores,
                    path="deadline_claim")
        return True

    def _assemble_victim_set(
            self, adapter: StrategyAdapter, key: NodeKey,
            eligible: list[tuple[NodeKey, ScheduledOp, int, float]],
            free: int, single_key: NodeKey | None,
    ) -> tuple[list[NodeKey], OpPlan, MovePrice] | None:
        """Multi-victim preemption (``PreemptionPolicy.max_victims > 1``):
        a victim SET that seats the overdue op's preferred width when the
        single longest-remaining victim cannot.

        Victims are accumulated cheapest summed re-billed restart waste
        first, affinity-aware under quadrant topology (a victim whose
        cores sit in the waiter's preferred quadrant frees the cores the
        placement actually wants).  The set is adopted — atomically, no
        revoke happens on a failed price check — only when the priced SLO
        gain (predicted-time improvement at the preferred width, weighted
        by that width) STRICTLY exceeds the summed waste of the whole set
        (``repro.core.planstore.claim_price``).  Returns ``(victims,
        preferred_plan, price)`` or ``None`` to fall back to the
        single-victim move."""
        pol = self.config.preemption
        spec = self.machine.spec
        inst = adapter.instance_plan(key)
        prefer_w = min(inst.threads, self.cores)
        achievable = free + (adapter.running[single_key].threads
                             if single_key is not None else 0)
        if achievable >= prefer_w:
            return None                    # the single move already seats it
        t_with = (inst.predicted_time if prefer_w == inst.threads
                  else adapter.predict(key, prefer_w, inst.variant))
        hint = (adapter.placement_hint(key)
                if self.config.topology == "quadrant" else None)

        def waste_of(r: ScheduledOp) -> float:
            return restart_cost(r.threads, adapter.clock - r.start,
                                spec.restart_waste)

        def affinity(r: ScheduledOp) -> int:
            if hint is None or not r.cores:
                return 1
            return 0 if any(spec.quadrant_of_core(c) == hint
                            for c in r.cores) else 1

        order = sorted(eligible,
                       key=lambda e: (affinity(e[1]), waste_of(e[1]), e[2]))
        chosen: list[NodeKey] = []
        width = free
        waste = 0.0
        for vk, r, _, _ in order:
            if len(chosen) >= pol.max_victims or width >= prefer_w:
                break
            chosen.append(vk)
            width += r.threads
            waste += waste_of(r)
        if width < prefer_w:
            return None                    # even the full set can't seat it
        # the no-multi-victim alternative: launch at the best width the
        # single move reaches, or (machine fully busy, no single victim
        # viable) wait out the shortest eligible runner first
        if achievable >= 1:
            t_without = adapter.predict(key, achievable, inst.variant)
        else:
            t_without = min(rem for *_, rem in eligible) + t_with
        price = claim_price(prefer_w, t_without, t_with, waste)
        if not price.worth_it:
            if self.sink.enabled:
                self._emit(FAM_PREEMPTION, "multi_too_costly", key,
                           adapter.clock, op_class=adapter.op(key).op_class,
                           victims=list(chosen), prefer_threads=prefer_w,
                           gain=price.gain, waste=price.cost)
            return None
        return chosen, OpPlan(prefer_w, inst.variant, t_with), price

    # ---- width migration ------------------------------------------------
    def try_migrate(self, adapter: StrategyAdapter) -> bool:
        """Relaunch one running op at a different width when that is
        priced strictly cheaper than letting it finish where it is
        (``PreemptionPolicy.migration``; see ``migration_price``).

        Two situations make this win: the op was SQUEEZED at claim time
        (deadline path clamped it to whatever was free) and cores have
        since freed up, or the PlanStore's corrected curve moved the op's
        best width under ``feedback="ewma"``.  The move reuses the
        preemption machinery — revoke, refund (the discarded partial run
        is re-billed at the restart-waste factor), observe — and then
        relaunches the SAME node immediately, so exactly-once completion
        holds by construction.  A relaunch starts at the current clock and
        runners started at this instant are never migrated, so one
        scheduling instant cannot ping-pong an op between widths."""
        pol = self.config.preemption
        if not (pol.enabled and pol.migration):
            return False
        clock = adapter.clock
        free = self.free(adapter)
        quadrant = self.config.topology == "quadrant"
        spec = self.machine.spec
        best = None      # (net, key, plan, cores, price)
        for key, r in adapter.running.items():
            if r.hyper or r.start >= clock:
                continue
            remaining = r.finish - clock
            elapsed = clock - r.start
            budget = free + r.threads
            others = [o for k2, o in adapter.running.items() if k2 != key]
            other_loads = [(o.threads, o.cores) for o in others]
            busy = frozenset(c for o in others for c in o.cores)
            for c in adapter.candidates_for(key, self.config.candidates):
                if c.threads > budget:
                    continue
                if c.threads == r.threads and c.variant == r.variant:
                    continue               # same config: nothing to migrate
                if adapter.clamp(key, c) != c:
                    continue               # S2 hysteresis vetoes the width
                cores: tuple[int, ...] = ()
                if quadrant:
                    placed = place(spec, c.threads, busy,
                                   cache_sharing=c.variant,
                                   prefer=adapter.placement_hint(key),
                                   avoid=frozenset())
                    if placed is None:
                        continue
                    cores = placed
                    share = self.machine.quadrant_bw_share(
                        cores, other_loads)
                else:
                    share = self.bw_share(c.threads,
                                          (o.threads for o in others))
                # price the move against the duration the relaunch will
                # ACTUALLY get (contention-aware, same formula launch()
                # applies after the revoke) — not the solo curve
                dur = self._duration(r.op, c, False, share)
                price = migration_price(remaining, dur, elapsed,
                                        spec.restart_waste)
                if not price.worth_it:
                    continue
                net = price.gain - price.cost
                if best is None or net > best[0]:
                    best = (net, key, c, cores, price)
        if best is None:
            return False
        _, key, plan, cores, price = best
        revoked = adapter.revoke(key)
        elapsed = clock - revoked.start
        if self.sink.enabled:
            self._emit(FAM_PREEMPTION, "migrate", key, clock,
                       op_class=revoked.op.op_class,
                       from_threads=revoked.threads,
                       to_threads=plan.threads,
                       from_variant=revoked.variant,
                       to_variant=plan.variant,
                       remaining=revoked.finish - clock,
                       elapsed=elapsed, gain=price.gain, cost=price.cost)
        adapter.refund(key, revoked, elapsed)
        adapter.observe(key, revoked, OBS_REVOKE, elapsed)
        adapter.migrated(key, revoked)
        self.launch(adapter, key, plan, hyper=False, cores=cores,
                    path="migrate")
        return True

    # ---- the launch fixpoint loop --------------------------------------
    def drain(self, adapter: StrategyAdapter) -> None:
        """Launch everything launchable at this scheduling instant.

        S3 on: co-run admission with the run-biggest fallback.  S3 off:
        serial execution with per-op tuned concurrency only (Strategies
        1-2, the paper's Fig 3.a configuration).  The deadline path
        (``try_preempt``) runs first each iteration — an overdue op
        belongs on real cores at its preferred width, not squeezed into
        S3 leftovers or the 0.55-efficiency HT lane.  S4 tops up the
        hyper-thread lane either way."""
        launched = True
        while launched:
            # deadline path first: an overdue op must get its preferred
            # width now (preempting if the cores are taken), not be
            # squeezed into whatever S3 happens to leave idle
            launched = self.try_preempt(adapter)
            if launched:
                continue
            if self.config.enable_s3:
                if adapter.running:
                    launched = self.try_corun(adapter)
                    if not launched:
                        # paper fallback: no candidate fits without
                        # decreasing throughput -> run the most
                        # time-consuming ready op in the idle cores
                        launched = self.run_biggest(adapter)
                else:
                    launched = self.run_biggest(adapter)
            elif not adapter.running:
                launched = self.run_biggest(adapter)
            if not launched:
                # width migration before the HT lane: re-seating a running
                # op on real cores beats topping up the 0.55-efficiency
                # hyper-thread lane (a no-op unless the policy arms it)
                launched = self.try_migrate(adapter)
            if not launched:
                launched = self.try_hyper(adapter)
