"""Co-run interference recording (paper §III-D "Discussion").

The performance model predicts SOLO op times; co-running ops contend for
memory bandwidth, so observed times can exceed predictions.  The paper's
runtime "can record such cases and avoid co-running such operations in the
future training steps".  ``InterferenceRecorder`` implements exactly that:
per co-run pair (unordered op-class pair), track the observed slowdown
ratio; pairs whose EMA slowdown exceeds ``threshold`` are blacklisted and
the scheduler refuses to co-run them again.

Observations are additionally keyed by the PLACEMENT RELATION of the
co-run (``repro.core.placement``): ``"any"`` is the flat-topology bucket
(the original recorder, one bucket per pair), while quadrant topology
records ``"local"`` (the launches occupied disjoint quadrants) and
``"cross"`` (they straddled into shared quadrants) separately.  Keying by
op class alone used to let one bad cross-quadrant observation blacklist
the pair EVERYWHERE — including quadrant-local co-runs that never
conflicted; splitting the key means a cross-blacklisted pair can still be
co-scheduled into disjoint quadrants, and only a local-relation blacklist
forbids the pair outright.
"""

from __future__ import annotations

import dataclasses


def _pair_key(a: str, b: str) -> tuple[str, str]:
    return (a, b) if a <= b else (b, a)


def _rel_key(a: str, b: str, relation: str) -> tuple[str, str, str]:
    return _pair_key(a, b) + (relation,)


@dataclasses.dataclass
class InterferenceRecorder:
    threshold: float = 1.35       # blacklist pairs slower than 35% over solo
    ema_alpha: float = 0.4

    def __post_init__(self) -> None:
        self._ema: dict[tuple[str, str, str], float] = {}
        self._count: dict[tuple[str, str, str], int] = {}

    def record(self, cls_a: str, cls_b: str, predicted: float,
               observed: float, relation: str = "any") -> None:
        """Record one co-run observation of op with class ``cls_a`` running
        alongside ``cls_b``: predicted = solo model time, observed = actual.
        ``relation`` is the placement relation of the co-run ("any" for
        flat topology; "local"/"cross" under quadrant placement)."""
        key = _rel_key(cls_a, cls_b, relation)
        ratio = observed / max(predicted, 1e-12)
        prev = self._ema.get(key, ratio)
        self._ema[key] = (1 - self.ema_alpha) * prev + self.ema_alpha * ratio
        self._count[key] = self._count.get(key, 0) + 1

    def slowdown(self, cls_a: str, cls_b: str,
                 relation: str = "any") -> float:
        return self._ema.get(_rel_key(cls_a, cls_b, relation), 1.0)

    def blacklisted(self, cls_a: str, cls_b: str,
                    relation: str = "any") -> bool:
        return self.slowdown(cls_a, cls_b, relation) > self.threshold

    def compatible(self, cls_a: str, running_classes: list[str],
                   relation: str = "any") -> bool:
        return not any(self.blacklisted(cls_a, r, relation)
                       for r in running_classes)

    def blacklist(self) -> frozenset[tuple[str, str, str]]:
        """Snapshot of currently blacklisted (class, class, relation)
        triples.

        The paper's contract is that recorded interference is avoided "in
        the future training steps": schedulers freeze this snapshot at the
        start of a run and enforce it on EVERY launch path, while
        observations recorded during the run only take effect on the next
        one (see ``repro.core.strategy.StrategyCore.begin_run``)."""
        return frozenset(k for k in self._ema
                         if self._ema[k] > self.threshold)

    @property
    def observations(self) -> int:
        return sum(self._count.values())

    def report(self) -> dict[tuple[str, str, str], float]:
        return dict(self._ema)
