"""The runtime driver — the paper's Fig. 2 workflow.

``ConcurrencyRuntime`` glues the pieces together exactly in the paper's
order: the first N training steps run ops serially while the hill-climbing
profiler measures them (profiling steps); the resulting curves freeze a
``ConcurrencyPlan`` (Strategies 1-2); every subsequent step executes under
the co-run scheduler (Strategies 3-4).  The same step graph is reused
across steps (the paper's stable-step observation, §II-A), so profiling
cost amortizes over thousands of steps.

Two executors:

* the **simulated executor** (``SimMachine``-timed) validates the decision
  logic deterministically — this is what the paper-table benchmarks use;
* ``RealGraphExecutor`` runs op payloads (real jitted JAX callables) on a
  worker pool with dependency tracking — used by the examples and
  integration tests to show the runtime drives real computation.
"""

from __future__ import annotations

import dataclasses
import os
import time
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait

from repro.core.concurrency import ConcurrencyController, ConcurrencyPlan
from repro.core.graph import Op, OpGraph
from repro.core.interference import InterferenceRecorder
from repro.core.perfmodel import (CurveCache, HillClimbProfiler, ProfileStore,
                                  paper_case_lists)
from repro.core.planstore import (OBS_FINISH, OpObservation, PlanStore,
                                  make_plan_store)
from repro.core.scheduler import CorunScheduler, ScheduleResult, uniform_schedule
from repro.core.simmachine import Placement, SimMachine
from repro.core.strategy import (CONFIG_SCHEMA_VERSION, StrategyConfig,
                                 _check_config_dict,
                                 fold_deprecated_strategy_kwargs)
from repro.obs.trace import TraceSink


@dataclasses.dataclass(init=False)
class RuntimeConfig:
    """Single-job runtime knobs.  Strategy-owned knobs (S3/S4 switches,
    candidate counts, topology, feedback, sink, ...) live ONCE on the
    composed ``strategy`` field; only the knobs the runtime itself
    consumes (profiling interval, S2 clamp, interference threshold) are
    declared here.  The old flat constructor kwargs
    (``RuntimeConfig(feedback="ewma")``) keep working with a
    DeprecationWarning — they fold onto ``strategy``."""

    interval: int = 4               # hill-climb probe interval x
    max_deviation: int = 2          # Strategy 2 clamp (paper's empirical 2)
    strategy2: bool = True
    interference_threshold: float = 1.35
    strategy: StrategyConfig = dataclasses.field(
        default_factory=StrategyConfig)

    def __init__(self, interval: int = 4, max_deviation: int = 2,
                 strategy2: bool = True,
                 interference_threshold: float = 1.35,
                 strategy: StrategyConfig | None = None, **deprecated):
        self.interval = interval
        self.max_deviation = max_deviation
        self.strategy2 = strategy2
        self.interference_threshold = interference_threshold
        self.strategy = fold_deprecated_strategy_kwargs(
            type(self).__name__,
            strategy if strategy is not None else StrategyConfig(),
            deprecated)

    # read-only views of the strategy-owned knobs, so the sprawling
    # existing read sites (schedulers, benchmarks, tests) keep working
    @property
    def enable_s3(self) -> bool: return self.strategy.enable_s3

    @property
    def enable_s4(self) -> bool: return self.strategy.enable_s4

    @property
    def candidates(self) -> int: return self.strategy.candidates

    @property
    def max_ht_corunners(self) -> int: return self.strategy.max_ht_corunners

    @property
    def min_fallback_cores(self) -> int:
        return self.strategy.min_fallback_cores

    @property
    def fallback_slack(self) -> float: return self.strategy.fallback_slack

    @property
    def topology(self) -> str: return self.strategy.topology

    @property
    def feedback(self) -> str: return self.strategy.feedback

    @property
    def sink(self) -> TraceSink: return self.strategy.sink

    def strategy_config(self) -> StrategyConfig:
        """The shared-core view of these knobs (see repro.core.strategy).
        The multi-tenant PoolConfig composes the same StrategyConfig, so
        Strategy-3/4 rule parameters cannot drift between schedulers."""
        return self.strategy

    def to_dict(self) -> dict:
        """Versioned JSON form (the daemon's persisted store and the CLI
        share this serialization; the strategy nests its own document)."""
        return {"schema": CONFIG_SCHEMA_VERSION,
                "interval": self.interval,
                "max_deviation": self.max_deviation,
                "strategy2": self.strategy2,
                "interference_threshold": self.interference_threshold,
                "strategy": self.strategy.to_dict()}

    @classmethod
    def from_dict(cls, d) -> "RuntimeConfig":
        d = dict(d)
        strat = d.pop("strategy", None)
        kw = _check_config_dict(
            cls.__name__, d,
            {"interval", "max_deviation", "strategy2",
             "interference_threshold"})
        if strat is not None:
            kw["strategy"] = StrategyConfig.from_dict(strat)
        return cls(**kw)


@dataclasses.dataclass
class TrainingSummary:
    profiling_steps: int
    profiling_time: float           # serial time spent probing
    step_time: float                # steady-state scheduled step time
    baseline_step_time: float       # TF-recommendation uniform schedule
    total_steps: int

    @property
    def speedup(self) -> float:
        return self.baseline_step_time / self.step_time

    @property
    def total_time(self) -> float:
        return self.profiling_time + self.step_time * max(
            0, self.total_steps - self.profiling_steps)

    @property
    def profiling_overhead(self) -> float:
        return self.profiling_time / max(self.total_time, 1e-12)


class ConcurrencyRuntime:
    def __init__(self, machine: SimMachine | None = None,
                 config: RuntimeConfig | None = None,
                 plan_cache: "CurveCache | None" = None):
        self.machine = machine or SimMachine()
        self.config = config or RuntimeConfig()
        # optional cross-job curve cache (multi-tenant pool): profiling
        # probes one job paid for are reused by every later job
        self.plan_cache = plan_cache
        self.store: ProfileStore | None = None
        self.plan: ConcurrencyPlan | None = None
        self.controller: ConcurrencyController | None = None
        # the closed-loop plan store (built at profile time): every
        # prediction the scheduler consumes and every completion it
        # reports flows through it; persists across execute_step calls so
        # feedback="ewma" corrections carry from one step to the next
        self.planstore: PlanStore | None = None
        self.recorder = InterferenceRecorder(
            threshold=self.config.interference_threshold)

    # ---- phase 1: profiling steps -------------------------------------
    def _measure(self, op: Op, threads: int, variant: bool) -> float:
        return self.machine.op_time(
            op, Placement(threads, cache_sharing=variant))

    def profile(self, graph: OpGraph) -> ProfileStore:
        if self.plan_cache is not None:
            # caches that can pin themselves must refuse reuse across a
            # different timing function OR probe protocol: a curve's
            # measured samples carry the probe spacing, which Strategy-3
            # candidates and the S2 clamp's case_step assume
            bind = getattr(self.plan_cache, "bind_machine", None)
            if bind is not None:
                bind((self.machine.fingerprint, self.config.interval))
        profiler = HillClimbProfiler(
            measure=self._measure,
            case_lists=paper_case_lists(self.machine.spec.cores,
                                        self.machine.spec.tiles),
            interval=self.config.interval)
        # dynamic graphs are profiled/planned through their static
        # profile_view — one clone of every op a region could ever
        # materialize, so the frozen plan covers loop bodies and branches
        # before the first iteration exists (a static graph is its own
        # view: bit-identical to profiling the graph directly)
        view = graph.profile_view()
        self.store = profiler.profile_graph(view, cache=self.plan_cache)
        self.controller = ConcurrencyController(
            self.store, max_deviation=self.config.max_deviation,
            default_threads=self.machine.spec.cores,
            interval=self.config.interval)
        self.plan = self.controller.build_plan(view)
        self.planstore = make_plan_store(self.config.feedback,
                                         self.controller)
        return self.store

    def profiling_cost(self) -> tuple[int, float]:
        """(#profiling steps, serial seconds spent probing).

        The paper bounds N <= C/x * 2; each probing step runs every op once
        serially at that step's concurrency."""
        assert self.store is not None
        probes_per_curve = [c.probes for c in self.store.curves.values()]
        n_steps = max(probes_per_curve) if probes_per_curve else 0
        # curves served by a warm plan cache carry probes=0 — their sample
        # times were paid by another job, not this run
        probe_time = sum(y for c in self.store.curves.values() if c.probes
                         for pts in c.samples.values() for _, y in pts)
        return n_steps, probe_time

    # ---- phase 2: scheduled steps --------------------------------------
    def scheduler(self) -> CorunScheduler:
        assert self.plan is not None and self.controller is not None
        cfg = self.config
        return CorunScheduler(
            self.machine, self.controller, self.plan,
            recorder=self.recorder,
            enable_s3=cfg.enable_s3,
            enable_s4=cfg.enable_s4,
            strategy2=cfg.strategy2,
            max_ht_corunners=cfg.max_ht_corunners,
            candidates=cfg.candidates,
            min_fallback_cores=cfg.min_fallback_cores,
            fallback_slack=cfg.fallback_slack,
            topology=cfg.topology,
            feedback=cfg.feedback,
            sink=cfg.sink,
            planstore=self.planstore)

    def execute_step(self, graph: OpGraph) -> ScheduleResult:
        if self.plan is None:
            self.profile(graph)
        return self.scheduler().run(graph)

    # ---- end-to-end ------------------------------------------------------
    def train(self, graph: OpGraph, total_steps: int = 1000,
              baseline_intra: int | None = None) -> TrainingSummary:
        self.profile(graph)
        n_steps, probe_time = self.profiling_cost()
        result = self.execute_step(graph)
        baseline = uniform_schedule(
            graph, self.machine,
            intra=baseline_intra or self.machine.spec.cores, inter=1)
        return TrainingSummary(
            profiling_steps=n_steps,
            profiling_time=probe_time,
            step_time=result.makespan,
            baseline_step_time=baseline.makespan,
            total_steps=total_steps)


# ---------------------------------------------------------------------------
# Real-payload executor
# ---------------------------------------------------------------------------

def report_payload_observation(store: PlanStore, plan: ConcurrencyPlan | None,
                               op, dt: float) -> None:
    """Report one real payload completion through ``PlanStore.observe``.

    The wall time is attributed to the op's frozen-plan width (falling
    back to solo when the plan has no entry), so real timings feed the
    same closed loop the simulated schedulers use.  Shared by the batch
    ``RealGraphExecutor.run`` path and the service daemon's persistent
    executor."""
    if plan is not None and op.size_key in plan.per_instance:
        p = plan.per_instance[op.size_key]
        threads, variant = p.threads, p.variant
    else:
        threads, variant = 1, True
    try:
        predicted = store.predict(op, threads, variant)
    except KeyError:
        # op never profiled under this store — the observation record
        # still needs a predicted value (it is informative only:
        # AdaptivePlanStore re-derives the base prediction itself and
        # skips ops without a curve)
        predicted = dt
    store.observe(OpObservation(
        op=op, threads=threads, variant=variant, hyper=False,
        predicted=predicted, observed=dt, kind=OBS_FINISH))


def _request_host_devices(n: int) -> None:
    """Ask XLA for ``n`` host-platform devices.  Appends the flag to
    ``XLA_FLAGS`` unless a device count is already pinned there (the
    launch tools set 512 at import; respect any explicit choice).  A
    no-op on an already-initialized jax — the count is locked at first
    init, and ``device_for`` round-robins over whatever jax granted."""
    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" in flags:
        return
    flag = f"--xla_force_host_platform_device_count={n}"
    os.environ["XLA_FLAGS"] = f"{flags} {flag}".strip()


class RealGraphExecutor:
    """Dependency-ordered execution of op payloads on a worker pool.

    ``op.payload`` is ``fn(dep_results: dict[uid, value]) -> value``.  The
    worker count plays the role of inter-op parallelism; per-op results are
    returned with wall-clock timings so the runtime's decisions can be
    validated against real JAX computations.

    Real timings can feed the same closed loop as the simulated
    schedulers: pass ``store``/``plan`` to ``run`` and every payload
    completion is reported through ``PlanStore.observe`` as an
    ``OBS_FINISH`` event at the op's frozen-plan width — the first step
    toward a pool-backed real executor whose observed wall times drive
    online re-estimation.

    ``persistent=True`` switches to the service-daemon mode: the worker
    pool outlives any one graph and callers drive it op-by-op with
    ``submit_op`` (the pool's launch decisions pick the order) instead of
    handing over a whole graph.  ``submit_op`` futures wait for their
    dependency futures INSIDE the worker, which keeps ``Future.cancel``
    meaningful: a revoked op that has not reached a worker yet is
    cancelled before any payload runs.  Deadlock-free because payloads
    are only submitted in dependency order (the pool launches an op only
    after its deps completed), so every queued task waits only on
    strictly earlier submissions.

    ``n_devices`` maps the cluster daemon's simulated machines onto
    DISTINCT host JAX devices: it requests that many host-platform XLA
    devices (``--xla_force_host_platform_device_count``, which only
    takes effect if set before jax's first initialization — jax locks
    the device count then) and ``device_for(machine)`` returns the
    device a machine's payloads should land on.  Payload execution and
    the device mapping degrade gracefully without jax: ``device_for``
    returns None and payloads run unpinned."""

    def __init__(self, max_workers: int = 2, *, persistent: bool = False,
                 n_devices: int | None = None):
        self.max_workers = max_workers
        self.n_devices = n_devices
        self._devices: tuple | None = None     # resolved lazily
        if n_devices is not None and n_devices > 1:
            _request_host_devices(n_devices)
        self._pool: ThreadPoolExecutor | None = (
            ThreadPoolExecutor(max_workers=max_workers)
            if persistent else None)

    # ---- persistent (service-daemon) mode ------------------------------
    def device_for(self, machine: int | None):
        """The host JAX device simulated machine ``machine`` maps to
        (round-robin when jax granted fewer devices than machines; None
        when unmapped, jax-less, or ``n_devices`` was never set)."""
        if machine is None or self.n_devices is None:
            return None
        if self._devices is None:
            try:
                import jax
                self._devices = tuple(jax.devices("cpu"))
            except Exception:  # noqa: BLE001 - jax-less: run unpinned
                self._devices = ()
        if not self._devices:
            return None
        return self._devices[machine % len(self._devices)]

    def submit_op(self, op, deps: dict[int, object],
                  device=None) -> Future:
        """Submit one op's payload to the persistent worker set.

        ``deps`` maps dep uid -> either the dep's ``Future`` (resolved
        inside the worker) or an already-materialized value (ops without
        payloads produce ``None`` directly).  ``device`` (from
        ``device_for``) pins the payload's jax computations to one host
        device.  Returns a future of ``(result, wall_seconds)``."""
        assert self._pool is not None, "submit_op needs persistent=True"

        def call() -> tuple[object, float]:
            # dep futures resolve to (value, wall_s); payloads see values
            vals = {u: (f.result()[0] if isinstance(f, Future) else f)
                    for u, f in deps.items()}
            ts = time.perf_counter()
            if device is not None:
                import jax
                with jax.default_device(device):
                    out = op.payload(vals) if op.payload else None
            else:
                out = op.payload(vals) if op.payload else None
            return out, time.perf_counter() - ts

        return self._pool.submit(call)

    def close(self) -> None:
        """Shut down the persistent worker set (queued work cancelled,
        running payloads finish).  No-op in batch mode."""
        if self._pool is not None:
            self._pool.shutdown(wait=False, cancel_futures=True)
            self._pool = None

    def run(self, graph: OpGraph, *, store: PlanStore | None = None,
            plan: ConcurrencyPlan | None = None
            ) -> tuple[dict[int, object], dict[int, float], float]:
        results: dict[int, object] = {}
        timings: dict[int, float] = {}
        pending = {u: len(op.deps) for u, op in graph.ops.items()}
        ready = [u for u, n in pending.items() if n == 0]
        t0 = time.perf_counter()

        def observe(uid: int, dt: float) -> None:
            if store is None:
                return
            report_payload_observation(store, plan, graph.ops[uid], dt)

        with ThreadPoolExecutor(max_workers=self.max_workers) as pool:
            futures: dict[Future, int] = {}

            def submit(uid: int) -> None:
                op = graph.ops[uid]
                deps = {d: results[d] for d in op.deps}

                def call(op=op, deps=deps):
                    ts = time.perf_counter()
                    out = op.payload(deps) if op.payload else None
                    return out, time.perf_counter() - ts

                futures[pool.submit(call)] = uid

            for u in ready:
                submit(u)
            while futures:
                done, _ = wait(list(futures), return_when=FIRST_COMPLETED)
                for fut in done:
                    uid = futures.pop(fut)
                    out, dt = fut.result()
                    results[uid] = out
                    timings[uid] = dt
                    observe(uid, dt)
                    for c in graph.consumers(uid):
                        pending[c] -= 1
                        if pending[c] == 0:
                            submit(c)
        return results, timings, time.perf_counter() - t0
