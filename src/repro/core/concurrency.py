"""Concurrency control — the paper's Strategies 1 and 2 (§III-D).

* Strategy 1: every op instance runs with the thread count minimizing its
  modeled time (per (op_class, input_shape) curve).
* Strategy 2: hysteresis — all instances of an op class share ONE thread
  count, the optimum of the class's most expensive instance, because
  re-deciding concurrency per instance thrashes caches and re-spawns
  threads.  A scheduler proposal deviating from the class plan by more than
  ``max_deviation`` (paper's empirical value: 2 cases) is clamped back.
"""

from __future__ import annotations

import dataclasses
from typing import Hashable

from repro.core.graph import Op, OpGraph
from repro.core.perfmodel import CurveModel, ProfileStore


@dataclasses.dataclass(frozen=True)
class OpPlan:
    threads: int
    variant: bool            # affinity flavor (cache sharing / collective axis)
    predicted_time: float


@dataclasses.dataclass
class ConcurrencyPlan:
    """Frozen output of strategies 1-2 for one graph."""

    per_instance: dict[Hashable, OpPlan]       # size_key -> plan (Strategy 1)
    per_class: dict[str, OpPlan]                # op_class -> plan (Strategy 2)
    max_deviation: int = 2                      # in probe-CASE units
    case_step: int = 8                          # threads per probe case step

    def plan_for(self, op: Op, *, strategy2: bool = True) -> OpPlan:
        if strategy2 and op.op_class in self.per_class:
            return self.per_class[op.op_class]
        return self.per_instance[op.size_key]

    def clamp(self, op: Op, proposal: OpPlan) -> OpPlan:
        """Strategy 2 guard over Strategy 3 proposals: if the scheduler's
        candidate deviates from the class plan by more than max_deviation
        probe cases (paper's empirical "2"), fall back to the class plan
        (§III-D, S3/S2 interaction).  Deviation is measured in profiling-
        case units because candidates are drawn from the probe grid."""
        cls = self.per_class.get(op.op_class)
        if cls is None:
            return proposal
        if abs(proposal.threads - cls.threads) > self.max_deviation * self.case_step:
            return cls
        return proposal


class ConcurrencyController:
    """Builds the frozen plan from hill-climb profiles.

    Ops with ``tunable=False`` (Eigen-implemented in the paper's setting,
    §IV-A) are pinned to the session-default concurrency
    (``default_threads``, cache-sharing) in every plan and candidate list —
    the runtime never re-tunes them."""

    def __init__(self, store: ProfileStore, max_deviation: int = 2,
                 default_threads: int = 68, interval: int = 4):
        self.store = store
        self.max_deviation = max_deviation
        self.default_threads = default_threads
        self.interval = interval

    def _fixed_plan(self, curve: CurveModel) -> OpPlan:
        t = self.default_threads
        return OpPlan(t, True, curve.predict(t, True))

    def build_plan(self, graph: OpGraph) -> ConcurrencyPlan:
        tunable_cls = {cls: all(o.tunable for o in ops)
                       for cls, ops in graph.classes().items()}
        per_instance: dict[Hashable, OpPlan] = {}
        for key, curve in self.store.curves.items():
            if not tunable_cls.get(key[0], True):
                per_instance[key] = self._fixed_plan(curve)
                continue
            t, v, y = curve.best()
            per_instance[key] = OpPlan(t, v, y)

        per_class: dict[str, OpPlan] = {}
        for cls, ops in graph.classes().items():
            # the paper fixes the class's threads by its most expensive
            # (largest-input) instance
            heaviest = max(ops, key=lambda o: o.weight)
            curve = self.store.curves.get(heaviest.size_key)
            if curve is None:
                continue
            if not tunable_cls[cls]:
                per_class[cls] = self._fixed_plan(curve)
                continue
            t, v, _ = curve.best()
            # predicted time is instance-specific; store class default time
            per_class[cls] = OpPlan(t, v, curve.predict(t, v))
        return ConcurrencyPlan(per_instance=per_instance, per_class=per_class,
                               max_deviation=self.max_deviation,
                               case_step=self.interval * 2)

    def candidates_for(self, op: Op, k: int = 3) -> list[OpPlan]:
        """Strategy 3's top-k candidate configurations for one op."""
        curve: CurveModel = self.store.curve(op)
        if not op.tunable:
            return [self._fixed_plan(curve)]
        return [OpPlan(t, v, y) for t, v, y in curve.candidates(k)]
