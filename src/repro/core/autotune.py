"""TPU adaptation of the paper's technique: shard-degree autotuning.

On TPU the paper's "intra-op parallelism" becomes the **shard degree** of
an op class on the `model` mesh axis (DESIGN.md §2, assumption A2), and the
measurement function becomes the compiled roofline time of the op lowered
at that degree (assumption A1).  The algorithm is UNCHANGED: the same
``HillClimbProfiler`` climbs the degree ladder (1,2,4,...,M — the
power-of-two ladder is the analogue of the paper's even-threads-only rule),
stops at the first time increase, interpolates untested degrees, and the
same Strategy-1/2 freeze fixes one degree per op class.

The Strategy-3 analogue (`corun_groups`) space-shares the model axis
between independent op classes whose tuned degrees underuse it, balancing
sub-mesh sizes so co-runners finish together (the paper's throughput
guard).  The Strategy-4 analogue is a flag consumed by the trainer: overlap
collectives of small ops under big ops' compute (collective matmul /
hierarchical all-reduce), i.e. use the "second pipe".
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

from repro.core.graph import Op
from repro.core.perfmodel import CurveModel, HillClimbProfiler, power_of_two_cases
from repro.hw.spec import dominant_term


@dataclasses.dataclass(frozen=True)
class RooflineMeasurement:
    """The three terms, seconds, for one candidate configuration."""

    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def time(self) -> float:
        """Overlapped roofline bound — what the hill climb minimizes."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def serial_time(self) -> float:
        return self.compute_s + self.memory_s + self.collective_s

    @property
    def bottleneck(self) -> str:
        return dominant_term(self.compute_s, self.memory_s, self.collective_s)


# (op_class, degree, variant) -> RooflineMeasurement.  ``variant`` selects
# the collective-axis flavor (False = contiguous minor axis / ICI-near,
# True = split across the pod axis) — the affinity analogue.
MeasureShardFn = Callable[[str, int, bool], RooflineMeasurement]


@dataclasses.dataclass(frozen=True)
class ShardDecision:
    op_class: str
    degree: int
    variant: bool
    predicted: RooflineMeasurement


@dataclasses.dataclass
class ShardPlanResult:
    decisions: dict[str, ShardDecision]
    curves: dict[str, CurveModel]
    probes: int

    def degree(self, op_class: str, default: int = 1) -> int:
        d = self.decisions.get(op_class)
        return d.degree if d else default


class ShardDegreeAutotuner:
    """Hill-climb per-op-class shard degrees with roofline measurements."""

    def __init__(self, measure: MeasureShardFn, *, max_degree: int,
                 variants: tuple[bool, ...] = (False,), interval: int = 1):
        self.measure = measure
        self.max_degree = max_degree
        self.variants = variants
        self.interval = interval
        self._cache: dict[tuple[str, int, bool], RooflineMeasurement] = {}

    def _measured(self, op_class: str, degree: int, variant: bool
                  ) -> RooflineMeasurement:
        key = (op_class, degree, variant)
        if key not in self._cache:
            self._cache[key] = self.measure(op_class, degree, variant)
        return self._cache[key]

    def tune(self, op_classes: list[str]) -> ShardPlanResult:
        cases = {v: power_of_two_cases(self.max_degree)[False]
                 for v in self.variants}
        decisions: dict[str, ShardDecision] = {}
        curves: dict[str, CurveModel] = {}
        probes = 0
        for cls in op_classes:
            def measure_fn(op: Op, degree: int, variant: bool,
                           _cls=cls) -> float:
                return self._measured(_cls, degree, variant).time

            profiler = HillClimbProfiler(measure=measure_fn,
                                         case_lists=cases,
                                         interval=self.interval)
            dummy = Op(uid=0, name=cls, op_class=cls, input_shape=())
            curve = profiler.profile(dummy)
            probes += curve.probes
            deg, variant, _ = curve.measured_best()
            decisions[cls] = ShardDecision(
                op_class=cls, degree=deg, variant=variant,
                predicted=self._measured(cls, deg, variant))
            curves[cls] = curve
        return ShardPlanResult(decisions=decisions, curves=curves,
                               probes=probes)


# ---------------------------------------------------------------------------
# Strategy-3 analogue: space-share the model axis between independent ops.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CorunGroup:
    members: tuple[str, ...]        # op classes co-running
    degrees: tuple[int, ...]        # sub-mesh sizes, sum <= axis
    makespan: float


def corun_groups(plan: ShardPlanResult, independent_sets: list[list[str]],
                 axis_size: int) -> list[CorunGroup]:
    """For each set of mutually independent op classes, decide how to
    partition the model axis among them (paper Strategy 3 / Table III's
    'co-run with threads control').

    Greedy: scale each member's degree ladder so the group fits the axis,
    choosing the split minimizing max member time (the throughput guard:
    co-runners should finish together)."""
    groups: list[CorunGroup] = []
    for members in independent_sets:
        members = [m for m in members if m in plan.curves]
        if not members:
            continue
        if len(members) == 1:
            d = plan.decisions[members[0]]
            groups.append(CorunGroup((members[0],), (d.degree,),
                                     d.predicted.time))
            continue
        best: CorunGroup | None = None
        # enumerate power-of-two splits of the axis among members
        ladders = [1 << i for i in range(int(math.log2(axis_size)) + 1)]

        def search(i: int, remaining: int, degs: list[int]) -> None:
            nonlocal best
            if i == len(members):
                t = max(plan.curves[m].predict(d, plan.decisions[m].variant)
                        for m, d in zip(members, degs))
                if best is None or t < best.makespan:
                    best = CorunGroup(tuple(members), tuple(degs), t)
                return
            for d in ladders:
                if d <= remaining - (len(members) - i - 1):
                    search(i + 1, remaining - d, degs + [d])

        search(0, axis_size, [])
        sequential = sum(plan.curves[m].predict(
            plan.decisions[m].degree, plan.decisions[m].variant)
            for m in members)
        if best is not None and best.makespan < sequential:
            groups.append(best)
        else:
            # co-running loses: keep them sequential at tuned degrees
            for m in members:
                d = plan.decisions[m]
                groups.append(CorunGroup((m,), (d.degree,), d.predicted.time))
    return groups
