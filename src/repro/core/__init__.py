"""Core library: the paper's runtime concurrency control + op scheduling.

Faithful pieces (paper SIII):
  graph        -- dataflow op-graph IR the runtime schedules
  perfmodel    -- hill-climbing performance model + regression baseline
  concurrency  -- Strategies 1-2 (per-op parallelism, hysteresis)
  planstore    -- closed-loop plan store: every prediction out
                  (predict/candidates/demand/critical-path), every
                  observation back in (launch/finish/revoke events;
                  EWMA re-estimation under feedback="ewma")
  strategy     -- StrategyCore: the S2-clamp/S3-admission/S4-hyper rules,
                  shared by CorunScheduler and the multitenant pool
  scheduler    -- single-graph adapter over StrategyCore + baselines
  interference -- co-run slowdown blacklist (SIII-D discussion)
  simmachine   -- deterministic KNL-like cost oracle (see DESIGN.md A4)
  runtime      -- profile->freeze->schedule driver, real-payload executor

TPU adaptation (DESIGN.md S2):
  autotune     -- shard-degree hill climbing on compiled roofline cost
"""

from repro.core.graph import (
    CondRegion, DynamicGraphBuilder, DynamicOpGraph, GraphBuilder, Op,
    OpGraph, RegionEvent, WhileRegion, PAPER_INPUT_SIZES,
    build_early_exit_wave, build_paper_graph, build_recurrent_step_graph,
    build_transformer_step_graph, region_exit_op)
from repro.core.perfmodel import (
    CurveCache, CurveModel, HillClimbProfiler, ProfileStore, RegressionSuite,
    paper_case_lists, power_of_two_cases, REGRESSORS)
from repro.core.concurrency import ConcurrencyController, ConcurrencyPlan, OpPlan
from repro.core.planstore import (
    AdaptivePlanStore, CorrectionTable, DemandIndex, FrozenPlanStore,
    MovePrice, OpObservation,
    PlanStore, TripCountEstimator, FEEDBACK_MODES, OBS_FINISH, OBS_LAUNCH,
    OBS_REVOKE, critical_path_from, make_plan_store, split_price)
from repro.core.strategy import (
    PreemptionPolicy, StrategyAdapter, StrategyConfig, StrategyCore,
    free_cores, pick_admissible, remaining_horizon)
from repro.core.scheduler import (
    CorunScheduler, ScheduleResult, ScheduledOp, uniform_schedule,
    manual_best_schedule)
from repro.core.interference import InterferenceRecorder
from repro.core.simmachine import SimMachine, Placement
from repro.core.runtime import (
    ConcurrencyRuntime, RuntimeConfig, TrainingSummary, RealGraphExecutor)
from repro.core.autotune import (
    RooflineMeasurement, ShardDegreeAutotuner, ShardDecision,
    ShardPlanResult, corun_groups, CorunGroup)

__all__ = [
    "Op", "OpGraph", "GraphBuilder", "build_paper_graph",
    "build_transformer_step_graph", "PAPER_INPUT_SIZES",
    "CondRegion", "DynamicGraphBuilder", "DynamicOpGraph", "RegionEvent",
    "WhileRegion", "build_early_exit_wave", "build_recurrent_step_graph",
    "region_exit_op", "TripCountEstimator",
    "CurveCache", "CurveModel", "HillClimbProfiler", "ProfileStore",
    "RegressionSuite",
    "paper_case_lists", "power_of_two_cases", "REGRESSORS",
    "ConcurrencyController", "ConcurrencyPlan", "OpPlan",
    "AdaptivePlanStore", "CorrectionTable", "DemandIndex", "FrozenPlanStore",
    "MovePrice", "OpObservation", "PlanStore", "FEEDBACK_MODES",
    "OBS_FINISH", "OBS_LAUNCH", "OBS_REVOKE",
    "critical_path_from", "make_plan_store", "split_price",
    "PreemptionPolicy", "StrategyAdapter", "StrategyConfig", "StrategyCore",
    "free_cores", "pick_admissible", "remaining_horizon",
    "CorunScheduler", "ScheduleResult", "ScheduledOp",
    "uniform_schedule",
    "manual_best_schedule", "InterferenceRecorder",
    "SimMachine", "Placement",
    "ConcurrencyRuntime", "RuntimeConfig", "TrainingSummary",
    "RealGraphExecutor",
    "RooflineMeasurement", "ShardDegreeAutotuner", "ShardDecision",
    "ShardPlanResult", "corun_groups", "CorunGroup",
]
