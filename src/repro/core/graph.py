"""Dataflow op-graph IR — the unit the paper's runtime schedules.

A training step is a DAG of ``Op`` nodes.  Each op carries the analytic
workload attributes the cost oracles need (flops, bytes moved, working set,
parallel fraction) plus its *op class* — the key under which concurrency
decisions are cached (paper Strategy 2 keys decisions by operation type, not
instance).

Graph builders for the paper's three evaluation networks (ResNet-50, DCGAN,
Inception-v3 — op mixes taken from the paper's Table VI and profiling
claims) and for transformer-block step graphs (the TPU-side integration)
live here too.
"""

from __future__ import annotations

import dataclasses
import hashlib
from collections import defaultdict, deque
from typing import Callable, Iterable


@dataclasses.dataclass
class Op:
    """One schedulable operation instance."""

    uid: int
    name: str                      # unique instance name, e.g. "conv2d_bwd_filter/12"
    op_class: str                  # class key, e.g. "Conv2DBackpropFilter"
    input_shape: tuple[int, ...]   # the paper's "input data size"
    flops: float = 0.0
    bytes_moved: float = 0.0       # main-memory traffic at parallelism 1
    working_set: float = 0.0       # bytes live during execution
    parallel_fraction: float = 0.95  # Amdahl fraction (simmachine only)
    deps: tuple[int, ...] = ()     # uids of producers
    payload: Callable | None = None  # optional real callable (jitted JAX op)
    tunable: bool = True           # False: Eigen-style op, keep session default

    @property
    def size_key(self) -> tuple[str, tuple[int, ...]]:
        """(op_class, input_shape): the paper's per-(op, input-size) key."""
        return (self.op_class, self.input_shape)

    @property
    def weight(self) -> float:
        """Scalar proxy for 'how big' this instance is (Strategy 2 uses the
        largest instance of a class to fix the class's concurrency)."""
        return self.flops + self.bytes_moved


@dataclasses.dataclass
class OpGraph:
    name: str
    ops: dict[int, Op]

    def __post_init__(self) -> None:
        self._consumers: dict[int, list[int]] = defaultdict(list)
        for op in self.ops.values():
            for d in op.deps:
                if d not in self.ops:
                    raise ValueError(f"{op.name} depends on unknown uid {d}")
                self._consumers[d].append(op.uid)

    # ---- structure ------------------------------------------------------
    def consumers(self, uid: int) -> list[int]:
        return self._consumers.get(uid, [])

    def sources(self) -> list[int]:
        return [u for u, op in self.ops.items() if not op.deps]

    def topo_order(self) -> list[int]:
        indeg = {u: len(op.deps) for u, op in self.ops.items()}
        q = deque(sorted(u for u, d in indeg.items() if d == 0))
        order: list[int] = []
        while q:
            u = q.popleft()
            order.append(u)
            for c in self._consumers.get(u, []):
                indeg[c] -= 1
                if indeg[c] == 0:
                    q.append(c)
        if len(order) != len(self.ops):
            raise ValueError(f"cycle detected in graph {self.name}")
        return order

    def validate(self) -> None:
        self.topo_order()

    # ---- stats ----------------------------------------------------------
    @property
    def n_ops(self) -> int:
        return len(self.ops)

    def classes(self) -> dict[str, list[Op]]:
        by_class: dict[str, list[Op]] = defaultdict(list)
        for op in self.ops.values():
            by_class[op.op_class].append(op)
        return dict(by_class)

    def total_flops(self) -> float:
        return sum(op.flops for op in self.ops.values())

    def fingerprint(self) -> str:
        h = hashlib.sha1()
        for u in sorted(self.ops):
            op = self.ops[u]
            h.update(f"{u}:{op.op_class}:{op.input_shape}:{op.deps}".encode())
        return h.hexdigest()[:12]


class GraphBuilder:
    """Incremental DAG construction helper."""

    def __init__(self, name: str):
        self.name = name
        self._ops: dict[int, Op] = {}
        self._next = 0

    def add(self, op_class: str, input_shape: tuple[int, ...], *,
            flops: float = 0.0, bytes_moved: float = 0.0,
            working_set: float = 0.0, parallel_fraction: float = 0.95,
            deps: Iterable[int] = (), name: str | None = None,
            payload: Callable | None = None, tunable: bool = True) -> int:
        uid = self._next
        self._next += 1
        self._ops[uid] = Op(
            uid=uid,
            name=name or f"{op_class.lower()}/{uid}",
            op_class=op_class,
            input_shape=tuple(input_shape),
            flops=flops, bytes_moved=bytes_moved,
            working_set=working_set or bytes_moved,
            parallel_fraction=parallel_fraction,
            deps=tuple(deps), payload=payload, tunable=tunable)
        return uid

    def build(self) -> OpGraph:
        g = OpGraph(self.name, dict(self._ops))
        g.validate()
        return g


# ---------------------------------------------------------------------------
# Paper workload graphs.
#
# The op mixes approximate the paper's profiled networks: op classes, rough
# instance counts, the Table II input sizes, and per-class scalability
# character (conv backprop scales worst; elementwise ops are tiny and
# bandwidth-bound — the Strategy 4 "small op" population).
# ---------------------------------------------------------------------------

_CONV_CLASSES = {
    # op_class: (parallel_fraction, flops_per_elem, bytes_per_elem, tunable)
    # conv flops/elem calibrated so thread-count optima track the paper's
    # Table II (small inputs -> ~2x cores/3, the largest -> all 68 cores).
    # ``tunable=False`` marks Eigen-implemented ops: the paper only
    # re-tunes MKL-DNN ops (>70% of step time); Eigen ops keep the session
    # default concurrency (§IV-A "Controlling intra-op parallelism").
    "Conv2DBackpropFilter": (0.95, 740.0, 260.0, True),
    "Conv2DBackpropInput": (0.95, 700.0, 240.0, True),
    "Conv2D": (0.96, 660.0, 200.0, True),
    "MatMul": (0.96, 400.0, 60.0, True),
    "FusedBatchNorm": (0.80, 8.0, 12.0, True),
    "FusedBatchNormGrad": (0.80, 10.0, 14.0, True),
    "MaxPool": (0.85, 4.0, 8.0, True),
    "MaxPoolGrad": (0.85, 5.0, 10.0, True),
    "AvgPool": (0.85, 4.0, 8.0, True),
    "BiasAddGrad": (0.70, 2.0, 8.0, False),
    "ApplyAdam": (0.88, 8.0, 16.0, False),
    "Mul": (0.60, 1.0, 12.0, False),
    "Sum": (0.65, 1.0, 8.0, False),
    "Mean": (0.65, 1.0, 8.0, False),
    "Select": (0.55, 1.0, 12.0, False),
    "Tile": (0.60, 0.5, 16.0, False),
    "InputConversion": (0.75, 2.0, 12.0, False),
    "ToTf": (0.55, 0.5, 12.0, False),
    "SquaredDifference": (0.60, 2.0, 12.0, False),
}

# Table II input sizes (NHWC) used throughout the paper's measurements.
PAPER_INPUT_SIZES = [
    (32, 8, 8, 384),
    (32, 17, 17, 384),
    (32, 8, 8, 2048),
]


def _elems(shape: tuple[int, ...]) -> float:
    n = 1.0
    for d in shape:
        n *= d
    return n


def _chain_block(b: GraphBuilder, prev: int, shape: tuple[int, ...],
                 classes: list[str], rng_shapes: list[tuple[int, ...]],
                 idx: int, extra_pools: bool = False) -> int:
    """One fwd+bwd 'layer': conv fwd, then bwd pair + small ops fanning in."""
    shp = rng_shapes[idx % len(rng_shapes)]
    spec = _CONV_CLASSES
    conv = b.add("Conv2D", shp, deps=[prev],
                 flops=_elems(shp) * spec["Conv2D"][1],
                 bytes_moved=_elems(shp) * spec["Conv2D"][2],
                 parallel_fraction=spec["Conv2D"][0],
                 tunable=spec["Conv2D"][3])
    bn = b.add("FusedBatchNorm", shp, deps=[conv],
               flops=_elems(shp) * spec["FusedBatchNorm"][1],
               bytes_moved=_elems(shp) * spec["FusedBatchNorm"][2],
               parallel_fraction=spec["FusedBatchNorm"][0],
               tunable=spec["FusedBatchNorm"][3])
    # small ops act on a genuinely smaller tensor (distinct input size:
    # the paper's premise is that (op_class, input_size) determines the
    # work, so instances sharing a size_key must share their cost)
    small_shp = (*shp[:3], max(shp[3] // 16, 8))
    small_cls = classes[idx % len(classes)]
    small = b.add(small_cls, small_shp, deps=[conv],
                  flops=_elems(small_shp) * spec[small_cls][1],
                  bytes_moved=_elems(small_shp) * spec[small_cls][2],
                  parallel_fraction=spec[small_cls][0],
                  tunable=spec[small_cls][3])
    # backward pair — independent of each other given bn: the co-run pair
    # of the paper's Table III.
    bf = b.add("Conv2DBackpropFilter", shp, deps=[bn, small],
               flops=_elems(shp) * spec["Conv2DBackpropFilter"][1],
               bytes_moved=_elems(shp) * spec["Conv2DBackpropFilter"][2],
               parallel_fraction=spec["Conv2DBackpropFilter"][0],
               tunable=True)
    bi = b.add("Conv2DBackpropInput", shp, deps=[bn, small],
               flops=_elems(shp) * spec["Conv2DBackpropInput"][1],
               bytes_moved=_elems(shp) * spec["Conv2DBackpropInput"][2],
               parallel_fraction=spec["Conv2DBackpropInput"][0],
               tunable=True)
    join_deps = [bf, bi]
    if extra_pools:
        # Inception-v3 is pool/Tile-heavy (paper Table VI tops out with
        # AvgPool and Tile): full-weight pooling branches per block.
        for pool_cls in ("AvgPool", "MaxPool", "MaxPoolGrad"):
            join_deps.append(b.add(
                pool_cls, shp, deps=[conv],
                flops=_elems(shp) * spec[pool_cls][1] * 3.0,
                bytes_moved=_elems(shp) * 40.0,
                parallel_fraction=spec[pool_cls][0],
                tunable=spec[pool_cls][3]))
    join = b.add("Sum", shp, deps=join_deps,
                 flops=_elems(shp) * 1.0,
                 bytes_moved=_elems(shp) * 8.0,
                 parallel_fraction=0.65, tunable=False)
    return join


def build_paper_graph(model: str, scale: int = 1) -> OpGraph:
    """Op graphs shaped like the paper's three networks.

    ``scale`` multiplies layer count (1 = a representative single step
    skeleton; the paper's Inception-v3 step has ~16k ops — use scale to
    stress the scheduler).
    """
    model = model.lower()
    # per-model input-size distributions: ResNet-50/DCGAN train on
    # CIFAR-10/MNIST (small ops, low thread optima — the paper's manual
    # best used intra=16/34), Inception-v3 on ImageNet (big ops, optima up
    # to 68 — manual best intra=68).
    if model == "resnet50":
        layers, smalls = 16 * scale, ["Mul", "Select", "Mean", "Tile",
                                      "InputConversion", "ToTf"]
        sizes = [(64, 16, 16, 64), (64, 8, 8, 128), (64, 4, 4, 256)]
    elif model == "dcgan":
        layers, smalls = 8 * scale, ["Mul", "BiasAddGrad", "ToTf",
                                     "FusedBatchNormGrad"]
        # MNIST-scale: every op saturates around half the socket (the
        # paper's DCGAN manual-best intra-op was 34)
        sizes = [(64, 14, 14, 64), (64, 7, 7, 256), (64, 28, 28, 16)]
    elif model == "inception_v3":
        layers, smalls = 42 * scale, ["Mul", "Tile", "SquaredDifference",
                                      "InputConversion", "MaxPool", "AvgPool"]
        sizes = list(PAPER_INPUT_SIZES)
    elif model == "alexnet":
        # the paper's regression-model TEST set (Table IV)
        layers, smalls = 5 * scale, ["Mul", "BiasAddGrad", "MaxPool", "Mean"]
        sizes = [(16, 13, 13, 384), (16, 27, 27, 256), (16, 55, 55, 96)]
    else:
        raise ValueError(f"unknown paper model {model!r}")

    b = GraphBuilder(model)
    root = b.add("InputConversion", (32, 224, 224, 3),
                 flops=_elems((32, 224, 224, 3)) * 2.0,
                 bytes_moved=_elems((32, 224, 224, 3)) * 12.0,
                 parallel_fraction=0.75, tunable=False)
    prev = root
    pools = model == "inception_v3"
    for i in range(layers):
        prev = _chain_block(b, prev, (32, 8, 8, 384), smalls, sizes, i,
                            extra_pools=pools)
    b.add("ApplyAdam", (32, 8, 8, 2048), deps=[prev],
          flops=_elems((32, 8, 8, 2048)) * 8.0,
          bytes_moved=_elems((32, 8, 8, 2048)) * 16.0,
          parallel_fraction=0.88, tunable=False)
    return b.build()


def build_transformer_step_graph(*, n_layers: int, d_model: int, n_heads: int,
                                 d_ff: int, seq: int, batch: int,
                                 moe_experts: int = 0,
                                 name: str = "transformer") -> OpGraph:
    """Layer-grain step graph for the TPU-side integration.

    Op classes here are the tuner's op classes: qkv_proj, attention, out_proj,
    mlp_up, mlp_down (or moe_expert + router), norm, embed, unembed.
    """
    b = GraphBuilder(name)
    tok = float(batch * seq)
    d = float(d_model)
    embed = b.add("embed", (batch, seq, d_model), flops=2 * tok * d,
                  bytes_moved=tok * d * 2, parallel_fraction=0.9)
    prev = embed
    for li in range(n_layers):
        norm1 = b.add("norm", (batch, seq, d_model), deps=[prev],
                      flops=6 * tok * d, bytes_moved=tok * d * 4,
                      parallel_fraction=0.7)
        qkv = b.add("qkv_proj", (batch, seq, d_model), deps=[norm1],
                    flops=2 * tok * d * (3 * d), bytes_moved=tok * d * 8,
                    parallel_fraction=0.98)
        attn = b.add("attention", (batch, n_heads, seq, seq), deps=[qkv],
                     flops=4 * tok * seq * d, bytes_moved=tok * d * 6,
                     parallel_fraction=0.97)
        out = b.add("out_proj", (batch, seq, d_model), deps=[attn],
                    flops=2 * tok * d * d, bytes_moved=tok * d * 6,
                    parallel_fraction=0.98)
        norm2 = b.add("norm", (batch, seq, d_model), deps=[out],
                      flops=6 * tok * d, bytes_moved=tok * d * 4,
                      parallel_fraction=0.7)
        if moe_experts:
            router = b.add("router", (batch, seq, moe_experts), deps=[norm2],
                           flops=2 * tok * d * moe_experts,
                           bytes_moved=tok * d * 2, parallel_fraction=0.8)
            experts = [
                b.add("moe_expert", (batch, seq, d_ff), deps=[router],
                      flops=6 * tok * d * d_ff / moe_experts,
                      bytes_moved=tok * d * 4 / moe_experts,
                      parallel_fraction=0.97,
                      name=f"moe_expert/{li}.{e}")
                for e in range(moe_experts)
            ]
            prev = b.add("moe_combine", (batch, seq, d_model), deps=experts,
                         flops=2 * tok * d, bytes_moved=tok * d * 4,
                         parallel_fraction=0.75)
        else:
            up = b.add("mlp_up", (batch, seq, d_ff), deps=[norm2],
                       flops=4 * tok * d * d_ff, bytes_moved=tok * d * 6,
                       parallel_fraction=0.98)
            prev = b.add("mlp_down", (batch, seq, d_model), deps=[up],
                         flops=2 * tok * d * d_ff, bytes_moved=tok * d * 6,
                         parallel_fraction=0.98)
    b.add("unembed", (batch, seq, d_model), deps=[prev],
          flops=2 * tok * d * 32000, bytes_moved=tok * d * 4,
          parallel_fraction=0.96)
    return b.build()
