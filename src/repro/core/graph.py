"""Dataflow op-graph IR — the unit the paper's runtime schedules.

A training step is a DAG of ``Op`` nodes.  Each op carries the analytic
workload attributes the cost oracles need (flops, bytes moved, working set,
parallel fraction) plus its *op class* — the key under which concurrency
decisions are cached (paper Strategy 2 keys decisions by operation type, not
instance).

Graph builders for the paper's three evaluation networks (ResNet-50, DCGAN,
Inception-v3 — op mixes taken from the paper's Table VI and profiling
claims) and for transformer-block step graphs (the TPU-side integration)
live here too.
"""

from __future__ import annotations

import dataclasses
import hashlib
from collections import defaultdict, deque
from typing import Callable, Iterable


@dataclasses.dataclass
class Op:
    """One schedulable operation instance."""

    uid: int
    name: str                      # unique instance name, e.g. "conv2d_bwd_filter/12"
    op_class: str                  # class key, e.g. "Conv2DBackpropFilter"
    input_shape: tuple[int, ...]   # the paper's "input data size"
    flops: float = 0.0
    bytes_moved: float = 0.0       # main-memory traffic at parallelism 1
    working_set: float = 0.0       # bytes live during execution
    parallel_fraction: float = 0.95  # Amdahl fraction (simmachine only)
    deps: tuple[int, ...] = ()     # uids of producers
    payload: Callable | None = None  # optional real callable (jitted JAX op)
    tunable: bool = True           # False: Eigen-style op, keep session default

    @property
    def size_key(self) -> tuple[str, tuple[int, ...]]:
        """(op_class, input_shape): the paper's per-(op, input-size) key."""
        return (self.op_class, self.input_shape)

    @property
    def weight(self) -> float:
        """Scalar proxy for 'how big' this instance is (Strategy 2 uses the
        largest instance of a class to fix the class's concurrency)."""
        return self.flops + self.bytes_moved


@dataclasses.dataclass
class OpGraph:
    name: str
    ops: dict[int, Op]

    def __post_init__(self) -> None:
        self._consumers: dict[int, list[int]] = defaultdict(list)
        for op in self.ops.values():
            for d in op.deps:
                if d not in self.ops:
                    raise ValueError(f"{op.name} depends on unknown uid {d}")
                self._consumers[d].append(op.uid)

    # ---- structure ------------------------------------------------------
    def consumers(self, uid: int) -> list[int]:
        return self._consumers.get(uid, [])

    def sources(self) -> list[int]:
        return [u for u, op in self.ops.items() if not op.deps]

    # ---- ready-frontier contract ----------------------------------------
    # Every scheduler (``_EventSim``, ``_PoolSim``) drains a graph through
    # exactly four calls, so dynamic control flow needs no structural fork
    # in the strategy core:
    #
    # * ``reset()`` — restore the graph to its initial shape and return the
    #   ``RegionEvent``s of any regions that expand immediately (regions
    #   with no entry deps).  Called once per run, before readiness is
    #   derived, so a graph object can be scheduled many times.
    # * ``advance(uid, completed)`` — notify the graph that ``uid`` just
    #   completed (``completed`` is the full completed-uid set).  May
    #   materialize new ops (loop iterations, taken branches, region
    #   exits) and returns the ``RegionEvent``s describing them; the sim
    #   absorbs any new ops whose deps are already complete into its ready
    #   frontier.
    # * ``unresolved_regions()`` — regions whose final shape is still
    #   unknown; the pricing layer turns these into expectations.
    # * ``profile_view()`` — a static, dependency-free view carrying one
    #   clone of every op the graph could ever materialize, for the
    #   profiler/controller (which never read ``deps``).
    #
    # A static ``OpGraph`` is the trivial fixed point of this contract:
    # nothing ever changes shape, so all four are no-ops.
    def reset(self) -> list["RegionEvent"]:
        """Static graphs never change shape: nothing to restore."""
        return []

    def advance(self, uid: int,
                completed: set[int]) -> list["RegionEvent"]:
        """Static graphs never materialize ops on completion."""
        return []

    def unresolved_regions(self) -> tuple:
        """A static graph's shape is always fully resolved."""
        return ()

    def profile_view(self) -> "OpGraph":
        """Every op is already materialized: the graph is its own view."""
        return self

    def topo_order(self) -> list[int]:
        indeg = {u: len(op.deps) for u, op in self.ops.items()}
        q = deque(sorted(u for u, d in indeg.items() if d == 0))
        order: list[int] = []
        while q:
            u = q.popleft()
            order.append(u)
            for c in self._consumers.get(u, []):
                indeg[c] -= 1
                if indeg[c] == 0:
                    q.append(c)
        if len(order) != len(self.ops):
            raise ValueError(f"cycle detected in graph {self.name}")
        return order

    def validate(self) -> None:
        self.topo_order()

    # ---- stats ----------------------------------------------------------
    @property
    def n_ops(self) -> int:
        return len(self.ops)

    def classes(self) -> dict[str, list[Op]]:
        by_class: dict[str, list[Op]] = defaultdict(list)
        for op in self.ops.values():
            by_class[op.op_class].append(op)
        return dict(by_class)

    def total_flops(self) -> float:
        return sum(op.flops for op in self.ops.values())

    def fingerprint(self) -> str:
        h = hashlib.sha1()
        for u in sorted(self.ops):
            op = self.ops[u]
            h.update(f"{u}:{op.op_class}:{op.input_shape}:{op.deps}".encode())
        return h.hexdigest()[:12]


class GraphBuilder:
    """Incremental DAG construction helper."""

    def __init__(self, name: str):
        self.name = name
        self._ops: dict[int, Op] = {}
        self._next = 0

    def add(self, op_class: str, input_shape: tuple[int, ...], *,
            flops: float = 0.0, bytes_moved: float = 0.0,
            working_set: float = 0.0, parallel_fraction: float = 0.95,
            deps: Iterable[int] = (), name: str | None = None,
            payload: Callable | None = None, tunable: bool = True) -> int:
        uid = self._next
        self._next += 1
        self._ops[uid] = Op(
            uid=uid,
            name=name or f"{op_class.lower()}/{uid}",
            op_class=op_class,
            input_shape=tuple(input_shape),
            flops=flops, bytes_moved=bytes_moved,
            working_set=working_set or bytes_moved,
            parallel_fraction=parallel_fraction,
            deps=tuple(deps), payload=payload, tunable=tunable)
        return uid

    def build(self) -> OpGraph:
        g = OpGraph(self.name, dict(self._ops))
        g.validate()
        return g


# ---------------------------------------------------------------------------
# Dynamic control flow: regions + DynamicOpGraph.
#
# A region is a placeholder for a data-dependent subgraph: a while-loop
# whose trip count is unknown until the predicate resolves at runtime, or
# a conditional whose taken branch is unknown until its inputs arrive.
# Each region reserves one ``exit_uid`` at build time so downstream static
# ops can depend on the region's result before the region has any shape;
# the exit op itself is materialized only when the region resolves.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RegionEvent:
    """One shape change at a scheduling instant.

    ``kind`` is ``"expand"`` (a while-loop materialized its next
    iteration; trip count still unknown) or ``"resolve"`` (the region's
    final shape is now known: the exit op exists and ``outcome`` carries
    the observed trip count / branch direction for trip-count learning).
    """

    kind: str                    # "expand" | "resolve"
    region: "WhileRegion | CondRegion"
    new_uids: tuple[int, ...]    # ops materialized by this step
    outcome: float | None = None  # resolve only: trips taken / 1.0 if true


@dataclasses.dataclass
class WhileRegion:
    """Lazily-unrolled loop: ``body`` repeats ``actual_trips`` times.

    ``actual_trips`` is the hidden ground truth (what the data decides at
    runtime); the scheduler only ever sees ``est_trips`` (the pricing
    prior), the iterations materialized so far, and — once the region
    resolves — the observed count, which feeds ``TripCountEstimator``
    under ``key`` so later tenants running the same loop start informed.
    """

    kind = "while"               # class attr: duck-typed dispatch key

    rid: int
    body: OpGraph                # one iteration, cloned per trip
    entry_deps: tuple[int, ...]  # outer uids gating the first iteration
    exit_uid: int                # reserved uid of the (future) exit op
    exit_op: Op                  # template; deps filled at resolution
    est_trips: float             # pricing prior (expected trip count)
    max_trips: int               # upper bound (predicate hard limit)
    actual_trips: int            # hidden ground truth for this run
    key: tuple = None            # pool-wide trip-count learning key
    # runtime state (owned by the enclosing DynamicOpGraph)
    trips_started: int = 0
    trips_done: int = 0
    resolved: bool = False
    gate: tuple[int, ...] = ()   # uids whose completion steps the region

    def __post_init__(self) -> None:
        if self.body.n_ops == 0:
            raise ValueError(f"while region {self.rid}: empty body")
        if not 0 <= self.actual_trips <= self.max_trips:
            raise ValueError(
                f"while region {self.rid}: actual_trips "
                f"{self.actual_trips} outside [0, {self.max_trips}]")
        if self.key is None:
            self.key = ("while", self.body.fingerprint())


@dataclasses.dataclass
class CondRegion:
    """Two-armed conditional: exactly one branch materializes.

    ``taken`` is the hidden ground truth; ``p_true`` is the pricing prior
    (probability the true branch runs).  Resolution happens the instant
    the entry gate completes — the branch is then known, so expand and
    resolve collapse into one event with ``outcome`` 1.0/0.0.
    """

    kind = "cond"

    rid: int
    branches: tuple[OpGraph, OpGraph]  # (true, false); either may be empty
    entry_deps: tuple[int, ...]
    exit_uid: int
    exit_op: Op
    p_true: float                # pricing prior in [0, 1]
    taken: bool                  # hidden ground truth for this run
    key: tuple = None
    resolved: bool = False
    gate: tuple[int, ...] = ()

    def __post_init__(self) -> None:
        if self.key is None:
            self.key = ("cond", self.branches[0].fingerprint(),
                        self.branches[1].fingerprint())


@dataclasses.dataclass
class DynamicOpGraph(OpGraph):
    """An ``OpGraph`` whose shape resolves at runtime.

    Implements the ready-frontier contract documented on ``OpGraph``:
    ``reset()`` restores the initial (static ops only) shape and expands
    entry-free regions; ``advance(uid, completed)`` steps any region
    whose gate just completed, cloning body/branch ops with fresh uids
    and finally materializing the reserved exit op; with zero regions it
    degenerates to a static graph bit-for-bit (every method matches the
    ``OpGraph`` no-op behavior exactly).
    """

    regions: list = dataclasses.field(default_factory=list)

    def __post_init__(self) -> None:
        reserved = {r.exit_uid for r in self.regions}
        if len(reserved) != len(self.regions):
            raise ValueError(f"{self.name}: duplicate region exit uids")
        for op in self.ops.values():
            for d in op.deps:
                if d not in self.ops and d not in reserved:
                    raise ValueError(
                        f"{op.name} depends on unknown uid {d}")
        self._initial_ops = dict(self.ops)
        self._base_next = max([*self.ops, *reserved], default=-1) + 1
        self.reset()

    # ---- frontier contract ----------------------------------------------
    def reset(self) -> list[RegionEvent]:
        self.ops = dict(self._initial_ops)
        self._consumers = defaultdict(list)
        for op in self.ops.values():
            for d in op.deps:
                self._consumers[d].append(op.uid)
        self._next = self._base_next
        events: list[RegionEvent] = []
        for r in self.regions:
            r.resolved = False
            r.gate = r.entry_deps
            if r.kind == "while":
                r.trips_started = 0
                r.trips_done = 0
        for r in self.regions:
            # no entry deps: the region's first step is unconditional
            if not r.gate:
                events.append(self._step_region(r))
        return events

    def advance(self, uid: int, completed: set[int]) -> list[RegionEvent]:
        events: list[RegionEvent] = []
        for r in self.regions:
            if (not r.resolved and uid in r.gate
                    and all(g in completed for g in r.gate)):
                events.append(self._step_region(r))
        return events

    def unresolved_regions(self) -> tuple:
        return tuple(r for r in self.regions if not r.resolved)

    def profile_view(self) -> OpGraph:
        """Static dep-free view with one clone of every materializable op.

        The profiler dedups on ``size_key`` and the controller keys plans
        by op class — neither reads ``deps`` — so stripping edges yields a
        valid static ``OpGraph`` covering body/branch/exit ops that have
        not materialized yet.  With zero regions the graph is its own
        view (bit-for-bit the static path).
        """
        if not self.regions:
            return self
        ops: dict[int, Op] = {}
        nxt = 0
        for op in self._initial_ops.values():
            ops[nxt] = dataclasses.replace(op, uid=nxt, deps=())
            nxt += 1
        templates: list[Op] = []
        for r in self.regions:
            bodies = [r.body] if r.kind == "while" else list(r.branches)
            for body in bodies:
                templates.extend(body.ops[u] for u in sorted(body.ops))
            templates.append(r.exit_op)
        for op in templates:
            ops[nxt] = dataclasses.replace(op, uid=nxt, deps=())
            nxt += 1
        return OpGraph(f"{self.name}/profile", ops)

    # ---- region stepping -------------------------------------------------
    def _step_region(self, r) -> RegionEvent:
        if r.kind == "cond":
            branch = r.branches[0] if r.taken else r.branches[1]
            new, sinks = self._materialize(
                branch, r.gate, r.rid, "t" if r.taken else "f")
            self._place_exit(r, sinks if sinks else r.gate)
            r.resolved = True
            r.gate = ()
            return RegionEvent("resolve", r, (*new, r.exit_uid),
                               outcome=1.0 if r.taken else 0.0)
        # while: gate completion means the previous iteration finished
        r.trips_done = r.trips_started
        if r.trips_done >= r.actual_trips:
            self._place_exit(r, r.gate)
            r.resolved = True
            r.gate = ()
            return RegionEvent("resolve", r, (r.exit_uid,),
                               outcome=float(r.trips_done))
        new, sinks = self._materialize(
            r.body, r.gate, r.rid, f"i{r.trips_started}")
        r.trips_started += 1
        r.gate = tuple(sinks)
        return RegionEvent("expand", r, tuple(new))

    def _materialize(self, template: OpGraph, src_deps: tuple[int, ...],
                     rid: int, tag: str) -> tuple[list[int], list[int]]:
        """Clone ``template`` with fresh uids; template sources inherit
        ``src_deps``.  Returns (new uids, mapped template-sink uids)."""
        sinks = set(template.ops)
        for op in template.ops.values():
            for d in op.deps:
                sinks.discard(d)
        mapping: dict[int, int] = {}
        new_uids: list[int] = []
        for tu in template.topo_order():
            top = template.ops[tu]
            uid = self._next
            self._next += 1
            deps = (tuple(mapping[d] for d in top.deps) if top.deps
                    else tuple(src_deps))
            self.ops[uid] = dataclasses.replace(
                top, uid=uid, name=f"{top.name}@r{rid}.{tag}", deps=deps)
            for d in deps:
                self._consumers[d].append(uid)
            mapping[tu] = uid
            new_uids.append(uid)
        return new_uids, [mapping[s] for s in sorted(sinks)]

    def _place_exit(self, r, deps) -> None:
        op = dataclasses.replace(r.exit_op, uid=r.exit_uid,
                                 deps=tuple(deps))
        self.ops[r.exit_uid] = op
        for d in op.deps:
            self._consumers[d].append(r.exit_uid)

    # ---- overrides over unmaterialized deps ------------------------------
    def topo_order(self) -> list[int]:
        # reserved exit uids are future producers: only materialized
        # edges constrain the order of materialized ops
        indeg = {u: sum(1 for d in op.deps if d in self.ops)
                 for u, op in self.ops.items()}
        q = deque(sorted(u for u, d in indeg.items() if d == 0))
        order: list[int] = []
        while q:
            u = q.popleft()
            order.append(u)
            for c in self._consumers.get(u, []):
                if c in self.ops:
                    indeg[c] -= 1
                    if indeg[c] == 0:
                        q.append(c)
        if len(order) != len(self.ops):
            raise ValueError(f"cycle detected in graph {self.name}")
        return order


def region_exit_op(shape: tuple[int, ...] = (1, 64)) -> Op:
    """Template for the tiny op materialized when a region resolves.

    Real and schedulable (downstream deps anchor on it) but deliberately
    cheap and non-tunable so it never perturbs concurrency decisions.
    """
    return Op(uid=-1, name="region_exit", op_class="RegionExit",
              input_shape=tuple(shape), flops=_elems(shape) * 1.0,
              bytes_moved=_elems(shape) * 8.0, working_set=_elems(shape) * 8.0,
              parallel_fraction=0.55, tunable=False)


class DynamicGraphBuilder(GraphBuilder):
    """``GraphBuilder`` + control-flow regions.

    ``add_while``/``add_cond`` reserve and return an exit uid that later
    ops may list in ``deps`` exactly like a normal producer uid.
    """

    def __init__(self, name: str):
        super().__init__(name)
        self._regions: list = []

    def add_while(self, body: OpGraph, *, deps: Iterable[int] = (),
                  est_trips: float, max_trips: int, actual_trips: int,
                  exit_op: Op | None = None, key: tuple | None = None) -> int:
        exit_uid = self._next
        self._next += 1
        self._regions.append(WhileRegion(
            rid=len(self._regions), body=body, entry_deps=tuple(deps),
            exit_uid=exit_uid, exit_op=exit_op or region_exit_op(),
            est_trips=float(est_trips), max_trips=int(max_trips),
            actual_trips=int(actual_trips), key=key))
        return exit_uid

    def add_cond(self, true_branch: OpGraph, false_branch: OpGraph, *,
                 deps: Iterable[int] = (), p_true: float, taken: bool,
                 exit_op: Op | None = None, key: tuple | None = None) -> int:
        exit_uid = self._next
        self._next += 1
        self._regions.append(CondRegion(
            rid=len(self._regions),
            branches=(true_branch, false_branch), entry_deps=tuple(deps),
            exit_uid=exit_uid, exit_op=exit_op or region_exit_op(),
            p_true=float(p_true), taken=bool(taken), key=key))
        return exit_uid

    def build(self) -> DynamicOpGraph:
        g = DynamicOpGraph(self.name, dict(self._ops),
                           regions=list(self._regions))
        g.validate()
        return g


# ---------------------------------------------------------------------------
# Paper workload graphs.
#
# The op mixes approximate the paper's profiled networks: op classes, rough
# instance counts, the Table II input sizes, and per-class scalability
# character (conv backprop scales worst; elementwise ops are tiny and
# bandwidth-bound — the Strategy 4 "small op" population).
# ---------------------------------------------------------------------------

_CONV_CLASSES = {
    # op_class: (parallel_fraction, flops_per_elem, bytes_per_elem, tunable)
    # conv flops/elem calibrated so thread-count optima track the paper's
    # Table II (small inputs -> ~2x cores/3, the largest -> all 68 cores).
    # ``tunable=False`` marks Eigen-implemented ops: the paper only
    # re-tunes MKL-DNN ops (>70% of step time); Eigen ops keep the session
    # default concurrency (§IV-A "Controlling intra-op parallelism").
    "Conv2DBackpropFilter": (0.95, 740.0, 260.0, True),
    "Conv2DBackpropInput": (0.95, 700.0, 240.0, True),
    "Conv2D": (0.96, 660.0, 200.0, True),
    "MatMul": (0.96, 400.0, 60.0, True),
    "FusedBatchNorm": (0.80, 8.0, 12.0, True),
    "FusedBatchNormGrad": (0.80, 10.0, 14.0, True),
    "MaxPool": (0.85, 4.0, 8.0, True),
    "MaxPoolGrad": (0.85, 5.0, 10.0, True),
    "AvgPool": (0.85, 4.0, 8.0, True),
    "BiasAddGrad": (0.70, 2.0, 8.0, False),
    "ApplyAdam": (0.88, 8.0, 16.0, False),
    "Mul": (0.60, 1.0, 12.0, False),
    "Sum": (0.65, 1.0, 8.0, False),
    "Mean": (0.65, 1.0, 8.0, False),
    "Select": (0.55, 1.0, 12.0, False),
    "Tile": (0.60, 0.5, 16.0, False),
    "InputConversion": (0.75, 2.0, 12.0, False),
    "ToTf": (0.55, 0.5, 12.0, False),
    "SquaredDifference": (0.60, 2.0, 12.0, False),
}

# Table II input sizes (NHWC) used throughout the paper's measurements.
PAPER_INPUT_SIZES = [
    (32, 8, 8, 384),
    (32, 17, 17, 384),
    (32, 8, 8, 2048),
]


def _elems(shape: tuple[int, ...]) -> float:
    n = 1.0
    for d in shape:
        n *= d
    return n


def _chain_block(b: GraphBuilder, prev: int, shape: tuple[int, ...],
                 classes: list[str], rng_shapes: list[tuple[int, ...]],
                 idx: int, extra_pools: bool = False) -> int:
    """One fwd+bwd 'layer': conv fwd, then bwd pair + small ops fanning in."""
    shp = rng_shapes[idx % len(rng_shapes)]
    spec = _CONV_CLASSES
    conv = b.add("Conv2D", shp, deps=[prev],
                 flops=_elems(shp) * spec["Conv2D"][1],
                 bytes_moved=_elems(shp) * spec["Conv2D"][2],
                 parallel_fraction=spec["Conv2D"][0],
                 tunable=spec["Conv2D"][3])
    bn = b.add("FusedBatchNorm", shp, deps=[conv],
               flops=_elems(shp) * spec["FusedBatchNorm"][1],
               bytes_moved=_elems(shp) * spec["FusedBatchNorm"][2],
               parallel_fraction=spec["FusedBatchNorm"][0],
               tunable=spec["FusedBatchNorm"][3])
    # small ops act on a genuinely smaller tensor (distinct input size:
    # the paper's premise is that (op_class, input_size) determines the
    # work, so instances sharing a size_key must share their cost)
    small_shp = (*shp[:3], max(shp[3] // 16, 8))
    small_cls = classes[idx % len(classes)]
    small = b.add(small_cls, small_shp, deps=[conv],
                  flops=_elems(small_shp) * spec[small_cls][1],
                  bytes_moved=_elems(small_shp) * spec[small_cls][2],
                  parallel_fraction=spec[small_cls][0],
                  tunable=spec[small_cls][3])
    # backward pair — independent of each other given bn: the co-run pair
    # of the paper's Table III.
    bf = b.add("Conv2DBackpropFilter", shp, deps=[bn, small],
               flops=_elems(shp) * spec["Conv2DBackpropFilter"][1],
               bytes_moved=_elems(shp) * spec["Conv2DBackpropFilter"][2],
               parallel_fraction=spec["Conv2DBackpropFilter"][0],
               tunable=True)
    bi = b.add("Conv2DBackpropInput", shp, deps=[bn, small],
               flops=_elems(shp) * spec["Conv2DBackpropInput"][1],
               bytes_moved=_elems(shp) * spec["Conv2DBackpropInput"][2],
               parallel_fraction=spec["Conv2DBackpropInput"][0],
               tunable=True)
    join_deps = [bf, bi]
    if extra_pools:
        # Inception-v3 is pool/Tile-heavy (paper Table VI tops out with
        # AvgPool and Tile): full-weight pooling branches per block.
        for pool_cls in ("AvgPool", "MaxPool", "MaxPoolGrad"):
            join_deps.append(b.add(
                pool_cls, shp, deps=[conv],
                flops=_elems(shp) * spec[pool_cls][1] * 3.0,
                bytes_moved=_elems(shp) * 40.0,
                parallel_fraction=spec[pool_cls][0],
                tunable=spec[pool_cls][3]))
    join = b.add("Sum", shp, deps=join_deps,
                 flops=_elems(shp) * 1.0,
                 bytes_moved=_elems(shp) * 8.0,
                 parallel_fraction=0.65, tunable=False)
    return join


def build_paper_graph(model: str, scale: int = 1) -> OpGraph:
    """Op graphs shaped like the paper's three networks.

    ``scale`` multiplies layer count (1 = a representative single step
    skeleton; the paper's Inception-v3 step has ~16k ops — use scale to
    stress the scheduler).
    """
    model = model.lower()
    # per-model input-size distributions: ResNet-50/DCGAN train on
    # CIFAR-10/MNIST (small ops, low thread optima — the paper's manual
    # best used intra=16/34), Inception-v3 on ImageNet (big ops, optima up
    # to 68 — manual best intra=68).
    if model == "resnet50":
        layers, smalls = 16 * scale, ["Mul", "Select", "Mean", "Tile",
                                      "InputConversion", "ToTf"]
        sizes = [(64, 16, 16, 64), (64, 8, 8, 128), (64, 4, 4, 256)]
    elif model == "dcgan":
        layers, smalls = 8 * scale, ["Mul", "BiasAddGrad", "ToTf",
                                     "FusedBatchNormGrad"]
        # MNIST-scale: every op saturates around half the socket (the
        # paper's DCGAN manual-best intra-op was 34)
        sizes = [(64, 14, 14, 64), (64, 7, 7, 256), (64, 28, 28, 16)]
    elif model == "inception_v3":
        layers, smalls = 42 * scale, ["Mul", "Tile", "SquaredDifference",
                                      "InputConversion", "MaxPool", "AvgPool"]
        sizes = list(PAPER_INPUT_SIZES)
    elif model == "alexnet":
        # the paper's regression-model TEST set (Table IV)
        layers, smalls = 5 * scale, ["Mul", "BiasAddGrad", "MaxPool", "Mean"]
        sizes = [(16, 13, 13, 384), (16, 27, 27, 256), (16, 55, 55, 96)]
    else:
        raise ValueError(f"unknown paper model {model!r}")

    b = GraphBuilder(model)
    root = b.add("InputConversion", (32, 224, 224, 3),
                 flops=_elems((32, 224, 224, 3)) * 2.0,
                 bytes_moved=_elems((32, 224, 224, 3)) * 12.0,
                 parallel_fraction=0.75, tunable=False)
    prev = root
    pools = model == "inception_v3"
    for i in range(layers):
        prev = _chain_block(b, prev, (32, 8, 8, 384), smalls, sizes, i,
                            extra_pools=pools)
    b.add("ApplyAdam", (32, 8, 8, 2048), deps=[prev],
          flops=_elems((32, 8, 8, 2048)) * 8.0,
          bytes_moved=_elems((32, 8, 8, 2048)) * 16.0,
          parallel_fraction=0.88, tunable=False)
    return b.build()


def build_transformer_step_graph(*, n_layers: int, d_model: int, n_heads: int,
                                 d_ff: int, seq: int, batch: int,
                                 moe_experts: int = 0,
                                 name: str = "transformer") -> OpGraph:
    """Layer-grain step graph for the TPU-side integration.

    Op classes here are the tuner's op classes: qkv_proj, attention, out_proj,
    mlp_up, mlp_down (or moe_expert + router), norm, embed, unembed.
    """
    b = GraphBuilder(name)
    tok = float(batch * seq)
    d = float(d_model)
    embed = b.add("embed", (batch, seq, d_model), flops=2 * tok * d,
                  bytes_moved=tok * d * 2, parallel_fraction=0.9)
    prev = embed
    for li in range(n_layers):
        norm1 = b.add("norm", (batch, seq, d_model), deps=[prev],
                      flops=6 * tok * d, bytes_moved=tok * d * 4,
                      parallel_fraction=0.7)
        qkv = b.add("qkv_proj", (batch, seq, d_model), deps=[norm1],
                    flops=2 * tok * d * (3 * d), bytes_moved=tok * d * 8,
                    parallel_fraction=0.98)
        attn = b.add("attention", (batch, n_heads, seq, seq), deps=[qkv],
                     flops=4 * tok * seq * d, bytes_moved=tok * d * 6,
                     parallel_fraction=0.97)
        out = b.add("out_proj", (batch, seq, d_model), deps=[attn],
                    flops=2 * tok * d * d, bytes_moved=tok * d * 6,
                    parallel_fraction=0.98)
        norm2 = b.add("norm", (batch, seq, d_model), deps=[out],
                      flops=6 * tok * d, bytes_moved=tok * d * 4,
                      parallel_fraction=0.7)
        if moe_experts:
            router = b.add("router", (batch, seq, moe_experts), deps=[norm2],
                           flops=2 * tok * d * moe_experts,
                           bytes_moved=tok * d * 2, parallel_fraction=0.8)
            experts = [
                b.add("moe_expert", (batch, seq, d_ff), deps=[router],
                      flops=6 * tok * d * d_ff / moe_experts,
                      bytes_moved=tok * d * 4 / moe_experts,
                      parallel_fraction=0.97,
                      name=f"moe_expert/{li}.{e}")
                for e in range(moe_experts)
            ]
            prev = b.add("moe_combine", (batch, seq, d_model), deps=experts,
                         flops=2 * tok * d, bytes_moved=tok * d * 4,
                         parallel_fraction=0.75)
        else:
            up = b.add("mlp_up", (batch, seq, d_ff), deps=[norm2],
                       flops=4 * tok * d * d_ff, bytes_moved=tok * d * 6,
                       parallel_fraction=0.98)
            prev = b.add("mlp_down", (batch, seq, d_model), deps=[up],
                         flops=2 * tok * d * d_ff, bytes_moved=tok * d * 6,
                         parallel_fraction=0.98)
    b.add("unembed", (batch, seq, d_model), deps=[prev],
          flops=2 * tok * d * 32000, bytes_moved=tok * d * 4,
          parallel_fraction=0.96)
    return b.build()


# ---------------------------------------------------------------------------
# Dynamic workloads: data-dependent shape.
# ---------------------------------------------------------------------------

def _rnn_cell_body(shape: tuple[int, ...], work: float) -> OpGraph:
    """One recurrent cell: gate -> mix -> out chain (a while-loop body)."""
    b = GraphBuilder("rnn_cell")
    n = _elems(shape)
    gate = b.add("rnn_gate", shape, flops=n * work,
                 bytes_moved=n * 24.0, parallel_fraction=0.94)
    mix = b.add("rnn_mix", shape, deps=[gate], flops=n * work * 1.5,
                bytes_moved=n * 16.0, parallel_fraction=0.96)
    b.add("rnn_out", shape, deps=[mix], flops=n * work * 0.5,
          bytes_moved=n * 20.0, parallel_fraction=0.9)
    return b.build()


def build_recurrent_step_graph(*, trips: int, max_trips: int = 8,
                               est_trips: float | None = None,
                               shape: tuple[int, ...] = (32, 32, 128),
                               work: float = 220.0,
                               name: str = "recurrent") -> DynamicOpGraph:
    """Recurrent training step: embed -> while(rnn cell) -> unembed.

    ``trips`` is the data-dependent sequence-chunk count (hidden ground
    truth); ``est_trips`` is the pricing prior (defaults to the
    pessimistic ``max_trips``, the frozen-plan worst case).
    """
    b = DynamicGraphBuilder(name)
    n = _elems(shape)
    embed = b.add("embed", shape, flops=n * 16.0, bytes_moved=n * 12.0,
                  parallel_fraction=0.9)
    loop = b.add_while(
        _rnn_cell_body(shape, work), deps=[embed],
        est_trips=est_trips if est_trips is not None else float(max_trips),
        max_trips=max_trips, actual_trips=trips,
        key=("while", "rnn_cell", shape))
    b.add("unembed", shape, deps=[loop], flops=n * 24.0,
          bytes_moved=n * 12.0, parallel_fraction=0.9)
    return b.build()


def _decoder_body(shape: tuple[int, ...], work: float) -> OpGraph:
    b = GraphBuilder("decoder_layer")
    n = _elems(shape)
    attn = b.add("dec_attn", shape, flops=n * work,
                 bytes_moved=n * 18.0, parallel_fraction=0.96)
    b.add("dec_mlp", shape, deps=[attn], flops=n * work * 2.0,
          bytes_moved=n * 14.0, parallel_fraction=0.97)
    return b.build()


def _verify_branch(shape: tuple[int, ...], work: float,
                   heavy: bool) -> OpGraph:
    b = GraphBuilder("verify_big" if heavy else "verify_small")
    n = _elems(shape)
    scale = 6.0 if heavy else 0.5
    cls = "correct_big" if heavy else "verify_small"
    b.add(cls, shape, flops=n * work * scale, bytes_moved=n * 16.0,
          parallel_fraction=0.95)
    return b.build()


def build_early_exit_wave(*, depth: int, max_depth: int = 6,
                          est_depth: float | None = None,
                          accept: bool = True, p_accept: float = 0.5,
                          shape: tuple[int, ...] = (16, 64, 96),
                          work: float = 160.0,
                          name: str = "early_exit") -> DynamicOpGraph:
    """Early-exit serving wave with data-dependent depth.

    prefill -> while(decoder layer, ``depth`` trips) -> cond(cheap verify
    if the draft is ``accept``-ed, expensive correction otherwise) ->
    readout.  ``est_depth``/``p_accept`` are the pricing priors.
    """
    b = DynamicGraphBuilder(name)
    n = _elems(shape)
    prefill = b.add("prefill", shape, flops=n * 60.0, bytes_moved=n * 16.0,
                    parallel_fraction=0.96)
    loop = b.add_while(
        _decoder_body(shape, work), deps=[prefill],
        est_trips=est_depth if est_depth is not None else float(max_depth),
        max_trips=max_depth, actual_trips=depth,
        key=("while", "decoder_layer", shape))
    cond = b.add_cond(
        _verify_branch(shape, work, heavy=False),
        _verify_branch(shape, work, heavy=True),
        deps=[loop], p_true=p_accept, taken=accept,
        key=("cond", "verify", shape))
    b.add("readout", shape, deps=[cond], flops=n * 12.0,
          bytes_moved=n * 10.0, parallel_fraction=0.85)
    return b.build()
