"""Operation scheduling — the paper's Strategies 3 and 4 (§III-D).

``CorunScheduler`` is an event-driven list scheduler over an ``OpGraph``:

* **Strategy 3** — whenever cores idle, examine ready ops; for each, take
  its top-3 modeled candidates (threads, affinity, predicted time); a
  candidate is *admissible* if it (a) fits the idle cores, (b) does not
  outlast the longest-remaining ongoing op (throughput guard), (c) is not
  interference-blacklisted against the running classes.  Among admissible
  candidates of an op, pick the FEWEST threads (the paper deliberately
  leaves cores free to admit more co-runners).  If nothing is admissible
  and the machine is idle, run the most time-consuming ready op at its
  frozen plan.
* **Strategy 4** — when the running set occupies every physical core, admit
  the smallest ready ops (shortest serial time) onto the hyper-thread lane.
* Strategy 2 interaction — every launch decision is clamped by
  ``ConcurrencyPlan.clamp`` (deviation > 2 cases falls back to class plan).

The strategy RULES live in ``repro.core.strategy.StrategyCore`` — shared
verbatim with the multi-tenant ``repro.multitenant.pool.PoolScheduler`` —
and ``CorunScheduler`` is the single-graph adapter over them: it supplies
the candidate source (one global ready group), the plan/controller lookup,
and the event-sim commit.  ``ScheduledOp``/``ScheduleResult`` and the
admission helpers are defined in ``repro.core.strategy`` and re-exported
here for compatibility.

Baselines for the paper's Table I / Fig 3 comparisons:

* ``uniform_schedule`` — TensorFlow-style: fixed (inter-op, intra-op)
  parallelism, FIFO ready queue, oversubscription penalty when
  inter*intra exceeds physical cores.
"""

from __future__ import annotations

import heapq
import itertools
from collections import deque
from typing import Mapping, Sequence

from repro.core.concurrency import ConcurrencyPlan, ConcurrencyController, OpPlan
from repro.core.graph import Op, OpGraph
from repro.core.interference import InterferenceRecorder
from repro.core.perfmodel import cross_graph_key
from repro.core.planstore import (OBS_FINISH, FrozenPlanStore, OpObservation,
                                  PlanStore, make_plan_store)
from repro.core.simmachine import Placement, SimMachine
from repro.core.strategy import (ScheduledOp, ScheduleResult, StrategyAdapter,
                                 StrategyConfig, StrategyCore, free_cores,
                                 pick_admissible, remaining_horizon)
from repro.obs.trace import (FAM_PLANSTORE, FAM_REGION, NULL_SINK, TraceEvent,
                             TraceSink)

__all__ = [
    "CorunScheduler", "ScheduledOp", "ScheduleResult", "free_cores",
    "pick_admissible", "remaining_horizon", "uniform_schedule",
    "manual_best_schedule",
]


class _EventSim:
    """Shared discrete-event machinery over one graph.

    The multi-tenant pool (``repro.multitenant.pool``) runs the same
    launch/complete event loop over many graphs at once (its ``_PoolSim``
    keys nodes by ``(jid, uid)``) and keeps the ``ScheduledOp`` record and
    event-timeline conventions defined here, so pool records and
    single-graph records stay interchangeable."""

    def __init__(self, graph: OpGraph):
        self.graph = graph
        # restore dynamic graphs to their initial shape; entry-free
        # regions expand immediately (no-op [] on static graphs)
        self.region_events = list(graph.reset())
        self.pending = {u: len(op.deps) for u, op in graph.ops.items()}
        self.ready: deque[int] = deque(sorted(graph.sources()))
        self.heap: list[tuple[float, int, int]] = []   # (finish, seq, uid)
        self.running: dict[int, ScheduledOp] = {}
        self.completed: set[int] = set()
        self.clock = 0.0
        self.records: list[ScheduledOp] = []
        self.events: list[tuple[float, int]] = []
        self._seq = itertools.count()

    def launch(self, uid: int, sched: ScheduledOp) -> None:
        self.running[uid] = sched
        heapq.heappush(self.heap, (sched.finish, next(self._seq), uid))
        self.events.append((self.clock, len(self.running)))

    def complete_next(self) -> ScheduledOp:
        finish, _, uid = heapq.heappop(self.heap)
        self.clock = finish
        sched = self.running.pop(uid)
        self.records.append(sched)
        self.completed.add(uid)
        for c in self.graph.consumers(uid):
            self.pending[c] -= 1
            if self.pending[c] == 0:
                self.ready.append(c)
        # dynamic graphs may materialize ops at this instant (next loop
        # iteration, taken branch, region exit); absorb them into the
        # frontier — their gate deps are already complete, so consumer
        # decrements will never arrive for those edges
        for ev in self.graph.advance(uid, self.completed):
            self.region_events.append(ev)
            self._absorb(ev.new_uids)
        self.events.append((self.clock, len(self.running)))
        return sched

    def _absorb(self, new_uids) -> None:
        for u in new_uids:
            op = self.graph.ops[u]
            n = sum(1 for d in op.deps if d not in self.completed)
            self.pending[u] = n
            if n == 0:
                self.ready.append(u)

    @property
    def done(self) -> bool:
        return not self.ready and not self.running


class _GraphAdapter(StrategyAdapter):
    """Single-graph view for ``StrategyCore``: node keys are op uids, the
    candidate source is ONE global ready group, and every plan lookup
    resolves through the graph's ``PlanStore`` (frozen profiling curves
    under ``feedback="off"``, observation-corrected ones under
    ``feedback="ewma"`` — see ``repro.core.planstore``)."""

    def __init__(self, sim: _EventSim, controller: ConcurrencyController,
                 plan: ConcurrencyPlan, *, strategy2: bool,
                 spec=None, store: PlanStore | None = None,
                 sink: TraceSink = NULL_SINK):
        self.sim = sim
        self.controller = controller
        self.plan = plan
        self.strategy2 = strategy2
        self.store = store if store is not None \
            else FrozenPlanStore(controller)
        self.sink = sink
        self._spec = spec
        self._last_quadrant: int | None = None

    @property
    def clock(self) -> float:
        return self.sim.clock

    @property
    def running(self) -> Mapping[int, ScheduledOp]:
        return self.sim.running

    def ready_groups(self) -> list[Sequence[int]]:
        return [list(self.sim.ready)]

    def op(self, key: int) -> Op:
        return self.sim.graph.ops[key]

    def instance_plan(self, key: int) -> OpPlan:
        op = self.op(key)
        # predicted time must be instance-specific: the store re-prices
        # the frozen plan's width (corrected under feedback="ewma")
        return self.store.replan(op, self.plan.plan_for(
            op, strategy2=self.strategy2))

    def candidates_for(self, key: int, k: int) -> list[OpPlan]:
        return self.store.candidates(self.op(key), k)

    def clamp(self, key: int, proposal: OpPlan) -> OpPlan:
        return self.plan.clamp(self.op(key), proposal)

    def predict(self, key: int, threads: int, variant: bool) -> float:
        return self.store.predict(self.op(key), threads, variant)

    def observe(self, key: int, sched: ScheduledOp, kind: str,
                elapsed: float) -> None:
        self.store.observe(OpObservation(
            op=sched.op, threads=sched.threads, variant=sched.variant,
            hyper=sched.hyper, predicted=sched.predicted,
            observed=elapsed, kind=kind))
        if self.sink.enabled:
            corrections = getattr(self.store, "corrections", None)
            self.sink.emit(TraceEvent(
                ts=self.sim.clock, family=FAM_PLANSTORE, kind=kind, key=key,
                data={"op_class": sched.op.op_class,
                      "size_key": sched.op.size_key,
                      "threads": sched.threads, "variant": sched.variant,
                      "hyper": sched.hyper, "predicted": sched.predicted,
                      "observed": elapsed,
                      "correction": (corrections.factor(
                          cross_graph_key(sched.op), sched.threads,
                          sched.variant)
                          if corrections is not None else 1.0)}))

    def commit(self, key: int, sched: ScheduledOp) -> None:
        self.sim.ready.remove(key)
        self.sim.launch(key, sched)

    def charge(self, key: int, sched: ScheduledOp) -> None:
        # no service accounting for a single graph, but the same quadrant
        # affinity the pool keeps per tenant (primary quadrant of the last
        # placed launch) — one graph is one tenant, and the single-job
        # pool must stay bit-identical to this scheduler under EVERY
        # topology, so both adapters must answer placement_hint alike
        if sched.cores and self._spec is not None:
            self._last_quadrant = self._spec.quadrant_of_core(
                sched.cores[0])

    def placement_hint(self, key: int) -> int | None:
        return self._last_quadrant


class CorunScheduler:
    """Thin single-graph adapter over ``StrategyCore``."""

    def __init__(self, machine: SimMachine, controller: ConcurrencyController,
                 plan: ConcurrencyPlan, *,
                 recorder: InterferenceRecorder | None = None,
                 total_cores: int | None = None,
                 enable_s3: bool = True, enable_s4: bool = True,
                 strategy2: bool = True, max_ht_corunners: int = 2,
                 candidates: int = 3, min_fallback_cores: int = 4,
                 fallback_slack: float = 1.25, topology: str = "flat",
                 feedback: str = "off", sink: TraceSink = NULL_SINK,
                 planstore: PlanStore | None = None):
        self.machine = machine
        self.controller = controller
        self.plan = plan
        self.strategy2 = strategy2
        # the closed-loop plan store every prediction/observation flows
        # through; callers (ConcurrencyRuntime) usually inject one so the
        # store outlives a single scheduler, but a direct construction
        # gets its own from the feedback knob
        self.planstore = planstore if planstore is not None \
            else make_plan_store(feedback, controller)
        self.core = StrategyCore(
            machine,
            StrategyConfig(enable_s3=enable_s3, enable_s4=enable_s4,
                           candidates=candidates,
                           max_ht_corunners=max_ht_corunners,
                           min_fallback_cores=min_fallback_cores,
                           fallback_slack=fallback_slack,
                           topology=topology, feedback=feedback,
                           sink=sink),
            recorder=recorder, total_cores=total_cores)

    @property
    def recorder(self) -> InterferenceRecorder:
        return self.core.recorder

    @property
    def cores(self) -> int:
        return self.core.cores

    def adapter(self, sim: _EventSim) -> _GraphAdapter:
        return _GraphAdapter(sim, self.controller, self.plan,
                             strategy2=self.strategy2,
                             spec=self.machine.spec,
                             store=self.planstore,
                             sink=self.core.sink)

    # ------------------------------------------------------------------
    def _drain_region_events(self, sim: _EventSim,
                             adapter: _GraphAdapter) -> None:
        """Report region shape changes: resolutions feed the store's
        trip-count learning; every event traces under FAM_REGION."""
        while sim.region_events:
            ev = sim.region_events.pop(0)
            if ev.kind == "resolve" and ev.outcome is not None:
                adapter.store.observe_region(ev.region, ev.outcome)
            if self.core.sink.enabled:
                self.core.sink.emit(TraceEvent(
                    ts=sim.clock, family=FAM_REGION, kind=ev.kind,
                    key=ev.region.rid,
                    data={"region": ev.region.kind,
                          "region_key": str(ev.region.key),
                          "new_ops": len(ev.new_uids),
                          **({"outcome": ev.outcome}
                             if ev.outcome is not None else {}),
                          **({"trips": ev.region.trips_started}
                             if ev.region.kind == "while" else {})}))

    def run(self, graph: OpGraph) -> ScheduleResult:
        sim = _EventSim(graph)
        adapter = self.adapter(sim)
        # freeze the interference blacklist for this step; observations
        # recorded now take effect on the NEXT run (paper §III-D: avoid
        # recorded pairs "in the future training steps")
        self.core.begin_run()
        self._drain_region_events(sim, adapter)
        while not sim.done:
            self.core.drain(adapter)
            if sim.running:
                sched = sim.complete_next()
                # close the loop: the completion's service time flows
                # back into the plan store (no-op under feedback="off")
                adapter.observe(sched.op.uid, sched, OBS_FINISH,
                                sched.duration)
                self._drain_region_events(sim, adapter)
        return ScheduleResult(makespan=sim.clock, records=sim.records,
                              events=sim.events)


# ---------------------------------------------------------------------------
# TensorFlow-style baseline: fixed inter/intra parallelism, FIFO.
# ---------------------------------------------------------------------------

def _oversubscription_penalty(total_threads: int, cores: int) -> float:
    r = total_threads / cores
    if r <= 1.0:
        return 1.0
    return 0.45 + 0.55 * r      # calibrated to the paper's Table I ratios


def uniform_schedule(graph: OpGraph, machine: SimMachine, *,
                     intra: int, inter: int,
                     cache_sharing: bool = True) -> ScheduleResult:
    """Fixed (inter, intra) FIFO execution — the paper's baseline runtime.

    ``inter`` concurrent lanes, every op with ``intra`` threads.  If
    inter*intra oversubscribes the physical cores, every running op pays
    the oversubscription penalty (thread time-slicing + management)."""
    sim = _EventSim(graph)
    penalty = _oversubscription_penalty(
        inter * intra, machine.spec.cores)
    while not sim.done:
        while sim.ready and len(sim.running) < inter:
            uid = sim.ready.popleft()              # FIFO, as TF's executor
            op = graph.ops[uid]
            n_running = len(sim.running) + 1
            pl = Placement(min(intra, machine.spec.cores),
                           cache_sharing=cache_sharing)
            dur = machine.op_time(op, pl, bw_share=1.0 / n_running) * penalty
            sched = ScheduledOp(op=op, threads=intra, variant=cache_sharing,
                                hyper=False, start=sim.clock,
                                finish=sim.clock + dur, predicted=dur)
            sim.launch(uid, sched)
        if sim.running:
            sim.complete_next()
    return ScheduleResult(makespan=sim.clock, records=sim.records,
                          events=sim.events)


def manual_best_schedule(graph: OpGraph, machine: SimMachine,
                         inters: tuple[int, ...] = (1, 2, 4),
                         intras: tuple[int, ...] = (17, 34, 68)
                         ) -> tuple[ScheduleResult, tuple[int, int]]:
    """The paper's 'manual optimization': exhaustive uniform grid search."""
    best: tuple[ScheduleResult, tuple[int, int]] | None = None
    for inter in inters:
        for intra in intras:
            res = uniform_schedule(graph, machine, intra=intra, inter=inter)
            if best is None or res.makespan < best[0].makespan:
                best = (res, (inter, intra))
    assert best is not None
    return best
