"""Operation scheduling — the paper's Strategies 3 and 4 (§III-D).

``CorunScheduler`` is an event-driven list scheduler over an ``OpGraph``:

* **Strategy 3** — whenever cores idle, examine ready ops; for each, take
  its top-3 modeled candidates (threads, affinity, predicted time); a
  candidate is *admissible* if it (a) fits the idle cores, (b) does not
  outlast the longest-remaining ongoing op (throughput guard), (c) is not
  interference-blacklisted against the running classes.  Among admissible
  candidates of an op, pick the FEWEST threads (the paper deliberately
  leaves cores free to admit more co-runners).  If nothing is admissible
  and the machine is idle, run the most time-consuming ready op at its
  frozen plan.
* **Strategy 4** — when the running set occupies every physical core, admit
  the smallest ready ops (shortest serial time) onto the hyper-thread lane.
* Strategy 2 interaction — every launch decision is clamped by
  ``ConcurrencyPlan.clamp`` (deviation > 2 cases falls back to class plan).

Baselines for the paper's Table I / Fig 3 comparisons:

* ``uniform_schedule`` — TensorFlow-style: fixed (inter-op, intra-op)
  parallelism, FIFO ready queue, oversubscription penalty when
  inter*intra exceeds physical cores.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from collections import deque
from typing import Iterable

from repro.core.concurrency import ConcurrencyPlan, ConcurrencyController, OpPlan
from repro.core.graph import Op, OpGraph
from repro.core.interference import InterferenceRecorder
from repro.core.simmachine import Placement, SimMachine


@dataclasses.dataclass
class ScheduledOp:
    op: Op
    threads: int
    variant: bool
    hyper: bool
    start: float
    finish: float
    predicted: float

    @property
    def duration(self) -> float:
        return self.finish - self.start


@dataclasses.dataclass
class ScheduleResult:
    makespan: float
    records: list[ScheduledOp]
    events: list[tuple[float, int]]      # (time, #co-running) — paper Fig 4
    profiling_probes: int = 0

    @property
    def mean_corunning(self) -> float:
        if not self.events:
            return 0.0
        return sum(n for _, n in self.events) / len(self.events)

    def per_class_time(self) -> dict[str, float]:
        out: dict[str, float] = {}
        for r in self.records:
            out[r.op.op_class] = out.get(r.op.op_class, 0.0) + r.duration
        return out


def free_cores(running: Iterable[ScheduledOp], total_cores: int) -> int:
    """Physical cores not occupied by non-hyper-thread runners."""
    used = sum(r.threads for r in running if not r.hyper)
    return max(0, total_cores - used)


def remaining_horizon(running: Iterable[ScheduledOp], clock: float) -> float:
    """Longest remaining time among running ops — Strategy 3's throughput
    guard: a new co-runner must not outlast everything already running."""
    return max((r.finish - clock for r in running), default=float("inf"))


def pick_admissible(cands: list[OpPlan], free: int,
                    horizon: float) -> OpPlan | None:
    """Strategy 3's admission rule, shared by the single-graph scheduler
    and the multi-tenant pool: admissible = fits the idle cores AND won't
    outlast the running set; among admissible candidates pick the FEWEST
    threads (the paper deliberately leaves cores free for more
    co-runners)."""
    adm = [c for c in cands
           if c.threads <= free and c.predicted_time <= horizon]
    return min(adm, key=lambda c: c.threads) if adm else None


class _EventSim:
    """Shared discrete-event machinery over one graph.

    The multi-tenant pool (``repro.multitenant.pool``) runs the same
    launch/complete event loop over many graphs at once (its ``_PoolSim``
    keys nodes by ``(jid, uid)``) and keeps the ``ScheduledOp`` record and
    event-timeline conventions defined here, so pool records and
    single-graph records stay interchangeable."""

    def __init__(self, graph: OpGraph):
        self.graph = graph
        self.pending = {u: len(op.deps) for u, op in graph.ops.items()}
        self.ready: deque[int] = deque(sorted(graph.sources()))
        self.heap: list[tuple[float, int, int]] = []   # (finish, seq, uid)
        self.running: dict[int, ScheduledOp] = {}
        self.clock = 0.0
        self.records: list[ScheduledOp] = []
        self.events: list[tuple[float, int]] = []
        self._seq = itertools.count()

    def launch(self, uid: int, sched: ScheduledOp) -> None:
        self.running[uid] = sched
        heapq.heappush(self.heap, (sched.finish, next(self._seq), uid))
        self.events.append((self.clock, len(self.running)))

    def complete_next(self) -> ScheduledOp:
        finish, _, uid = heapq.heappop(self.heap)
        self.clock = finish
        sched = self.running.pop(uid)
        self.records.append(sched)
        for c in self.graph.consumers(uid):
            self.pending[c] -= 1
            if self.pending[c] == 0:
                self.ready.append(c)
        self.events.append((self.clock, len(self.running)))
        return sched

    @property
    def done(self) -> bool:
        return not self.ready and not self.running


class CorunScheduler:
    def __init__(self, machine: SimMachine, controller: ConcurrencyController,
                 plan: ConcurrencyPlan, *,
                 recorder: InterferenceRecorder | None = None,
                 total_cores: int | None = None,
                 enable_s3: bool = True, enable_s4: bool = True,
                 strategy2: bool = True, max_ht_corunners: int = 2,
                 candidates: int = 3, min_fallback_cores: int = 4):
        self.machine = machine
        self.controller = controller
        self.plan = plan
        self.recorder = recorder if recorder is not None else InterferenceRecorder()
        self.cores = total_cores or machine.spec.cores
        self.enable_s3 = enable_s3
        self.enable_s4 = enable_s4
        self.strategy2 = strategy2
        self.max_ht = max_ht_corunners
        self.k = candidates
        self.min_fallback_cores = min_fallback_cores
        self.fallback_slack = 1.25

    # ------------------------------------------------------------------
    def _bw_share(self, threads: int, sim: _EventSim) -> float:
        # contention policy lives on the machine so every scheduler
        # (this one, the multi-tenant pool) divides bandwidth identically
        return self.machine.corun_bw_share(
            threads, (r.threads for r in sim.running.values()))

    def _duration(self, op: Op, plan: OpPlan, hyper: bool,
                  sim: _EventSim) -> float:
        pl = Placement(plan.threads, cache_sharing=plan.variant,
                       hyper_thread=hyper)
        return self.machine.op_time(op, pl,
                                    bw_share=self._bw_share(plan.threads, sim))

    def _launch(self, sim: _EventSim, uid: int, plan: OpPlan,
                hyper: bool) -> None:
        op = sim.graph.ops[uid]
        dur = self._duration(op, plan, hyper, sim)
        sched = ScheduledOp(op=op, threads=plan.threads, variant=plan.variant,
                            hyper=hyper, start=sim.clock,
                            finish=sim.clock + dur,
                            predicted=plan.predicted_time)
        sim.launch(uid, sched)
        # interference bookkeeping: observed co-run duration vs solo model
        for other in sim.running.values():
            if other.op.uid != uid:
                self.recorder.record(op.op_class, other.op.op_class,
                                     plan.predicted_time, dur)

    def _free_cores(self, sim: _EventSim) -> int:
        return free_cores(sim.running.values(), self.cores)

    def _instance_plan(self, op: Op) -> OpPlan:
        base = self.plan.plan_for(op, strategy2=self.strategy2)
        # predicted time must be instance-specific: re-predict from curve
        curve = self.controller.store.curve(op)
        return OpPlan(base.threads, base.variant,
                      curve.predict(base.threads, base.variant))

    # ------------------------------------------------------------------
    def _try_corun(self, sim: _EventSim) -> bool:
        """Strategy 3: admit one ready op into idle cores. True if launched."""
        free = self._free_cores(sim)
        if free <= 0 or not sim.ready:
            return False
        running_classes = [r.op.op_class for r in sim.running.values()]
        horizon = remaining_horizon(sim.running.values(), sim.clock)
        # examine ready ops, prefer the most expensive first (they gate the
        # critical path)
        order = sorted(sim.ready,
                       key=lambda u: -self._instance_plan(sim.graph.ops[u])
                       .predicted_time)
        for uid in order:
            op = sim.graph.ops[uid]
            if not self.recorder.compatible(op.op_class, running_classes):
                continue
            cands = self.controller.candidates_for(op, self.k)
            pick = pick_admissible(cands, free, horizon)
            if pick is None:
                continue
            pick = self.plan.clamp(op, pick)
            if pick.threads > free:
                continue
            sim.ready.remove(uid)
            self._launch(sim, uid, pick, hyper=False)
            return True
        return False

    def _run_biggest(self, sim: _EventSim) -> bool:
        """Fallback: most time-consuming ready op at its frozen plan.

        When other ops are running, the clamped-to-idle-cores launch must
        still respect the throughput guard (with a little slack for
        contention): squeezing a big op into a few leftover cores makes it
        outlast everything and hurts throughput — better to wait."""
        if not sim.ready:
            return False
        free = self._free_cores(sim)
        if free <= 0 or (sim.running and free < self.min_fallback_cores):
            return False
        uid = max(sim.ready, key=lambda u: self._instance_plan(
            sim.graph.ops[u]).predicted_time)
        op = sim.graph.ops[uid]
        plan = self._instance_plan(op)
        if plan.threads > free:
            plan = OpPlan(free, plan.variant,
                          self.controller.store.curve(op).predict(
                              free, plan.variant))
        if sim.running:
            horizon = remaining_horizon(sim.running.values(), sim.clock)
            if plan.predicted_time > horizon * self.fallback_slack:
                return False
        sim.ready.remove(uid)
        self._launch(sim, uid, plan, hyper=False)
        return True

    def _try_hyper(self, sim: _EventSim) -> bool:
        """Strategy 4: free physical cores exhausted — run the smallest
        ready ops on the hyper-thread lane."""
        if not self.enable_s4 or not sim.ready:
            return False
        if self._free_cores(sim) > 0:
            return False
        ht_running = sum(1 for r in sim.running.values() if r.hyper)
        if ht_running >= self.max_ht:
            return False
        running_classes = [r.op.op_class for r in sim.running.values()]
        # smallest = shortest serial-execution time (threads=1 prediction)
        def serial_time(u: int) -> float:
            op = sim.graph.ops[u]
            return self.controller.store.curve(op).predict(1, False)
        order = sorted(sim.ready, key=serial_time)
        for uid in order:
            op = sim.graph.ops[uid]
            if not self.recorder.compatible(op.op_class, running_classes):
                continue
            inst = self._instance_plan(op)
            plan = OpPlan(min(inst.threads, self.cores), inst.variant,
                          inst.predicted_time)
            sim.ready.remove(uid)
            self._launch(sim, uid, plan, hyper=True)
            return True
        return False

    # ------------------------------------------------------------------
    def run(self, graph: OpGraph) -> ScheduleResult:
        sim = _EventSim(graph)
        while not sim.done:
            launched = True
            while launched:
                launched = False
                if self.enable_s3:
                    if sim.running:
                        launched = self._try_corun(sim)
                        if not launched:
                            # paper fallback: no candidate fits without
                            # decreasing throughput -> run the most
                            # time-consuming ready op in the idle cores
                            launched = self._run_biggest(sim)
                    else:
                        launched = self._run_biggest(sim)
                elif not sim.running:
                    # Strategies 1-2 only: serial execution with per-op
                    # tuned concurrency (the paper's Fig 3.a configuration)
                    launched = self._run_biggest(sim)
                if not launched:
                    launched = self._try_hyper(sim)
            if sim.running:
                sim.complete_next()
        return ScheduleResult(makespan=sim.clock, records=sim.records,
                              events=sim.events)


# ---------------------------------------------------------------------------
# TensorFlow-style baseline: fixed inter/intra parallelism, FIFO.
# ---------------------------------------------------------------------------

def _oversubscription_penalty(total_threads: int, cores: int) -> float:
    r = total_threads / cores
    if r <= 1.0:
        return 1.0
    return 0.45 + 0.55 * r      # calibrated to the paper's Table I ratios


def uniform_schedule(graph: OpGraph, machine: SimMachine, *,
                     intra: int, inter: int,
                     cache_sharing: bool = True) -> ScheduleResult:
    """Fixed (inter, intra) FIFO execution — the paper's baseline runtime.

    ``inter`` concurrent lanes, every op with ``intra`` threads.  If
    inter*intra oversubscribes the physical cores, every running op pays
    the oversubscription penalty (thread time-slicing + management)."""
    sim = _EventSim(graph)
    penalty = _oversubscription_penalty(
        inter * intra, machine.spec.cores)
    while not sim.done:
        while sim.ready and len(sim.running) < inter:
            uid = sim.ready.popleft()              # FIFO, as TF's executor
            op = graph.ops[uid]
            n_running = len(sim.running) + 1
            pl = Placement(min(intra, machine.spec.cores),
                           cache_sharing=cache_sharing)
            dur = machine.op_time(op, pl, bw_share=1.0 / n_running) * penalty
            sched = ScheduledOp(op=op, threads=intra, variant=cache_sharing,
                                hyper=False, start=sim.clock,
                                finish=sim.clock + dur, predicted=dur)
            sim.launch(uid, sched)
        if sim.running:
            sim.complete_next()
    return ScheduleResult(makespan=sim.clock, records=sim.records,
                          events=sim.events)


def manual_best_schedule(graph: OpGraph, machine: SimMachine,
                         inters: tuple[int, ...] = (1, 2, 4),
                         intras: tuple[int, ...] = (17, 34, 68)
                         ) -> tuple[ScheduleResult, tuple[int, int]]:
    """The paper's 'manual optimization': exhaustive uniform grid search."""
    best: tuple[ScheduleResult, tuple[int, int]] | None = None
    for inter in inters:
        for intra in intras:
            res = uniform_schedule(graph, machine, intra=intra, inter=inter)
            if best is None or res.makespan < best[0].makespan:
                best = (res, (inter, intra))
    assert best is not None
    return best
