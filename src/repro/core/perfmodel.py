"""Performance models for op time vs. concurrency (paper §III-B, §III-C).

Two model families, exactly as the paper explores them:

* ``HillClimbProfiler`` (§III-C, the one the runtime uses): probe an op's
  execution time at thread counts 1, 1+x, 1+2x … (interval ``x``) per
  affinity variant, stop at the first time increase (or the core limit),
  then predict every untested count by linear interpolation between probes.

* ``RegressionSuite`` (§III-B, the rejected baseline): per-case regression
  models over normalized counter-like features.  Reimplemented in numpy
  (OLS, k-NN, decision tree, gradient boosting, Theil-Sen, passive-
  aggressive) to reproduce the paper's Table IV conclusion that these are
  too inaccurate to drive the scheduler.

Both are generic over the *measurement function* so the same algorithms
drive (a) the KNL-like simulated machine for the faithful reproduction and
(b) the TPU shard-degree autotuner where "time" is the compiled roofline
term (see ``core/autotune.py``).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Hashable, Protocol, Sequence

import numpy as np

from repro.core.graph import Op, OpGraph

# measure(op, threads, variant) -> seconds.  ``variant`` is the affinity
# flavor (paper: cache-sharing True/False; TPU: collective-axis choice).
MeasureFn = Callable[[Op, int, bool], float]


class CurveCache(Protocol):
    """Cross-graph curve store the profiler consults before probing.

    Implemented by ``repro.multitenant.plancache.PlanCache``; kept as a
    protocol here so core has no dependency on the multitenant layer."""

    def lookup(self, key: Hashable) -> "CurveModel | None": ...

    def insert(self, key: Hashable, curve: "CurveModel") -> None: ...


def cross_graph_key(op: Op) -> Hashable:
    """Cache key for cross-graph curve reuse.

    Within one graph, ``op.size_key`` = (op_class, input_shape) determines
    cost by construction (see graph.py).  ACROSS graphs that invariant can
    break — transformer builders encode d_model/n_layers in flops, not in
    the shape — so the shared cache keys on the full analytic profile: two
    ops share a curve only if the machine would genuinely time them
    identically."""
    return (op.op_class, op.input_shape, op.flops, op.bytes_moved,
            op.working_set, op.parallel_fraction, op.tunable)


# ---------------------------------------------------------------------------
# Curve model: per-op-instance predicted time over every concurrency case.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CurveModel:
    """Piecewise-linear time curve per affinity variant."""

    samples: dict[bool, list[tuple[int, float]]]   # variant -> [(threads, s)]
    case_lists: dict[bool, list[int]]              # full prediction domains
    probes: int = 0                                # measurements consumed

    def predict(self, threads: int, variant: bool) -> float:
        pts = self.samples[variant]
        if not pts:
            raise ValueError("no samples for variant")
        xs = [p[0] for p in pts]
        ys = [p[1] for p in pts]
        if threads <= xs[0]:
            if len(pts) >= 2:                       # linear extrapolation
                slope = (ys[1] - ys[0]) / (xs[1] - xs[0])
                return max(ys[0] + slope * (threads - xs[0]), 1e-12)
            return ys[0]
        if threads >= xs[-1]:
            if len(pts) >= 2:
                slope = (ys[-1] - ys[-2]) / (xs[-1] - xs[-2])
                return max(ys[-1] + slope * (threads - xs[-1]), 1e-12)
            return ys[-1]
        for i in range(1, len(xs)):
            if threads <= xs[i]:
                w = (threads - xs[i - 1]) / (xs[i] - xs[i - 1])
                return ys[i - 1] * (1 - w) + ys[i] * w
        return ys[-1]

    def best(self) -> tuple[int, bool, float]:
        """(threads, variant, predicted_time) minimizing predicted time."""
        out: tuple[int, bool, float] | None = None
        for variant, cases in self.case_lists.items():
            if not self.samples.get(variant):
                continue
            for t in cases:
                y = self.predict(t, variant)
                if out is None or y < out[2]:
                    out = (t, variant, y)
        assert out is not None
        return out

    def measured_cases(self) -> list[tuple[int, bool, float]]:
        """Every measured (threads, variant, time) probe point, in the
        profiler's deterministic iteration order.  This is the candidate
        SOURCE: both the frozen ranking below and the feedback store's
        corrected re-ranking (``repro.core.planstore``) draw from exactly
        this list, so the two rankings differ only by the correction
        factors — never by which cases are eligible."""
        return [(t, v, y)
                for v, pts in self.samples.items()
                for t, y in pts]

    @staticmethod
    def rank_cases(cases: list[tuple[int, bool, float]], k: int
                   ) -> list[tuple[int, bool, float]]:
        """Top-k of ``cases`` by time (stable sort), deduplicated by
        thread count — Strategy 3's candidate rule, shared by the frozen
        and corrected rankings."""
        picked: list[tuple[int, bool, float]] = []
        seen: set[int] = set()
        for t, v, y in sorted(cases, key=lambda c: c[2]):
            if t in seen:
                continue
            picked.append((t, v, y))
            seen.add(t)
            if len(picked) == k:
                break
        return picked

    def candidates(self, k: int = 3) -> list[tuple[int, bool, float]]:
        """Top-k most performant (threads, variant, time) — Strategy 3's
        three candidates.  Candidates come from the MEASURED profiling
        cases (the paper's runtime "tests a few cases ... and measures
        their execution times"), so they are spaced by the probe interval
        — that spacing is what lets a candidate drop low enough to fit
        idle cores."""
        return self.rank_cases(self.measured_cases(), k)

    def measured_best(self) -> tuple[int, bool, float]:
        out: tuple[int, bool, float] | None = None
        for v, pts in self.samples.items():
            for t, y in pts:
                if out is None or y < out[2]:
                    out = (t, v, y)
        assert out is not None
        return out


# ---------------------------------------------------------------------------
# Hill climbing profiler (§III-C)
# ---------------------------------------------------------------------------

def paper_case_lists(max_cores: int = 68, tiles: int = 34
                     ) -> dict[bool, list[int]]:
    """The paper's 68 prediction cases: variant False = no cache sharing
    (1 thread/tile, 1..34); variant True = sharing (even counts 2..68)."""
    return {
        False: list(range(1, tiles + 1)),
        True: list(range(2, max_cores + 1, 2)),
    }


def power_of_two_cases(max_degree: int, variants: Sequence[bool] = (False, True)
                       ) -> dict[bool, list[int]]:
    """Case list for the TPU shard-degree adaptation: 1,2,4,..,max."""
    cases = [1 << i for i in range(int(math.log2(max_degree)) + 1)]
    return {v: list(cases) for v in variants}


@dataclasses.dataclass
class HillClimbProfiler:
    """The paper's profiling algorithm, generic over the measure function."""

    measure: MeasureFn
    case_lists: dict[bool, list[int]]
    interval: int = 4            # the paper's x

    def _probe_schedule(self, cases: list[int]) -> list[int]:
        """Indices to probe: every ``interval``-th case, always incl. first."""
        idx = list(range(0, len(cases), max(1, self.interval)))
        if idx[-1] != len(cases) - 1:
            idx.append(len(cases) - 1)   # domain edge reachable (paper case 2)
        return idx

    def profile(self, op: Op) -> CurveModel:
        samples: dict[bool, list[tuple[int, float]]] = {}
        probes = 0
        for variant, cases in self.case_lists.items():
            sched = self._probe_schedule(cases)
            pts: list[tuple[int, float]] = []
            prev = math.inf
            for j, ci in enumerate(sched):
                t = cases[ci]
                y = self.measure(op, t, variant)
                probes += 1
                pts.append((t, y))
                if y > prev:
                    break            # first increase -> stop (paper case 1)
                prev = y
            samples[variant] = pts
        return CurveModel(samples=samples, case_lists=dict(self.case_lists),
                          probes=probes)

    def profile_graph(self, graph: OpGraph,
                      cache: "CurveCache | None" = None) -> "ProfileStore":
        """Profile every distinct (op_class, input_shape) in ``graph``.

        ``cache`` is an optional cross-graph curve cache (see
        ``repro.multitenant.plancache.PlanCache``): a curve another graph
        already paid the profiling probes for is reused instead of
        re-measured — the paper's profiling steps amortize across tenants,
        not just across steps of one job.  Cache entries are keyed by
        ``cross_graph_key`` (the op's full analytic profile), never by the
        bare size_key, so tenants whose builders hide cost parameters
        outside the input shape cannot poison each other's curves."""
        store = ProfileStore()
        for op in graph.ops.values():
            if op.size_key in store.curves:
                continue
            key = cross_graph_key(op)
            curve = cache.lookup(key) if cache is not None else None
            if curve is not None:
                # zero-probe view: this run paid nothing for the curve, so
                # ProfileStore.total_probes / profiling_cost() only count
                # probes actually measured here (the cache keeps the
                # original probe count for its own amortization stats)
                curve = dataclasses.replace(curve, probes=0)
            else:
                curve = self.profile(op)
                if cache is not None:
                    cache.insert(key, curve)
            store.curves[op.size_key] = curve
        return store


@dataclasses.dataclass
class ProfileStore:
    """Curves keyed by (op_class, input_shape) — paper's per-(op,size) unit."""

    curves: dict[Hashable, CurveModel] = dataclasses.field(default_factory=dict)

    def curve(self, op: Op) -> CurveModel:
        return self.curves[op.size_key]

    @property
    def total_probes(self) -> int:
        return sum(c.probes for c in self.curves.values())

    def prediction_accuracy(self, op: Op, oracle: MeasureFn) -> float:
        """Paper's accuracy metric 1 - mean|ŷ-y|/y over UNTESTED cases."""
        curve = self.curves[op.size_key]
        errs: list[float] = []
        for variant, cases in curve.case_lists.items():
            tested = {t for t, _ in curve.samples.get(variant, [])}
            for t in cases:
                if t in tested:
                    continue
                y = oracle(op, t, variant)
                errs.append(abs(curve.predict(t, variant) - y) / y)
        if not errs:
            return 1.0
        return 1.0 - float(np.mean(errs))


# ---------------------------------------------------------------------------
# Regression baseline (§III-B)
# ---------------------------------------------------------------------------

class _OLS:
    def fit(self, X, y):
        Xb = np.c_[X, np.ones(len(X))]
        self.w, *_ = np.linalg.lstsq(Xb, y, rcond=None)
        return self

    def predict(self, X):
        return np.c_[X, np.ones(len(X))] @ self.w


class _KNN:
    def __init__(self, k=3):
        self.k = k

    def fit(self, X, y):
        self.mu, self.sd = X.mean(0), X.std(0) + 1e-9
        self.X = (X - self.mu) / self.sd
        self.y = y
        return self

    def predict(self, X):
        Xn = (X - self.mu) / self.sd
        d = ((Xn[:, None, :] - self.X[None, :, :]) ** 2).sum(-1)
        idx = np.argsort(d, axis=1)[:, :self.k]
        return self.y[idx].mean(1)


class _Tree:
    """CART regression tree (variance-reduction splits)."""

    def __init__(self, max_depth=4, min_samples=4):
        self.max_depth, self.min_samples = max_depth, min_samples

    def fit(self, X, y):
        self.root = self._grow(X, y, 0)
        return self

    def _grow(self, X, y, depth):
        if depth >= self.max_depth or len(y) < self.min_samples or np.ptp(y) == 0:
            return float(y.mean())
        best = None
        base = ((y - y.mean()) ** 2).sum()
        for f in range(X.shape[1]):
            for thr in np.unique(np.quantile(X[:, f], [0.25, 0.5, 0.75])):
                m = X[:, f] <= thr
                if m.sum() < 2 or (~m).sum() < 2:
                    continue
                sse = (((y[m] - y[m].mean()) ** 2).sum()
                       + ((y[~m] - y[~m].mean()) ** 2).sum())
                if best is None or sse < best[0]:
                    best = (sse, f, thr, m)
        if best is None or best[0] >= base:
            return float(y.mean())
        _, f, thr, m = best
        return (f, thr, self._grow(X[m], y[m], depth + 1),
                self._grow(X[~m], y[~m], depth + 1))

    def _eval(self, node, x):
        while not isinstance(node, float):
            f, thr, lo, hi = node
            node = lo if x[f] <= thr else hi
        return node

    def predict(self, X):
        return np.array([self._eval(self.root, x) for x in X])


class _GradientBoosting:
    def __init__(self, n_estimators=50, lr=0.1, max_depth=2):
        self.n, self.lr, self.depth = n_estimators, lr, max_depth

    def fit(self, X, y):
        self.base = float(y.mean())
        self.trees = []
        resid = y - self.base
        for _ in range(self.n):
            t = _Tree(max_depth=self.depth, min_samples=3).fit(X, resid)
            pred = t.predict(X)
            self.trees.append(t)
            resid = resid - self.lr * pred
        return self

    def predict(self, X):
        out = np.full(len(X), self.base)
        for t in self.trees:
            out += self.lr * t.predict(X)
        return out


class _TheilSen:
    """Subsampled median-of-OLS Theil-Sen approximation."""

    def __init__(self, n_subsets=30, seed=0):
        self.n_subsets, self.seed = n_subsets, seed

    def fit(self, X, y):
        rng = np.random.default_rng(self.seed)
        p = X.shape[1] + 1
        ws = []
        for _ in range(self.n_subsets):
            idx = rng.choice(len(X), size=min(len(X), p + 2), replace=False)
            Xb = np.c_[X[idx], np.ones(len(idx))]
            w, *_ = np.linalg.lstsq(Xb, y[idx], rcond=None)
            ws.append(w)
        self.w = np.median(np.stack(ws), axis=0)
        return self

    def predict(self, X):
        return np.c_[X, np.ones(len(X))] @ self.w


class _PassiveAggressive:
    """PA-I regression (epsilon-insensitive, online)."""

    def __init__(self, C=0.5, eps=0.02, epochs=5, seed=0):
        self.C, self.eps, self.epochs, self.seed = C, eps, epochs, seed

    def fit(self, X, y):
        self.mu, self.sd = X.mean(0), X.std(0) + 1e-9
        Xn = np.c_[(X - self.mu) / self.sd, np.ones(len(X))]
        w = np.zeros(Xn.shape[1])
        rng = np.random.default_rng(self.seed)
        for _ in range(self.epochs):
            for i in rng.permutation(len(Xn)):
                pred = Xn[i] @ w
                loss = max(0.0, abs(y[i] - pred) - self.eps)
                if loss > 0:
                    tau = min(self.C, loss / (Xn[i] @ Xn[i] + 1e-12))
                    w += np.sign(y[i] - pred) * tau * Xn[i]
        self.w = w
        return self

    def predict(self, X):
        return np.c_[(X - self.mu) / self.sd, np.ones(len(X))] @ self.w


REGRESSORS = {
    "GradientBoosting": _GradientBoosting,
    "KNeighbors": _KNN,
    "TSR": _TheilSen,
    "OLS": _OLS,
    "PAR": _PassiveAggressive,
    "DecisionTree": _Tree,
}


@dataclasses.dataclass
class RegressionSuite:
    """Per-case regression models (the paper trains one model per thread
    count — 68 models).  ``feature_fn(op, threads) -> dict`` supplies the
    normalized counter-like features; ``oracle`` the measured time."""

    feature_fn: Callable[[Op, int], dict[str, float]]
    oracle: MeasureFn
    cases: list[int]
    sample_counts: tuple[int, ...] = (1, 4, 8, 16)

    def _features(self, op: Op, n_samples: int) -> np.ndarray:
        # profile the op at n_samples evenly spaced thread counts and
        # concatenate their normalized features + measured times
        probe_ts = np.linspace(1, max(self.cases), n_samples).astype(int)
        feats: list[float] = []
        for t in probe_ts:
            c = self.feature_fn(op, int(t))
            feats.extend(sorted(c.values()))
            feats.append(self.oracle(op, int(t), True))
        return np.array(feats)

    def dataset(self, ops: list[Op], case: int, n_samples: int
                ) -> tuple[np.ndarray, np.ndarray]:
        X = np.stack([self._features(op, n_samples) for op in ops])
        y = np.array([self.oracle(op, case, True) for op in ops])
        return X, y

    def evaluate(self, train_ops: list[Op], test_ops: list[Op],
                 n_samples: int, regressor: str,
                 cases: list[int] | None = None) -> dict[str, float]:
        """Train per-case models on train_ops, report paper metrics on
        test_ops: accuracy = 1 - mean|ŷ-y|/y and R^2 (pooled)."""
        cases = cases or self.cases
        y_all, p_all = [], []
        for case in cases:
            Xtr, ytr = self.dataset(train_ops, case, n_samples)
            Xte, yte = self.dataset(test_ops, case, n_samples)
            model = REGRESSORS[regressor]().fit(Xtr, np.log(ytr + 1e-12))
            pred = np.exp(model.predict(Xte))
            y_all.append(yte)
            p_all.append(pred)
        y = np.concatenate(y_all)
        p = np.concatenate(p_all)
        acc = 1.0 - float(np.mean(np.abs(p - y) / y))
        ss_res = float(((y - p) ** 2).sum())
        ss_tot = float(((y - y.mean()) ** 2).sum()) + 1e-12
        return {"accuracy": acc, "r2": 1.0 - ss_res / ss_tot}
