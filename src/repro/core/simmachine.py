"""Deterministic KNL-like machine model — the cost oracle for the faithful
op-graph reproduction.

The paper measures wall-time of TF ops on a 68-core Knights Landing socket.
This container has one CPU core, so the *timing function* is modeled; every
scheduling/modeling decision downstream of the timing function is computed
by the real reimplemented algorithms (hill climbing, strategies 1-4).

The model reproduces the qualitative structure the paper reports:

* concave speedup with an interior optimum thread count (Fig 1 /
  Observation 1): Amdahl serial fraction + per-thread spawn/management
  overhead + bandwidth saturation;
* optimum grows with input size (Table II / Observation 2): bigger ops
  amortize spawn overhead further;
* cache-sharing affinity matters (paper §III-B): when two threads of a tile
  share data and the per-tile working set fits L2, traffic drops; when it
  does not fit, sharing thrashes;
* hyper-threads help only co-run throughput, not single-op latency
  (Table III: +3% co-run with HT vs +38% with core partitioning);
* co-running ops contend for MCDRAM bandwidth (§III-D interference).

A small deterministic "measurement jitter" (hash-seeded, ±1.5%) makes the
hill-climb/interpolation accuracy numbers honest rather than trivially 100%.
"""

from __future__ import annotations

import dataclasses
import math
import zlib
from typing import Iterable

from repro.core.graph import Op
from repro.hw.spec import KNL, KnlLikeSpec


@dataclasses.dataclass(frozen=True)
class Placement:
    """How an op's threads are placed (the paper's two affinity variants)."""

    threads: int
    cache_sharing: bool = True      # two threads per tile vs one per tile
    hyper_thread: bool = False      # running on the 2nd HW thread lane (S4)

    def cores_used(self, spec: KnlLikeSpec) -> int:
        if self.hyper_thread:
            return 0                # borrows busy cores' spare HW threads
        if self.cache_sharing:
            return self.threads     # 2 threads/tile => threads/2 tiles
        return self.threads         # 1 thread/tile, tile-exclusive cores


class SimMachine:
    """Deterministic cost oracle: time(op, placement, contention)."""

    def __init__(self, spec: KnlLikeSpec = KNL, jitter: float = 0.015,
                 seed: int = 0):
        self.spec = spec
        self.jitter = jitter
        self.seed = seed

    @property
    def fingerprint(self) -> tuple:
        """Identity of the timing function: curves measured on one machine
        are only valid on a machine with the same fingerprint (used by the
        cross-job PlanCache to refuse cross-machine reuse)."""
        return (self.spec, self.jitter, self.seed)

    # ------------------------------------------------------------------
    def _jitter_factor(self, op: Op, placement: Placement) -> float:
        if self.jitter == 0.0:
            return 1.0
        key = f"{self.seed}:{op.op_class}:{op.input_shape}:" \
              f"{placement.threads}:{placement.cache_sharing}"
        h = zlib.crc32(key.encode()) / 0xFFFFFFFF
        return 1.0 + self.jitter * math.sin(2 * math.pi * h)

    def _effective_bandwidth(self, threads: int, bw_share: float) -> float:
        # MCDRAM saturates around ~16 streams; share models co-run contention.
        sat = min(1.0, threads / 16.0 + 0.15)
        return self.spec.mcdram_bandwidth * sat * bw_share

    def corun_bw_share(self, threads: int,
                       co_running_threads: Iterable[int]) -> float:
        """Bandwidth fraction a ``threads``-wide launch gets next to the
        given co-runners — the machine owns the contention policy so every
        scheduler (single-graph co-run, multi-tenant pool) divides MCDRAM
        the same way.  Floored at 0.25: even a narrow op keeps a minimum
        stream share (MCDRAM is not perfectly fair-queued)."""
        total = threads + sum(co_running_threads)
        return max(0.25, threads / max(total, 1))

    def quadrant_bw_share(
            self, cores: tuple[int, ...],
            co_running: Iterable[tuple[int, tuple[int, ...]]]) -> float:
        """Topology-aware replacement for ``corun_bw_share``: bandwidth
        fraction of a launch PLACED on concrete core ids, next to
        co-runners given as ``(threads, cores)`` pairs.

        MCDRAM pages stay interleaved machine-wide (quadrant clustering
        localizes the tag directory, not the memory), so the BASE share is
        the same fair split as the flat rule — a solo launch gets 1.0
        whatever its placement.  The topology modulates the base per
        thread: a thread in a quadrant no co-runner occupies keeps its
        directory traffic home and recovers the all-to-all conflict waste
        (``spec.quadrant_local_boost``, calibrated to the paper's Table
        III core-partitioning gain), while a thread in a CONTESTED
        quadrant — one that co-runners also occupy — pays
        ``spec.cross_quadrant_penalty``, the cross-quadrant co-run the
        placement policy exists to avoid.  The blend is the per-core
        weighted mean, so the share degrades smoothly with how much of
        the launch overlaps foreign traffic.  Unplaced co-runners
        (hyper-thread-lane launches) count toward the fair split — their
        streams are real — but contest no quadrant: they have no pinned
        placement, just time-sliced spare HW threads at 0.55 efficiency,
        so they don't drag a placed launch's directory traffic off its
        home quadrant."""
        spec = self.spec
        mine: dict[int, int] = {}
        for c in cores:
            q = spec.quadrant_of_core(c)
            mine[q] = mine.get(q, 0) + 1
        my_threads = len(cores)
        other_threads = 0
        contested: set[int] = set()
        for threads, other in co_running:
            other_threads += len(other) if other else threads
            if other:
                contested |= ({spec.quadrant_of_core(c) for c in other}
                              & set(mine))
        share = max(0.25, my_threads / max(my_threads + other_threads, 1))
        locality = sum(
            (m / my_threads) * (spec.cross_quadrant_penalty
                                if q in contested
                                else spec.quadrant_local_boost)
            for q, m in mine.items()) if my_threads else 1.0
        return min(1.0, share * locality)

    def op_time(self, op: Op, placement: Placement, *,
                bw_share: float = 1.0) -> float:
        """Seconds to execute ``op`` under ``placement``.

        ``bw_share`` in (0,1]: fraction of memory bandwidth available
        (co-run contention, computed by the scheduler from concurrent load).
        """
        p = max(1, placement.threads)
        spec = self.spec
        if not placement.hyper_thread:
            p = min(p, spec.cores)

        # --- compute: bounded-parallelism Amdahl + sync serialization ----
        # an op only exposes ceil(elems/chunk) independent work chunks
        # (MKL-DNN loop blocking), so threads beyond p_max add overhead but
        # no speedup: the curve decreases to p_max, then rises gently —
        # the paper's Fig 1 shape, with Table II's size-dependent optimum.
        elems = 1.0
        for d in op.input_shape:
            elems *= d
        p_max = max(1, int(-(-elems // spec.chunk_elems)))
        eff = spec.hyper_thread_efficiency if placement.hyper_thread else 1.0
        p_used = min(p * eff, p_max)
        t1 = op.flops / spec.core_flops
        f = op.parallel_fraction
        sigma = spec.sync_serialization
        t_comp = t1 * (1.0 - f) + t1 * f * ((1.0 - sigma) / p_used + sigma)

        # --- memory traffic ----------------------------------------------
        traffic = op.bytes_moved
        if placement.cache_sharing and p >= 2:
            # two threads/tile share the tile's 1MB L2
            per_tile_ws = op.working_set / max(1, p // 2)
            if per_tile_ws <= spec.l2_bytes_per_tile:
                traffic *= 0.62          # reuse hits in shared L2
            else:
                traffic *= 1.12          # thrash: two working sets, one L2
        t_mem = traffic / self._effective_bandwidth(p, bw_share)

        # --- thread management overhead (spawn/bind), paper §III-D -------
        t_spawn = p * spec.thread_spawn_us * 1e-6

        return (t_comp + t_mem + t_spawn) * self._jitter_factor(op, placement)

    # ------------------------------------------------------------------
    def best_time_exhaustive(self, op: Op, max_threads: int | None = None
                             ) -> tuple[float, Placement]:
        """Ground-truth optimum by scanning every (threads, sharing) case —
        the oracle the model-accuracy benchmarks compare against."""
        max_threads = max_threads or self.spec.cores
        best: tuple[float, Placement] | None = None
        for sharing in (False, True):
            for t in self.thread_cases(sharing, max_threads):
                pl = Placement(t, cache_sharing=sharing)
                dt = self.op_time(op, pl)
                if best is None or dt < best[0]:
                    best = (dt, pl)
        assert best is not None
        return best

    def thread_cases(self, cache_sharing: bool, max_threads: int | None = None
                     ) -> list[int]:
        """The paper's 68 prediction cases: 34 no-sharing (1 thread/tile,
        1..34) + 34 sharing (even counts 2..68)."""
        max_threads = max_threads or self.spec.cores
        if cache_sharing:
            return [t for t in range(2, max_threads + 1, 2)]
        return [t for t in range(1, self.spec.tiles + 1) if t <= max_threads]

    # ------------------------------------------------------------------
    # Synthetic "hardware counter" features for the regression baseline.
    # Deterministic functions of the op's analytic profile, normalized by
    # instruction count (as the paper normalizes) — plus hash noise at the
    # magnitude the paper blames for counter inaccuracy.
    # ------------------------------------------------------------------
    def counters(self, op: Op, threads: int) -> dict[str, float]:
        instrs = max(op.flops / 4.0, 1.0)
        cycles = (op.flops / self.spec.core_flops) * 1.3e9 / max(threads, 1)
        llc_acc = op.bytes_moved / 64.0
        fit = min(1.0, self.spec.l2_bytes_per_tile /
                  max(op.working_set / max(threads // 2, 1), 1.0))
        llc_miss = llc_acc * (1.0 - 0.55 * fit)
        l1_hit = instrs * (0.6 + 0.3 * fit)
        noise_key = f"cnt:{self.seed}:{op.uid}:{threads}"
        noise = 1.0 + 0.08 * math.sin(
            2 * math.pi * zlib.crc32(noise_key.encode()) / 0xFFFFFFFF)
        return {
            "cycles_per_instr": cycles / instrs * noise,
            "llc_miss_per_instr": llc_miss / instrs * noise,
            "llc_acc_per_instr": llc_acc / instrs,
            "l1_hit_per_instr": l1_hit / instrs,
        }
