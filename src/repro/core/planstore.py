"""Closed-loop plan store: predictions out, observations back in.

The paper's runtime is a *closed loop*: the performance model predicts op
execution time per concurrency width, the scheduler acts on the
prediction, and observed behavior feeds back (§III-D records co-run
slowdowns into the interference blacklist).  Until this module the
blacklist was the ONLY feedback path we reproduced — every other
prediction (``Plan`` curves, ``Job.demand``, deadline critical paths)
was frozen at profiling/admission time and consumed through ad-hoc
``controller.store.curve(op).predict(...)`` reads scattered across the
schedulers.

``PlanStore`` closes the loop as ONE interface:

* **predict side** — everything a scheduler consumes: per-width op time
  (``predict``), Strategy-3 candidate configurations (``candidates``),
  the frozen-plan width re-priced (``replan``), and the aggregate
  predictions built on top of them — a job's outstanding demand in
  core-seconds (``remaining_demand``) and per-node downstream critical
  paths that turn deadlines into slack (``remaining_critical_path``);
* **observe side** — everything a scheduler produces: launch, finish,
  and preemption-revoke events arrive as ``OpObservation`` records via
  ``observe`` (the ``StrategyAdapter.observe`` seam reports them for
  both the single-graph scheduler and the multi-tenant pool, and
  ``RealGraphExecutor`` reports real JAX payload wall times through the
  same call).

Two implementations:

* ``FrozenPlanStore`` — ``feedback="off"`` (the default): predictions
  come from the profiling-time curves verbatim and observations are
  discarded, reproducing the pre-feedback schedulers bit for bit
  (locked by the golden/differential suites);
* ``AdaptivePlanStore`` — ``feedback="ewma"``: observed service is
  EWMA-blended into per-(op-key, width) correction factors over the
  frozen curves, so when profiles mispredict (stale measurements, a
  perturbed machine) every downstream prediction — candidate ranking,
  admission horizons, ``Job.demand``, deadline slack — converges toward
  observed reality while the profiling structure (probe grid, S1/S2
  widths) stays intact.

The blend is the *incremental* EWMA form ``c += alpha * (ratio - c)``,
which is exactly stable at the fixed point: a stream of observations
matching predictions (ratio 1.0) leaves every correction at 1.0 and —
because a 1.0 factor short-circuits to the raw curve value — every
prediction bit-identical to ``feedback="off"``.  The parity suite runs a
zero-error trace through the adaptive store to pin that property.
"""

from __future__ import annotations

import abc
import dataclasses
import math
from typing import Hashable

from repro.core.concurrency import (ConcurrencyController, ConcurrencyPlan,
                                    OpPlan)
from repro.core.graph import Op, OpGraph
from repro.core.perfmodel import CurveModel, cross_graph_key

# observation kinds, as reported through StrategyAdapter.observe
OBS_LAUNCH = "launch"      # op committed to cores (no duration yet)
OBS_FINISH = "finish"      # op completed; observed = full service time
OBS_REVOKE = "revoke"      # op preempted; observed = discarded partial run

FEEDBACK_MODES = ("off", "ewma")


def _freeze(x):
    """JSON arrays -> tuples, recursively.  Learned-state keys are tuples
    (``cross_graph_key`` values, region keys) and JSON round-trips them
    as lists; freezing restores dict-key hashability and equality."""
    if isinstance(x, list):
        return tuple(_freeze(v) for v in x)
    return x


@dataclasses.dataclass(frozen=True)
class OpObservation:
    """One scheduler-reported execution event for one op launch."""

    op: Op
    threads: int
    variant: bool            # affinity flavor of the launch
    hyper: bool              # hyper-thread-lane launch (S4)
    predicted: float         # what the plan said this launch would take
    observed: float          # elapsed seconds (partial for OBS_REVOKE)
    kind: str = OBS_FINISH


def critical_path_from(graph: OpGraph,
                       pred: dict[int, float]) -> dict[int, float]:
    """uid -> ``pred[uid]`` plus the longest consumer chain (reverse
    topological order via Kahn on consumer counts — graph uids are
    usually topo-ordered already, but don't rely on it)."""
    out_deg = {uid: len(graph.consumers(uid)) for uid in graph.ops}
    stack = [uid for uid, n in out_deg.items() if n == 0]
    cp: dict[int, float] = {}
    while stack:
        uid = stack.pop()
        cp[uid] = pred[uid] + max(
            (cp[c] for c in graph.consumers(uid)), default=0.0)
        for d in graph.ops[uid].deps:
            out_deg[d] -= 1
            if out_deg[d] == 0:
                stack.append(d)
    return cp


# ---------------------------------------------------------------------------
# move pricing — the preemption-economics currency
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MovePrice:
    """One candidate scheduler move, priced in a single currency (seconds
    or core-seconds — a price only ever compares against another price in
    the same unit).  ``gain`` is the predicted benefit of making the move,
    ``cost`` the re-billed restart waste it incurs; the scheduler makes
    the move only when the gain STRICTLY exceeds the cost, so a move that
    merely breaks even never discards partial work."""

    gain: float
    cost: float

    @property
    def worth_it(self) -> bool:
        return self.gain > self.cost


def restart_cost(threads: int, elapsed: float, restart_waste: float,
                 efficiency: float = 1.0) -> float:
    """Core-seconds of partial work a checkpoint-free revoke throws away,
    re-billed at the machine's restart-waste factor (the same formula the
    pool's ``refund`` charges back, so a priced move and the accounting
    it triggers can never disagree)."""
    return threads * elapsed * efficiency * restart_waste


def claim_price(width: int, time_without: float, time_with: float,
                waste: float) -> MovePrice:
    """Multi-victim revoke, priced in core-seconds: the SLO gain is the
    waiter's predicted-time improvement at its preferred ``width`` (vs
    the best width reachable without the extra victims), weighted by that
    width; the cost is the summed restart waste of the victim set."""
    return MovePrice(gain=max(0.0, time_without - time_with) * width,
                     cost=waste)


def migration_price(remaining: float, relaunch_time: float, elapsed: float,
                    restart_waste: float) -> MovePrice:
    """Width migration, priced in op-seconds: relaunching is worth it only
    when the predicted relaunch duration plus the re-billed waste (the
    discarded ``elapsed`` at the restart-waste factor) strictly undercuts
    finishing at the current width."""
    return MovePrice(gain=remaining - relaunch_time,
                     cost=elapsed * restart_waste)


def split_price(whole_time: float, split_time: float, transfer_cost: float,
                restart_cost_s: float = 0.0) -> MovePrice:
    """Cross-machine split/move, priced in seconds of the tenant's own
    makespan: spanning a second machine is worth it only when the
    predicted parallel finish strictly undercuts staying put PLUS the
    modeled working-set transfer and any restart waste of already-started
    work.  Same strict-inequality discipline as every other priced move:
    a split that merely breaks even stays on one machine."""
    return MovePrice(gain=max(0.0, whole_time - split_time),
                     cost=transfer_cost + restart_cost_s)


# ---------------------------------------------------------------------------
# demand queries per machine fingerprint — the cluster-routing currency
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DemandIndex:
    """Memoized demand (core-seconds) estimates keyed by
    ``(machine fingerprint, workload key)``.

    The cluster router bin-packs jobs against per-machine free capacity,
    which on a heterogeneous cluster means re-estimating every arriving
    job's demand under EACH candidate machine's cost model.  A full
    re-profile per (job, machine) pair would be absurdly expensive, but
    demand is a pure function of (workload shape, machine fingerprint):
    training jobs repeat a handful of step-graph shapes, so the first
    estimate per pair is authoritative for every later arrival of the
    same shape.  Estimates are keyed by the same canonical fingerprint
    reprs the ``PlanCache`` namespaces curves under — the two caches
    agree about what "the same machine" means."""

    values: dict = dataclasses.field(default_factory=dict)
    hits: int = 0
    misses: int = 0

    @staticmethod
    def workload_key(graph) -> tuple:
        """Canonical shape key of a graph: the sorted multiset of its
        per-op cross-graph keys.  Two independently built graphs with the
        same op population have the same demand on the same machine."""
        from repro.core.perfmodel import cross_graph_key
        return tuple(sorted(map(repr, (cross_graph_key(op)
                                       for op in graph.ops.values()))))

    def query(self, fingerprint, graph, compute) -> float:
        """Demand of ``graph`` on the machine ``fingerprint`` — memoized;
        ``compute()`` (profile + ``remaining_demand`` under that
        machine's planstore) runs only on the first miss per pair."""
        key = (repr(fingerprint), self.workload_key(graph))
        if key in self.values:
            self.hits += 1
            return self.values[key]
        self.misses += 1
        value = float(compute())
        self.values[key] = value
        return value

    def peek(self, fingerprint, graph, *,
             count: bool = False) -> float | None:
        """Memoized demand if present, without computing (used by the
        router's capacity projections, which must never trigger a
        profile).  ``count=True`` bills a found value as a hit — the
        router's facts pass sets it so reuse shows up in the stats;
        existence probes leave the counters alone."""
        v = self.values.get((repr(fingerprint), self.workload_key(graph)))
        if count and v is not None:
            self.hits += 1
        return v


class PlanStore(abc.ABC):
    """Every prediction a scheduler consumes and every completion it
    produces, through one interface (see module docstring)."""

    # ---- predict side --------------------------------------------------
    @abc.abstractmethod
    def predict(self, op: Op, threads: int, variant: bool) -> float:
        """Predicted solo execution time of ``op`` at a width/affinity."""

    @abc.abstractmethod
    def candidates(self, op: Op, k: int = 3) -> list[OpPlan]:
        """Strategy 3's top-k candidate configurations for ``op``."""

    def replan(self, op: Op, base: OpPlan) -> OpPlan:
        """The frozen plan's width, re-priced by the store — the
        instance-plan lookup both scheduler adapters use."""
        return OpPlan(base.threads, base.variant,
                      self.predict(op, base.threads, base.variant))

    # ---- observe side --------------------------------------------------
    def observe(self, obs: OpObservation) -> None:
        """Report an execution event.  The frozen store discards it."""

    def observe_region(self, region, outcome: float) -> None:
        """Report a region resolution (observed trip count for a while,
        1.0/0.0 branch direction for a cond).  Frozen stores discard it;
        the adaptive store feeds its ``TripCountEstimator``."""

    @property
    def adaptive(self) -> bool:
        """True when observations can change future predictions (callers
        that re-derive cached aggregates key off this)."""
        return False

    # ---- region expectations -------------------------------------------
    # Unresolved regions have no materialized ops to price, so their
    # contribution to demand/critical-path is an EXPECTATION: expected
    # remaining trip count x per-iteration body cost for a while region,
    # probability-weighted branch costs for a cond.  The frozen store
    # prices from the build-time priors (``est_trips``/``p_true``); the
    # adaptive store substitutes pool-wide learned estimates.

    def region_trips(self, region) -> float:
        """Expected TOTAL trip count of a while region (prior-based)."""
        return min(max(float(region.est_trips), 0.0),
                   float(region.max_trips))

    def region_taken_p(self, region) -> float:
        """Probability the cond region takes its true branch."""
        return min(max(float(region.p_true), 0.0), 1.0)

    def _plan_time(self, op: Op, plan: ConcurrencyPlan) -> float:
        p = plan.per_instance[op.size_key]
        return self.predict(op, p.threads, p.variant)

    def _plan_demand(self, body: OpGraph, plan: ConcurrencyPlan) -> float:
        total = 0.0
        for op in body.ops.values():
            p = plan.per_instance[op.size_key]
            total += self.predict(op, p.threads, p.variant) * p.threads
        return total

    def _body_tail(self, body: OpGraph, plan: ConcurrencyPlan) -> float:
        pred = {u: self._plan_time(op, plan) for u, op in body.ops.items()}
        return max(critical_path_from(body, pred).values(), default=0.0)

    def region_demand(self, region, plan: ConcurrencyPlan) -> float:
        """Expected outstanding core-seconds of an unresolved region
        (iterations/branches not yet materialized, plus the exit op)."""
        p_exit = plan.per_instance[region.exit_op.size_key]
        exit_d = (self.predict(region.exit_op, p_exit.threads,
                               p_exit.variant) * p_exit.threads)
        if region.kind == "cond":
            p = self.region_taken_p(region)
            return (p * self._plan_demand(region.branches[0], plan)
                    + (1.0 - p) * self._plan_demand(region.branches[1], plan)
                    + exit_d)
        future = max(self.region_trips(region) - region.trips_started, 0.0)
        return future * self._plan_demand(region.body, plan) + exit_d

    def region_tail(self, region, plan: ConcurrencyPlan) -> float:
        """Expected serial time through an unresolved region's not-yet
        materialized part (iteration critical paths chain; branches are
        probability-weighted), ending with the exit op."""
        exit_t = self._plan_time(region.exit_op, plan)
        if region.kind == "cond":
            p = self.region_taken_p(region)
            return (p * self._body_tail(region.branches[0], plan)
                    + (1.0 - p) * self._body_tail(region.branches[1], plan)
                    + exit_t)
        future = max(self.region_trips(region) - region.trips_started, 0.0)
        return future * self._body_tail(region.body, plan) + exit_t

    # ---- aggregate predictions ----------------------------------------
    def remaining_demand(self, graph: OpGraph, plan: ConcurrencyPlan,
                         done: frozenset[int] | set[int] = frozenset()
                         ) -> float:
        """Outstanding predicted core-seconds of ``graph`` under the
        frozen plan widths, excluding completed uids — the admission and
        fair-share currency (``Job.demand``).  Unresolved regions add
        their expected demand (a dynamic graph with zero unresolved
        regions prices bit-identically to the static graph)."""
        total = 0.0
        for uid, op in graph.ops.items():
            if uid in done:
                continue
            p = plan.per_instance[op.size_key]
            total += self.predict(op, p.threads, p.variant) * p.threads
        regions = graph.unresolved_regions()
        if regions:
            total += sum(self.region_demand(r, plan) for r in regions)
        return total

    def remaining_critical_path(self, graph: OpGraph, plan: ConcurrencyPlan,
                                done: frozenset[int] | set[int] = frozenset()
                                ) -> dict[int, float]:
        """uid -> predicted time from starting that node to finishing the
        graph (the node's own re-priced plan prediction plus the longest
        consumer chain; completed nodes contribute zero).  This is what
        turns a job deadline into per-node slack (``Job.cp``).

        Unresolved regions join the longest-path computation as VIRTUAL
        nodes: each reserved exit uid gets weight ``region_tail`` (the
        expected serial time through the unmaterialized part) and a
        virtual edge from every gate uid, so a node upstream of a
        half-unrolled loop sees deadline slack through the loop's
        expected remainder.  The virtual exit entries stay in the
        returned dict (the pool's root-slack takes a max over values).
        With zero unresolved regions this is exactly the static path."""
        pred = {}
        for uid, op in graph.ops.items():
            if uid in done:
                pred[uid] = 0.0
            else:
                p = plan.per_instance[op.size_key]
                pred[uid] = self.predict(op, p.threads, p.variant)
        regions = graph.unresolved_regions()
        if not regions:
            return critical_path_from(graph, pred)
        tail = {r.exit_uid: self.region_tail(r, plan) for r in regions}
        extra: dict[int, list[int]] = {}
        for r in regions:
            for g in r.gate:
                extra.setdefault(g, []).append(r.exit_uid)

        def succ(u: int) -> list[int]:
            return list(graph.consumers(u)) + extra.get(u, [])

        cp: dict[int, float] = {}
        for root in (*graph.ops, *tail):
            stack = [root]
            while stack:
                u = stack[-1]
                if u in cp:
                    stack.pop()
                    continue
                pending = [s for s in succ(u) if s not in cp]
                if pending:
                    stack.extend(pending)
                    continue
                own = tail[u] if u in tail else pred[u]
                cp[u] = own + max((cp[s] for s in succ(u)), default=0.0)
                stack.pop()
        return cp


class FrozenPlanStore(PlanStore):
    """``feedback="off"``: the profiling-time curves, verbatim.

    Predictions resolve against the controller's frozen ``ProfileStore``
    exactly as the pre-feedback schedulers did (same floats, same
    candidate order), and ``observe`` is a no-op — so every scheduler
    built on this store is bit-for-bit the PR-4 scheduler."""

    def __init__(self, controller: ConcurrencyController):
        self.controller = controller

    def predict(self, op: Op, threads: int, variant: bool) -> float:
        return self.controller.store.curve(op).predict(threads, variant)

    def candidates(self, op: Op, k: int = 3) -> list[OpPlan]:
        return self.controller.candidates_for(op, k)


@dataclasses.dataclass
class CorrectionTable:
    """Shared EWMA state: observed/predicted service ratios per curve
    point, blended incrementally (``c += alpha * (ratio - c)``).

    One table can back many ``AdaptivePlanStore`` views (the pool shares
    one across all tenants, keyed by ``cross_graph_key`` — the same key
    the PlanCache shares curves under, so an op two tenants both run
    teaches both).  ``point`` entries correct the exact (key, width,
    variant) observed; ``overall`` keeps a per-key ratio used as the
    fallback for widths never observed, so a correction learned at the
    plan width still informs a squeezed fallback launch.

    ``zero_error`` is the parity-suite hook: every observation is
    treated as exactly matching its prediction (ratio 1.0), which must
    leave the adaptive store bit-identical to the frozen one — any drift
    is a bug in the blend math."""

    alpha: float = 0.25
    # observed/predicted ratios outside this band are clamped before
    # blending: a single pathological co-run (or a division by a tiny
    # prediction) must not catapult the correction
    ratio_bounds: tuple[float, float] = (0.125, 8.0)
    zero_error: bool = False
    point: dict[tuple[Hashable, int, bool], float] = dataclasses.field(
        default_factory=dict)
    overall: dict[Hashable, float] = dataclasses.field(default_factory=dict)
    observed: int = 0        # finish observations blended
    revoked: int = 0         # preemption revokes reported (not blended)

    def update(self, key: Hashable, threads: int, variant: bool,
               ratio: float) -> None:
        lo, hi = self.ratio_bounds
        ratio = min(max(ratio, lo), hi)
        for table, k in ((self.point, (key, threads, variant)),
                         (self.overall, key)):
            old = table.get(k, 1.0)
            table[k] = old + self.alpha * (ratio - old)
        self.observed += 1

    def factor(self, key: Hashable, threads: int, variant: bool) -> float:
        c = self.point.get((key, threads, variant))
        if c is None:
            c = self.overall.get(key, 1.0)
        return c

    def stats(self) -> dict[str, float]:
        mags = [abs(math.log(max(c, 1e-12))) for c in self.point.values()]
        return {
            "observed": self.observed,
            "revoked": self.revoked,
            "points": len(self.point),
            "keys": len(self.overall),
            # how far the blended corrections sit from the frozen curves,
            # in |log| space (0.0 = profiles were exact) — the metrics
            # registry surfaces these as feedback.* gauges
            "mean_abs_log_correction": (sum(mags) / len(mags)
                                        if mags else 0.0),
            "max_abs_log_correction": max(mags, default=0.0),
        }

    # ---- persistence (service daemon job store) -----------------------
    def to_dict(self) -> dict:
        """JSON form.  Floats round-trip exactly (shortest-repr doubles),
        so a reloaded table corrects predictions bit-identically — the
        property the daemon crash-recovery test pins."""
        return {
            "alpha": self.alpha,
            "ratio_bounds": list(self.ratio_bounds),
            "zero_error": self.zero_error,
            "point": [{"key": k, "threads": t, "variant": v, "c": c}
                      for (k, t, v), c in self.point.items()],
            "overall": [{"key": k, "c": c}
                        for k, c in self.overall.items()],
            "observed": self.observed,
            "revoked": self.revoked,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "CorrectionTable":
        return cls(
            alpha=float(d["alpha"]),
            ratio_bounds=tuple(d["ratio_bounds"]),
            zero_error=bool(d["zero_error"]),
            point={(_freeze(e["key"]), int(e["threads"]),
                    bool(e["variant"])): float(e["c"])
                   for e in d["point"]},
            overall={_freeze(e["key"]): float(e["c"])
                     for e in d["overall"]},
            observed=int(d["observed"]),
            revoked=int(d["revoked"]),
        )


@dataclasses.dataclass
class TripCountEstimator:
    """Pool-wide EWMA over observed region outcomes, keyed by region key
    (the ``CorrectionTable`` pattern applied to control flow): while
    regions blend observed trip counts, cond regions blend taken
    fractions (resolutions arrive as 1.0/0.0, so the EWMA converges on
    the empirical taken probability).  One estimator backs every
    adaptive store in a pool, so the second tenant running the same loop
    starts with the learned trip count instead of the build-time prior.

    The first observation for a key initializes the estimate directly
    (the build-time prior is a guess, not evidence — don't average
    against it); later observations blend incrementally."""

    alpha: float = 0.5
    values: dict[Hashable, float] = dataclasses.field(default_factory=dict)
    observed: int = 0

    def update(self, key: Hashable, outcome: float) -> None:
        old = self.values.get(key)
        self.values[key] = (outcome if old is None
                            else old + self.alpha * (outcome - old))
        self.observed += 1

    def estimate(self, key: Hashable, prior: float) -> float:
        return self.values.get(key, prior)

    def stats(self) -> dict[str, float]:
        return {"observed": self.observed, "keys": len(self.values)}

    # ---- persistence (service daemon job store) -----------------------
    def to_dict(self) -> dict:
        return {"alpha": self.alpha,
                "values": [{"key": k, "v": v}
                           for k, v in self.values.items()],
                "observed": self.observed}

    @classmethod
    def from_dict(cls, d: dict) -> "TripCountEstimator":
        return cls(alpha=float(d["alpha"]),
                   values={_freeze(e["key"]): float(e["v"])
                           for e in d["values"]},
                   observed=int(d["observed"]))


class AdaptivePlanStore(PlanStore):
    """``feedback="ewma"``: frozen curves, online corrections.

    Predictions are the frozen curve value times the EWMA correction for
    that (op key, width, variant); Strategy-3 candidates are re-ranked
    by their CORRECTED times, so a width the profile under-predicted
    loses its top-k seat once observations expose it.  Only finish
    events blend (a launch carries no duration yet; a revoked partial
    run is not a service time; a hyper-lane duration measures the
    0.55-efficiency spare-thread lane, not the curve's placement) — but
    all events flow through ``observe`` so accounting hooks and future
    stores see the full stream.

    A correction factor of exactly 1.0 short-circuits to the raw curve
    value, so an all-zero-error observation stream keeps this store
    bit-identical to ``FrozenPlanStore`` (the parity lock)."""

    def __init__(self, controller: ConcurrencyController,
                 corrections: CorrectionTable | None = None,
                 trip_counts: TripCountEstimator | None = None):
        self.controller = controller
        self.corrections = (corrections if corrections is not None
                            else CorrectionTable())
        self.trip_counts = (trip_counts if trip_counts is not None
                            else TripCountEstimator())

    @property
    def adaptive(self) -> bool:
        return True

    # region expectations use the learned estimates instead of the priors
    def region_trips(self, region) -> float:
        est = self.trip_counts.estimate(region.key, float(region.est_trips))
        return min(max(est, 0.0), float(region.max_trips))

    def region_taken_p(self, region) -> float:
        p = self.trip_counts.estimate(region.key, float(region.p_true))
        return min(max(p, 0.0), 1.0)

    def observe_region(self, region, outcome: float) -> None:
        self.trip_counts.update(region.key, float(outcome))

    def predict(self, op: Op, threads: int, variant: bool) -> float:
        base = self.controller.store.curve(op).predict(threads, variant)
        c = self.corrections.factor(cross_graph_key(op), threads, variant)
        return base if c == 1.0 else base * c

    def candidates(self, op: Op, k: int = 3) -> list[OpPlan]:
        if not op.tunable:
            # non-tunable ops keep the controller's pinned plan (the
            # runtime never re-tunes them); only the time is re-priced
            base = self.controller.candidates_for(op, 1)[0]
            return [self.replan(op, base)]
        curve = self.controller.store.curve(op)
        # CurveModel.candidates over the same measured-case source and
        # ranking rule, but with CORRECTED times (identical output when
        # every correction is 1.0 — predict() at a probed point returns
        # the sample value exactly, see the zero-error parity suite)
        scored = [(t, v, self.predict(op, t, v))
                  for t, v, _ in curve.measured_cases()]
        return [OpPlan(t, v, y)
                for t, v, y in CurveModel.rank_cases(scored, k)]

    def observe(self, obs: OpObservation) -> None:
        if obs.kind == OBS_REVOKE:
            self.corrections.revoked += 1
            return
        if obs.kind != OBS_FINISH or obs.hyper:
            return
        if self.corrections.zero_error:
            ratio = 1.0
        else:
            # the ratio is observed over the BASE curve prediction, not
            # over obs.predicted (the launch-time prediction, which
            # already carries the current correction — dividing by it
            # would chase the fixed point c^2 = observed/base instead of
            # c = observed/base, stalling convergence at the square root)
            try:
                base = self.controller.store.curve(obs.op).predict(
                    obs.threads, obs.variant)
            except KeyError:
                return              # no curve to correct (unprofiled op)
            ratio = obs.observed / max(base, 1e-12)
        self.corrections.update(cross_graph_key(obs.op), obs.threads,
                                obs.variant, ratio)


def make_plan_store(feedback: str, controller: ConcurrencyController, *,
                    corrections: CorrectionTable | None = None,
                    trip_counts: TripCountEstimator | None = None
                    ) -> PlanStore:
    """The one constructor every runtime/pool uses, so the gating knob
    (``StrategyConfig.feedback``) has a single interpretation."""
    if feedback == "off":
        return FrozenPlanStore(controller)
    if feedback == "ewma":
        return AdaptivePlanStore(controller, corrections, trip_counts)
    raise ValueError(
        f"unknown feedback mode {feedback!r}; expected one of "
        f"{FEEDBACK_MODES}")
