"""Topology-aware thread placement: width -> concrete core set.

The paper's scheduler divides MCDRAM bandwidth among co-running ops but
places threads on a flat 68-core pool.  On the real KNL socket the cores
sit on 34 shared-L2 tiles grouped into mesh quadrants, and co-runs that
straddle quadrants contend far harder than quadrant-local ones — so under
``topology="quadrant"`` placement becomes a first-class scheduling
decision.  ``place`` maps a launch's width to concrete core ids:

1. prefer an EMPTY quadrant that fits the width (best fit among empties,
   so big empty quadrants stay open for wide launches);
2. else pack quadrant-local: the single quadrant with enough free cores
   and the fewest co-resident busy cores (least local contention);
3. else bounded spill: fill from the freest quadrants so the launch
   touches as few quadrants as possible — the straddle is priced by the
   cost oracle (``SimMachine.quadrant_bw_share``), not forbidden.

A ``prefer`` quadrant hint (pool tenant affinity) wins ties at every
tier, and ``avoid`` quadrants (co-residents whose class pair is
blacklisted under the cross-quadrant relation) are never allocated —
when avoiding them leaves too few cores, placement fails and the caller
skips the launch.

Core selection inside the chosen quadrants is tile-aware: cache-sharing
launches take whole shared-L2 tile pairs first (the paper's two-threads-
per-tile affinity variant), falling back to singleton free cores only
when the pairs run out.  Everything is deterministic: same occupancy in,
same core set out.

``topology="flat"`` bypasses this module entirely — flat timelines stay
bit-for-bit identical to the pre-topology scheduler, which is what the
differential/golden suites lock down.
"""

from __future__ import annotations

from typing import Iterable

from repro.hw.spec import KnlLikeSpec

Relation = str          # "any" (flat topology) | "local" | "cross"

#: the legacy single-key relation used by flat topology: every co-run
#: observation lands in one bucket, exactly the pre-topology recorder
REL_ANY = "any"
#: quadrant-local co-run: the two launches occupy disjoint quadrants
REL_LOCAL = "local"
#: cross-quadrant co-run: the launches straddle into shared quadrants
#: (or either side is an unplaced hyper-lane launch riding busy cores)
REL_CROSS = "cross"


def quadrants_of(spec: KnlLikeSpec, cores: Iterable[int]) -> frozenset[int]:
    return frozenset(spec.quadrant_of_core(c) for c in cores)


def placement_relation(spec: KnlLikeSpec, cores_a: tuple[int, ...],
                       cores_b: tuple[int, ...]) -> Relation:
    """How two co-running placements relate: disjoint quadrant sets are a
    quadrant-LOCAL co-run (each op's traffic stays home), any overlap —
    or an unplaced side, which rides everyone's cores — is CROSS."""
    if not cores_a or not cores_b:
        return REL_CROSS
    if quadrants_of(spec, cores_a) & quadrants_of(spec, cores_b):
        return REL_CROSS
    return REL_LOCAL


def free_cores_by_quadrant(spec: KnlLikeSpec,
                           busy: frozenset[int]) -> dict[int, list[int]]:
    """quadrant -> ascending free core ids (busy = union of running
    placements, so a preemption revoke frees its cores implicitly)."""
    return {q: [c for c in spec.quadrant_cores(q) if c not in busy]
            for q in range(spec.quadrants)}


def _take(spec: KnlLikeSpec, free: list[int], width: int,
          cache_sharing: bool) -> list[int]:
    """Pick ``width`` cores from one quadrant's free list, whole shared-L2
    tile pairs first for cache-sharing launches (both threads of a pair
    share the tile's 1MB L2 — the paper's sharing affinity)."""
    if not cache_sharing:
        return free[:width]
    fs = set(free)
    pairs = [c for c in free if (c ^ 1) in fs]      # c^1 = the tile-mate
    singles = [c for c in free if (c ^ 1) not in fs]
    return (pairs + singles)[:width]


def place(spec: KnlLikeSpec, width: int, busy: frozenset[int],
          cache_sharing: bool = True, prefer: int | None = None,
          avoid: frozenset[int] = frozenset()) -> tuple[int, ...] | None:
    """Concrete core ids for a ``width``-thread launch, or ``None`` when
    the avoid constraints leave too few free cores (the caller treats
    that launch as incompatible at this instant)."""
    free = {q: f for q, f in free_cores_by_quadrant(spec, busy).items()
            if q not in avoid}
    if sum(len(f) for f in free.values()) < width:
        return None

    def tiered(q: int) -> tuple:
        # smaller tuple = better; prefer-hint beats everything in a tier
        n_busy = len(spec.quadrant_cores(q)) - len(free[q])
        return (q != prefer, n_busy, len(free[q]), q)

    # 1. empty quadrant, best fit (smallest capacity that holds width)
    empties = [q for q, f in free.items()
               if len(f) >= width and len(f) == len(spec.quadrant_cores(q))]
    if empties:
        q = min(empties, key=lambda q: (q != prefer, len(free[q]), q))
        return tuple(_take(spec, free[q], width, cache_sharing))
    # 2. quadrant-local packing: fewest co-residents
    fitting = [q for q, f in free.items() if len(f) >= width]
    if fitting:
        q = min(fitting, key=tiered)
        return tuple(_take(spec, free[q], width, cache_sharing))
    # 3. bounded spill: freest quadrants first, so the launch straddles as
    #    few quadrants as possible (the straddle is priced, not forbidden)
    order = sorted(free, key=lambda q: (q != prefer, -len(free[q]), q))
    out: list[int] = []
    for q in order:
        if len(out) >= width:
            break
        out.extend(_take(spec, free[q], width - len(out), cache_sharing))
    return tuple(out)
