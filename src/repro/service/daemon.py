"""Pool-as-a-service: a long-lived daemon owning one ``RuntimePool``
(or, given a ``ClusterSpec``, one ``ClusterPool`` spanning N machines).

``PoolDaemon`` turns the library pool into a service: it owns one
``RuntimePool`` plus one persistent ``RealGraphExecutor`` worker set,
accepts jobs while work is in flight (file-based inbox, see below, or
the in-process ``submit``/``cancel``/``status``/``drain`` methods the
CLI smoke and the tests drive directly), and checkpoints the whole
scheduling world into the versioned job store after every decision
instant — so a killed daemon restarts into the same world.

**Submission protocol.**  Clients drop one JSON file per command into
``<state_dir>/inbox/`` (processed in filename order); the daemon writes
the reply to ``<state_dir>/outbox/<same name>`` and deletes the inbox
file.  Commands: ``{"op": "submit", "spec": {...JobSpec wire dict...}}``,
``{"op": "cancel", "job": "job-N"}``, ``{"op": "status"}``,
``{"op": "drain"}``, ``{"op": "stop"}``.  Replies always carry ``ok``;
errors carry ``error`` instead of crashing the daemon.

**Execution.**  The pool's discrete-event sim stays the single source of
scheduling truth; a ``PoolObserver`` mirrors its decisions onto real
payload execution: launch -> ``RealGraphExecutor.submit_op`` (payload
futures wait for their dependency futures inside the worker), revoke ->
``Future.cancel`` (a revoked payload that has not started never runs),
complete -> optionally report the real wall time through the job's
``PlanStore.observe`` (``payload_feedback=True``).  Ops without payloads
(every simulated workload) cost nothing — only payload-carrying ops
reach the worker set.

**Crash recovery.**  See ``repro.service.jobstore``: on boot the daemon
loads ``store.json`` + ``plancache.json``, seeds the pool's
``CorrectionTable``/``TripCountEstimator`` from the checkpoint (probe
and observation counts carry over — learning does not reset), resubmits
every unfinished job's spec in original submit order, bills interrupted
work as restart waste exactly once, and resumes the sim at the
checkpointed clock.

**Cluster mode.**  ``PoolDaemon(..., cluster=ClusterSpec(...))`` drives
a ``repro.cluster.ClusterPool`` instead: jobs route across N machines,
the job store records each entry's machine assignment (recovery
resubmits to the SAME machine rather than re-routing — the placement is
state, not policy), checkpoints carry every member machine's local
clock, restart waste is billed at the assigned machine's own
``restart_waste`` rate, and each simulated machine's payloads are
pinned to a distinct host JAX device
(``--xla_force_host_platform_device_count=N``).
"""

from __future__ import annotations

import json
import os
import pathlib
import time
import warnings
from concurrent.futures import Future
from typing import Mapping

from repro.cluster.pool import ClusterPool, ClusterResult
from repro.cluster.router import RouterConfig
from repro.core.planstore import CorrectionTable, TripCountEstimator
from repro.core.runtime import RealGraphExecutor, report_payload_observation
from repro.hw.spec import ClusterSpec
from repro.multitenant.plancache import PlanCache, atomic_write_text
from repro.multitenant.pool import (PoolConfig, PoolObserver, PoolResult,
                                    RuntimePool)
from repro.obs.trace import FAM_SERVICE, TraceEvent
from repro.service.jobstore import (JobEntry, StoreState, load_store,
                                    save_store)
from repro.service.spec import ATTACHED_GRAPH, JobSpec, submit_spec


class _PayloadObserver(PoolObserver):
    """Mirror the sim's launch/revoke/complete decisions onto real
    payload futures (read-only on the sim: the timeline it observes is
    bit-for-bit the unobserved one)."""

    def __init__(self, pool, executor: RealGraphExecutor,
                 *, payload_feedback: bool = False):
        self.pool = pool                 # RuntimePool or ClusterPool
        self.executor = executor
        self.payload_feedback = payload_feedback
        #: jid -> {uid -> payload future} for in-flight/finished launches
        self.futures: dict[int, dict[int, Future]] = {}

    def _sim_of(self, jid: int):
        """The sim that owns ``jid`` (a cluster pool has one per
        machine; the jid's assignment names it)."""
        pools = getattr(self.pool, "pools", None)
        if pools is None:
            return self.pool._sim
        m = self.pool.assignment.get(jid)
        return pools[m]._sim if m is not None else None

    def on_launch(self, key, sched) -> None:
        jid, uid = key
        op = sched.op
        if op.payload is None:
            return
        futs = self.futures.setdefault(jid, {})
        # deps resolve to their payload future when one exists, else to
        # the materialized None a payload-less dep produces
        deps = {d: futs.get(d) for d in op.deps}
        # cluster mode: the payload lands on the host JAX device mapped
        # to the machine this job was routed to (None = unpinned)
        machine = getattr(self.pool, "assignment", {}).get(jid)
        futs[uid] = self.executor.submit_op(
            op, deps, device=self.executor.device_for(machine))

    def on_revoke(self, key, sched) -> None:
        jid, uid = key
        fut = self.futures.get(jid, {}).pop(uid, None)
        if fut is not None:
            # not-yet-started payloads are cancelled outright; a payload
            # already on a worker runs to completion but its result is
            # dropped (the sim will relaunch the op later and submit a
            # fresh payload)
            fut.cancel()

    def on_complete(self, key, sched) -> None:
        if not self.payload_feedback:
            return
        jid, uid = key
        fut = self.futures.get(jid, {}).get(uid)
        sim = self._sim_of(jid)
        job = sim.jobs.get(jid) if sim is not None else None
        if fut is None or fut.cancelled() or job is None \
                or job.store is None:
            return
        # close the loop on REAL time: block for the payload (sim
        # completion may lead real completion) and report its wall
        # seconds at the op's frozen-plan width
        _, dt = fut.result()
        report_payload_observation(job.store, job.plan, sched.op, dt)


class PoolDaemon:
    """One long-lived pool + worker set behind a file inbox (see module
    docstring).  Drive it with ``serve()`` (the CLI loop) or call
    ``submit``/``cancel``/``status``/``pump``/``drain`` directly."""

    def __init__(self, state_dir: str | pathlib.Path, *,
                 config: PoolConfig | None = None, machine=None,
                 cluster: ClusterSpec | None = None,
                 router: RouterConfig | None = None,
                 checkpoint_every: int = 1, max_workers: int = 2,
                 execute_payloads: bool = True,
                 payload_feedback: bool = False):
        if cluster is not None and machine is not None:
            raise ValueError("pass cluster (the daemon builds the "
                             "member machines) OR machine, not both")
        self.cluster = cluster
        self.state_dir = pathlib.Path(state_dir)
        self.inbox = self.state_dir / "inbox"
        self.outbox = self.state_dir / "outbox"
        self.inbox.mkdir(parents=True, exist_ok=True)
        self.outbox.mkdir(parents=True, exist_ok=True)
        self.store_path = self.state_dir / "store.json"
        self.cache_path = self.state_dir / "plancache.json"

        state = load_store(self.store_path)
        recovered = state is not None
        if config is None:
            config = (PoolConfig.from_dict(state.config)
                      if recovered and state.config else PoolConfig())
        self.config = config
        strat = config.strategy_config()
        self.sink = strat.sink
        cache = (PlanCache.load(self.cache_path)
                 if self.cache_path.exists() else PlanCache())
        corrections = trip_counts = None
        if recovered and strat.feedback != "off":
            if state.corrections is not None:
                corrections = CorrectionTable.from_dict(state.corrections)
            if state.trip_counts is not None:
                trip_counts = TripCountEstimator.from_dict(
                    state.trip_counts)
        if cluster is not None:
            self.pool = ClusterPool(cluster, config=config,
                                    plan_cache=cache, router=router,
                                    corrections=corrections,
                                    trip_counts=trip_counts)
        else:
            self.pool = RuntimePool(machine=machine, config=config,
                                    plan_cache=cache,
                                    corrections=corrections,
                                    trip_counts=trip_counts)

        self.executor: RealGraphExecutor | None = None
        self.observer: _PayloadObserver | None = None
        if execute_payloads:
            self.executor = RealGraphExecutor(
                max_workers=max_workers, persistent=True,
                n_devices=len(cluster) if cluster is not None else None)
            self.observer = _PayloadObserver(
                self.pool, self.executor,
                payload_feedback=payload_feedback)
            self.pool.observer = self.observer

        self.entries: list[JobEntry] = []
        self._jid_by_order: dict[int, int] = {}
        #: restart-waste service billed onto the live job at recovery —
        #: the baseline progress_core_s measures NEW work against
        self._billed: dict[int, float] = {}
        self.restarts = (state.restarts + 1) if recovered else 0
        self.checkpoint_every = max(1, checkpoint_every)
        self.total_steps = 0
        self._stopping = False
        self.once = False

        clock = state.clock if recovered else 0.0
        if recovered:
            self._emit("recover", data={"restarts": self.restarts,
                                        "clock": clock,
                                        "entries": len(state.entries)})
            self._recover(state)
        else:
            self._emit("start", data={})
        if self._is_cluster:
            # each member machine resumes at ITS OWN checkpointed clock
            # (a pre-cluster or 1-entry store falls back to the max)
            clocks = state.clocks if recovered else None
            if clocks is not None and len(clocks) == len(self.pool.pools):
                self.pool.begin(clocks=clocks)
            else:
                self.pool.begin(clock=clock)
        else:
            self.pool.begin(clock=clock)
        self.checkpoint()

    @property
    def _is_cluster(self) -> bool:
        return self.cluster is not None

    @property
    def _member_pools(self) -> list[RuntimePool]:
        return self.pool.pools if self._is_cluster else [self.pool]

    def _clock(self) -> float:
        if self._is_cluster:
            return max((p.clock for p in self.pool.pools), default=0.0)
        return (self.pool._sim.clock
                if self.pool._sim is not None else 0.0)

    # ---- recovery -------------------------------------------------------
    def _waste_factor(self, entry: JobEntry) -> float:
        """Restart waste is billed at the rate of the machine the work
        was LOST on (heterogeneous clusters: a fat machine's lost
        core-seconds cost what that machine charges)."""
        if self._is_cluster:
            m = entry.machine if (entry.machine is not None
                                  and entry.machine
                                  < len(self.pool.pools)) else 0
            return self.pool.pools[m].machine.spec.restart_waste
        return self.pool.machine.spec.restart_waste

    def _recover(self, state: StoreState) -> None:
        for entry in sorted(state.entries, key=lambda e: e.order):
            self.entries.append(entry)
            if entry.state in ("done", "cancelled"):
                continue        # terminal history, never resubmitted
            if entry.spec.workload == ATTACHED_GRAPH:
                # in-process graphs cannot be rebuilt from the wire form
                warnings.warn(
                    f"job {entry.order} ({entry.spec.name}) carried an "
                    f"attached graph; not recoverable", stacklevel=2)
                entry.state = "cancelled"
                continue
            waste_factor = self._waste_factor(entry)
            # resubmission in original order = original queue order (the
            # queue's FIFO tie-break follows submission sequence), so an
            # admitted-but-unlaunched job is readmitted exactly as the
            # eviction path would readmit it: deferred, never demoted.
            # Cluster mode: the checkpointed machine assignment is
            # RESTORED, not re-routed — placement is state
            forced = (entry.machine if self._is_cluster
                      and entry.machine is not None
                      and entry.machine < len(self.pool.pools) else None)
            job = submit_spec(self.pool, entry.spec, machine=forced)
            if self._is_cluster:
                entry.machine = self.pool.assignment.get(job.jid)
            self._jid_by_order[entry.order] = job.jid
            # the crash lost this entry's in-flight work; bill it as
            # restart waste EXACTLY ONCE (progress resets to zero below,
            # so a second crash with no new progress re-bills nothing)
            waste = waste_factor * entry.progress_core_s
            if waste > 0.0:
                job.service += waste
                entry.carried_waste += waste
            self._billed[entry.order] = waste
            entry.progress_core_s = 0.0
            entry.restarts += 1
            entry.state = "queued"
            self._emit("recover_job", key=self.public_id(entry.order),
                       data={"jid": job.jid, "state": entry.state,
                             "billed_waste": waste,
                             "carried_waste": entry.carried_waste})

    # ---- bookkeeping ----------------------------------------------------
    @staticmethod
    def public_id(order: int) -> str:
        return f"job-{order}"

    def _entry_by_id(self, job_id: str) -> JobEntry | None:
        return next((e for e in self.entries
                     if self.public_id(e.order) == job_id), None)

    def _job_of(self, entry: JobEntry):
        jid = self._jid_by_order.get(entry.order)
        if jid is None:
            return None
        if self._is_cluster:
            # a rebalance re-minted the jid; follow the alias chain and
            # remember the current one
            jid = self.pool.current_jid(jid)
            self._jid_by_order[entry.order] = jid
        return next((j for j in self.pool.jobs if j.jid == jid), None)

    def _sync_entry(self, entry: JobEntry) -> None:
        job = self._job_of(entry)
        if job is None:
            return                      # recovered terminal history
        if job.cancelled:
            entry.state = "cancelled"
            entry.progress_core_s = 0.0
        elif job.done:
            entry.state = "done"
            entry.progress_core_s = 0.0
            entry.result = {"finish_time": job.finish_time,
                            "latency_s": job.latency,
                            "service_core_s": job.service,
                            "preemptions": job.preemptions}
        else:
            if self._is_cluster:
                m = self.pool.assignment.get(job.jid)
                entry.machine = m if m is not None else entry.machine
                sim = (self.pool.pools[m]._sim if m is not None else None)
            else:
                sim = self.pool._sim
            if sim is not None and job.jid in sim.jobs:
                started = (bool(sim.records.get(job.jid))
                           or any(k[0] == job.jid for k in sim.running)
                           or bool(sim.preempted.get(job.jid)))
                entry.state = "running" if started else "admitted"
            else:
                entry.state = "queued"
            entry.progress_core_s = max(
                job.service - self._billed.get(entry.order, 0.0), 0.0)

    def _emit(self, kind: str, key=None, data: Mapping | None = None):
        if not self.sink.enabled:
            return
        self.sink.emit(TraceEvent(ts=self._clock(), family=FAM_SERVICE,
                                  kind=kind, key=key,
                                  data=dict(data or {})))

    # ---- checkpointing --------------------------------------------------
    def checkpoint(self) -> None:
        """Persist the whole scheduling world (atomic writes: a crash
        mid-checkpoint keeps the previous good snapshot)."""
        for entry in self.entries:
            self._sync_entry(entry)
        pool = self.pool
        state = StoreState(
            clock=self._clock(),
            restarts=self.restarts,
            config=self.config.to_dict(),
            entries=self.entries,
            corrections=(pool.corrections.to_dict()
                         if pool.corrections is not None else None),
            trip_counts=(pool.trip_counts.to_dict()
                         if pool.trip_counts is not None else None),
            clocks=([p.clock for p in pool.pools]
                    if self._is_cluster else None))
        save_store(self.store_path, state)
        pool.plan_cache.dump(self.cache_path)
        self._emit("checkpoint", data={"entries": len(self.entries),
                                       "steps": self.total_steps})

    # ---- client operations ----------------------------------------------
    def submit(self, spec: JobSpec | Mapping, *, graph=None) -> str:
        """Accept one job (wire dict or ``JobSpec``); returns its stable
        client-facing id (``job-N``, unchanged across restarts)."""
        if isinstance(spec, Mapping):
            spec = JobSpec.from_dict(spec)
        order = (max((e.order for e in self.entries), default=-1)) + 1
        job = submit_spec(self.pool, spec, graph=graph)
        entry = JobEntry(spec=spec, order=order,
                         machine=(self.pool.assignment.get(job.jid)
                                  if self._is_cluster else None))
        self.entries.append(entry)
        self._jid_by_order[order] = job.jid
        self._emit("submit", key=self.public_id(order),
                   data={"jid": job.jid, "workload": spec.workload,
                         "name": job.name, "machine": entry.machine})
        self.checkpoint()
        return self.public_id(order)

    def cancel(self, job_id: str) -> bool:
        entry = self._entry_by_id(job_id)
        if entry is None:
            return False
        job = self._job_of(entry)      # alias-resolves rebalanced jids
        ok = self.pool.cancel(job.jid) if job is not None else False
        if ok:
            entry.state = "cancelled"
            self.checkpoint()
        self._emit("cancel", key=job_id, data={"ok": ok})
        return ok

    def status(self) -> dict:
        for entry in self.entries:
            self._sync_entry(entry)
        out = {
            "clock": self._clock(),
            "restarts": self.restarts,
            "steps": self.total_steps,
            "queued": sum(len(p.queue) for p in self._member_pools),
            "active": sum(len(p._active) for p in self._member_pools),
            "jobs": [{"id": self.public_id(e.order),
                      "name": e.spec.name or e.spec.workload,
                      "workload": e.spec.workload,
                      "state": e.state,
                      "machine": e.machine,
                      "carried_waste": e.carried_waste,
                      "restarts": e.restarts,
                      "result": e.result}
                     for e in sorted(self.entries,
                                     key=lambda e: e.order)]}
        if self._is_cluster:
            out["machines"] = len(self.pool.pools)
            out["clocks"] = [p.clock for p in self.pool.pools]
            out["rebalances"] = self.pool.n_rebalances
        return out

    # ---- the pump -------------------------------------------------------
    def _after_step(self) -> None:
        self.total_steps += 1
        if self.total_steps % self.checkpoint_every == 0:
            self.checkpoint()

    def pump(self, max_steps: int | None = None) -> int:
        """Advance the pool up to ``max_steps`` decision instants
        (unbounded when None); returns how many it advanced."""
        steps = 0
        while ((max_steps is None or steps < max_steps)
               and self.pool.step()):
            steps += 1
            self._after_step()
        return steps

    def drain(self) -> PoolResult | ClusterResult:
        """Run every accepted job to completion and return the pool
        result (same metrics surface as ``RuntimePool.run``; a
        ``ClusterResult`` in cluster mode)."""
        self.pump()
        self.checkpoint()
        result = self.pool.result()
        self._emit("drain", data={"makespan": result.makespan,
                                  "jobs": len(result.jobs)})
        return result

    def close(self) -> None:
        self.checkpoint()
        if self.executor is not None:
            self.executor.close()
        self._emit("stop", data={"steps": self.total_steps})

    # ---- file inbox -----------------------------------------------------
    def _execute(self, cmd: Mapping) -> dict:
        op = cmd.get("op")
        if op == "submit":
            return {"ok": True, "job": self.submit(cmd["spec"])}
        if op == "cancel":
            return {"ok": self.cancel(cmd["job"])}
        if op == "status":
            return {"ok": True, **self.status()}
        if op == "drain":
            result = self.drain()
            if self.once:
                self._stopping = True
            return {"ok": True, "makespan": result.makespan,
                    "metrics": result.metrics}
        if op == "stop":
            self._stopping = True
            return {"ok": True}
        return {"ok": False, "error": f"unknown op {op!r}"}

    def poll_inbox(self) -> int:
        """Process every pending inbox command (filename order); one
        reply file per command.  A malformed command becomes an error
        reply, never a daemon crash."""
        n = 0
        for path in sorted(self.inbox.glob("*.json")):
            try:
                cmd = json.loads(path.read_text())
                reply = self._execute(cmd)
            except Exception as exc:  # noqa: BLE001 - reply, don't die
                reply = {"ok": False, "error": str(exc)}
            atomic_write_text(self.outbox / path.name, json.dumps(reply))
            path.unlink()
            n += 1
            if self._stopping:
                break
        return n

    def serve(self, *, poll_interval: float = 0.05, once: bool = False,
              crash_after_steps: int | None = None) -> None:
        """The daemon loop: poll the inbox, advance one decision instant,
        repeat.  ``once=True`` exits after the first ``drain`` command
        completes (submit-all-then-drain mode).  ``crash_after_steps``
        simulates a hard crash (``os._exit``) after that many pool steps
        — the recovery tests' kill switch; checkpoints written up to the
        crash instant survive, nothing later does."""
        self.once = once
        while not self._stopping:
            handled = self.poll_inbox()
            stepped = self.pump(max_steps=1)
            if (crash_after_steps is not None
                    and self.total_steps >= crash_after_steps):
                os._exit(1)
            if not handled and not stepped and not self._stopping:
                time.sleep(poll_interval)
        self.close()
