"""``JobSpec`` — the ONE wire schema for submitting work to the pool.

Before the service redesign each entry point described a job its own
way: ``repro.launch.pool`` turned CLI flags into ``pool.submit`` calls,
``ServeEngine.submit_waves_to_pool`` built its own arrival/deadline
arithmetic, and there was no way to describe a job OUTSIDE a Python
process at all.  The pool daemon needs exactly that — a job description
that survives a socket/file hop and a daemon restart — so the schema
lives here once and all three consumers speak it:

* ``repro.launch.pool`` parses its flags into ``JobSpec``s (a thin
  parser over the schema, not a second submission path);
* ``ServeEngine.submit_waves_to_pool`` emits one spec per pending wave
  (with the wave's already-built op graph attached in-process);
* the service daemon's inbox accepts the JSON form verbatim, persists
  it in the job store, and REBUILDS the graph from it after a crash —
  which is why the spec records the workload + its dynamic-region
  priors rather than a pickled graph.

The JSON form is versioned and strict: unknown keys are rejected (a
typo'd field must fail loudly at submit time, not silently schedule a
default job), and the schema version is shared with the config
serialization (``repro.core.strategy.CONFIG_SCHEMA_VERSION``) so one
bump covers the whole on-disk surface.
"""

from __future__ import annotations

import dataclasses

from repro.core.graph import (OpGraph, build_early_exit_wave,
                              build_paper_graph,
                              build_recurrent_step_graph)
from repro.core.strategy import CONFIG_SCHEMA_VERSION, _check_config_dict

# workloads a spec can (re)build by itself; "graph" marks a spec whose
# graph was attached in-process (serving waves) and cannot be rebuilt
# from the wire form alone
DYNAMIC_WORKLOADS = ("rnn", "wave")
ATTACHED_GRAPH = "graph"


@dataclasses.dataclass(frozen=True)
class JobSpec:
    """One pool job, as data.

    ``workload`` is a paper model name (``resnet50``, ``dcgan``, ... —
    anything ``build_paper_graph`` accepts), ``"rnn"``/``"wave"`` for
    the dynamic-graph mix, or ``"graph"`` for a caller-attached graph
    (e.g. a serving wave) that only exists in-process.

    ``deadline`` is ABSOLUTE (pool-clock seconds); ``latency_budget``
    is relative to ``submit_time`` — set at most one (the resolved
    deadline is ``submit_time + latency_budget``).  ``demand_hint``
    overrides the profiled core-seconds demand for admission pricing
    until the closed loop re-estimates it.  The trips/depth fields are
    the dynamic-region priors the rnn/wave builders consume."""

    workload: str
    name: str | None = None          # default: the built graph's name
    scale: int = 1                   # layer-count multiplier (paper models)
    priority: float = 1.0
    submit_time: float = 0.0
    deadline: float | None = None
    latency_budget: float | None = None
    demand_hint: float | None = None
    # dynamic-region priors (rnn: while-loop trips; wave: branch depth)
    trips: int = 4
    max_trips: int = 8
    depth: int = 1
    max_depth: int = 6
    accept: bool = True

    def __post_init__(self) -> None:
        if self.deadline is not None and self.latency_budget is not None:
            raise ValueError(
                "JobSpec: set deadline (absolute) OR latency_budget "
                "(relative to submit_time), not both")

    def resolved_deadline(self) -> float | None:
        if self.deadline is not None:
            return self.deadline
        if self.latency_budget is not None:
            return self.submit_time + self.latency_budget
        return None

    def build_graph(self) -> OpGraph:
        """Rebuild the op graph this spec describes — the call the daemon
        makes on submit AND on crash recovery, so a spec must stay
        buildable from its own fields alone."""
        if self.workload == ATTACHED_GRAPH:
            raise ValueError(
                "JobSpec(workload='graph') carries an in-process graph; "
                "pass it via submit_spec(graph=...) — it cannot be "
                "rebuilt from the wire form")
        if self.workload == "rnn":
            return build_recurrent_step_graph(
                trips=self.trips, max_trips=self.max_trips,
                name=self.name or "rnn")
        if self.workload == "wave":
            return build_early_exit_wave(
                depth=self.depth, max_depth=self.max_depth,
                accept=self.accept, name=self.name or "wave")
        return build_paper_graph(self.workload, scale=self.scale)

    # ---- wire form -----------------------------------------------------
    def to_dict(self) -> dict:
        """Versioned JSON form; defaults are written out explicitly so a
        stored spec is self-describing even across default changes."""
        d = {"schema": CONFIG_SCHEMA_VERSION}
        d.update(dataclasses.asdict(self))
        return d

    @classmethod
    def from_dict(cls, d) -> "JobSpec":
        return cls(**_check_config_dict(
            cls.__name__, dict(d),
            {f.name for f in dataclasses.fields(cls)}))


def submit_spec(pool, spec: JobSpec, *, graph: OpGraph | None = None,
                machine: int | None = None):
    """Submit one spec to a ``repro.multitenant.RuntimePool`` or a
    ``repro.cluster.ClusterPool`` — the ONE call every entry point
    funnels through.  Returns the created Job.  ``machine`` forces the
    cluster placement (the daemon's recovery path restoring a
    checkpointed assignment); only valid on a ClusterPool."""
    g = graph if graph is not None else spec.build_graph()
    kwargs = {"machine": machine} if machine is not None else {}
    job = pool.submit(g, priority=spec.priority,
                      name=spec.name or g.name,
                      submit_time=spec.submit_time,
                      deadline=spec.resolved_deadline(), **kwargs)
    if spec.demand_hint is not None:
        # admission prices the job at the hint instead of the profiled
        # estimate (the closed loop re-derives demand once ops finish)
        job.demand = float(spec.demand_hint)
    return job
