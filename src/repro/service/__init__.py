"""Pool-as-a-service: the long-lived daemon, its persistent job store,
and the one submission wire schema every entrypoint shares.

* ``JobSpec`` / ``submit_spec`` — the wire schema (``repro.launch.pool``
  flags, ``ServeEngine.submit_waves_to_pool``, and the daemon inbox all
  build the same spec);
* ``PoolDaemon`` — the daemon (file inbox, per-instant checkpointing,
  crash recovery);
* ``JobEntry`` / ``StoreState`` / ``load_store`` / ``save_store`` — the
  versioned on-disk job store.
"""

from repro.service.daemon import PoolDaemon
from repro.service.jobstore import (JobEntry, StoreState, load_store,
                                    save_store)
from repro.service.spec import (ATTACHED_GRAPH, DYNAMIC_WORKLOADS, JobSpec,
                                submit_spec)

__all__ = [
    "ATTACHED_GRAPH", "DYNAMIC_WORKLOADS", "JobSpec", "submit_spec",
    "PoolDaemon", "JobEntry", "StoreState", "load_store", "save_store",
]
