"""Versioned on-disk job store for the pool daemon.

The daemon checkpoints its whole scheduling world into one JSON document
(``store.json``) after every decision instant: the pool clock, the
effective ``PoolConfig``, every job the daemon has ever accepted (spec +
lifecycle state + restart-waste ledger), and the learned feedback state
(``CorrectionTable`` / ``TripCountEstimator``).  The shared ``PlanCache``
is persisted next to it through its own ``dump`` (both writes go through
``atomic_write_text``, so a crash mid-checkpoint leaves the PREVIOUS
good snapshot in place, never a truncated one).

A restarted daemon loads the store and rebuilds the same world:

* ``done`` / ``cancelled`` entries are history — kept for status
  reporting, never resubmitted;
* every other entry's spec is resubmitted in original ``order``, so
  queued jobs re-enter under their original submit order and
  admitted-but-unlaunched jobs are readmitted with zero waste (the
  admission-eviction semantics: deferred, never demoted);
* entries with in-flight progress (``progress_core_s`` > 0 at the last
  checkpoint) lost that work in the crash — the recovery path re-bills
  it as restart waste (``machine.spec.restart_waste`` x lost
  core-seconds) onto the fresh job's service ledger, exactly once:
  ``progress_core_s`` measures work since the LAST restart billing, so
  a second crash with no new progress re-bills nothing.

Corrupt or unreadable stores degrade to a fresh world with a warning —
same contract as ``PlanCache.load``.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
import warnings
from typing import Mapping

from repro.core.strategy import CONFIG_SCHEMA_VERSION, _check_config_dict
from repro.multitenant.plancache import atomic_write_text
from repro.service.spec import JobSpec

#: schema version of ``store.json`` (bumped on layout changes; a version
#: mismatch degrades to a fresh store rather than misreading old state)
STORE_SCHEMA_VERSION = CONFIG_SCHEMA_VERSION

#: entry lifecycle states (``queued``/``admitted``/``running`` entries
#: are resubmitted on recovery; the other two are terminal history)
ENTRY_STATES = ("queued", "admitted", "running", "done", "cancelled")


@dataclasses.dataclass
class JobEntry:
    """One accepted job as the store sees it.

    ``order`` is the daemon-level submission ticket — stable across
    restarts (pool jids are not) and the basis of the client-facing job
    id.  ``carried_waste`` accumulates every restart-waste charge ever
    billed to this job; ``progress_core_s`` is the core-seconds of
    un-checkpointed-as-done work at the last checkpoint (what a crash
    would lose)."""

    spec: JobSpec
    order: int
    state: str = "queued"
    carried_waste: float = 0.0
    progress_core_s: float = 0.0
    restarts: int = 0
    result: dict | None = None        # summary, filled when state == done
    # cluster daemons record WHICH machine the job was routed to, so
    # recovery restores the checkpointed placement instead of re-routing
    # (None: single-machine daemon, or a pre-cluster store)
    machine: int | None = None

    def to_dict(self) -> dict:
        return {"spec": self.spec.to_dict(), "order": self.order,
                "state": self.state, "carried_waste": self.carried_waste,
                "progress_core_s": self.progress_core_s,
                "restarts": self.restarts, "result": self.result,
                "machine": self.machine}

    @classmethod
    def from_dict(cls, d: Mapping) -> "JobEntry":
        kw = _check_config_dict(
            cls.__name__, dict(d),
            {f.name for f in dataclasses.fields(cls)}, versioned=False)
        kw["spec"] = JobSpec.from_dict(kw["spec"])
        if kw.get("state") not in ENTRY_STATES:
            raise ValueError(f"JobEntry state {kw.get('state')!r} unknown")
        return cls(**kw)


@dataclasses.dataclass
class StoreState:
    """The whole checkpointed world (see module docstring)."""

    clock: float = 0.0
    restarts: int = 0                 # completed daemon restarts so far
    config: dict | None = None        # PoolConfig.to_dict()
    entries: list[JobEntry] = dataclasses.field(default_factory=list)
    corrections: dict | None = None   # CorrectionTable.to_dict()
    trip_counts: dict | None = None   # TripCountEstimator.to_dict()
    # cluster daemons: each member machine's local clock at checkpoint
    # (``clock`` keeps the max, for status/back-compat)
    clocks: list[float] | None = None

    def to_dict(self) -> dict:
        return {"schema": STORE_SCHEMA_VERSION, "clock": self.clock,
                "restarts": self.restarts, "config": self.config,
                "entries": [e.to_dict() for e in self.entries],
                "corrections": self.corrections,
                "trip_counts": self.trip_counts,
                "clocks": self.clocks}

    @classmethod
    def from_dict(cls, d: Mapping) -> "StoreState":
        kw = _check_config_dict(
            cls.__name__, dict(d),
            {f.name for f in dataclasses.fields(cls)})
        kw["entries"] = [JobEntry.from_dict(e)
                         for e in kw.get("entries", ())]
        return cls(**kw)


def save_store(path: str | pathlib.Path, state: StoreState) -> None:
    """Atomically persist the store (temp-write + rename: a crash during
    the write never shadows the previous good snapshot)."""
    atomic_write_text(path, json.dumps(state.to_dict()))


def load_store(path: str | pathlib.Path) -> StoreState | None:
    """Load a checkpointed store, or ``None`` for a fresh start.

    Missing file = first boot (silent).  Unreadable/corrupt/mismatched
    file = degrade to fresh with a warning — a daemon must come up even
    when its state dir was damaged, and the atomic writer makes this
    path unreachable for crashes (only external damage lands here)."""
    path = pathlib.Path(path)
    if not path.exists():
        return None
    try:
        return StoreState.from_dict(json.loads(path.read_text()))
    except Exception as exc:  # noqa: BLE001 - degrade, never crash boot
        warnings.warn(f"job store {path} unreadable ({exc}); "
                      f"starting fresh", stacklevel=2)
        return None
