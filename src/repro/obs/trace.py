"""Decision tracing: structured events behind the ``TraceSink`` seam.

The scheduling stack decides constantly — admit this tenant or defer it,
launch this op through S3 or the fallback, book these cores, revoke that
victim, blend this observation — and until this module the only record
of any decision was its *effect* on the timeline.  ``TraceSink`` is the
seam every layer emits into: the strategy core, the placement bookings,
the preemption path, the admission tier, and the plan-store observation
stream all produce ``TraceEvent`` records tagged with one of five
**families**:

* ``admission``  — admit / defer / reserve, with the demand and slack
  inputs the queue decided on;
* ``strategy``   — every launch path (S3 admission, S2 clamp, run-biggest
  fallback, S4 hyper lane, deadline claim), every considered-but-rejected
  candidate with its cause, and the fair-share charge/refund stream;
* ``placement``  — every quadrant booking (chosen quadrants, spill,
  avoid-set overrides) under ``topology="quadrant"``;
* ``preemption`` — waive / squeeze / revoke with the victim-selection
  inputs, so "why was job X preempted at t=..." is answerable from the
  trace alone;
* ``planstore``  — every launch/finish/revoke observation (predicted vs
  observed, the correction factor in force) plus per-job profiling cost.

The default sink is ``NullSink`` — ``enabled`` is False and every emit
site in the schedulers is guarded by it, so the default configuration
builds no event objects at all and is bit-for-bit the untraced scheduler
(tracing is read-only by construction; the differential/golden suites and
the traced parity leg in ``repro.multitenant.parity`` lock it down).
``RecordingSink`` collects events in memory for the metrics registry
(``repro.obs.metrics``) and the Perfetto exporter (``repro.obs.perfetto``).

This module deliberately imports nothing from ``repro.core`` — the core
imports *us* (the sink rides on ``StrategyConfig``), and the obs layer
stays reusable by every later subsystem (pool daemon, learned model,
multi-machine placement) without cycles.
"""

from __future__ import annotations

import abc
import dataclasses
from typing import Any, Hashable, Mapping

# the decision-event families (see module docstring)
FAM_ADMISSION = "admission"
FAM_STRATEGY = "strategy"
FAM_PLACEMENT = "placement"
FAM_PREEMPTION = "preemption"
FAM_PLANSTORE = "planstore"
FAM_REGION = "region"       # dynamic control flow: expand/resolve instants
# service-daemon lifecycle (repro.service): start / recover / recover_job
# / submit / cancel / checkpoint / drain / stop — the daemon emits its
# lifecycle into the same seam the schedulers use, per the ROADMAP's
# no-private-logging rule
FAM_SERVICE = "service"
# multi-machine placement (repro.cluster): route / rebalance / split
# decisions above the per-machine pools, keyed by cluster job id with the
# chosen machine index, the demand estimate that drove the choice, and
# the per-machine loads at the decision instant
FAM_CLUSTER = "cluster"

# FAM_CLUSTER is appended LAST deliberately: the Perfetto exporter derives
# decision-lane tids from this tuple's order, so end-appending keeps every
# pre-cluster trace's lane numbering stable
FAMILIES = (FAM_ADMISSION, FAM_STRATEGY, FAM_PLACEMENT, FAM_PREEMPTION,
            FAM_PLANSTORE, FAM_REGION, FAM_SERVICE, FAM_CLUSTER)


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One scheduling decision (or observation) at one instant.

    ``key`` is the node key the decision concerns (``int`` uid for the
    single-graph scheduler, ``(jid, uid)`` for the pool, a bare ``jid``
    for admission events, ``None`` for machine-wide events); ``data``
    carries the decision's inputs and outputs — enough to re-derive the
    accounting the schedulers did (see ``metrics_from_events``)."""

    ts: float
    family: str
    kind: str
    key: Hashable | None = None
    data: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    def to_json(self) -> dict:
        """JSON-serializable form (tuples become lists; callers that need
        the original key shape re-freeze on load)."""
        return {"ts": self.ts, "family": self.family, "kind": self.kind,
                "key": self.key, "data": dict(self.data)}


class TraceSink(abc.ABC):
    """Where decision events go.  ``enabled`` gates every emit site: the
    schedulers check it BEFORE building the event object, so a disabled
    sink costs one attribute read per decision and nothing else."""

    enabled: bool = True

    @abc.abstractmethod
    def emit(self, event: TraceEvent) -> None: ...


class NullSink(TraceSink):
    """The default: tracing off, guaranteed inert.

    All ``NullSink`` instances compare equal (and hash alike) so frozen
    ``StrategyConfig`` values built independently still compare equal —
    config equality must not depend on which default sink object a
    dataclass factory happened to construct."""

    enabled = False

    def emit(self, event: TraceEvent) -> None:  # pragma: no cover - inert
        pass

    def __eq__(self, other: object) -> bool:
        return type(other) is NullSink

    def __hash__(self) -> int:
        return hash(NullSink)


#: shared inert instance for default arguments (NullSink is stateless)
NULL_SINK = NullSink()


class RecordingSink(TraceSink):
    """Collect every event in memory — the sink behind ``--trace-out``,
    the metrics registry, and the Perfetto exporter."""

    def __init__(self) -> None:
        self.events: list[TraceEvent] = []

    def emit(self, event: TraceEvent) -> None:
        self.events.append(event)

    def __len__(self) -> int:
        return len(self.events)

    def by_family(self, family: str) -> list[TraceEvent]:
        return [e for e in self.events if e.family == family]

    def families(self) -> set[str]:
        return {e.family for e in self.events}
