"""Chrome-trace / Perfetto export of a scheduled timeline + decision trace.

Emits the Trace Event Format JSON that ui.perfetto.dev (and
chrome://tracing) loads directly: ``{"traceEvents": [...]}`` where every
event carries ``ph`` (phase), ``ts`` (microseconds), ``pid``/``tid``.
The export lays the run out as four synthetic processes:

* **pid 1 "cores"** — one lane per physical core under
  ``topology="quadrant"`` (an op slice appears on every core it booked);
  flat-topology and hyper-lane launches, which book no concrete cores,
  get greedy virtual lanes (``tid`` 1000+ / 2000+) so overlap is still
  visible;
* **pid 2 "jobs"** — one track per tenant: its op slices, revoked
  partials (``preempted:`` prefix), and the revoke→relaunch **flow
  arrows** (``ph`` s/f) that make a preemption's cost visually traceable;
* **pid 3 "counters"** — ``co_running`` (the paper's Fig-4 signal),
  ``queue_depth`` from admission events, and ``bw_share_demand`` (sum of
  modeled bandwidth shares of everything running) from launch events;
* **pid 4 "decisions"** — one thread per event family, every decision as
  an instant (``ph`` "i") with its cause/inputs in ``args``.

Everything is duck-typed over ``ScheduleResult``/``PoolResult`` — the
obs layer never imports the schedulers it observes.
"""

from __future__ import annotations

import json
from typing import Iterable, Mapping

from repro.obs.trace import FAMILIES, TraceEvent

US = 1e6                       # seconds -> Trace Event Format microseconds

CORES_PID = 1
JOBS_PID = 2
COUNTERS_PID = 3
DECISIONS_PID = 4
# cluster export: one process per machine, pid = MACHINE_PID_BASE + index
# (well above the four single-machine pids so both exports can coexist in
# one viewer session without colliding)
MACHINE_PID_BASE = 100

# virtual-lane tid bases on the cores process for launches with no booked
# core set (flat topology / hyper-thread lane)
FLAT_LANE_BASE = 1000
HYPER_LANE_BASE = 2000


def _jsonable(v):
    """Trace args must be plain JSON; decision-event payloads carry
    tuples, frozensets, and tuple keys."""
    if isinstance(v, Mapping):
        return {str(k): _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple, set, frozenset)):
        return [_jsonable(x) for x in v]
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return repr(v)


def _meta(pid: int, name: str, tid: int | None = None,
          tname: str | None = None) -> list[dict]:
    out = [{"ph": "M", "pid": pid, "name": "process_name",
            "args": {"name": name}}]
    if tid is not None:
        out.append({"ph": "M", "pid": pid, "tid": tid,
                    "name": "thread_name", "args": {"name": tname}})
    return out


def _slice(name: str, ts: float, dur: float, pid: int, tid: int,
           args: dict, cat: str = "op") -> dict:
    return {"ph": "X", "name": name, "cat": cat, "ts": ts * US,
            "dur": max(dur, 0.0) * US, "pid": pid, "tid": tid,
            "args": _jsonable(args)}


def _greedy_lanes(records) -> dict[int, int]:
    """Assign overlap-free virtual lanes: record index -> lane index."""
    lanes: list[float] = []          # per-lane last finish time
    out: dict[int, int] = {}
    order = sorted(range(len(records)), key=lambda i: records[i].start)
    for i in order:
        r = records[i]
        for li, busy_until in enumerate(lanes):
            if busy_until <= r.start + 1e-12:
                lanes[li] = r.finish
                out[i] = li
                break
        else:
            out[i] = len(lanes)
            lanes.append(r.finish)
    return out


def _op_args(r) -> dict:
    return {"op_class": r.op.op_class, "uid": r.op.uid,
            "threads": r.threads, "variant": r.variant, "hyper": r.hyper,
            "predicted": r.predicted, "duration": r.duration,
            "cores": list(r.cores)}


def _core_lane_events(jobs_records: dict, trace: list[dict]) -> None:
    """Cores process: booked ops on their core tids, unbooked ops on
    greedy virtual lanes (flat launches vs hyper lane kept separate)."""
    used_cores: set[int] = set()
    flat, hyper = [], []
    for label, recs in jobs_records.items():
        for r in recs:
            if r.cores:
                used_cores.update(r.cores)
                for c in r.cores:
                    trace.append(_slice(f"{label}:{r.op.op_class}",
                                        r.start, r.duration, CORES_PID, c,
                                        _op_args(r)))
            elif r.hyper:
                hyper.append((label, r))
            else:
                flat.append((label, r))
    for base, group, cat in ((FLAT_LANE_BASE, flat, "op"),
                             (HYPER_LANE_BASE, hyper, "hyper")):
        lanes = _greedy_lanes([r for _, r in group])
        for i, (label, r) in enumerate(group):
            trace.append(_slice(f"{label}:{r.op.op_class}", r.start,
                                r.duration, CORES_PID, base + lanes[i],
                                _op_args(r), cat=cat))
    for c in sorted(used_cores):
        trace.extend(_meta(CORES_PID, "cores", c, f"core {c}")[1:])


def _flow_pair(fid: int, ts_from: float, ts_to: float, tid: int,
               name: str, pid: int = JOBS_PID,
               cat: str = "preempt") -> list[dict]:
    return [{"ph": "s", "id": fid, "name": name, "cat": cat,
             "ts": ts_from * US, "pid": pid, "tid": tid},
            {"ph": "f", "bp": "e", "id": fid, "name": name,
             "cat": cat, "ts": ts_to * US, "pid": pid,
             "tid": tid}]


def _counter(name: str, ts: float, value: float, series: str) -> dict:
    return {"ph": "C", "name": name, "ts": ts * US, "pid": COUNTERS_PID,
            "tid": 0, "args": {series: value}}


def _decision_events(events: Iterable[TraceEvent], trace: list[dict]) -> None:
    fam_tid = {fam: i for i, fam in enumerate(FAMILIES)}
    queue_depth_seen = False
    share_points: list[tuple[float, float, float]] = []  # start, finish, share
    for e in events:
        trace.append({"ph": "i", "s": "t", "name": f"{e.family}:{e.kind}",
                      "cat": e.family, "ts": e.ts * US,
                      "pid": DECISIONS_PID, "tid": fam_tid[e.family],
                      "args": _jsonable({"key": e.key, **e.data})})
        if e.family == "admission" and "queue_depth" in e.data:
            queue_depth_seen = True
            trace.append(_counter("queue_depth", e.ts,
                                  e.data["queue_depth"], "waiting"))
        if (e.family == "strategy" and "bw_share" in e.data
                and "finish" in e.data):
            share_points.append((e.ts, e.data["finish"], e.data["bw_share"]))
    # bw_share_demand: total modeled bandwidth share in force over time
    if share_points:
        deltas: dict[float, float] = {}
        for start, finish, share in share_points:
            deltas[start] = deltas.get(start, 0.0) + share
            deltas[finish] = deltas.get(finish, 0.0) - share
        total = 0.0
        for ts in sorted(deltas):
            total += deltas[ts]
            trace.append(_counter("bw_share_demand", ts,
                                  round(total, 9), "share"))
    for fam, tid in fam_tid.items():
        trace.extend(_meta(DECISIONS_PID, "decisions", tid, fam)[1:])
    if queue_depth_seen or share_points:
        trace.extend(_meta(COUNTERS_PID, "counters"))


def pool_trace(result, events: Iterable[TraceEvent] = ()) -> dict:
    """Trace Event Format dict for one pool run (+ its decision events).

    ``result`` is duck-typed over ``PoolResult``: ``jobs``, ``records``
    (jid -> launches), ``preempted`` (jid -> revoked partials), and
    ``events`` (the (time, #co-running) signal)."""
    trace: list[dict] = []
    events = list(events)
    # width migrations revoke + relaunch at one instant; the decision
    # event marks which revoke→relaunch arrows are migrations so the flow
    # name distinguishes a priced re-seat from an SLO preemption
    migrates = {(e.key, e.ts) for e in events
                if e.family == "preemption" and e.kind == "migrate"}
    names = {j.jid: f"j{j.jid}:{j.name}" for j in result.jobs}
    trace.extend(_meta(CORES_PID, "cores"))
    trace.extend(_meta(JOBS_PID, "jobs"))
    trace.extend(_meta(DECISIONS_PID, "decisions"))
    labeled = {names[jid]: recs for jid, recs in result.records.items()}
    _core_lane_events(labeled, trace)
    flow_id = 0
    for jid, recs in result.records.items():
        tid = jid
        trace.extend(_meta(JOBS_PID, "jobs", tid, names[jid])[1:])
        for r in recs:
            trace.append(_slice(r.op.op_class, r.start, r.duration,
                                JOBS_PID, tid, _op_args(r)))
        for p in result.preempted.get(jid, []):
            trace.append(_slice(f"preempted:{p.op.op_class}", p.start,
                                p.duration, JOBS_PID, tid, _op_args(p),
                                cat="preempted"))
            # flow arrow revoke -> relaunch: the next launch of the same
            # node at or after the revoke instant (work-conserving restart)
            relaunch = min(
                (r for r in recs
                 if r.op.uid == p.op.uid and r.start >= p.finish - 1e-12),
                key=lambda r: r.start, default=None)
            if relaunch is not None:
                flow_id += 1
                name = ("migrate→relaunch"
                        if ((jid, p.op.uid), p.finish) in migrates
                        else "revoke→relaunch")
                trace.extend(_flow_pair(flow_id, p.finish, relaunch.start,
                                        tid, name))
    for ts, n in result.events:
        trace.append(_counter("co_running", ts, float(n), "ops"))
    if result.events:
        trace.extend(_meta(COUNTERS_PID, "counters"))
    _decision_events(events, trace)
    return {"traceEvents": trace, "displayTimeUnit": "ms"}


def cluster_trace(result, events: Iterable[TraceEvent] = ()) -> dict:
    """Trace Event Format dict for one cluster run (+ decision events).

    ``result`` is duck-typed over ``repro.cluster.ClusterResult``: it
    carries ``machines`` (one ``PoolResult``-shaped object per machine).
    Each machine becomes its own process (pid ``MACHINE_PID_BASE + m``)
    holding one track per tenant routed there, so the per-machine load
    balance is visible at a glance; ``cluster``-family ``route`` events
    draw a **route→launch flow arrow** from the routing instant to the
    job's first launch on its assigned machine, making a queued-behind
    routing decision visually traceable the same way preemption cost is
    on the single-machine export."""
    machines = getattr(result, "machines", result)
    events = list(events)
    trace: list[dict] = []
    first_launch: dict[int, tuple[int, float]] = {}  # jid -> (pid, start)
    for m, res in enumerate(machines):
        pid = MACHINE_PID_BASE + m
        trace.extend(_meta(pid, f"machine {m}"))
        names = {j.jid: f"j{j.jid}:{j.name}" for j in res.jobs}
        for jid, recs in res.records.items():
            trace.extend(_meta(pid, f"machine {m}", jid, names[jid])[1:])
            for r in recs:
                trace.append(_slice(r.op.op_class, r.start, r.duration,
                                    pid, jid, _op_args(r)))
            if recs:
                start = min(r.start for r in recs)
                if jid not in first_launch or start < first_launch[jid][1]:
                    first_launch[jid] = (pid, start)
            for p in res.preempted.get(jid, []):
                trace.append(_slice(f"preempted:{p.op.op_class}", p.start,
                                    p.duration, pid, jid, _op_args(p),
                                    cat="preempted"))
        for ts, n in res.events:
            trace.append(_counter(f"co_running.m{m}", ts, float(n), "ops"))
    if any(res.events for res in machines):
        trace.extend(_meta(COUNTERS_PID, "counters"))
    flow_id = 10_000   # clear of pool_trace's revoke-arrow id range
    for e in events:
        if e.family != "cluster" or e.kind != "route":
            continue
        landed = first_launch.get(e.key)
        if landed is None:
            continue
        pid, start = landed
        if start >= e.ts - 1e-12:
            flow_id += 1
            trace.extend(_flow_pair(flow_id, e.ts, start, e.key,
                                    "route→launch", pid=pid, cat="cluster"))
    trace.extend(_meta(DECISIONS_PID, "decisions"))
    _decision_events(events, trace)
    return {"traceEvents": trace, "displayTimeUnit": "ms"}


def write_trace(path, trace: dict) -> None:
    with open(path, "w") as f:
        json.dump(trace, f)


def export_pool_trace(result, path,
                      events: Iterable[TraceEvent] = ()) -> dict:
    """Build and write a pool run's Perfetto trace; returns the dict."""
    trace = pool_trace(result, events)
    write_trace(path, trace)
    return trace


def export_cluster_trace(result, path,
                         events: Iterable[TraceEvent] = ()) -> dict:
    """Build and write a cluster run's Perfetto trace; returns the dict."""
    trace = cluster_trace(result, events)
    write_trace(path, trace)
    return trace
