"""Metrics registry: counters, gauges, histograms over scheduler telemetry.

Before this module every benchmark re-derived its own sums from raw
``PoolResult`` records (probe counts here, quadrant-local fractions
there, |log error| quartiles somewhere else).  ``MetricsRegistry`` is the
one accounting surface:

* ``pool_metrics`` folds a finished ``PoolResult`` (plus the plan-cache
  stats and the EWMA correction table) into the standard metric names —
  this is what ``RuntimePool.run`` attaches as ``PoolResult.metrics``,
  with or without tracing;
* ``metrics_from_events`` re-derives the same accounting from the
  decision-event stream ALONE (``repro.obs.trace``) — service and
  restart-waste from the charge/refund events, throughput and fairness
  from the observation stream, probe counts from the profile events.
  The test suite pins that both paths agree, so the event stream is a
  sufficient audit record of what the scheduler did;
* ``slowdown_metrics`` adds the per-job slowdown gauges once a serial
  baseline exists (benches own the baseline, so they call it).

Standard names (see README "Observability" for the glossary):
``pool.*`` run aggregates, ``admission.*``/``queue.*`` the admission
tier, ``sched.*`` launch paths and prediction error, ``preemption.*``
the deadline path, ``placement.*`` quadrant locality, ``cache.*`` the
plan cache, ``feedback.*`` the correction table.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable

from repro.obs.trace import (FAM_ADMISSION, FAM_CLUSTER, FAM_PLACEMENT,
                             FAM_PLANSTORE, FAM_PREEMPTION, FAM_REGION,
                             FAM_STRATEGY, TraceEvent)


def _jain(values: list[float]) -> float:
    """Jain's fairness index (1.0 = all equal, 1/n = one takes all);
    duplicated from ``repro.multitenant.job`` deliberately — the obs
    layer must not import the layers that emit into it."""
    if not values:
        return 1.0
    s = sum(values)
    sq = sum(x * x for x in values)
    return (s * s) / (len(values) * sq) if sq else 1.0


@dataclasses.dataclass
class Counter:
    value: float = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v


@dataclasses.dataclass
class Gauge:
    value: float = 0.0

    def set(self, v: float) -> None:
        self.value = v


@dataclasses.dataclass
class Histogram:
    """Exact histogram (values retained): scheduler runs are bounded, and
    exact percentiles beat bucketed ones for bench assertions."""

    values: list[float] = dataclasses.field(default_factory=list)

    def observe(self, v: float) -> None:
        self.values.append(v)

    @property
    def count(self) -> int:
        return len(self.values)

    @property
    def total(self) -> float:
        return sum(self.values)

    @property
    def mean(self) -> float:
        return self.total / len(self.values) if self.values else 0.0

    def percentile(self, p: float) -> float:
        """Nearest-rank percentile, p in [0, 100]."""
        if not self.values:
            return 0.0
        ordered = sorted(self.values)
        rank = max(0, min(len(ordered) - 1,
                          math.ceil(p / 100.0 * len(ordered)) - 1))
        return ordered[rank]

    @property
    def max(self) -> float:
        return max(self.values) if self.values else 0.0


class MetricsRegistry:
    """Named counters/gauges/histograms with a flat ``snapshot()``."""

    def __init__(self) -> None:
        self.counters: dict[str, Counter] = {}
        self.gauges: dict[str, Gauge] = {}
        self.histograms: dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        return self.counters.setdefault(name, Counter())

    def gauge(self, name: str) -> Gauge:
        return self.gauges.setdefault(name, Gauge())

    def histogram(self, name: str) -> Histogram:
        return self.histograms.setdefault(name, Histogram())

    def value(self, name: str) -> float:
        """Scalar lookup across counters and gauges (KeyError if absent —
        a silent 0.0 would let a renamed metric pass a bench assert)."""
        if name in self.counters:
            return self.counters[name].value
        return self.gauges[name].value

    def snapshot(self) -> dict[str, float]:
        """One flat name -> float dict (histograms expand to
        ``.count``/``.mean``/``.p50``/``.p95``/``.max``)."""
        out: dict[str, float] = {}
        for name, c in self.counters.items():
            out[name] = c.value
        for name, g in self.gauges.items():
            out[name] = g.value
        for name, h in self.histograms.items():
            out[f"{name}.count"] = float(h.count)
            out[f"{name}.mean"] = h.mean
            out[f"{name}.p50"] = h.percentile(50)
            out[f"{name}.p95"] = h.percentile(95)
            out[f"{name}.max"] = h.max
        return out


# ---------------------------------------------------------------------------
# PoolResult -> registry (the path RuntimePool.run always takes)
# ---------------------------------------------------------------------------

def pool_metrics(result, *, spec=None, cache_stats=None,
                 corrections=None) -> MetricsRegistry:
    """Standard metrics of one finished pool run.

    ``result`` is duck-typed over ``PoolResult`` (the obs layer must not
    import the pool).  ``spec`` enables the quadrant-locality metrics and
    prices restart waste; ``cache_stats`` is ``PlanCache.stats()``;
    ``corrections`` the pool's shared ``CorrectionTable`` (or None)."""
    reg = MetricsRegistry()
    reg.gauge("pool.makespan_s").set(result.makespan)
    reg.counter("pool.total_ops").inc(result.total_ops)
    reg.gauge("pool.throughput_ops_s").set(result.aggregate_throughput)
    reg.counter("pool.preemptions").inc(result.n_preemptions)
    # preemption economics (0 on every pool that leaves the knobs off):
    # evictions are free admission-level bounces, migrations are priced
    # width re-seats (also present in the preempted partials they revoked)
    reg.counter("pool.evictions").inc(
        sum(getattr(j, "evictions", 0) for j in result.jobs))
    reg.counter("pool.migrations").inc(
        sum(getattr(j, "migrations", 0) for j in result.jobs))
    # dynamic control flow (0 on static mixes; counters only materialize
    # when a region actually stepped, so static snapshots are unchanged)
    n_exp = getattr(result, "n_region_expands", 0)
    if n_exp:
        reg.counter("region.expand").inc(n_exp)
    n_res = getattr(result, "n_region_resolves", 0)
    if n_res:
        reg.counter("region.resolve").inc(n_res)
    service = 0.0
    shares = []
    for j in result.jobs:
        service += j.service
        if j.admit_time is not None:
            shares.append(j.service / max(j.priority, 1e-9))
        if j.queue_wait is not None:
            reg.histogram("queue.wait_s").observe(j.queue_wait)
    reg.counter("pool.service_core_s").inc(service)
    reg.gauge("pool.fairness_jain").set(_jain(shares))
    waste = 0.0
    if spec is not None:
        for recs in result.preempted.values():
            for r in recs:
                # victims are never hyper launches (the deadline path
                # skips them), so the charge-back is at full efficiency
                waste += r.threads * r.duration * spec.restart_waste
    reg.counter("pool.restart_waste_core_s").inc(waste)
    if spec is not None and getattr(spec, "quadrants", 0):
        placed = local = 0
        # revoked partials booked cores too — count them, so this agrees
        # with the per-booking placement events
        all_recs = list(result.records.values()) + \
            list(result.preempted.values())
        for recs in all_recs:
            for r in recs:
                if not r.cores:
                    continue
                placed += 1
                quads = {spec.quadrant_of_core(c) for c in r.cores}
                if len(quads) == 1:
                    local += 1
                reg.histogram("placement.quadrants_per_launch").observe(
                    len(quads))
        if placed:
            reg.counter("placement.launches").inc(placed)
            reg.counter("placement.local").inc(local)
            reg.gauge("placement.local_fraction").set(local / placed)
    # prediction error of the completed timeline (solo-prediction vs
    # achieved duration; hyper launches measure the spare-thread lane,
    # not the curve's placement — same exclusion the EWMA blend makes)
    for recs in result.records.values():
        for r in recs:
            if r.hyper:
                continue
            err = abs(math.log(r.duration / max(r.predicted, 1e-12)))
            reg.histogram("sched.abs_log_err").observe(err)
            reg.histogram(f"sched.abs_log_err/{r.op.op_class}").observe(err)
    if cache_stats is not None:
        for k, v in cache_stats.items():
            reg.gauge(f"cache.{k}").set(float(v))
    if corrections is not None:
        for k, v in corrections.stats().items():
            reg.gauge(f"feedback.{k}").set(float(v))
        for c in corrections.point.values():
            reg.histogram("feedback.abs_log_correction").observe(
                abs(math.log(max(c, 1e-12))))
    return reg


def slowdown_metrics(reg: MetricsRegistry, result,
                     solo_makespans: dict[int, float]) -> MetricsRegistry:
    """Per-job slowdown gauges + slowdown-fairness, given the serial
    baseline the benches own (a pool run alone cannot know them)."""
    for j in result.jobs:
        if j.done and j.latency is not None and j.jid in solo_makespans:
            reg.gauge(f"job.{j.name}.slowdown").set(
                j.latency / max(solo_makespans[j.jid], 1e-12))
    reg.gauge("pool.slowdown_fairness_e2e_jain").set(
        result.slowdown_fairness(solo_makespans))
    reg.gauge("pool.slowdown_fairness_sched_jain").set(
        result.slowdown_fairness(solo_makespans, include_queue_wait=False))
    return reg


# ---------------------------------------------------------------------------
# decision events -> registry (the audit path: events alone)
# ---------------------------------------------------------------------------

def metrics_from_events(events: Iterable[TraceEvent]) -> MetricsRegistry:
    """Re-derive the run's accounting purely from the decision-event
    stream: if this disagrees with ``pool_metrics`` over the same run,
    either an emit site is missing or one is lying — both are bugs the
    test suite exists to catch."""
    reg = MetricsRegistry()
    service: dict[int, float] = {}
    priority: dict[int, float] = {}
    makespan = 0.0
    for e in events:
        makespan = max(makespan, e.ts)
        if e.family == FAM_ADMISSION:
            reg.counter(f"admission.{e.kind}").inc()
            if "queue_depth" in e.data:
                reg.histogram("queue.depth").observe(e.data["queue_depth"])
            if e.kind == "admit" and "queue_wait" in e.data:
                reg.histogram("queue.wait_s").observe(e.data["queue_wait"])
        elif e.family == FAM_STRATEGY:
            if e.kind == "charge":
                jid = e.data["jid"]
                service[jid] = service.get(jid, 0.0) + e.data["amount"]
                priority[jid] = e.data["priority"]
            elif e.kind == "refund":
                jid = e.data["jid"]
                service[jid] = (service.get(jid, 0.0) - e.data["refund"]
                                + e.data["waste"])
                reg.counter("pool.restart_waste_core_s").inc(
                    e.data["waste"])
            elif e.kind == "reject":
                reg.counter("sched.rejects").inc()
                reg.counter(f"sched.reject.{e.data['cause']}").inc()
            elif e.kind == "s2_clamp":
                reg.counter("sched.s2_clamps").inc()
            else:                      # a launch path (s3_admit, fallback,
                reg.counter("sched.launches").inc()      # s4_hyper, ...)
                reg.counter(f"sched.launch.{e.kind}").inc()
        elif e.family == FAM_PLACEMENT:
            if e.kind in ("book", "spill"):
                reg.counter("placement.launches").inc()
                if not e.data.get("spill"):
                    reg.counter("placement.local").inc()
                reg.histogram("placement.quadrants_per_launch").observe(
                    len(e.data["quadrants"]))
            elif e.kind == "avoid_override":
                reg.counter("placement.avoid_overrides").inc()
        elif e.family == FAM_PREEMPTION:
            reg.counter(f"preemption.{e.kind}").inc()
            # re-derive the economics counters PoolResult keeps:
            # "revoke" fires once per revoked victim and "migrate" revokes
            # its launch at the sim level WITHOUT a "revoke" event, so
            # both count as preempted partials; "multi_revoke" is the
            # per-set summary (already counted victim-by-victim)
            if e.kind in ("revoke", "migrate"):
                reg.counter("pool.preemptions").inc()
            if e.kind == "evict":
                reg.counter("pool.evictions").inc()
            if e.kind == "migrate":
                reg.counter("pool.migrations").inc()
        elif e.family == FAM_REGION:
            reg.counter(f"region.{e.kind}").inc()
        elif e.family == FAM_CLUSTER:
            reg.counter(f"cluster.{e.kind}").inc()
            if e.kind == "route":
                reg.counter(
                    f"cluster.machine.{e.data['machine']}.routed").inc()
                if "demand" in e.data:
                    reg.histogram("cluster.routed_demand").observe(
                        e.data["demand"])
            elif e.kind == "rebalance":
                reg.counter(
                    f"cluster.machine.{e.data['to']}.routed").inc()
        elif e.family == FAM_PLANSTORE:
            if e.kind == "profile":
                reg.counter("cache.probes_spent").inc(e.data["probes"])
                reg.counter("cache.hits").inc(e.data["cache_hits"])
            else:
                reg.counter(f"planstore.{e.kind}").inc()
                if e.kind == "finish":
                    reg.counter("pool.total_ops").inc()
                    if not e.data.get("hyper"):
                        err = abs(math.log(
                            e.data["observed"]
                            / max(e.data["predicted"], 1e-12)))
                        reg.histogram("sched.abs_log_err").observe(err)
                        reg.histogram(
                            "sched.abs_log_err/"
                            f"{e.data['op_class']}").observe(err)
    reg.gauge("pool.makespan_s").set(makespan)
    ops = reg.counter("pool.total_ops").value
    reg.gauge("pool.throughput_ops_s").set(ops / max(makespan, 1e-12))
    reg.counter("pool.service_core_s").inc(sum(service.values()))
    reg.gauge("pool.fairness_jain").set(
        _jain([s / max(priority[j], 1e-9) for j, s in service.items()]))
    placed = reg.counters.get("placement.launches")
    if placed is not None and placed.value:
        reg.gauge("placement.local_fraction").set(
            reg.counter("placement.local").value / placed.value)
    return reg
