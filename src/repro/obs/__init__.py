"""Observability: decision tracing, metrics, timeline export, logging.

The telemetry subsystem every scheduling layer emits into — see
``repro.obs.trace`` for the ``TraceSink`` seam and the six decision-event
families, ``repro.obs.metrics`` for the registry, ``repro.obs.perfetto``
for Chrome-trace/Perfetto export, ``repro.obs.log`` for the shared
``repro`` logger.  This package never imports the schedulers (they import
us), so any later subsystem can emit into it without cycles.
"""

from repro.obs.log import configure_logging, get_logger
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               metrics_from_events, pool_metrics,
                               slowdown_metrics)
from repro.obs.perfetto import (cluster_trace, export_cluster_trace,
                                export_pool_trace, pool_trace, write_trace)
from repro.obs.trace import (FAM_ADMISSION, FAM_CLUSTER, FAM_PLACEMENT,
                             FAM_PLANSTORE, FAM_PREEMPTION, FAM_REGION,
                             FAM_SERVICE, FAM_STRATEGY, FAMILIES, NULL_SINK,
                             NullSink, RecordingSink, TraceEvent, TraceSink)

__all__ = [
    "FAM_ADMISSION", "FAM_CLUSTER", "FAM_PLACEMENT", "FAM_PLANSTORE",
    "FAM_PREEMPTION", "FAM_REGION", "FAM_SERVICE",
    "FAM_STRATEGY", "FAMILIES", "NULL_SINK", "NullSink",
    "RecordingSink",
    "TraceEvent", "TraceSink",
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "metrics_from_events", "pool_metrics", "slowdown_metrics",
    "cluster_trace", "export_cluster_trace",
    "export_pool_trace", "pool_trace", "write_trace",
    "configure_logging", "get_logger",
]
