"""Shared ``repro`` logger.

Every module logs through a child of the single ``repro`` logger
(``get_logger("repro.multitenant.plancache")`` etc.), so one
``configure_logging(level)`` call — wired to ``--log-level`` on the pool
CLI — controls the whole stack, and the future pool daemon inherits real
logs instead of the ad-hoc ``print``/``warnings.warn`` mix this replaced.

Library rule: importing ``repro`` never configures handlers or touches
the root logger; only entry points call ``configure_logging``.
"""

from __future__ import annotations

import logging

ROOT_NAME = "repro"


def get_logger(name: str = ROOT_NAME) -> logging.Logger:
    """The shared ``repro`` logger (or a dotted child of it)."""
    if name != ROOT_NAME and not name.startswith(ROOT_NAME + "."):
        name = f"{ROOT_NAME}.{name}"
    return logging.getLogger(name)


def configure_logging(level: str | int = "warning") -> logging.Logger:
    """Entry-point setup: one stderr handler on the ``repro`` logger.

    Idempotent — repeated calls re-level the existing handler instead of
    stacking duplicates (the pool CLI may be invoked in-process by
    tests/benches)."""
    if isinstance(level, str):
        level = getattr(logging, level.upper())
    logger = get_logger()
    logger.setLevel(level)
    if not logger.handlers:
        handler = logging.StreamHandler()
        handler.setFormatter(logging.Formatter(
            "%(asctime)s %(levelname)s %(name)s: %(message)s"))
        logger.addHandler(handler)
    for handler in logger.handlers:
        handler.setLevel(level)
    return logger
