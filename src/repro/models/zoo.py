"""Family dispatch: one uniform interface over the six model families."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import (recurrentgemma, rwkv6, transformer, vlm, whisper)
from repro.models.common import ModelConfig, register_family

FAMILY_MODULES = {
    "dense": transformer,
    "moe": transformer,
    "ssm": rwkv6,
    "hybrid": recurrentgemma,
    "vlm": vlm,
    "audio": whisper,
}

for fam, mod in FAMILY_MODULES.items():
    register_family(fam, mod.abstract)


def module_for(cfg: ModelConfig):
    return FAMILY_MODULES[cfg.family]


def init(cfg: ModelConfig, key) -> dict:
    return module_for(cfg).init(cfg, key)


def abstract(cfg: ModelConfig) -> dict:
    return module_for(cfg).abstract(cfg)


def specs(cfg: ModelConfig) -> dict:
    return module_for(cfg).specs(cfg)


def forward(cfg: ModelConfig, params: dict, batch: dict):
    """batch: tokens (B,S) [+ frontend (B,T,d) for vlm/audio].
    Returns (logits, aux_loss)."""
    return module_for(cfg).forward(cfg, params, batch)


def needs_frontend(cfg: ModelConfig) -> bool:
    return cfg.family in ("vlm", "audio")


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int):
    return module_for(cfg).abstract_cache(cfg, batch, max_len)


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    return module_for(cfg).init_cache(cfg, batch, max_len)


def cache_max_len(cfg: ModelConfig, seq_len: int) -> int:
    return module_for(cfg).cache_max_len(cfg, seq_len)


def prefill(cfg: ModelConfig, params: dict, batch: dict, max_len: int):
    mod = module_for(cfg)
    if needs_frontend(cfg):
        return mod.prefill(cfg, params, batch["tokens"], max_len,
                           frontend=batch.get("frontend"))
    return mod.prefill(cfg, params, batch["tokens"], max_len)


def decode_step(cfg: ModelConfig, params: dict, cache: dict,
                token: jax.Array, pos):
    return module_for(cfg).decode_step(cfg, params, cache, token, pos)


def loss_fn(cfg: ModelConfig, params: dict, batch: dict,
            aux_weight: float = 0.01):
    """Next-token cross-entropy (+ MoE aux).  batch needs "tokens" and
    "targets" (usually tokens shifted by one).

    The true-class logit is extracted with a one-hot contraction, NOT
    take_along_axis: a gather along the vocab dim of vocab-sharded logits
    forces GSPMD to replicate the full (B,S,V) tensor (involuntary full
    rematerialization), while the one-hot einsum partitions cleanly
    (local partial sum + small all-reduce)."""
    logits, aux = forward(cfg, params, batch)
    targets = batch["targets"]
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    onehot = jax.nn.one_hot(targets, lf.shape[-1], dtype=lf.dtype)
    true_logit = jnp.einsum("bsv,bsv->bs", lf, onehot)
    nll = lse - true_logit
    mask = batch.get("mask")
    if mask is not None:
        nll = nll * mask
        denom = jnp.maximum(jnp.sum(mask), 1.0)
    else:
        denom = nll.size
    loss = jnp.sum(nll) / denom
    return loss + aux_weight * aux, {"ce": loss, "aux": aux}
