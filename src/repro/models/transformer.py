"""Decoder-only transformer LM (dense GQA + optional MoE + optional SWA).

Covers assigned archs: granite-3-8b, llama3-405b, codeqwen1.5-7b, olmo-1b
(non-parametric LN), mixtral-8x7b (MoE top-2 + SWA), llama4-scout (MoE
top-1).  Layers are scanned (stacked params, leading "layers" dim) so HLO
size is O(1) in depth; remat is applied per layer by the trainer.

Interfaces (shared by every family module):
  init(cfg, key) / abstract(cfg) / specs(cfg)
  forward(cfg, params, batch)            -> (logits, aux)
  abstract_cache(cfg, batch, max_len)    -> cache SDS tree
  prefill(cfg, params, tokens)           -> (logits_last, cache)
  decode_step(cfg, params, cache, token) -> (logits, cache)

KV cache layout: k/v (L, S_max, B, K, hd) + "len" scalar — one
dynamic_update_slice per decode step writes the (L,1,B,K,hd) row (minimal
HBM traffic; see DESIGN.md §6).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.common import ModelConfig, TreeBuilder


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------

def _build(cfg: ModelConfig, key, abstract: bool):
    tb = TreeBuilder(cfg, key, abstract=abstract)
    d, hd = cfg.d_model, cfg.hd
    nl = cfg.n_layers
    tb.leaf("embed/table", (cfg.padded_vocab, d), ("vocab", "table_d"), scale=0.02)

    tb.leaf("layers/attn_norm", (nl, d), ("layers", None), init="zeros")
    tb.leaf("layers/mlp_norm", (nl, d), ("layers", None), init="zeros")
    tb.leaf("layers/wq", (nl, d, cfg.n_heads * hd),
            ("layers", "embed", "heads"))
    tb.leaf("layers/wk", (nl, d, cfg.n_kv_heads * hd),
            ("layers", "embed", "kv"))
    tb.leaf("layers/wv", (nl, d, cfg.n_kv_heads * hd),
            ("layers", "embed", "kv"))
    tb.leaf("layers/wo", (nl, cfg.n_heads * hd, d),
            ("layers", "heads", "embed"))
    if cfg.moe_experts:
        e = cfg.moe_experts
        tb.leaf("layers/router", (nl, d, e), ("layers", "embed", None))
        tb.leaf("layers/w_gate", (nl, e, d, cfg.d_ff),
                ("layers", "expert", "embed", "ff"))
        tb.leaf("layers/w_up", (nl, e, d, cfg.d_ff),
                ("layers", "expert", "embed", "ff"))
        tb.leaf("layers/w_down", (nl, e, cfg.d_ff, d),
                ("layers", "expert", "ff", "embed"))
    else:
        tb.leaf("layers/w_gate", (nl, d, cfg.d_ff), ("layers", "embed", "ff"))
        tb.leaf("layers/w_up", (nl, d, cfg.d_ff), ("layers", "embed", "ff"))
        tb.leaf("layers/w_down", (nl, cfg.d_ff, d), ("layers", "ff", "embed"))

    tb.leaf("final_norm", (d,), (None,), init="zeros")
    if not cfg.tie_embeddings:
        tb.leaf("unembed", (d, cfg.padded_vocab), ("embed", "vocab"), scale=0.02)
    return tb.build()


def init(cfg: ModelConfig, key) -> dict:
    return _build(cfg, key, abstract=False)[0]


def abstract(cfg: ModelConfig) -> dict:
    return _build(cfg, None, abstract=True)[0]


def specs(cfg: ModelConfig) -> dict:
    return _build(cfg, None, abstract=True)[1]


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _norm(cfg: ModelConfig, x, scale):
    if cfg.norm == "nonparam":
        return L.nonparam_layer_norm(x)
    if cfg.norm == "layernorm":
        return L.layer_norm(x, 1.0 + scale, None)
    return L.rms_norm(x, scale)


def _layer(cfg: ModelConfig, lp: dict, x: jax.Array,
           cos: jax.Array, sin: jax.Array) -> tuple[jax.Array, tuple]:
    """One transformer block. x: (B,S,D). Returns (x', (k, v, aux)).

    Sequence parallelism (cfg.seq_axes non-empty) follows the Megatron-SP
    handoff: the residual stream / layer boundary is SEQ-SHARDED (so scan
    carries stay small), each norm output is gathered into the
    seq-unsharded tensor-parallel region, and each block output is
    reduce-scattered back before the residual add.  Pinning only the
    boundary (without explicit handoffs) makes the weight-grad
    contractions conflict on the model axis and XLA materializes full
    unsharded fp32 weight grads (found in the 405b dry-run)."""
    x = L.seq_boundary(x, cfg.batch_axes, cfg.seq_axes)
    dt = x.dtype
    b, s, d = x.shape
    hd = cfg.hd
    h = _norm(cfg, x, lp["attn_norm"])
    if cfg.seq_axes:
        h = L.constrain_batch(h, cfg.batch_axes, ())   # gather into TP
    q = jnp.einsum("bsd,dh->bsh", h, lp["wq"].astype(dt)
                   ).reshape(b, s, cfg.n_heads, hd)
    k = jnp.einsum("bsd,dh->bsh", h, lp["wk"].astype(dt)
                   ).reshape(b, s, cfg.n_kv_heads, hd)
    v = jnp.einsum("bsd,dh->bsh", h, lp["wv"].astype(dt)
                   ).reshape(b, s, cfg.n_kv_heads, hd)
    q = L.apply_rope(q, cos, sin)
    k = L.apply_rope(k, cos, sin)
    o = L.attention(q, k, v, causal=True, window=cfg.window,
                    unroll=cfg.scan_unroll)
    o = jnp.einsum("bsh,hd->bsd", o.reshape(b, s, cfg.n_heads * hd),
                   lp["wo"].astype(dt))
    if cfg.seq_axes:
        o = L.seq_boundary(o, cfg.batch_axes, cfg.seq_axes)  # RS back
    x = x + o

    h2 = _norm(cfg, x, lp["mlp_norm"])
    if cfg.seq_axes:
        h2 = L.constrain_batch(h2, cfg.batch_axes, ())
    aux = jnp.zeros((), jnp.float32)
    if cfg.moe_experts:
        moe_out, aux = L.moe_block(
            lp, h2, n_experts=cfg.moe_experts, top_k=cfg.moe_top_k,
            capacity_factor=cfg.moe_capacity_factor)
        if cfg.seq_axes:
            moe_out = L.seq_boundary(moe_out, cfg.batch_axes,
                                     cfg.seq_axes)
        x = x + moe_out
    else:
        m = (L.mlp_swiglu(lp, h2) if cfg.act == "swiglu"
             else L.mlp_gelu(lp, h2))
        if cfg.seq_axes:
            m = L.seq_boundary(m, cfg.batch_axes, cfg.seq_axes)
        x = x + m
    return x, (k, v, aux)


def forward(cfg: ModelConfig, params: dict, batch: dict,
            collect_cache: bool = False, last_only: bool = False):
    """batch: {"tokens": (B,S) int32}. Returns (logits, aux_loss[, kv]).

    ``last_only``: unembed only the final position (prefill path — avoids
    materializing (B,S,vocab) logits)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    dt = cfg.activation_dtype
    x = params["embed"]["table"].astype(dt)[tokens]
    pos = jnp.arange(s)
    cos, sin = L.rope_angles(pos, cfg.hd, cfg.rope_theta)

    def body(carry, lp):
        y, (k, v, aux) = _layer(cfg, lp, carry, cos, sin)
        ys = (jnp.swapaxes(k, 0, 1), jnp.swapaxes(v, 0, 1), aux) \
            if collect_cache else (aux,)
        return y, ys

    x, ys = jax.lax.scan(L.maybe_remat(body, cfg.remat), x,
                         params["layers"], unroll=cfg.scan_unroll)
    aux = jnp.sum(ys[-1])
    x = _norm(cfg, x, params["final_norm"])
    if last_only:
        x = x[:, -1:]
    unemb = (params["embed"]["table"].astype(dt).T if cfg.tie_embeddings
             else params["unembed"].astype(dt))
    logits = jnp.einsum("bsd,dv->bsv", x, unemb)
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    if collect_cache:
        return logits, aux, (ys[0], ys[1])   # (L,S,B,K,hd) each
    return logits, aux


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def abstract_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    dt = cfg.activation_dtype
    shape = (cfg.n_layers, max_len, batch, cfg.n_kv_heads, cfg.hd)
    return {
        "k": jax.ShapeDtypeStruct(shape, dt),
        "v": jax.ShapeDtypeStruct(shape, dt),
        "len": jax.ShapeDtypeStruct((), jnp.int32),
    }


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    dt = cfg.activation_dtype
    shape = (cfg.n_layers, max_len, batch, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt),
            "len": jnp.zeros((), jnp.int32)}


def cache_max_len(cfg: ModelConfig, seq_len: int) -> int:
    """SWA archs bound the live cache by the window size."""
    if cfg.window is not None:
        return min(seq_len, cfg.window)
    return seq_len


def prefill(cfg: ModelConfig, params: dict, tokens: jax.Array,
            max_len: int) -> tuple[jax.Array, dict]:
    """Run the full prompt; build the cache. Returns (last-token logits,
    cache).  If max_len < prompt length (SWA), keep the trailing window."""
    b, s = tokens.shape
    logits, _, (kc, vc) = forward(cfg, params, {"tokens": tokens},
                                  collect_cache=True, last_only=True)
    if max_len >= s:
        pad = max_len - s
        kc = jnp.pad(kc, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        vc = jnp.pad(vc, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
    else:
        kc = kc[:, s - max_len:]
        vc = vc[:, s - max_len:]
    cache = {"k": kc, "v": vc,
             "len": jnp.asarray(min(s, max_len), jnp.int32)}
    return logits[:, -1], cache


def decode_step(cfg: ModelConfig, params: dict, cache: dict,
                token: jax.Array, pos: jax.Array
                ) -> tuple[jax.Array, dict]:
    """token: (B,) int32; pos: absolute position (for RoPE).  Writes the
    new kv at slot cache["len"] % max_len (ring buffer for SWA)."""
    b = token.shape[0]
    dt = cfg.activation_dtype
    max_len = cache["k"].shape[1]
    slot = cache["len"] % max_len
    x = params["embed"]["table"].astype(dt)[token][:, None]   # (B,1,D)
    cos, sin = L.rope_angles(jnp.asarray(pos).reshape(1), cfg.hd,
                             cfg.rope_theta)

    def body(carry, xs):
        x, = carry
        lp, kc, vc = xs
        h = _norm(cfg, x, lp["attn_norm"])
        q = jnp.einsum("bsd,dh->bsh", h, lp["wq"].astype(dt)
                       ).reshape(b, 1, cfg.n_heads, cfg.hd)
        k = jnp.einsum("bsd,dh->bsh", h, lp["wk"].astype(dt)
                       ).reshape(b, 1, cfg.n_kv_heads, cfg.hd)
        v = jnp.einsum("bsd,dh->bsh", h, lp["wv"].astype(dt)
                       ).reshape(b, 1, cfg.n_kv_heads, cfg.hd)
        q = L.apply_rope(q, cos[None], sin[None])
        k = L.apply_rope(k, cos[None], sin[None])
        # write new kv into this layer's slot
        kc = jax.lax.dynamic_update_slice(
            kc, jnp.swapaxes(k, 0, 1), (slot, 0, 0, 0))
        vc = jax.lax.dynamic_update_slice(
            vc, jnp.swapaxes(v, 0, 1), (slot, 0, 0, 0))
        n_valid = jnp.minimum(cache["len"] + 1, max_len)
        o = L.decode_attention(
            q, jnp.swapaxes(kc, 0, 1), jnp.swapaxes(vc, 0, 1), n_valid,
            window=None)   # ring buffer already bounds the window
        o = jnp.einsum("bsh,hd->bsd",
                       o.reshape(b, 1, cfg.n_heads * cfg.hd),
                       lp["wo"].astype(dt))
        x = x + o
        h2 = _norm(cfg, x, lp["mlp_norm"])
        if cfg.moe_experts:
            moe_out, _ = L.moe_block(
                lp, h2, n_experts=cfg.moe_experts, top_k=cfg.moe_top_k,
                capacity_factor=cfg.moe_capacity_factor)
            x = x + moe_out
        else:
            x = x + (L.mlp_swiglu(lp, h2) if cfg.act == "swiglu"
                     else L.mlp_gelu(lp, h2))
        return (x,), (jnp.swapaxes(k, 0, 1)[0], jnp.swapaxes(v, 0, 1)[0])

    (x,), (k_new, v_new) = jax.lax.scan(
        body, (x,), (params["layers"], cache["k"], cache["v"]),
        unroll=cfg.scan_unroll)
    # single write of the (L,1,B,K,hd) row into the cache
    k_cache = jax.lax.dynamic_update_slice(
        cache["k"], k_new[:, None], (0, slot, 0, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        cache["v"], v_new[:, None], (0, slot, 0, 0, 0))
    x = _norm(cfg, x, params["final_norm"])
    unemb = (params["embed"]["table"].astype(dt).T if cfg.tie_embeddings
             else params["unembed"].astype(dt))
    logits = jnp.einsum("bsd,dv->bsv", x, unemb)[:, 0]
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    new_cache = {"k": k_cache, "v": v_cache, "len": cache["len"] + 1}
    return logits, new_cache
