"""RecurrentGemma / Griffin (arXiv:2402.19427) — RG-LRU + local-attention
hybrid, pattern 1 local-attn per 2 recurrent blocks.

Block kinds:
* recurrent: x -> {Wx -> conv1d(4) -> RG-LRU} ⊙ gelu(Wy) -> Wo
* local attention: MQA (kv=1) with sliding window + RoPE
Every block is followed by a GeGLU MLP; RMSNorm pre-norms throughout.

26 layers = 8 super-blocks of (rglru, rglru, attn) + 2 tail rglru blocks;
both groups are scanned (stacked params).  Serving state: per recurrent
block a (B,R) RG-LRU hidden + (B,3,R) conv tail; per attn block a
window-sized ring-buffer KV cache — O(window) memory, so this arch runs
``long_500k``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.common import ModelConfig, TreeBuilder

CONV_W = 4


def _rec_leaves(tb: TreeBuilder, prefix: str, n: int, cfg: ModelConfig):
    d = cfg.d_model
    r = cfg.rglru_dim or cfg.d_model
    tb.leaf(f"{prefix}/norm", (n, d), ("layers", None), init="zeros")
    tb.leaf(f"{prefix}/wx", (n, d, r), ("layers", "embed", "ff"))
    tb.leaf(f"{prefix}/wy", (n, d, r), ("layers", "embed", "ff"))
    tb.leaf(f"{prefix}/conv_w", (n, CONV_W, r), ("layers", "conv", "ff"))
    tb.leaf(f"{prefix}/conv_b", (n, r), ("layers", "ff"), init="zeros")
    tb.leaf(f"{prefix}/log_a", (n, r), ("layers", "ff"), init="zeros")
    tb.leaf(f"{prefix}/w_gx", (n, r, r), ("layers", "ff", "ff"))
    tb.leaf(f"{prefix}/w_ga", (n, r, r), ("layers", "ff", "ff"))
    tb.leaf(f"{prefix}/wo", (n, r, d), ("layers", "ff", "embed"))
    _mlp_leaves(tb, prefix, n, cfg)


def _attn_leaves(tb: TreeBuilder, prefix: str, n: int, cfg: ModelConfig):
    d, hd = cfg.d_model, cfg.hd
    tb.leaf(f"{prefix}/norm", (n, d), ("layers", None), init="zeros")
    tb.leaf(f"{prefix}/wq", (n, d, cfg.n_heads * hd), ("layers", "embed", "heads"))
    tb.leaf(f"{prefix}/wk", (n, d, cfg.n_kv_heads * hd), ("layers", "embed", "kv"))
    tb.leaf(f"{prefix}/wv", (n, d, cfg.n_kv_heads * hd), ("layers", "embed", "kv"))
    tb.leaf(f"{prefix}/wo", (n, cfg.n_heads * hd, d), ("layers", "heads", "embed"))
    _mlp_leaves(tb, prefix, n, cfg)


def _mlp_leaves(tb: TreeBuilder, prefix: str, n: int, cfg: ModelConfig):
    d = cfg.d_model
    tb.leaf(f"{prefix}/mlp_norm", (n, d), ("layers", None), init="zeros")
    tb.leaf(f"{prefix}/w_gate", (n, d, cfg.d_ff), ("layers", "embed", "ff"))
    tb.leaf(f"{prefix}/w_up", (n, d, cfg.d_ff), ("layers", "embed", "ff"))
    tb.leaf(f"{prefix}/w_down", (n, cfg.d_ff, d), ("layers", "ff", "embed"))


def n_supers(cfg: ModelConfig) -> tuple[int, int]:
    per = len(cfg.block_pattern)        # 3
    return cfg.n_layers // per, cfg.n_layers % per


def _build(cfg: ModelConfig, key, abstract: bool):
    tb = TreeBuilder(cfg, key, abstract=abstract)
    ns, tail = n_supers(cfg)
    tb.leaf("embed/table", (cfg.padded_vocab, cfg.d_model), ("vocab", "table_d"),
            scale=0.02)
    _rec_leaves(tb, "supers/rec1", ns, cfg)
    _rec_leaves(tb, "supers/rec2", ns, cfg)
    _attn_leaves(tb, "supers/attn", ns, cfg)
    if tail:
        _rec_leaves(tb, "tail", tail, cfg)
    tb.leaf("final_norm", (cfg.d_model,), (None,), init="zeros")
    if not cfg.tie_embeddings:
        tb.leaf("unembed", (cfg.d_model, cfg.padded_vocab), ("embed", "vocab"),
                scale=0.02)
    return tb.build()


def init(cfg, key):
    return _build(cfg, key, abstract=False)[0]


def abstract(cfg):
    return _build(cfg, None, abstract=True)[0]


def specs(cfg):
    return _build(cfg, None, abstract=True)[1]


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 tail: jax.Array | None) -> tuple[jax.Array, jax.Array]:
    """Depthwise causal conv1d, width CONV_W. x (B,S,R), w (CONV_W,R).
    ``tail``: (B,CONV_W-1,R) carried history. Returns (y, new_tail)."""
    if tail is None:
        tail = jnp.zeros((x.shape[0], CONV_W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
            for i in range(CONV_W))
    return y + b.astype(x.dtype), xp[:, -(CONV_W - 1):]


def _rec_block(cfg, lp, x, h0=None, conv_tail=None):
    x = L.constrain_batch(x, cfg.batch_axes, cfg.seq_axes)
    dt = x.dtype
    h = L.rms_norm(x, lp["norm"])
    gate = jax.nn.gelu(jnp.einsum(
        "bsd,dr->bsr", h, lp["wy"].astype(dt)).astype(jnp.float32),
        approximate=True).astype(dt)
    u = jnp.einsum("bsd,dr->bsr", h, lp["wx"].astype(dt))
    u, new_tail = _causal_conv(u, lp["conv_w"], lp["conv_b"], conv_tail)
    rec, h_last = L.rglru_block(
        {"log_a": lp["log_a"], "w_gx": lp["w_gx"], "w_ga": lp["w_ga"]},
        u, h0)
    out = jnp.einsum("bsr,rd->bsd", rec * gate, lp["wo"].astype(dt))
    x = x + out
    h2 = L.rms_norm(x, lp["mlp_norm"])
    x = x + _geglu(lp, h2)
    return x, (h_last, new_tail)


def _geglu(lp, x):
    dt = x.dtype
    g = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, lp["w_gate"].astype(dt))
                    .astype(jnp.float32), approximate=True).astype(dt)
    up = jnp.einsum("bsd,df->bsf", x, lp["w_up"].astype(dt))
    return jnp.einsum("bsf,fd->bsd", g * up, lp["w_down"].astype(dt))


def _attn_block(cfg, lp, x, cos, sin):
    x = L.constrain_batch(x, cfg.batch_axes, cfg.seq_axes)
    dt = x.dtype
    b, s, d = x.shape
    hd = cfg.hd
    h = L.rms_norm(x, lp["norm"])
    q = jnp.einsum("bsd,dh->bsh", h, lp["wq"].astype(dt)
                   ).reshape(b, s, cfg.n_heads, hd)
    k = jnp.einsum("bsd,dh->bsh", h, lp["wk"].astype(dt)
                   ).reshape(b, s, cfg.n_kv_heads, hd)
    v = jnp.einsum("bsd,dh->bsh", h, lp["wv"].astype(dt)
                   ).reshape(b, s, cfg.n_kv_heads, hd)
    q = L.apply_rope(q, cos, sin)
    k = L.apply_rope(k, cos, sin)
    o = L.attention(q, k, v, causal=True, window=cfg.window,
                    unroll=cfg.scan_unroll)
    o = jnp.einsum("bsh,hd->bsd", o.reshape(b, s, cfg.n_heads * hd),
                   lp["wo"].astype(dt))
    x = x + o
    h2 = L.rms_norm(x, lp["mlp_norm"])
    x = x + _geglu(lp, h2)
    return x, (jnp.swapaxes(k, 0, 1), jnp.swapaxes(v, 0, 1))


def forward(cfg: ModelConfig, params: dict, batch: dict):
    tokens = batch["tokens"]
    b, s = tokens.shape
    dt = cfg.activation_dtype
    x = params["embed"]["table"].astype(dt)[tokens]
    cos, sin = L.rope_angles(jnp.arange(s), cfg.hd, cfg.rope_theta)

    def super_body(carry, lp):
        y = carry
        y, _ = _rec_block(cfg, lp["rec1"], y)
        y, _ = _rec_block(cfg, lp["rec2"], y)
        y, _ = _attn_block(cfg, lp["attn"], y, cos, sin)
        return y, ()

    x, _ = jax.lax.scan(L.maybe_remat(super_body, cfg.remat), x,
                        params["supers"], unroll=cfg.scan_unroll)
    if "tail" in params:
        def tail_body(carry, lp):
            y, _ = _rec_block(cfg, lp, carry)
            return y, ()
        x, _ = jax.lax.scan(L.maybe_remat(tail_body, cfg.remat), x,
                            params["tail"], unroll=cfg.scan_unroll)
    x = L.rms_norm(x, params["final_norm"])
    unemb = (params["embed"]["table"].astype(dt).T if cfg.tie_embeddings
             else params["unembed"].astype(dt))
    logits = jnp.einsum("bsd,dv->bsv", x, unemb)
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def cache_max_len(cfg: ModelConfig, seq_len: int) -> int:
    return min(seq_len, cfg.window or seq_len)


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    ns, tail = n_supers(cfg)
    r = cfg.rglru_dim or cfg.d_model
    dt = cfg.activation_dtype
    kv = (ns, max_len, batch, cfg.n_kv_heads, cfg.hd)

    def rec_state(n):
        return {"h": jax.ShapeDtypeStruct((n, batch, r), jnp.float32),
                "conv": jax.ShapeDtypeStruct((n, batch, CONV_W - 1, r), dt)}

    cache = {"rec1": rec_state(ns), "rec2": rec_state(ns),
             "k": jax.ShapeDtypeStruct(kv, dt),
             "v": jax.ShapeDtypeStruct(kv, dt),
             "len": jax.ShapeDtypeStruct((), jnp.int32)}
    if tail:
        cache["tail"] = rec_state(tail)
    return cache


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        abstract_cache(cfg, batch, max_len))


def prefill(cfg: ModelConfig, params: dict, tokens: jax.Array,
            max_len: int):
    b, s = tokens.shape
    dt = cfg.activation_dtype
    x = params["embed"]["table"].astype(dt)[tokens]
    cos, sin = L.rope_angles(jnp.arange(s), cfg.hd, cfg.rope_theta)

    def super_body(carry, lp):
        y = carry
        y, st1 = _rec_block(cfg, lp["rec1"], y)
        y, st2 = _rec_block(cfg, lp["rec2"], y)
        y, (k, v) = _attn_block(cfg, lp["attn"], y, cos, sin)
        return y, (st1, st2, k, v)

    x, (st1, st2, kc, vc) = jax.lax.scan(super_body, x, params["supers"],
                                         unroll=cfg.scan_unroll)
    cache = {
        "rec1": {"h": st1[0], "conv": st1[1]},
        "rec2": {"h": st2[0], "conv": st2[1]},
        "len": jnp.asarray(min(s, max_len), jnp.int32),
    }
    if max_len >= s:
        pad = max_len - s
        kc = jnp.pad(kc, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        vc = jnp.pad(vc, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
    else:
        kc, vc = kc[:, s - max_len:], vc[:, s - max_len:]
    cache["k"], cache["v"] = kc, vc
    if "tail" in params:
        def tail_body(carry, lp):
            y, st = _rec_block(cfg, lp, carry)
            return y, st
        x, st = jax.lax.scan(tail_body, x, params["tail"],
                             unroll=cfg.scan_unroll)
        cache["tail"] = {"h": st[0], "conv": st[1]}
    x = L.rms_norm(x, params["final_norm"])
    unemb = (params["embed"]["table"].astype(dt).T if cfg.tie_embeddings
             else params["unembed"].astype(dt))
    logits = jnp.einsum("bd,dv->bv", x[:, -1], unemb)
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits, cache


def decode_step(cfg: ModelConfig, params: dict, cache: dict,
                token: jax.Array, pos) -> tuple[jax.Array, dict]:
    b = token.shape[0]
    dt = cfg.activation_dtype
    hd = cfg.hd
    max_len = cache["k"].shape[1]
    slot = cache["len"] % max_len
    x = params["embed"]["table"].astype(dt)[token][:, None]
    cos, sin = L.rope_angles(jnp.asarray(pos).reshape(1), cfg.hd,
                             cfg.rope_theta)

    def rec_step(lp, x, h, conv):
        y, (h2, conv2) = _rec_block(cfg, lp, x, h0=h, conv_tail=conv)
        return y, h2, conv2

    def super_body(carry, xs):
        x, = carry
        lp, h1, c1, h2, c2, kc, vc = xs
        x, nh1, nc1 = rec_step(lp["rec1"], x, h1, c1)
        x, nh2, nc2 = rec_step(lp["rec2"], x, h2, c2)
        # local attention against ring-buffer cache
        h = L.rms_norm(x, lp["attn"]["norm"])
        q = jnp.einsum("bsd,dh->bsh", h, lp["attn"]["wq"].astype(dt)
                       ).reshape(b, 1, cfg.n_heads, hd)
        k = jnp.einsum("bsd,dh->bsh", h, lp["attn"]["wk"].astype(dt)
                       ).reshape(b, 1, cfg.n_kv_heads, hd)
        v = jnp.einsum("bsd,dh->bsh", h, lp["attn"]["wv"].astype(dt)
                       ).reshape(b, 1, cfg.n_kv_heads, hd)
        q = L.apply_rope(q, cos[None], sin[None])
        k = L.apply_rope(k, cos[None], sin[None])
        kc = jax.lax.dynamic_update_slice(kc, jnp.swapaxes(k, 0, 1),
                                          (slot, 0, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, jnp.swapaxes(v, 0, 1),
                                          (slot, 0, 0, 0))
        n_valid = jnp.minimum(cache["len"] + 1, max_len)
        o = L.decode_attention(q, jnp.swapaxes(kc, 0, 1),
                               jnp.swapaxes(vc, 0, 1), n_valid)
        o = jnp.einsum("bsh,hd->bsd", o.reshape(b, 1, cfg.n_heads * hd),
                       lp["attn"]["wo"].astype(dt))
        x = x + o
        hm = L.rms_norm(x, lp["attn"]["mlp_norm"])
        x = x + _geglu(lp["attn"], hm)
        return (x,), (nh1, nc1, nh2, nc2,
                      jnp.swapaxes(k, 0, 1)[0], jnp.swapaxes(v, 0, 1)[0])

    (x,), ys = jax.lax.scan(
        super_body, (x,),
        (params["supers"], cache["rec1"]["h"], cache["rec1"]["conv"],
         cache["rec2"]["h"], cache["rec2"]["conv"], cache["k"], cache["v"]),
        unroll=cfg.scan_unroll)
    nh1, nc1, nh2, nc2, k_new, v_new = ys
    new_cache = {
        "rec1": {"h": nh1, "conv": nc1},
        "rec2": {"h": nh2, "conv": nc2},
        "k": jax.lax.dynamic_update_slice(cache["k"], k_new[:, None],
                                          (0, slot, 0, 0, 0)),
        "v": jax.lax.dynamic_update_slice(cache["v"], v_new[:, None],
                                          (0, slot, 0, 0, 0)),
        "len": cache["len"] + 1,
    }
    if "tail" in params:
        def tail_body(carry, xs):
            x, = carry
            lp, h, c = xs
            y, nh, nc = rec_step(lp, x, h, c)
            return (y,), (nh, nc)
        (x,), (th, tc) = jax.lax.scan(
            tail_body, (x,),
            (params["tail"], cache["tail"]["h"], cache["tail"]["conv"]),
            unroll=cfg.scan_unroll)
        new_cache["tail"] = {"h": th, "conv": tc}
    x = L.rms_norm(x[:, 0], params["final_norm"])
    unemb = (params["embed"]["table"].astype(dt).T if cfg.tie_embeddings
             else params["unembed"].astype(dt))
    logits = jnp.einsum("bd,dv->bv", x, unemb)
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits, new_cache
