"""Shared neural-net layers: norms, RoPE, attention flavors, MLPs, MoE,
gated linear recurrences (RG-LRU, RWKV6).

Everything is a pure function of (params subtree, activations).  Attention
defaults to the jnp reference math (what the dry-run lowers — XLA fuses it
adequately for roofline purposes); the Pallas flash kernel in
``repro.kernels.flash_attention`` is the TPU-target drop-in and is
validated against the same math in interpret mode.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp


def maybe_remat(body, remat: str):
    """Wrap a scan body in jax.checkpoint per the config's remat mode."""
    if remat == "full":
        return jax.checkpoint(body)
    return body


def constrain_batch(x: jax.Array, batch_axes: tuple,
                    seq_axes: tuple = ()) -> jax.Array:
    """Pin the (batch[, seq]) dims' sharding on a (B,S,...) activation.
    No-op when batch_axes is empty (single-device tests).  Non-empty
    seq_axes = sequence parallelism at layer boundaries."""
    if not batch_axes and not seq_axes:
        return x
    from jax.sharding import PartitionSpec as P
    parts = [tuple(batch_axes) or None]
    if x.ndim >= 2:
        parts.append(tuple(seq_axes) or None)
    spec = P(*parts, *([None] * (x.ndim - len(parts))))
    return jax.lax.with_sharding_constraint(x, spec)


def seq_boundary(x: jax.Array, batch_axes: tuple, seq_axes: tuple
                 ) -> jax.Array:
    """Sequence-parallel boundary: constrain the PRIMAL to
    (batch, seq-sharded) but leave the COTANGENT unconstrained.

    with_sharding_constraint transposes to the same constraint on the
    cotangent; at Megatron-SP handoffs that forces seq-sharded weight-grad
    contractions that conflict with tensor-parallel sharding on the same
    mesh axis, and XLA materializes full unsharded fp32 weight grads
    (found in the 405b dry-run).  The asymmetric custom_vjp lets GSPMD
    pick the natural backward sharding."""
    if not batch_axes and not seq_axes:
        return x

    @jax.custom_vjp
    def ident(y):
        return constrain_batch(y, batch_axes, seq_axes)

    def fwd(y):
        return constrain_batch(y, batch_axes, seq_axes), None

    def bwd(_, g):
        return (g,)

    ident.defvjp(fwd, bwd)
    return ident(x)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array | None, eps: float = 1e-6
             ) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    if scale is not None:
        y = y * (1.0 + scale.astype(jnp.float32))
    return y.astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array | None,
               bias: jax.Array | None, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    if scale is not None:
        y = y * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(dt)


def nonparam_layer_norm(x: jax.Array, eps: float = 1e-5) -> jax.Array:
    """OLMo's non-parametric LayerNorm (no scale/bias)."""
    return layer_norm(x, None, None, eps)


def apply_norm(kind: str, x: jax.Array, p: dict | None) -> jax.Array:
    if kind == "rms":
        return rms_norm(x, p["scale"] if p else None)
    if kind == "layernorm":
        return layer_norm(x, p["scale"] if p else None,
                          p.get("bias") if p else None)
    if kind == "nonparam":
        return nonparam_layer_norm(x)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_angles(positions: jax.Array, head_dim: int, theta: float
                ) -> tuple[jax.Array, jax.Array]:
    """positions (..., S) -> cos/sin (..., S, head_dim//2), float32."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., S, H, D). cos/sin: (..., S, D/2) broadcast over heads."""
    dt = x.dtype
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c = cos[..., :, None, :]
    s = sin[..., :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c],
                           axis=-1).astype(dt)


# ---------------------------------------------------------------------------
# Attention (jnp reference math; GQA, causal, sliding window, cross)
# ---------------------------------------------------------------------------

def repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    """(B,S,K,D) -> (B,S,K*n_rep,D)."""
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)
                            ).reshape(b, s, h * n_rep, d)


def _attention_dense(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     causal: bool, window: int | None,
                     q_offset, softcap: float) -> jax.Array:
    b, sq, h, d = q.shape
    _, sk, kh, _ = k.shape
    k = repeat_kv(k, h // kh)
    v = repeat_kv(v, h // kh)
    scale = 1.0 / math.sqrt(d)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    qpos = q_offset + jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (kpos > qpos - window)
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


# materializing (B,H,Sq,Sk) above this many score elements per (B,H) pair
# is chunked over q blocks (flash-lite: bounds HBM transients the way the
# Pallas kernel bounds VMEM; the kernel remains the TPU hot path)
_CHUNK_THRESHOLD = 1 << 26
_Q_CHUNK = 1024


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: int | None = None,
              q_offset: int = 0, softcap: float = 0.0,
              unroll: bool = False) -> jax.Array:
    """q: (B,Sq,H,D), k/v: (B,Sk,K,D) with H % K == 0.  Returns (B,Sq,H,D).

    ``q_offset``: absolute position of q[0] relative to k[0] (prefill=0,
    decode=Sk-1).  ``window``: keys further than ``window`` behind the
    query are masked (sliding-window / local attention).  Long sequences
    are processed in q-chunks so the score matrix transient stays bounded
    (each chunk still scores the full key range; the causal half-waste is
    what the Pallas kernel's block skipping removes on TPU)."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    if sq * sk < _CHUNK_THRESHOLD or sq <= _Q_CHUNK or sq % _Q_CHUNK:
        return _attention_dense(q, k, v, causal=causal, window=window,
                                q_offset=q_offset, softcap=softcap)
    nq = sq // _Q_CHUNK
    qc = jnp.moveaxis(q.reshape(b, nq, _Q_CHUNK, h, d), 1, 0)
    starts = jnp.arange(nq) * _Q_CHUNK

    def body(_, xs):
        qi, st = xs
        o = _attention_dense(qi, k, v, causal=causal, window=window,
                             q_offset=q_offset + st, softcap=softcap)
        return (), o

    _, outs = jax.lax.scan(body, (), (qc, starts), unroll=unroll)
    return jnp.moveaxis(outs, 0, 1).reshape(b, sq, h, d)


def cross_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    softcap: float = 0.0) -> jax.Array:
    return attention(q, k, v, causal=False, window=None, softcap=softcap)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array | int, *,
                     window: int | None = None) -> jax.Array:
    """Single-token decode: q (B,1,H,D), caches (B,S,K,D) with valid
    prefix ``cache_len``.  Position of q is cache_len-1 (the newest token
    is already written into the cache)."""
    b, s, kh, d = k_cache.shape
    h = q.shape[2]
    kq = repeat_kv(k_cache, h // kh)
    vq = repeat_kv(v_cache, h // kh)
    scale = 1.0 / math.sqrt(d)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, kq,
                        preferred_element_type=jnp.float32) * scale
    kpos = jnp.arange(s)[None, None, None, :]
    valid = kpos < jnp.asarray(cache_len).reshape(-1, 1, 1, 1)
    if window is not None:
        valid &= kpos >= jnp.asarray(cache_len).reshape(-1, 1, 1, 1) - window
    logits = jnp.where(valid, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, vq)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_swiglu(p: dict, x: jax.Array) -> jax.Array:
    dt = x.dtype
    gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(dt))
    up = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(dt))
    act = jax.nn.silu(gate.astype(jnp.float32)).astype(dt) * up
    return jnp.einsum("bsf,fd->bsd", act, p["w_down"].astype(dt))


def mlp_gelu(p: dict, x: jax.Array) -> jax.Array:
    dt = x.dtype
    h = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(dt))
    if "b_up" in p:
        h = h + p["b_up"].astype(dt)
    h = jax.nn.gelu(h.astype(jnp.float32), approximate=True).astype(dt)
    out = jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(dt))
    if "b_down" in p:
        out = out + p["b_down"].astype(dt)
    return out


def apply_mlp(kind: str, p: dict, x: jax.Array) -> jax.Array:
    return mlp_swiglu(p, x) if kind == "swiglu" else mlp_gelu(p, x)


# ---------------------------------------------------------------------------
# MoE (GShard-style capacity dispatch; top-1 and top-2)
# ---------------------------------------------------------------------------

def moe_block(p: dict, x: jax.Array, *, n_experts: int, top_k: int,
              capacity_factor: float = 1.25) -> tuple[jax.Array, jax.Array]:
    """x: (B,S,D) -> (out, aux_loss).  Experts stacked on dim 0 of
    p['w_gate'|'w_up'|'w_down']: (E, D, F) / (E, F, D).

    GShard-style GROUPED dispatch: each batch row is a dispatch group
    with its own capacity (C = f*S*k/E), so the one-hot dispatch/combine
    tensors are (B, S, E, C) — LINEAR in tokens.  (An ungrouped
    (T, E, C_total) formulation is quadratic in T: ~43 TB for mixtral's
    train_4k cell.)  Dispatch/combine become all-to-alls when the expert
    dim is sharded (expert parallelism)."""
    b, s, d = x.shape
    dt = x.dtype
    # fixed-size dispatch groups (GShard): long sequences are split into
    # <=4096-token groups so the (groups, G, E, C) one-hot tensors stay
    # linear in tokens at any sequence length (32k prefill would
    # otherwise grow capacity with S)
    if s > 4096:
        assert s % 4096 == 0, s
        xg = x.reshape(b * (s // 4096), 4096, d)
        out, aux = moe_block(p, xg, n_experts=n_experts, top_k=top_k,
                             capacity_factor=capacity_factor)
        return out.reshape(b, s, d), aux
    # per-group capacity with a floor (min_capacity=4) so tiny decode
    # groups don't degenerate to cap=1
    capacity = max(4, -(-int(capacity_factor * s * top_k) // n_experts))
    capacity = min(capacity, s)

    router_logits = jnp.einsum(
        "bsd,de->bse", x.astype(jnp.float32),
        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(router_logits, axis=-1)

    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)          # (B,S,k)
    # aux load-balancing loss (Switch): E * sum_e f_e * p_e per group
    me = jnp.mean(probs, axis=1)                               # (B,E)
    ce = jnp.mean(jax.nn.one_hot(gate_idx[..., 0], n_experts,
                                 dtype=jnp.float32), axis=1)
    aux = n_experts * jnp.mean(jnp.sum(me * ce, axis=-1))

    combine = jnp.zeros((b, s, n_experts, capacity), jnp.float32)
    dispatch = jnp.zeros((b, s, n_experts, capacity), bool)
    occupancy = jnp.zeros((b, n_experts), jnp.int32)
    for slot in range(top_k):
        idx = gate_idx[..., slot]
        gv = gate_vals[..., slot]
        onehot = jax.nn.one_hot(idx, n_experts, dtype=jnp.int32)  # (B,S,E)
        # expert-buffer position within the group: running count in this
        # slot, offset by earlier slots' occupancy (GShard cumsum)
        pos = jnp.cumsum(onehot, axis=1) - 1 + occupancy[:, None, :]
        pos = jnp.where(onehot > 0, pos, -1)
        occupancy = occupancy + jnp.sum(onehot, axis=1)
        in_cap = (pos >= 0) & (pos < capacity)
        pos_c = jnp.clip(pos, 0, capacity - 1)
        oh_cap = jax.nn.one_hot(pos_c, capacity, dtype=jnp.float32) \
            * in_cap[..., None]
        combine = combine + oh_cap * gv[..., None, None]
        dispatch = dispatch | (oh_cap > 0)

    expert_in = jnp.einsum("bsec,bsd->ebcd", dispatch.astype(dt), x)
    gate = jnp.einsum("ebcd,edf->ebcf", expert_in, p["w_gate"].astype(dt))
    up = jnp.einsum("ebcd,edf->ebcf", expert_in, p["w_up"].astype(dt))
    act = jax.nn.silu(gate.astype(jnp.float32)).astype(dt) * up
    expert_out = jnp.einsum("ebcf,efd->ebcd", act, p["w_down"].astype(dt))
    out = jnp.einsum("bsec,ebcd->bsd", combine.astype(dt), expert_out)
    return out, aux


# ---------------------------------------------------------------------------
# RG-LRU (RecurrentGemma) — jnp reference; Pallas kernel mirrors this
# ---------------------------------------------------------------------------

def rglru_scan(a: jax.Array, x: jax.Array, h0: jax.Array | None = None
               ) -> tuple[jax.Array, jax.Array]:
    """h_t = a_t * h_{t-1} + sqrt(1-a_t^2) * x_t  (elementwise, assoc-scan).

    a, x: (B, S, D) with a in (0,1).  Returns (h_all (B,S,D), h_last)."""
    a32 = a.astype(jnp.float32)
    x32 = x.astype(jnp.float32) * jnp.sqrt(
        jnp.maximum(1.0 - a32 * a32, 1e-12))
    if h0 is not None:
        # fold the carried state into step 0
        x32 = x32.at[:, 0].add(a32[:, 0] * h0.astype(jnp.float32))
        a32 = a32.at[:, 0].set(0.0 * a32[:, 0])

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    aa, hh = jax.lax.associative_scan(combine, (a32, x32), axis=1)
    return hh.astype(x.dtype), hh[:, -1]


def rglru_block(p: dict, x: jax.Array, h0: jax.Array | None = None,
                c: float = 8.0) -> tuple[jax.Array, jax.Array]:
    """Griffin's recurrent block core: input/rec gates + RG-LRU.

    x: (B,S,R).  p: log_a (R,), w_rx/w_ra gates (R,R)."""
    dt = x.dtype
    gate_x = jax.nn.sigmoid(
        jnp.einsum("bsr,rk->bsk", x, p["w_gx"].astype(dt))
        .astype(jnp.float32))
    gate_a = jax.nn.sigmoid(
        jnp.einsum("bsr,rk->bsk", x, p["w_ga"].astype(dt))
        .astype(jnp.float32))
    log_a = -c * gate_a * jax.nn.softplus(p["log_a"].astype(jnp.float32))
    a = jnp.exp(log_a).astype(x.dtype)
    gated_x = (x.astype(jnp.float32) * gate_x).astype(dt)
    h, h_last = rglru_scan(a, gated_x, h0)
    return h, h_last


def rglru_step(p: dict, x_t: jax.Array, h_prev: jax.Array, c: float = 8.0
               ) -> tuple[jax.Array, jax.Array]:
    """One decode step: x_t (B,R), h_prev (B,R) -> (out, h_new)."""
    dt = x_t.dtype
    gate_x = jax.nn.sigmoid(
        (x_t @ p["w_gx"].astype(dt)).astype(jnp.float32))
    gate_a = jax.nn.sigmoid(
        (x_t @ p["w_ga"].astype(dt)).astype(jnp.float32))
    log_a = -c * gate_a * jax.nn.softplus(p["log_a"].astype(jnp.float32))
    a = jnp.exp(log_a)
    xg = x_t.astype(jnp.float32) * gate_x
    h = a * h_prev.astype(jnp.float32) + jnp.sqrt(
        jnp.maximum(1.0 - a * a, 1e-12)) * xg
    return h.astype(dt), h.astype(jnp.float32)


# ---------------------------------------------------------------------------
# RWKV6 time-mix core (chunked linear attention with data-dependent decay)
# ---------------------------------------------------------------------------

def rwkv6_linear_attention(r: jax.Array, k: jax.Array, v: jax.Array,
                           w: jax.Array, u: jax.Array,
                           state0: jax.Array | None = None,
                           chunk: int = 64, unroll: bool = False
                           ) -> tuple[jax.Array, jax.Array]:
    """RWKV6 WKV recurrence, chunked form.

    r,k,v,w: (B, H, S, D); w = per-step decay in (0,1); u: (H, D) bonus.
    State S_t (B,H,D,D):  S_t = diag(w_t) S_{t-1} + k_t v_t^T
    out_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    Returns (out (B,H,S,D), final state)."""
    b, h, s, d = r.shape
    assert s % chunk == 0, (s, chunk)
    n = s // chunk
    rf = r.astype(jnp.float32).reshape(b, h, n, chunk, d)
    kf = k.astype(jnp.float32).reshape(b, h, n, chunk, d)
    vf = v.astype(jnp.float32).reshape(b, h, n, chunk, d)
    wf = w.astype(jnp.float32).reshape(b, h, n, chunk, d)
    uf = u.astype(jnp.float32)

    logw = jnp.log(jnp.clip(wf, 1e-8, 1.0))
    cum = jnp.cumsum(logw, axis=3)                  # inclusive per-chunk
    w_in = jnp.exp(cum - logw)                      # decay from chunk start to t-1
    w_all = jnp.exp(cum[:, :, :, -1, :])            # (b,h,n,d) full-chunk decay
    w_out = jnp.exp(cum[:, :, :, -1:, :] - cum)     # decay from t to chunk end

    # --- intra-chunk: t attends to j<t with decay prod_{j<i<t} w_i,
    # plus the u-bonus on the diagonal (current token) -------------------
    ct = cum - logw                                 # cum up to t-1
    dmat = jnp.exp(ct[:, :, :, :, None, :] - cum[:, :, :, None, :, :])
    tt = jnp.arange(chunk)
    causal = (tt[:, None] > tt[None, :])[None, None, None, :, :, None]
    att = jnp.where(causal, dmat, 0.0)
    scores = jnp.einsum("bhntd,bhnjd,bhntjd->bhntj", rf, kf, att)
    intra_out = jnp.einsum("bhntj,bhnjd->bhntd", scores, vf)
    intra_out = intra_out + jnp.einsum(
        "bhntd,bhntv->bhntv", rf * kf * uf[None, :, None, None, :], vf)

    # --- inter-chunk: sequential scan over per-chunk states --------------
    k_scaled = kf * w_out                           # key decayed to chunk end
    s0 = (jnp.zeros((b, h, d, d), jnp.float32) if state0 is None
          else state0.astype(jnp.float32))
    kk = jnp.moveaxis(k_scaled, 2, 0)               # (n,b,h,chunk,d)
    vv = jnp.moveaxis(vf, 2, 0)
    wa = jnp.moveaxis(w_all, 2, 0)                  # (n,b,h,d)
    rr = jnp.moveaxis(rf, 2, 0)
    wi = jnp.moveaxis(w_in, 2, 0)

    def body(carry, xs):
        kc, vc, w_all_c, rc, w_in_c = xs            # (b,h,chunk,d)/(b,h,d)
        out_c = jnp.einsum("bhtd,bhdv->bhtv", rc * w_in_c, carry)
        new = carry * w_all_c[..., None] + jnp.einsum(
            "bhtd,bhtv->bhdv", kc, vc)
        return new, out_c

    final_state, inter_out = jax.lax.scan(body, s0, (kk, vv, wa, rr, wi),
                                          unroll=unroll)
    inter_out = jnp.moveaxis(inter_out, 0, 2)       # (b,h,n,chunk,d)

    out = (intra_out + inter_out).reshape(b, h, s, d)
    return out.astype(r.dtype), final_state


def rwkv6_step(r_t, k_t, v_t, w_t, u, state):
    """One decode step. r_t..w_t: (B,H,D); state (B,H,D,D) float32."""
    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r_t, k_t, v_t, w_t))
    uf = u.astype(jnp.float32)
    kv = jnp.einsum("bhd,bhv->bhdv", kf, vf)
    out = jnp.einsum("bhd,bhdv->bhv", rf, state + uf[None, :, :, None] * kv)
    new_state = state * wf[..., None] + kv
    return out.astype(r_t.dtype), new_state
