"""RWKV6 "Finch" (arXiv:2404.05892) — attention-free LM with
data-dependent decay.

Per layer: a time-mix block (token-shift ddlerp -> r/k/v/w/g projections,
WKV6 matrix-state recurrence, group-norm + gated output) and a channel-mix
block (token-shift, squared-relu FFN).  The WKV recurrence is the chunked
linear attention in ``layers.rwkv6_linear_attention``; the Pallas kernel
(kernels/rwkv6) implements the same math with VMEM tiling.

State for serving: per layer, the (B,H,D,D) fp32 matrix state plus the
previous-token activations for both token-shifts — O(1) in sequence
length, which is why this arch runs the ``long_500k`` cell.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.common import ModelConfig, TreeBuilder

LORA_R = 32          # low-rank size of the ddlerp/decay LoRAs


def _build(cfg: ModelConfig, key, abstract: bool):
    tb = TreeBuilder(cfg, key, abstract=abstract)
    d, nl = cfg.d_model, cfg.n_layers
    n_heads = cfg.n_heads
    hd = d // n_heads
    assert n_heads * hd == d

    tb.leaf("embed/table", (cfg.padded_vocab, d), ("vocab", "table_d"), scale=0.02)
    # time-mix
    tb.leaf("layers/tm_norm", (nl, d), ("layers", None), init="zeros")
    tb.leaf("layers/tm_mu", (nl, 5, d), ("layers", None, None), init="zeros")
    tb.leaf("layers/tm_lora_a", (nl, d, 5 * LORA_R),
            ("layers", "embed", None))
    tb.leaf("layers/tm_lora_b", (nl, 5, LORA_R, d),
            ("layers", None, None, None), init="zeros")
    for name in ("wr", "wk", "wv", "wg"):
        tb.leaf(f"layers/{name}", (nl, d, d), ("layers", "embed", "heads"))
    tb.leaf("layers/wo", (nl, d, d), ("layers", "heads", "embed"))
    tb.leaf("layers/w0", (nl, d), ("layers", None), init="zeros")
    tb.leaf("layers/w_lora_a", (nl, d, LORA_R), ("layers", "embed", None))
    tb.leaf("layers/w_lora_b", (nl, LORA_R, d), ("layers", None, None),
            init="zeros")
    tb.leaf("layers/u", (nl, n_heads, hd), ("layers", "heads", None),
            init="zeros")
    tb.leaf("layers/ln_x", (nl, d), ("layers", None), init="ones")
    # channel-mix
    tb.leaf("layers/cm_norm", (nl, d), ("layers", None), init="zeros")
    tb.leaf("layers/cm_mu", (nl, 2, d), ("layers", None, None), init="zeros")
    tb.leaf("layers/cm_wk", (nl, d, cfg.d_ff), ("layers", "embed", "ff"))
    tb.leaf("layers/cm_wv", (nl, cfg.d_ff, d), ("layers", "ff", "embed"))
    tb.leaf("layers/cm_wr", (nl, d, d), ("layers", "embed", "embed"))

    tb.leaf("final_norm", (d,), (None,), init="zeros")
    tb.leaf("unembed", (d, cfg.padded_vocab), ("embed", "vocab"), scale=0.02)
    return tb.build()


def init(cfg, key):
    return _build(cfg, key, abstract=False)[0]


def abstract(cfg):
    return _build(cfg, None, abstract=True)[0]


def specs(cfg):
    return _build(cfg, None, abstract=True)[1]


# ---------------------------------------------------------------------------

def _shift(x: jax.Array, prev: jax.Array | None) -> jax.Array:
    """Token shift: x[t-1]; position 0 gets ``prev`` (carried state) or 0."""
    pad = jnp.zeros_like(x[:, :1]) if prev is None else prev[:, None]
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _time_mix(cfg: ModelConfig, lp: dict, x: jax.Array,
              state0: jax.Array | None, prev0: jax.Array | None,
              chunk: int = 64):
    """x: (B,S,D). Returns (out, final_state, last_x)."""
    b, s, d = x.shape
    chunk = min(chunk, s)
    while s % chunk:
        chunk -= 1
    dt = x.dtype
    n_heads = cfg.n_heads
    hd = d // n_heads
    xs = _shift(x, prev0)
    delta = xs - x
    # ddlerp: 5 interpolation amounts from a shared LoRA
    lora = jnp.einsum("bsd,dr->bsr", x, lp["tm_lora_a"].astype(dt))
    lora = jnp.tanh(lora.astype(jnp.float32)).astype(dt)
    lora = lora.reshape(b, s, 5, LORA_R)
    amt = lp["tm_mu"].astype(dt)[None, None] + jnp.einsum(
        "bskr,krd->bskd", lora, lp["tm_lora_b"].astype(dt))
    mixed = x[:, :, None, :] + delta[:, :, None, :] * amt     # (B,S,5,D)
    xw, xk, xv, xr, xg = [mixed[:, :, i] for i in range(5)]

    r = jnp.einsum("bsd,dh->bsh", xr, lp["wr"].astype(dt))
    k = jnp.einsum("bsd,dh->bsh", xk, lp["wk"].astype(dt))
    v = jnp.einsum("bsd,dh->bsh", xv, lp["wv"].astype(dt))
    g = jnp.einsum("bsd,dh->bsh", xg, lp["wg"].astype(dt))
    wl = jnp.einsum("bsd,dr->bsr", xw, lp["w_lora_a"].astype(dt))
    wl = jnp.einsum("bsr,rd->bsd", jnp.tanh(wl.astype(jnp.float32)),
                    lp["w_lora_b"].astype(jnp.float32))
    logw = -jnp.exp(jnp.clip(lp["w0"].astype(jnp.float32)[None, None]
                             + wl, -10.0, 4.0))
    w = jnp.exp(logw)                                          # decay in (0,1)

    def heads(z):
        return jnp.swapaxes(z.reshape(b, s, n_heads, hd), 1, 2)

    out, final_state = L.rwkv6_linear_attention(
        heads(r), heads(k), heads(v), heads(w.astype(dt)),
        lp["u"].astype(jnp.float32), state0, chunk=chunk,
        unroll=cfg.scan_unroll)
    out = jnp.swapaxes(out, 1, 2).reshape(b, s, d)
    # per-head group norm
    og = out.reshape(b, s, n_heads, hd).astype(jnp.float32)
    og = og * jax.lax.rsqrt(jnp.mean(og * og, axis=-1, keepdims=True) + 1e-6)
    out = (og.reshape(b, s, d) * lp["ln_x"].astype(jnp.float32)).astype(dt)
    out = out * jax.nn.silu(g.astype(jnp.float32)).astype(dt)
    out = jnp.einsum("bsh,hd->bsd", out, lp["wo"].astype(dt))
    return out, final_state, x[:, -1]


def _channel_mix(lp: dict, x: jax.Array, prev0: jax.Array | None):
    dt = x.dtype
    xs = _shift(x, prev0)
    delta = xs - x
    mu = lp["cm_mu"].astype(dt)
    xk = x + delta * mu[0][None, None]
    xr = x + delta * mu[1][None, None]
    k = jnp.einsum("bsd,df->bsf", xk, lp["cm_wk"].astype(dt))
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(dt)
    r = jax.nn.sigmoid(jnp.einsum(
        "bsd,de->bse", xr, lp["cm_wr"].astype(dt)).astype(jnp.float32))
    out = jnp.einsum("bsf,fd->bsd", k, lp["cm_wv"].astype(dt))
    return (out.astype(jnp.float32) * r).astype(dt), x[:, -1]


def _layer(cfg, lp, x, tm_state=None, tm_prev=None, cm_prev=None,
           chunk: int = 64):
    x = L.constrain_batch(x, cfg.batch_axes, cfg.seq_axes)
    h = L.rms_norm(x, lp["tm_norm"])
    tm_out, tm_state_new, tm_last = _time_mix(cfg, lp, h, tm_state, tm_prev,
                                              chunk)
    x = x + tm_out
    h2 = L.rms_norm(x, lp["cm_norm"])
    cm_out, cm_last = _channel_mix(lp, h2, cm_prev)
    x = x + cm_out
    return x, (tm_state_new, tm_last, cm_last)


def forward(cfg: ModelConfig, params: dict, batch: dict,
            chunk: int = 64):
    tokens = batch["tokens"]
    dt = cfg.activation_dtype
    x = params["embed"]["table"].astype(dt)[tokens]

    def body(carry, lp):
        y, _ = _layer(cfg, lp, carry, chunk=chunk)
        return y, ()

    x, _ = jax.lax.scan(L.maybe_remat(body, cfg.remat), x,
                        params["layers"], unroll=cfg.scan_unroll)
    x = L.rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"].astype(dt))
    return logits, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# serving: recurrent state instead of KV cache
# ---------------------------------------------------------------------------

def abstract_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    d, nl, nh = cfg.d_model, cfg.n_layers, cfg.n_heads
    hd = d // nh
    dt = cfg.activation_dtype
    return {
        "tm_state": jax.ShapeDtypeStruct((nl, batch, nh, hd, hd),
                                         jnp.float32),
        "tm_prev": jax.ShapeDtypeStruct((nl, batch, d), dt),
        "cm_prev": jax.ShapeDtypeStruct((nl, batch, d), dt),
        "len": jax.ShapeDtypeStruct((), jnp.int32),
    }


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        abstract_cache(cfg, batch, max_len))


def cache_max_len(cfg: ModelConfig, seq_len: int) -> int:
    return 1      # O(1) state


def prefill(cfg: ModelConfig, params: dict, tokens: jax.Array,
            max_len: int, chunk: int = 64):
    dt = cfg.activation_dtype
    x = params["embed"]["table"].astype(dt)[tokens]

    def body(carry, lp):
        y, (st, tmp, cmp) = _layer(cfg, lp, carry, chunk=chunk)
        return y, (st, tmp, cmp)

    x, (tm_state, tm_prev, cm_prev) = jax.lax.scan(
        body, x, params["layers"], unroll=cfg.scan_unroll)
    x = L.rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bd,dv->bv", x[:, -1], params["unembed"].astype(dt))
    cache = {"tm_state": tm_state, "tm_prev": tm_prev, "cm_prev": cm_prev,
             "len": jnp.asarray(tokens.shape[1], jnp.int32)}
    return logits, cache


def decode_step(cfg: ModelConfig, params: dict, cache: dict,
                token: jax.Array, pos) -> tuple[jax.Array, dict]:
    dt = cfg.activation_dtype
    x = params["embed"]["table"].astype(dt)[token][:, None]   # (B,1,D)

    def body(carry, xs):
        x, = carry
        lp, st, tmp, cmp = xs
        y, (st2, tm_last, cm_last) = _layer(
            cfg, lp, x, tm_state=st, tm_prev=tmp, cm_prev=cmp, chunk=1)
        return (y,), (st2, tm_last, cm_last)

    (x,), (tm_state, tm_prev, cm_prev) = jax.lax.scan(
        body, (x,), (params["layers"], cache["tm_state"],
                     cache["tm_prev"], cache["cm_prev"]),
        unroll=cfg.scan_unroll)
    x = L.rms_norm(x[:, 0], params["final_norm"])
    logits = jnp.einsum("bd,dv->bv", x, params["unembed"].astype(dt))
    return logits, {"tm_state": tm_state, "tm_prev": tm_prev,
                    "cm_prev": cm_prev, "len": cache["len"] + 1}
