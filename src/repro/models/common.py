"""Model substrate: configs, parameter pytrees with logical sharding axes.

Design (DESIGN.md §3):

* Models are pure functions over parameter pytrees (nested dicts of
  ``jnp.ndarray``).  No module framework — only jax.
* Every parameter carries a *logical axis spec* (tuple of logical axis
  names, one per array dim) in a parallel pytree.  A ``ShardingPlan``
  maps logical names → mesh axes; this mapping is THE knob the paper-
  technique autotuner turns (per-op-class shard degree, DESIGN.md A2).
* ``abstract_params`` builds the same pytree out of ShapeDtypeStruct —
  the dry-run lowers against it without allocating a single byte.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

Params = Any          # nested dict pytree of arrays
Specs = Any           # same treedef, leaves = tuple[str|None, ...]


# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One config covers every assigned family via optional fields."""

    arch_id: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    # MoE
    moe_experts: int = 0
    moe_top_k: int = 1
    moe_capacity_factor: float = 1.25
    # attention flavor
    window: int | None = None        # sliding-window size (Mixtral SWA, local)
    rope_theta: float = 10000.0
    # norms / activations
    norm: str = "rms"                # rms | layernorm | nonparam
    act: str = "swiglu"              # swiglu | gelu
    # hybrid (recurrentgemma): layer pattern, e.g. ("rglru","rglru","attn")
    block_pattern: tuple[str, ...] = ()
    rglru_dim: int = 0               # recurrence width (0 -> d_model)
    # ssm (rwkv6)
    # vlm: insert a cross-attn layer every k self-attn layers
    cross_attn_every: int = 0
    n_frontend_tokens: int = 0       # stub modality tokens (vlm/audio)
    # enc-dec (whisper)
    encoder_layers: int = 0
    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    # per-layer rematerialization: "none" | "full" — "full" wraps every
    # layer-scan body in jax.checkpoint so the backward pass stores only
    # scan carries (layer inputs), not stacked per-layer residuals
    remat: str = "none"
    # fully unroll layer/chunk scans (dry-run COST compiles only): XLA's
    # cost analysis counts while-loop bodies ONCE, so rolled scans
    # undercount flops/bytes/collectives by the trip count
    scan_unroll: bool = False
    # mesh axes the activation batch dim is sharded over; when non-empty,
    # layer bodies emit with_sharding_constraint on their (B,S,D)
    # activations — remat/scan boundary tensors otherwise lose their
    # sharding and GSPMD resolves them replicated (found in the dry-run)
    batch_axes: tuple = ()
    # sequence parallelism (Korthikanti et al.): shard the SEQ dim of
    # layer-boundary activations over these axes — for deep/wide models
    # the per-microbatch stacked scan carries (L,B,S,D) otherwise exceed
    # HBM (llama3-405b: 15.8 GiB/device of carries at 1 seq/device)
    seq_axes: tuple = ()
    tie_embeddings: bool = False
    logit_softcap: float = 0.0

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to 256 so vocab-sharded params divide evenly
        on any mesh factorization (Megatron-style padding; pad ids are
        never targets)."""
        return -(-self.vocab // 256) * 256

    @property
    def is_subquadratic(self) -> bool:
        """Supports 500k-token decode: recurrent state or bounded window."""
        return (self.family in ("ssm", "hybrid")
                or self.window is not None)

    @property
    def activation_dtype(self) -> jnp.dtype:
        return jnp.dtype(self.dtype)

    def param_count(self) -> int:
        """Exact count from the abstract parameter tree."""
        tree = abstract_params_for(self)
        return int(sum(math.prod(l.shape) for l in jax.tree.leaves(tree)))

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        total = self.param_count()
        if not self.moe_experts:
            return total
        expert = 3 * self.d_model * self.d_ff  # gate/up/down per expert
        inactive = (self.moe_experts - self.moe_top_k) * expert * self.n_layers
        return total - inactive


# late import hook — zoo registers the builder to avoid circular imports
_ABSTRACT_BUILDERS: dict[str, Any] = {}


def register_family(family: str, abstract_fn) -> None:
    _ABSTRACT_BUILDERS[family] = abstract_fn


def abstract_params_for(cfg: ModelConfig):
    from repro.models import zoo  # noqa: F401  (ensures registration)
    return _ABSTRACT_BUILDERS[cfg.family](cfg)


# ---------------------------------------------------------------------------
# Logical sharding axes
# ---------------------------------------------------------------------------

# Canonical logical axis names used by every model family:
#   "embed"   d_model dim            "ff"     mlp hidden dim
#   "heads"   q-head dim             "kv"     kv-head dim
#   "vocab"   vocabulary dim         "expert" MoE expert dim
#   "layers"  stacked scan dim       None     replicated
LOGICAL_AXES = ("embed", "ff", "heads", "kv", "vocab", "expert", "layers",
                "conv", "state", "table_d")


@dataclasses.dataclass
class ShardingPlan:
    """logical axis -> tuple of mesh axes.  THE tunable object: the
    shard-degree autotuner rewrites entries (e.g. 'ff' -> ('model',) at
    degree 16, or 'ff' -> () at degree 1).

    ``batch_axes``/``seq_axes`` control activation shardings."""

    rules: dict[str, tuple[str, ...]]
    batch_axes: tuple[str, ...] = ("data",)
    seq_axes: tuple[str, ...] = ()

    def spec_for(self, logical: tuple[str | None, ...]) -> P:
        parts = []
        used: set[str] = set()
        for name in logical:
            axes = self.rules.get(name, ()) if name else ()
            # a mesh axis may appear at most once per spec: first
            # occurrence wins (e.g. MoE (expert, embed, ff) keeps expert
            # parallelism on the model axis and leaves ff unsharded;
            # rwkv (embed, embed) square weights shard one dim)
            axes = tuple(a for a in axes if a not in used)
            used.update(axes)
            if len(axes) == 0:
                parts.append(None)
            elif len(axes) == 1:
                parts.append(axes[0])
            else:
                parts.append(tuple(axes))
        return P(*parts)

    def tree_specs(self, logical_tree: Specs) -> Any:
        return jax.tree.map(
            self.spec_for, logical_tree,
            is_leaf=lambda x: isinstance(x, tuple)
            and all(isinstance(e, (str, type(None))) for e in x))


def default_plan() -> ShardingPlan:
    """Paper-faithful baseline: uniform max shard degree on the model axis
    for every op class (the analogue of TF's 'one intra-op parallelism for
    all operations'), FSDP on the data axis over the embed dim."""
    return ShardingPlan(rules={
        "embed": ("data",),       # FSDP: gather at use
        "ff": ("model",),
        "heads": ("model",),
        "kv": ("model",),
        "vocab": ("model",),      # unembed projection (matmul, shards cleanly)
        "expert": ("model",),
        "layers": (),
        "conv": (),
        "state": (),
        # input embedding TABLE: clean 1-D vocab sharding — GSPMD then
        # partitions the token gather as masked-gather + all-reduce (the
        # Megatron pattern) and the tied unembed keeps logits
        # vocab-sharded.  (A 2-D-sharded table triggered XLA involuntary
        # full rematerialization; found in the first dry-run.)
        "table_d": (),
    })


def replicated_plan() -> ShardingPlan:
    return ShardingPlan(rules={k: () for k in LOGICAL_AXES},
                        batch_axes=(), seq_axes=())


# ---------------------------------------------------------------------------
# Param tree construction helpers
# ---------------------------------------------------------------------------

class TreeBuilder:
    """Collects (params, logical_specs) pairs with optional abstract mode."""

    def __init__(self, cfg: ModelConfig, key: jax.Array | None,
                 abstract: bool = False):
        self.cfg = cfg
        self.abstract = abstract
        self._key = key
        self.params: dict = {}
        self.specs: dict = {}

    def _next_key(self) -> jax.Array:
        assert self._key is not None
        self._key, sub = jax.random.split(self._key)
        return sub

    def leaf(self, path: str, shape: tuple[int, ...],
             logical: tuple[str | None, ...], *,
             init: str = "normal", scale: float | None = None):
        """Register one parameter array at a '/'-separated path."""
        assert len(shape) == len(logical), (path, shape, logical)
        dtype = jnp.dtype(self.cfg.param_dtype)
        if self.abstract:
            arr = jax.ShapeDtypeStruct(shape, dtype)
        elif init == "zeros":
            arr = jnp.zeros(shape, dtype)
        elif init == "ones":
            arr = jnp.ones(shape, dtype)
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            s = scale if scale is not None else 1.0 / math.sqrt(fan_in)
            arr = (jax.random.normal(self._next_key(), shape, jnp.float32)
                   * s).astype(dtype)
        node, snode = self.params, self.specs
        parts = path.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
            snode = snode.setdefault(p, {})
        node[parts[-1]] = arr
        snode[parts[-1]] = tuple(logical)

    def build(self) -> tuple[Params, Specs]:
        return self.params, self.specs


def tree_bytes(tree: Params) -> int:
    return sum(math.prod(l.shape) * np.dtype(l.dtype).itemsize
               for l in jax.tree.leaves(tree))


def cast_tree(tree: Params, dtype) -> Params:
    return jax.tree.map(lambda x: x.astype(dtype), tree)
