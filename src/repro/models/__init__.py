from repro.models.common import (ModelConfig, ShardingPlan, default_plan,
                                 replicated_plan, TreeBuilder, tree_bytes,
                                 cast_tree)

__all__ = ["ModelConfig", "ShardingPlan", "default_plan", "replicated_plan",
           "TreeBuilder", "tree_bytes", "cast_tree"]
