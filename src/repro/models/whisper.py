"""Whisper-small-style encoder-decoder (arXiv:2212.04356).

Per the task spec, only the transformer BACKBONE is modeled; the conv
frontend is a STUB — ``input_specs`` supplies precomputed frame embeddings
(B, n_frontend_tokens=1500, d_model) standing in for the mel->conv stack.

Encoder: bidirectional attention, sinusoidal positions, LayerNorm + GELU
MLP.  Decoder: causal self-attn + cross-attn over encoder output, learned
positions.  Serving caches decoder self-attn KV plus the precomputed
cross KV per layer; the encoder runs once at prefill.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.common import ModelConfig, TreeBuilder


def _attn_leaves(tb, prefix, n, cfg, kv=True):
    d, hd = cfg.d_model, cfg.hd
    tb.leaf(f"{prefix}/wq", (n, d, cfg.n_heads * hd),
            ("layers", "embed", "heads"))
    if kv:
        tb.leaf(f"{prefix}/wk", (n, d, cfg.n_kv_heads * hd),
                ("layers", "embed", "kv"))
        tb.leaf(f"{prefix}/wv", (n, d, cfg.n_kv_heads * hd),
                ("layers", "embed", "kv"))
    tb.leaf(f"{prefix}/wo", (n, cfg.n_heads * hd, d),
            ("layers", "heads", "embed"))


def _mlp_leaves(tb, prefix, n, cfg):
    d = cfg.d_model
    tb.leaf(f"{prefix}/w_up", (n, d, cfg.d_ff), ("layers", "embed", "ff"))
    tb.leaf(f"{prefix}/w_down", (n, cfg.d_ff, d), ("layers", "ff", "embed"))


def _build(cfg: ModelConfig, key, abstract: bool):
    tb = TreeBuilder(cfg, key, abstract=abstract)
    d = cfg.d_model
    ne, nd = cfg.encoder_layers, cfg.n_layers
    tb.leaf("embed/table", (cfg.padded_vocab, d), ("vocab", "table_d"), scale=0.02)
    tb.leaf("pos_embed", (4096, d), (None, "embed"), scale=0.01)

    # encoder
    tb.leaf("enc/attn_norm", (ne, d), ("layers", None), init="ones")
    tb.leaf("enc/mlp_norm", (ne, d), ("layers", None), init="ones")
    _attn_leaves(tb, "enc", ne, cfg)
    _mlp_leaves(tb, "enc", ne, cfg)
    tb.leaf("enc_final_norm", (d,), (None,), init="ones")

    # decoder: self + cross
    tb.leaf("dec/self_norm", (nd, d), ("layers", None), init="ones")
    tb.leaf("dec/cross_norm", (nd, d), ("layers", None), init="ones")
    tb.leaf("dec/mlp_norm", (nd, d), ("layers", None), init="ones")
    _attn_leaves(tb, "dec/self", nd, cfg)
    _attn_leaves(tb, "dec/cross", nd, cfg)
    _mlp_leaves(tb, "dec", nd, cfg)
    tb.leaf("final_norm", (d,), (None,), init="ones")
    return tb.build()


def init(cfg, key):
    return _build(cfg, key, abstract=False)[0]


def abstract(cfg):
    return _build(cfg, None, abstract=True)[0]


def specs(cfg):
    return _build(cfg, None, abstract=True)[1]


# ---------------------------------------------------------------------------

def _proj_heads(x, w, b, s, nh, hd):
    return jnp.einsum("bsd,dh->bsh", x, w.astype(x.dtype)
                      ).reshape(b, s, nh, hd)


def _mha(cfg, lp, xq, xkv, causal):
    dt = xq.dtype
    b, sq, _ = xq.shape
    sk = xkv.shape[1]
    hd = cfg.hd
    q = _proj_heads(xq, lp["wq"], b, sq, cfg.n_heads, hd)
    k = _proj_heads(xkv, lp["wk"], b, sk, cfg.n_kv_heads, hd)
    v = _proj_heads(xkv, lp["wv"], b, sk, cfg.n_kv_heads, hd)
    o = L.attention(q, k, v, causal=causal, unroll=cfg.scan_unroll)
    return jnp.einsum("bsh,hd->bsd", o.reshape(b, sq, cfg.n_heads * hd),
                      lp["wo"].astype(dt)), k, v


def encode(cfg: ModelConfig, params: dict, frames: jax.Array) -> jax.Array:
    """frames: (B, T, d) stub embeddings -> encoder states (B,T,d)."""
    dt = cfg.activation_dtype
    s = frames.shape[1]
    pos = jnp.arange(s)
    half = cfg.d_model // 2
    freqs = jnp.exp(-jnp.arange(half) / (half - 1) * jnp.log(10000.0))
    ang = pos[:, None] * freqs[None]
    sinusoid = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)
    x = frames.astype(dt) + sinusoid[None].astype(dt)

    def body(carry, lp):
        y = L.constrain_batch(carry, cfg.batch_axes, cfg.seq_axes)
        h = L.layer_norm(y, lp["attn_norm"], None)
        o, _, _ = _mha(cfg, lp, h, h, causal=False)
        y = y + o
        h2 = L.layer_norm(y, lp["mlp_norm"], None)
        y = y + L.mlp_gelu(lp, h2)
        return y, ()

    x, _ = jax.lax.scan(L.maybe_remat(body, cfg.remat), x, params["enc"],
                        unroll=cfg.scan_unroll)
    return L.layer_norm(x, params["enc_final_norm"], None)


def _dec_layer(cfg, lp, x, enc, cos_sin=None):
    x = L.constrain_batch(x, cfg.batch_axes, cfg.seq_axes)
    h = L.layer_norm(x, lp["self_norm"], None)
    o, k, v = _mha(cfg, lp["self"], h, h, causal=True)
    x = x + o
    h2 = L.layer_norm(x, lp["cross_norm"], None)
    oc, xk, xv = _mha(cfg, lp["cross"], h2, enc, causal=False)
    x = x + oc
    h3 = L.layer_norm(x, lp["mlp_norm"], None)
    x = x + L.mlp_gelu(lp, h3)
    return x, (k, v, xk, xv)


def forward(cfg: ModelConfig, params: dict, batch: dict):
    """batch: tokens (B,S_dec) + frontend (B, T_enc, d)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    dt = cfg.activation_dtype
    enc = encode(cfg, params, batch["frontend"])
    x = params["embed"]["table"].astype(dt)[tokens]
    npos = params["pos_embed"].shape[0]
    x = x + params["pos_embed"].astype(dt)[jnp.arange(s) % npos]

    def body(carry, lp):
        y, _ = _dec_layer(cfg, lp, carry, enc)
        return y, ()

    x, _ = jax.lax.scan(L.maybe_remat(body, cfg.remat), x, params["dec"],
                        unroll=cfg.scan_unroll)
    x = L.layer_norm(x, params["final_norm"], None)
    logits = jnp.einsum("bsd,vd->bsv", x,
                        params["embed"]["table"].astype(dt))  # tied unembed
    return logits, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def cache_max_len(cfg: ModelConfig, seq_len: int) -> int:
    return seq_len


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    dt = cfg.activation_dtype
    nd = cfg.n_layers
    kv = (nd, max_len, batch, cfg.n_kv_heads, cfg.hd)
    xkv = (nd, cfg.n_frontend_tokens, batch, cfg.n_kv_heads, cfg.hd)
    return {"k": jax.ShapeDtypeStruct(kv, dt),
            "v": jax.ShapeDtypeStruct(kv, dt),
            "xk": jax.ShapeDtypeStruct(xkv, dt),
            "xv": jax.ShapeDtypeStruct(xkv, dt),
            "len": jax.ShapeDtypeStruct((), jnp.int32)}


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        abstract_cache(cfg, batch, max_len))


def prefill(cfg: ModelConfig, params: dict, tokens: jax.Array,
            max_len: int, frontend: jax.Array | None = None):
    b, s = tokens.shape
    dt = cfg.activation_dtype
    frames = (frontend if frontend is not None else jnp.zeros(
        (b, cfg.n_frontend_tokens, cfg.d_model))).astype(dt)
    enc = encode(cfg, params, frames)
    x = params["embed"]["table"].astype(dt)[tokens]
    npos = params["pos_embed"].shape[0]
    x = x + params["pos_embed"].astype(dt)[jnp.arange(s) % npos]

    def body(carry, lp):
        y, (k, v, xk, xv) = _dec_layer(cfg, lp, carry, enc)
        return y, (jnp.swapaxes(k, 0, 1), jnp.swapaxes(v, 0, 1),
                   jnp.swapaxes(xk, 0, 1), jnp.swapaxes(xv, 0, 1))

    x, (kc, vc, xk, xv) = jax.lax.scan(body, x, params["dec"],
                                       unroll=cfg.scan_unroll)
    pad = max_len - s
    kc = jnp.pad(kc, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
    vc = jnp.pad(vc, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
    x = L.layer_norm(x, params["final_norm"], None)
    logits = jnp.einsum("bd,vd->bv", x[:, -1],
                        params["embed"]["table"].astype(dt))
    cache = {"k": kc, "v": vc, "xk": xk, "xv": xv,
             "len": jnp.asarray(s, jnp.int32)}
    return logits, cache


def decode_step(cfg: ModelConfig, params: dict, cache: dict,
                token: jax.Array, pos) -> tuple[jax.Array, dict]:
    b = token.shape[0]
    dt = cfg.activation_dtype
    hd = cfg.hd
    slot = cache["len"]
    x = params["embed"]["table"].astype(dt)[token][:, None]
    npos = params["pos_embed"].shape[0]
    x = x + params["pos_embed"].astype(dt)[jnp.asarray(pos) % npos][None, None]

    def body(carry, xs):
        x, = carry
        lp, kc, vc, xk, xv = xs
        h = L.layer_norm(x, lp["self_norm"], None)
        q = _proj_heads(h, lp["self"]["wq"], b, 1, cfg.n_heads, hd)
        k = _proj_heads(h, lp["self"]["wk"], b, 1, cfg.n_kv_heads, hd)
        v = _proj_heads(h, lp["self"]["wv"], b, 1, cfg.n_kv_heads, hd)
        kc = jax.lax.dynamic_update_slice(kc, jnp.swapaxes(k, 0, 1),
                                          (slot, 0, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, jnp.swapaxes(v, 0, 1),
                                          (slot, 0, 0, 0))
        o = L.decode_attention(q, jnp.swapaxes(kc, 0, 1),
                               jnp.swapaxes(vc, 0, 1), cache["len"] + 1)
        x = x + jnp.einsum("bsh,hd->bsd",
                           o.reshape(b, 1, cfg.n_heads * hd),
                           lp["self"]["wo"].astype(dt))
        h2 = L.layer_norm(x, lp["cross_norm"], None)
        q2 = _proj_heads(h2, lp["cross"]["wq"], b, 1, cfg.n_heads, hd)
        o2 = L.cross_attention(q2, jnp.swapaxes(xk, 0, 1),
                               jnp.swapaxes(xv, 0, 1))
        x = x + jnp.einsum("bsh,hd->bsd",
                           o2.reshape(b, 1, cfg.n_heads * hd),
                           lp["cross"]["wo"].astype(dt))
        h3 = L.layer_norm(x, lp["mlp_norm"], None)
        x = x + L.mlp_gelu(lp, h3)
        return (x,), (jnp.swapaxes(k, 0, 1)[0], jnp.swapaxes(v, 0, 1)[0])

    (x,), (k_new, v_new) = jax.lax.scan(
        body, (x,), (params["dec"], cache["k"], cache["v"],
                     cache["xk"], cache["xv"]), unroll=cfg.scan_unroll)
    new_cache = dict(cache)
    new_cache["k"] = jax.lax.dynamic_update_slice(
        cache["k"], k_new[:, None], (0, slot, 0, 0, 0))
    new_cache["v"] = jax.lax.dynamic_update_slice(
        cache["v"], v_new[:, None], (0, slot, 0, 0, 0))
    new_cache["len"] = cache["len"] + 1
    x = L.layer_norm(x[:, 0], params["final_norm"], None)
    logits = jnp.einsum("bd,vd->bv", x, params["embed"]["table"].astype(dt))
    return logits, new_cache
