"""Llama-3.2-Vision-style VLM backbone: a dense GQA decoder with
cross-attention layers interleaved every ``cross_attn_every`` self-attn
layers.  Per the task spec the vision frontend is a STUB — ``input_specs``
supplies precomputed patch embeddings (B, n_frontend_tokens, d_model);
this module consumes them through per-layer cross-attention (gated, as in
Llama 3.2).

40 layers with cross every 5 => 8 super-blocks of (4 self + 1 cross),
scanned two-level (outer supers, inner the 4 stacked self layers).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import transformer as T
from repro.models.common import ModelConfig, TreeBuilder


def layout(cfg: ModelConfig) -> tuple[int, int]:
    """(n_supers, self_per_super)."""
    per = cfg.cross_attn_every
    assert per > 1 and cfg.n_layers % per == 0
    return cfg.n_layers // per, per - 1


def _self_leaves(tb, prefix, shape_prefix, cfg):
    d, hd = cfg.d_model, cfg.hd
    sp = shape_prefix
    lead = tuple("layers" for _ in sp)
    tb.leaf(f"{prefix}/attn_norm", (*sp, d), (*lead, None), init="zeros")
    tb.leaf(f"{prefix}/mlp_norm", (*sp, d), (*lead, None), init="zeros")
    tb.leaf(f"{prefix}/wq", (*sp, d, cfg.n_heads * hd),
            (*lead, "embed", "heads"))
    tb.leaf(f"{prefix}/wk", (*sp, d, cfg.n_kv_heads * hd),
            (*lead, "embed", "kv"))
    tb.leaf(f"{prefix}/wv", (*sp, d, cfg.n_kv_heads * hd),
            (*lead, "embed", "kv"))
    tb.leaf(f"{prefix}/wo", (*sp, cfg.n_heads * hd, d),
            (*lead, "heads", "embed"))
    tb.leaf(f"{prefix}/w_gate", (*sp, d, cfg.d_ff), (*lead, "embed", "ff"))
    tb.leaf(f"{prefix}/w_up", (*sp, d, cfg.d_ff), (*lead, "embed", "ff"))
    tb.leaf(f"{prefix}/w_down", (*sp, cfg.d_ff, d), (*lead, "ff", "embed"))


def _build(cfg: ModelConfig, key, abstract: bool):
    tb = TreeBuilder(cfg, key, abstract=abstract)
    ns, sps = layout(cfg)
    d, hd = cfg.d_model, cfg.hd
    tb.leaf("embed/table", (cfg.padded_vocab, d), ("vocab", "table_d"), scale=0.02)
    _self_leaves(tb, "supers/self", (ns, sps), cfg)
    # gated cross-attention layers (one per super)
    tb.leaf("supers/cross/norm", (ns, d), ("layers", None), init="zeros")
    tb.leaf("supers/cross/wq", (ns, d, cfg.n_heads * hd),
            ("layers", "embed", "heads"))
    tb.leaf("supers/cross/wk", (ns, d, cfg.n_kv_heads * hd),
            ("layers", "embed", "kv"))
    tb.leaf("supers/cross/wv", (ns, d, cfg.n_kv_heads * hd),
            ("layers", "embed", "kv"))
    tb.leaf("supers/cross/wo", (ns, cfg.n_heads * hd, d),
            ("layers", "heads", "embed"))
    tb.leaf("supers/cross/gate_attn", (ns,), ("layers",), init="zeros")
    tb.leaf("supers/cross/gate_mlp", (ns,), ("layers",), init="zeros")
    tb.leaf("supers/cross/mlp_norm", (ns, d), ("layers", None), init="zeros")
    tb.leaf("supers/cross/w_gate", (ns, d, cfg.d_ff),
            ("layers", "embed", "ff"))
    tb.leaf("supers/cross/w_up", (ns, d, cfg.d_ff), ("layers", "embed", "ff"))
    tb.leaf("supers/cross/w_down", (ns, cfg.d_ff, d),
            ("layers", "ff", "embed"))
    tb.leaf("final_norm", (d,), (None,), init="zeros")
    tb.leaf("unembed", (d, cfg.padded_vocab), ("embed", "vocab"), scale=0.02)
    return tb.build()


def init(cfg, key):
    return _build(cfg, key, abstract=False)[0]


def abstract(cfg):
    return _build(cfg, None, abstract=True)[0]


def specs(cfg):
    return _build(cfg, None, abstract=True)[1]


# ---------------------------------------------------------------------------

def _cross_block(cfg, lp, x, img_k, img_v):
    """Gated cross-attn + gated MLP (Llama-3.2 style). img_k/v: (B,T,K,hd)."""
    x = L.constrain_batch(x, cfg.batch_axes, cfg.seq_axes)
    dt = x.dtype
    b, s, d = x.shape
    hd = cfg.hd
    h = L.rms_norm(x, lp["norm"])
    q = jnp.einsum("bsd,dh->bsh", h, lp["wq"].astype(dt)
                   ).reshape(b, s, cfg.n_heads, hd)
    o = L.cross_attention(q, img_k, img_v)
    o = jnp.einsum("bsh,hd->bsd", o.reshape(b, s, cfg.n_heads * hd),
                   lp["wo"].astype(dt))
    x = x + jnp.tanh(lp["gate_attn"].astype(jnp.float32)).astype(dt) * o
    h2 = L.rms_norm(x, lp["mlp_norm"])
    m = L.mlp_swiglu(lp, h2)
    x = x + jnp.tanh(lp["gate_mlp"].astype(jnp.float32)).astype(dt) * m
    return x


def _img_kv(cfg, lp, img):
    """Project frontend embeddings to per-layer cross k/v."""
    dt = img.dtype
    b, t, _ = img.shape
    k = jnp.einsum("btd,dh->bth", img, lp["wk"].astype(dt)
                   ).reshape(b, t, cfg.n_kv_heads, cfg.hd)
    v = jnp.einsum("btd,dh->bth", img, lp["wv"].astype(dt)
                   ).reshape(b, t, cfg.n_kv_heads, cfg.hd)
    return k, v


def forward(cfg: ModelConfig, params: dict, batch: dict):
    """batch: tokens (B,S) + frontend (B, T_img, d_model)."""
    tokens = batch["tokens"]
    img = batch["frontend"].astype(cfg.activation_dtype)
    b, s = tokens.shape
    dt = cfg.activation_dtype
    x = params["embed"]["table"].astype(dt)[tokens]
    cos, sin = L.rope_angles(jnp.arange(s), cfg.hd, cfg.rope_theta)

    def super_body(carry, lp):
        y = carry

        def self_body(c, slp):
            z, _ = T._layer(cfg, slp, c, cos, sin)
            return z, ()

        y, _ = jax.lax.scan(self_body, y, lp["self"],
                            unroll=cfg.scan_unroll)
        ik, iv = _img_kv(cfg, lp["cross"], img)
        y = _cross_block(cfg, lp["cross"], y, ik, iv)
        return y, ()

    x, _ = jax.lax.scan(L.maybe_remat(super_body, cfg.remat), x,
                        params["supers"], unroll=cfg.scan_unroll)
    x = L.rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"].astype(dt))
    return logits, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def cache_max_len(cfg: ModelConfig, seq_len: int) -> int:
    return seq_len


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    ns, sps = layout(cfg)
    dt = cfg.activation_dtype
    kv = (ns, sps, max_len, batch, cfg.n_kv_heads, cfg.hd)
    xkv = (ns, batch, cfg.n_frontend_tokens, cfg.n_kv_heads, cfg.hd)
    return {"k": jax.ShapeDtypeStruct(kv, dt),
            "v": jax.ShapeDtypeStruct(kv, dt),
            "xk": jax.ShapeDtypeStruct(xkv, dt),
            "xv": jax.ShapeDtypeStruct(xkv, dt),
            "len": jax.ShapeDtypeStruct((), jnp.int32)}


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        abstract_cache(cfg, batch, max_len))


def prefill(cfg: ModelConfig, params: dict, tokens: jax.Array,
            max_len: int, frontend: jax.Array | None = None):
    b, s = tokens.shape
    dt = cfg.activation_dtype
    img = (frontend if frontend is not None else jnp.zeros(
        (b, cfg.n_frontend_tokens, cfg.d_model))).astype(dt)
    x = params["embed"]["table"].astype(dt)[tokens]
    cos, sin = L.rope_angles(jnp.arange(s), cfg.hd, cfg.rope_theta)

    def super_body(carry, lp):
        y = carry

        def self_body(c, slp):
            z, (k, v, _) = T._layer(cfg, slp, c, cos, sin)
            return z, (jnp.swapaxes(k, 0, 1), jnp.swapaxes(v, 0, 1))

        y, (ks, vs) = jax.lax.scan(self_body, y, lp["self"],
                                   unroll=cfg.scan_unroll)
        ik, iv = _img_kv(cfg, lp["cross"], img)
        y = _cross_block(cfg, lp["cross"], y, ik, iv)
        return y, (ks, vs, ik, iv)

    x, (kc, vc, xk, xv) = jax.lax.scan(super_body, x, params["supers"],
                                       unroll=cfg.scan_unroll)
    pad = max_len - s
    kc = jnp.pad(kc, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
    vc = jnp.pad(vc, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
    x = L.rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bd,dv->bv", x[:, -1], params["unembed"].astype(dt))
    cache = {"k": kc, "v": vc, "xk": xk, "xv": xv,
             "len": jnp.asarray(s, jnp.int32)}
    return logits, cache


def decode_step(cfg: ModelConfig, params: dict, cache: dict,
                token: jax.Array, pos) -> tuple[jax.Array, dict]:
    b = token.shape[0]
    dt = cfg.activation_dtype
    hd = cfg.hd
    slot = cache["len"]
    x = params["embed"]["table"].astype(dt)[token][:, None]
    cos, sin = L.rope_angles(jnp.asarray(pos).reshape(1), cfg.hd,
                             cfg.rope_theta)

    def super_body(carry, xs):
        x, = carry
        lp, kc_s, vc_s, xk, xv = xs        # kc_s: (sps, S, B, K, hd)

        def self_body(c, xs2):
            z, = c
            slp, kc, vc = xs2
            h = L.rms_norm(z, slp["attn_norm"])
            q = jnp.einsum("bsd,dh->bsh", h, slp["wq"].astype(dt)
                           ).reshape(b, 1, cfg.n_heads, hd)
            k = jnp.einsum("bsd,dh->bsh", h, slp["wk"].astype(dt)
                           ).reshape(b, 1, cfg.n_kv_heads, hd)
            v = jnp.einsum("bsd,dh->bsh", h, slp["wv"].astype(dt)
                           ).reshape(b, 1, cfg.n_kv_heads, hd)
            q = L.apply_rope(q, cos[None], sin[None])
            k = L.apply_rope(k, cos[None], sin[None])
            kc = jax.lax.dynamic_update_slice(kc, jnp.swapaxes(k, 0, 1),
                                              (slot, 0, 0, 0))
            vc = jax.lax.dynamic_update_slice(vc, jnp.swapaxes(v, 0, 1),
                                              (slot, 0, 0, 0))
            o = L.decode_attention(q, jnp.swapaxes(kc, 0, 1),
                                   jnp.swapaxes(vc, 0, 1), cache["len"] + 1)
            o = jnp.einsum("bsh,hd->bsd",
                           o.reshape(b, 1, cfg.n_heads * hd),
                           slp["wo"].astype(dt))
            z = z + o
            h2 = L.rms_norm(z, slp["mlp_norm"])
            z = z + L.mlp_swiglu(slp, h2)
            return (z,), (jnp.swapaxes(k, 0, 1)[0], jnp.swapaxes(v, 0, 1)[0])

        (x,), (k_new, v_new) = jax.lax.scan(
            self_body, (x,), (lp["self"], kc_s, vc_s),
            unroll=cfg.scan_unroll)
        x = _cross_block(cfg, lp["cross"], x, xk, xv)
        return (x,), (k_new, v_new)

    (x,), (k_new, v_new) = jax.lax.scan(
        super_body, (x,),
        (params["supers"], cache["k"], cache["v"], cache["xk"], cache["xv"]),
        unroll=cfg.scan_unroll)
    new_cache = dict(cache)
    new_cache["k"] = jax.lax.dynamic_update_slice(
        cache["k"], k_new[:, :, None], (0, 0, slot, 0, 0, 0))
    new_cache["v"] = jax.lax.dynamic_update_slice(
        cache["v"], v_new[:, :, None], (0, 0, slot, 0, 0, 0))
    new_cache["len"] = cache["len"] + 1
    x = L.rms_norm(x[:, 0], params["final_norm"])
    logits = jnp.einsum("bd,dv->bv", x, params["unembed"].astype(dt))
    return logits, new_cache
