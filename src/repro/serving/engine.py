"""Batched serving engine: static-wave batching over a fixed slot set.

Requests are queued, then served in WAVES of up to ``n_slots``: one
batched prefill (prompts LEFT-padded to the wave's max prompt length, so
every slot's final prompt token sits at the right edge and the wave's
lock-step decode positions stay contiguous), then lock-step decode until
every slot hits EOS/max_new_tokens.  Slots that finish early idle until
the wave completes — the engine reports the wasted-slot fraction so the
serving benchmarks can quantify it (this is the static-batching baseline
that paged/continuous batching systems improve on; the simplification vs
vLLM is deliberate and documented).

Left-padding alone is NOT exact for shorter prompts: the models' causal
attention has no pad mask, so the pad tokens in front would leak into a
short prompt's logits (and shift its RoPE positions).  ``_run_wave``
therefore re-runs one exact, unpadded prefill per distinct shorter
prompt length — small batches at small sequence lengths — and takes each
short request's first token from that, so prefill outputs match the
unpadded single-request run bit-for-bit (locked by
tests/test_substrate.py).  Decode for shorter slots still attends to the
wave cache's pad positions — the documented static-batching
approximation; positions are homogeneous within a wave, matching the
models' scalar cache["len"] semantics.  Correctness of prefill+decode
against the full forward pass is covered by tests/test_models_smoke.py.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import GraphBuilder, OpGraph
from repro.models import zoo
from repro.models.common import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (S,) int32
    max_new_tokens: int = 32
    eos_id: int | None = None
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class WaveStats:
    n_requests: int
    prompt_len: int
    decode_steps: int
    slot_token_capacity: int         # n_slots * decode_steps
    useful_tokens: int
    wall_s: float

    @property
    def slot_utilization(self) -> float:
        return self.useful_tokens / max(self.slot_token_capacity, 1)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params: dict, *, n_slots: int,
                 max_len: int, pad_id: int = 0):
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.pad_id = pad_id
        self.queue: deque[Request] = deque()
        self.finished: list[Request] = []
        self.stats: list[WaveStats] = []
        self._decode = jax.jit(
            lambda p, c, t, pos: zoo.decode_step(cfg, p, c, t, pos))

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    # ------------------------------------------------------------------
    def _batch_for(self, prompts: np.ndarray) -> dict:
        batch = {"tokens": jnp.asarray(prompts)}
        if zoo.needs_frontend(self.cfg):
            batch["frontend"] = jnp.zeros(
                (prompts.shape[0], self.cfg.n_frontend_tokens,
                 self.cfg.d_model), self.cfg.activation_dtype)
        return batch

    def _exact_short_logits(self, wave: list[Request], plen: int,
                            tokens: np.ndarray) -> None:
        """Overwrite ``tokens[i]`` for every request shorter than ``plen``
        with the argmax of an exact unpadded prefill.

        The batched wave prefill left-pads, and the models' causal
        attention has no pad mask, so a short prompt's last-token logits
        would see the leading pads (and RoPE positions shifted by the pad
        count).  One extra prefill per distinct shorter length — a small
        batch at a small sequence length — makes every request's first
        generated token identical to its unpadded solo run."""
        by_len: dict[int, list[int]] = {}
        for i, r in enumerate(wave):
            # zero-token requests never emit the corrected token, so an
            # exact re-prefill for them would be a wasted forward pass
            if len(r.prompt) < plen and r.max_new_tokens > 0:
                by_len.setdefault(len(r.prompt), []).append(i)
        for length, slots in by_len.items():
            sub = np.stack([wave[i].prompt for i in slots]).astype(np.int32)
            # only the logits are kept, so size the (discarded) cache for
            # this sub-batch's own length, not the wave's decode budget
            logits, _ = zoo.prefill(self.cfg, self.params,
                                    self._batch_for(sub),
                                    zoo.cache_max_len(self.cfg, length))
            exact = np.asarray(jnp.argmax(logits, axis=-1))
            for j, i in enumerate(slots):
                tokens[i] = exact[j]

    def _run_wave(self, wave: list[Request]) -> None:
        t0 = time.perf_counter()
        plen = max(len(r.prompt) for r in wave)
        prompts = np.full((self.n_slots, plen), self.pad_id, np.int32)
        for i, r in enumerate(wave):
            prompts[i, plen - len(r.prompt):] = r.prompt   # left-pad
        max_new = max(r.max_new_tokens for r in wave)
        cache_len = zoo.cache_max_len(
            self.cfg, min(self.max_len, plen + max_new))
        logits, cache = zoo.prefill(self.cfg, self.params,
                                    self._batch_for(prompts), cache_len)
        tokens = np.array(jnp.argmax(logits, axis=-1))   # writable copy
        self._exact_short_logits(wave, plen, tokens)
        for i, r in enumerate(wave):
            if r.max_new_tokens <= 0:
                # a request for 0 tokens gets 0 tokens — the prefill-
                # produced token must not be appended
                r.done = True
                continue
            r.output.append(int(tokens[i]))
            if r.eos_id is not None and r.output[-1] == r.eos_id:
                r.done = True

        steps = 0
        useful = sum(1 for r in wave if r.max_new_tokens > 0)
        pos = plen
        while steps < max_new - 1 and not all(
                r.done or len(r.output) >= r.max_new_tokens for r in wave):
            logits, cache = self._decode(self.params, cache,
                                         jnp.asarray(tokens),
                                         jnp.asarray(pos))
            tokens = np.asarray(jnp.argmax(logits, axis=-1))
            for i, r in enumerate(wave):
                if r.done or len(r.output) >= r.max_new_tokens:
                    continue
                r.output.append(int(tokens[i]))
                useful += 1
                if r.eos_id is not None and r.output[-1] == r.eos_id:
                    r.done = True
            steps += 1
            pos += 1

        for r in wave:
            r.done = True
            self.finished.append(r)
        # the prefill-produced token counts as one generation step — unless
        # the whole wave asked for 0 tokens, in which case no slot capacity
        # was spent generating at all
        gen_steps = steps + (1 if max_new > 0 else 0)
        self.stats.append(WaveStats(
            n_requests=len(wave), prompt_len=plen, decode_steps=gen_steps,
            slot_token_capacity=self.n_slots * gen_steps,
            useful_tokens=useful, wall_s=time.perf_counter() - t0))

    # ------------------------------------------------------------------
    def run(self) -> list[Request]:
        while self.queue:
            wave = [self.queue.popleft()
                    for _ in range(min(self.n_slots, len(self.queue)))]
            self._run_wave(wave)
        return self.finished

    @property
    def mean_slot_utilization(self) -> float:
        if not self.stats:
            return 0.0
        return sum(w.slot_utilization for w in self.stats) / len(self.stats)

    # ---- multi-tenant pool integration --------------------------------
    def pending_waves(self) -> list[list[Request]]:
        """The wave partition ``run()`` would execute, without consuming
        the queue — the unit a runtime pool schedules as one job."""
        reqs = list(self.queue)
        return [reqs[i:i + self.n_slots]
                for i in range(0, len(reqs), self.n_slots)]

    def submit_waves_to_pool(self, pool, *, priority: float = 1.0,
                             arrival_gap: float = 0.0,
                             latency_target: float | None = None) -> list:
        """Submit every pending wave to a ``repro.multitenant.RuntimePool``
        as one job each (wave i arrives at ``i * arrival_gap``), so serving
        waves co-schedule against training steps and other tenants on the
        shared machine.  ``latency_target`` maps the serving SLO onto pool
        deadlines — each wave's deadline is its arrival time plus the
        target, which is what arms the pool's slack-aware ordering and
        (when enabled) deadline-driven preemption for these jobs.  Returns
        the created jobs; the engine's real-JAX queue is left untouched."""
        from repro.service.spec import ATTACHED_GRAPH, JobSpec, submit_spec
        jobs = []
        for i, wave in enumerate(self.pending_waves()):
            g = wave_op_graph(self.cfg, wave, n_slots=self.n_slots,
                              name=f"{self.cfg.arch_id}-wave{i}")
            # same wire schema as the CLI and the daemon inbox; the
            # wave's graph only exists in-process, so it rides along as
            # an attached graph rather than a rebuildable workload name
            spec = JobSpec(workload=ATTACHED_GRAPH, name=g.name,
                           priority=priority,
                           submit_time=i * arrival_gap,
                           latency_budget=latency_target)
            jobs.append(submit_spec(pool, spec, graph=g))
        return jobs


def wave_op_graph(cfg: ModelConfig, wave: list[Request], *,
                  n_slots: int | None = None,
                  name: str | None = None) -> OpGraph:
    """Analytic op graph of one serving wave (batched prefill + lock-step
    decode), in the same IR the paper's runtime schedules.

    Per-layer prefill ops carry the wave's (n_requests, prompt_len) token
    block; decode is one small op per lock-step token.  Flops/bytes use
    the standard transformer estimates (attn 8*d^2 + mlp ~6*d*d_ff per
    token-layer), so the pool's perfmodel sees prefill as big tunable ops
    and decode as the Strategy-4 "small op" population — exactly the mix
    that benefits from co-scheduling against a training tenant.

    ``n_slots``: the engine computes full n_slots-row batches even for a
    partial final wave (padding rows are real machine load), so cost
    terms use the padded batch when given."""
    if not wave:
        raise ValueError("wave_op_graph: empty wave (no requests)")
    n = max(len(wave), n_slots or 0)
    plen = max(len(r.prompt) for r in wave)
    # worst-case lock-step decode length, exactly as _run_wave bounds its
    # loop (max_len sizes the KV cache there, it does not cap the steps)
    max_new = max(r.max_new_tokens for r in wave)
    d = float(cfg.d_model)
    dff = float(cfg.d_ff)
    layer_params = 4 * d * d + 3 * d * dff      # attn qkvo + swiglu mlp
    b = GraphBuilder(name or f"{cfg.arch_id}-wave")
    tok = float(n * plen)
    prev = b.add("wave_embed", (n, plen, cfg.d_model),
                 flops=2 * tok * d, bytes_moved=tok * d * 4,
                 parallel_fraction=0.85, tunable=False)
    for li in range(cfg.n_layers):
        attn = b.add("wave_prefill_attn", (n, plen, cfg.d_model),
                     deps=[prev],
                     flops=tok * (8 * d * d) + 4 * tok * plen * d,
                     bytes_moved=tok * d * 8,
                     parallel_fraction=0.97,
                     name=f"wave_prefill_attn/{li}")
        prev = b.add("wave_prefill_mlp", (n, plen, cfg.d_model),
                     deps=[attn],
                     flops=tok * 6 * d * dff, bytes_moved=tok * d * 6,
                     parallel_fraction=0.98,
                     name=f"wave_prefill_mlp/{li}")
    # the prefill logits produce the wave's first token (unembed once)…
    prev = b.add("wave_unembed", (n, 1, cfg.d_model), deps=[prev],
                 flops=2 * n * d * cfg.vocab, bytes_moved=n * d * 4,
                 parallel_fraction=0.95, tunable=False)
    # …then lock-step decode: each step touches every weight once for n
    # tokens INCLUDING the logits projection the engine runs per step —
    # bandwidth-bound small ops chained by the autoregressive dependency.
    # max_new - 1 steps, not max_new: the first generated token came from
    # prefill above (see ServeEngine._run_wave).
    step_params = cfg.n_layers * layer_params + d * cfg.vocab
    step_flops = 2.0 * n * step_params
    step_bytes = step_params * 2.0                 # stream weights (bf16)
    for s in range(max(max_new - 1, 0)):
        prev = b.add("wave_decode_step", (n, 1, cfg.d_model), deps=[prev],
                     flops=step_flops, bytes_moved=step_bytes,
                     working_set=step_bytes,
                     parallel_fraction=0.80,
                     name=f"wave_decode_step/{s}")
    return b.build()
