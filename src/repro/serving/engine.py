"""Batched serving engine: static-wave batching over a fixed slot set.

Requests are queued, then served in WAVES of up to ``n_slots``: one
batched prefill (prompts right-padded to the wave's max prompt length),
then lock-step decode until every slot hits EOS/max_new_tokens.  Slots
that finish early idle until the wave completes — the engine reports the
wasted-slot fraction so the serving benchmarks can quantify it (this is
the static-batching baseline that paged/continuous batching systems
improve on; the simplification vs vLLM is deliberate and documented).

Positions are homogeneous within a wave, matching the models' scalar
cache["len"] semantics; correctness of prefill+decode against the full
forward pass is covered by tests/test_models_smoke.py.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import zoo
from repro.models.common import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (S,) int32
    max_new_tokens: int = 32
    eos_id: int | None = None
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class WaveStats:
    n_requests: int
    prompt_len: int
    decode_steps: int
    slot_token_capacity: int         # n_slots * decode_steps
    useful_tokens: int
    wall_s: float

    @property
    def slot_utilization(self) -> float:
        return self.useful_tokens / max(self.slot_token_capacity, 1)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params: dict, *, n_slots: int,
                 max_len: int, pad_id: int = 0):
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.pad_id = pad_id
        self.queue: deque[Request] = deque()
        self.finished: list[Request] = []
        self.stats: list[WaveStats] = []
        self._decode = jax.jit(
            lambda p, c, t, pos: zoo.decode_step(cfg, p, c, t, pos))

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    # ------------------------------------------------------------------
    def _run_wave(self, wave: list[Request]) -> None:
        t0 = time.perf_counter()
        plen = max(len(r.prompt) for r in wave)
        prompts = np.full((self.n_slots, plen), self.pad_id, np.int32)
        for i, r in enumerate(wave):
            prompts[i, plen - len(r.prompt):] = r.prompt   # left-pad
        batch = {"tokens": jnp.asarray(prompts)}
        if zoo.needs_frontend(self.cfg):
            batch["frontend"] = jnp.zeros(
                (self.n_slots, self.cfg.n_frontend_tokens,
                 self.cfg.d_model), self.cfg.activation_dtype)
        cache_len = zoo.cache_max_len(
            self.cfg, min(self.max_len,
                          plen + max(r.max_new_tokens for r in wave)))
        logits, cache = zoo.prefill(self.cfg, self.params, batch, cache_len)
        tokens = np.asarray(jnp.argmax(logits, axis=-1))
        for i, r in enumerate(wave):
            r.output.append(int(tokens[i]))
            if r.eos_id is not None and r.output[-1] == r.eos_id:
                r.done = True

        steps = 0
        useful = len(wave)
        pos = plen
        max_new = max(r.max_new_tokens for r in wave)
        while steps < max_new - 1 and not all(
                r.done or len(r.output) >= r.max_new_tokens for r in wave):
            logits, cache = self._decode(self.params, cache,
                                         jnp.asarray(tokens),
                                         jnp.asarray(pos))
            tokens = np.asarray(jnp.argmax(logits, axis=-1))
            for i, r in enumerate(wave):
                if r.done or len(r.output) >= r.max_new_tokens:
                    continue
                r.output.append(int(tokens[i]))
                useful += 1
                if r.eos_id is not None and r.output[-1] == r.eos_id:
                    r.done = True
            steps += 1
            pos += 1

        for r in wave:
            r.done = True
            self.finished.append(r)
        self.stats.append(WaveStats(
            n_requests=len(wave), prompt_len=plen, decode_steps=steps + 1,
            slot_token_capacity=self.n_slots * (steps + 1),
            useful_tokens=useful, wall_s=time.perf_counter() - t0))

    # ------------------------------------------------------------------
    def run(self) -> list[Request]:
        while self.queue:
            wave = [self.queue.popleft()
                    for _ in range(min(self.n_slots, len(self.queue)))]
            self._run_wave(wave)
        return self.finished

    @property
    def mean_slot_utilization(self) -> float:
        if not self.stats:
            return 0.0
        return sum(w.slot_utilization for w in self.stats) / len(self.stats)
