from repro.serving.engine import ServeEngine, Request, WaveStats
from repro.serving.kvcache import kv_cache_pspec, cache_shardings
__all__ = ["ServeEngine", "Request", "WaveStats", "kv_cache_pspec",
           "cache_shardings"]
