from repro.serving.engine import ServeEngine, Request, WaveStats, wave_op_graph
from repro.serving.kvcache import kv_cache_pspec, cache_shardings
__all__ = ["ServeEngine", "Request", "WaveStats", "wave_op_graph",
           "kv_cache_pspec", "cache_shardings"]
