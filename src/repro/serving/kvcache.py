"""KV-cache sharding policy.

Cache layout is (L, S, B, K, hd) (transformer.py).  The policy mirrors
the paper's per-op concurrency idea applied to decode (DESIGN.md §6):

* batch always shards over the data axis;
* if kv-head count divides the model-axis degree budget, shard heads
  (classic TP decode);
* otherwise shard the SEQUENCE dim over the model axis — partial-softmax
  decode (flash-decoding): GSPMD turns the softmax reductions over the
  sharded S dim into local reductions + small all-reduces of the
  (max, sum, weighted-v) statistics.
"""

from __future__ import annotations

from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.common import ModelConfig, ShardingPlan

# cache keys holding (…, S, B, K, hd) attention caches
_KV_KEYS = ("k", "v", "xk", "xv")


def kv_cache_pspec(cfg: ModelConfig, plan: ShardingPlan, *,
                   model_degree: int, lead_dims: int = 1
                   ) -> tuple[P, str]:
    """PartitionSpec for a (*lead, S, B, K, hd) cache + strategy name."""
    batch = tuple(plan.batch_axes) or None
    if isinstance(batch, tuple) and len(batch) == 1:
        batch = batch[0]
    model_axes = plan.rules.get("kv", ())
    ma = (model_axes if len(model_axes) > 1
          else (model_axes[0] if model_axes else None))
    lead = [None] * lead_dims
    if ma is None or model_degree <= 1:
        return P(*lead, None, batch, None, None), "replicated-heads"
    if cfg.n_kv_heads % model_degree == 0:
        return P(*lead, None, batch, ma, None), "head-sharded"
    return (P(*lead, ma, batch, None, None),
            "sequence-sharded(flash-decode)")


def cache_shardings(cfg: ModelConfig, plan: ShardingPlan, mesh: Mesh,
                    cache_tree, *, model_degree: int):
    """NamedSharding tree for a cache pytree.

    Keys in _KV_KEYS get the kv policy (lead dims inferred from rank);
    recurrent / shift / conv states shard batch on their batch dim;
    scalars replicate.  Any spec whose sharded dim does not divide evenly
    (e.g. whisper's 1500-frame cross-kv at degree 16) falls back to a
    batch-only spec for that leaf."""
    batch = tuple(plan.batch_axes) or None
    if isinstance(batch, tuple) and len(batch) == 1:
        batch = batch[0]
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    strategy = kv_cache_pspec(cfg, plan, model_degree=model_degree)[1]

    def axis_size(part) -> int:
        if part is None:
            return 1
        if isinstance(part, tuple):
            n = 1
            for a in part:
                n *= sizes.get(a, 1)
            return n
        return sizes.get(part, 1)

    def divides(spec: P, shape) -> bool:
        for dim, part in zip(shape, tuple(spec)):
            n = axis_size(part)
            if n > 1 and dim % n:
                return False
        return True

    def walk(node):
        if not isinstance(node, dict):
            return node
        out = {}
        for key, val in node.items():
            if isinstance(val, dict):
                out[key] = walk(val)
                continue
            rank = len(val.shape)
            if key in _KV_KEYS and rank >= 5:
                spec = kv_cache_pspec(cfg, plan, model_degree=model_degree,
                                      lead_dims=rank - 4)[0]
                if not divides(spec, val.shape):
                    # batch-only fallback (batch dim is rank-3 from the end)
                    parts = [None] * rank
                    parts[rank - 3] = batch
                    spec = P(*parts)
            elif rank >= 2:
                # (L, B, ...) recurrent/shift/conv states: batch on dim 1
                spec = P(None, batch, *([None] * (rank - 2)))
                if not divides(spec, val.shape):
                    spec = P()
            else:
                spec = P()
            out[key] = NamedSharding(mesh, spec)
        return out

    return walk(cache_tree), strategy
