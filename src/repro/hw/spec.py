"""Hardware model constants and roofline arithmetic.

Two machines are modeled:

* ``TpuV5eSpec`` — the deployment TARGET. All roofline terms in
  EXPERIMENTS.md are derived against these constants (values fixed by the
  task spec: 197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI).

* ``KnlLikeSpec`` — a deterministic stand-in for the paper's Intel Knights
  Landing socket (68 cores, 34 tiles x 2 cores sharing 1 MB L2, 4 HW
  threads/core).  Used exclusively by ``core.simmachine`` to give the
  faithful op-graph reproduction a concrete cost oracle; never used for the
  TPU roofline numbers.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class TpuV5eSpec:
    """Per-chip TPU v5e numbers used for the three roofline terms."""

    name: str = "tpu_v5e"
    peak_bf16_flops: float = 197e12      # FLOP/s per chip (MXU)
    hbm_bandwidth: float = 819e9         # B/s per chip
    ici_link_bandwidth: float = 50e9     # B/s per ICI link (intra-pod)
    dci_link_bandwidth: float = 12.5e9   # B/s effective per pod-crossing link
    hbm_bytes: int = 16 * 1024**3        # 16 GiB HBM per chip
    vmem_bytes: int = 128 * 1024**2      # ~128 MiB VMEM (v5e ~ 48-128 MiB usable)
    mxu_tile: int = 128                  # systolic array native dim

    # ---- roofline terms (seconds) -------------------------------------
    def compute_time(self, flops_per_device: float) -> float:
        return flops_per_device / self.peak_bf16_flops

    def memory_time(self, bytes_per_device: float) -> float:
        return bytes_per_device / self.hbm_bandwidth

    def collective_time(self, ici_bytes_per_device: float,
                        dci_bytes_per_device: float = 0.0) -> float:
        return (ici_bytes_per_device / self.ici_link_bandwidth
                + dci_bytes_per_device / self.dci_link_bandwidth)

    def step_time(self, flops: float, bytes_: float, ici_bytes: float,
                  dci_bytes: float = 0.0, overlap: bool = True) -> float:
        """Roofline step-time estimate.

        ``overlap=True`` models perfectly overlapped compute/memory/comm
        (the bound is the max term); ``overlap=False`` is the pessimistic
        serial sum. Real executions land between the two.
        """
        terms = (self.compute_time(flops), self.memory_time(bytes_),
                 self.collective_time(ici_bytes, dci_bytes))
        return max(terms) if overlap else sum(terms)


@dataclasses.dataclass(frozen=True)
class KnlLikeSpec:
    """Machine model for the paper's KNL socket (Xeon Phi 7250).

    Only what the scheduling reproduction needs: core/tile/HW-thread
    topology and enough bandwidth/latency structure for a convex
    time-vs-threads curve (the paper's Fig. 1 / Observation 1).
    """

    name: str = "knl_7250"
    cores: int = 68
    tiles: int = 34                       # 2 cores per tile share 1MB L2
    quadrants: int = 4                    # tiles group into mesh quadrants,
                                          # each owning 2 of the 8 MCDRAM
                                          # devices (KNL quadrant clustering)
    hw_threads_per_core: int = 4
    l2_bytes_per_tile: int = 1 * 1024**2
    mcdram_bandwidth: float = 450e9       # B/s (cache mode, ~STREAM)
    # quadrant-contained traffic skips the cross-mesh directory hop, so a
    # launch whose threads AND streams stay in one quadrant recovers the
    # bandwidth that all-to-all interleaving wastes on co-run conflicts —
    # calibrated to the paper's Table III, where core partitioning buys
    # +38% co-run throughput where hyper-threading buys +3%
    quadrant_local_boost: float = 1.38
    # a launch straddling INTO a quadrant that other ops occupy pays the
    # cross-quadrant contention premium per contested quadrant
    cross_quadrant_penalty: float = 0.85
    core_flops: float = 41.6e9            # 2x AVX-512 FMA @ ~1.3GHz
    thread_spawn_us: float = 4.0          # per-op thread wake/sync overhead
    sync_serialization: float = 0.005     # per-thread serialized sync share
    chunk_elems: int = 30000              # elems per independent work chunk:
                                          # an op with E elems exposes at most
                                          # ceil(E/chunk_elems) useful threads
                                          # (MKL-DNN loop-blocking structure)
    hyper_thread_efficiency: float = 0.55 # 2nd HW thread relative throughput
    restart_waste: float = 0.30           # fraction of a preempted op's
                                          # partial core-seconds charged as
                                          # waste: checkpoint-free preemption
                                          # discards the partial result, but
                                          # the fair-share ledger should not
                                          # bill the victim full price for
                                          # work the SCHEDULER threw away

    @property
    def logical_cpus(self) -> int:
        return self.cores * self.hw_threads_per_core

    # ---- topology: cores -> shared-L2 tiles -> quadrants ---------------
    # Core ids are 0..cores-1; tile t owns the shared-L2 pair (2t, 2t+1).
    # 34 tiles do not divide evenly by 4: quadrants get 9/9/8/8 tiles
    # (18/18/16/16 cores), matching the asymmetric real-chip floorplan.

    def tile_cores(self, tile: int) -> tuple[int, int]:
        """The shared-L2 core pair of one tile (cache-sharing affinity
        places both threads of a pair here — paper §III-B)."""
        return (2 * tile, 2 * tile + 1)

    @property
    def quadrant_tile_counts(self) -> tuple[int, ...]:
        base, extra = divmod(self.tiles, self.quadrants)
        return tuple(base + (1 if q < extra else 0)
                     for q in range(self.quadrants))

    def quadrant_tiles(self, quadrant: int) -> range:
        counts = self.quadrant_tile_counts
        start = sum(counts[:quadrant])
        return range(start, start + counts[quadrant])

    def quadrant_cores(self, quadrant: int) -> tuple[int, ...]:
        return tuple(c for t in self.quadrant_tiles(quadrant)
                     for c in self.tile_cores(t))

    def quadrant_of_core(self, core: int) -> int:
        tile = core // 2
        counts = self.quadrant_tile_counts
        start = 0
        for q, n in enumerate(counts):
            if tile < start + n:
                return q
            start += n
        raise ValueError(f"core {core} outside the {self.cores}-core socket")

    @property
    def quadrant_bandwidth(self) -> float:
        """Each quadrant's slice of MCDRAM (2 of the 8 devices)."""
        return self.mcdram_bandwidth / self.quadrants


V5E = TpuV5eSpec()
KNL = KnlLikeSpec()


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """N (possibly heterogeneous) KNL-like machines behind one placement
    layer (``repro.cluster``).

    The cluster model is shared-nothing: machines exchange no memory or
    bandwidth, only JOBS move between them — so each machine keeps its
    own ``KnlLikeSpec`` cost oracle and the cluster layer is pure
    routing.  ``transfer_cost_s`` is the modeled wall-clock price of
    moving one job's working set between machines; the router charges it
    (plus restart waste) through ``MovePrice`` before any cross-machine
    split or migration of started work, mirroring how the preemption
    economics price every other move in the stack."""

    machines: tuple[KnlLikeSpec, ...] = (KNL,)
    name: str = "cluster"
    transfer_cost_s: float = 0.5e-3       # per-job cross-machine move price

    def __post_init__(self):
        if not self.machines:
            raise ValueError("ClusterSpec needs at least one machine")

    @classmethod
    def homogeneous(cls, n: int, spec: KnlLikeSpec = KNL,
                    **kwargs) -> "ClusterSpec":
        return cls(machines=tuple(spec for _ in range(n)), **kwargs)

    @property
    def n_machines(self) -> int:
        return len(self.machines)

    @property
    def total_cores(self) -> int:
        return sum(m.cores for m in self.machines)

    def __len__(self) -> int:
        return len(self.machines)


def dominant_term(compute_s: float, memory_s: float, collective_s: float) -> str:
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    return max(terms, key=terms.get)
