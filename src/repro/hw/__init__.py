from repro.hw.spec import (V5E, KNL, ClusterSpec, TpuV5eSpec, KnlLikeSpec,
                           dominant_term)
from repro.hw.hlo import parse_collectives, op_histogram, shape_bytes, CollectiveStats

__all__ = [
    "V5E", "KNL", "ClusterSpec", "TpuV5eSpec", "KnlLikeSpec", "dominant_term",
    "parse_collectives", "op_histogram", "shape_bytes", "CollectiveStats",
]
