"""HLO text analysis: collective-byte accounting and op histograms.

``compiled.cost_analysis()`` reports flops and HBM bytes but NOT collective
traffic, so the collective roofline term is derived here by parsing the
optimized HLO (``compiled.as_text()``) of the per-device SPMD module.

Optimized HLO prints operands without type annotations, so byte counts
come from each collective's RESULT shape (for ``-start`` async forms the
result is a tuple — the largest element is the payload):

    all-gather       result = full gathered tensor
    reduce-scatter   result = one shard (full = result * g)
    all-reduce       result = full tensor
    all-to-all       result = full (same total as operand)
    collective-permute  result = payload

Ring cost model per device (bytes on the wire):
    all-gather / reduce-scatter   (g-1)/g * full
    all-reduce                    2 (g-1)/g * full
    all-to-all                    (g-1)/g * full
    collective-permute            payload

Groups whose members span more than one pod are classified as DCI
(pod-crossing) traffic, the rest ICI.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Iterable

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

# e.g. "bf16[256,4096]{1,0}" or "f32[]" ; layout braces optional
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\](?:\{[^}]*\})?")

# one HLO instruction: "%name = <result-type> <opcode>(...), attrs"
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+"
    r"(all-gather(?:-start)?|all-reduce(?:-start)?|reduce-scatter"
    r"|all-to-all|collective-permute(?:-start)?)\("
    r"(.*)$"
)

_EXPLICIT_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^=]*?\})\}")
_IOTA_GROUPS_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")


def shape_bytes(shape_str: str) -> int:
    """Bytes of one HLO shape string like ``bf16[8,128]{1,0}``."""
    m = _SHAPE_RE.match(shape_str.strip())
    if not m:
        return 0
    dtype, dims = m.group(1), m.group(2)
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _result_bytes(result_str: str) -> int:
    """Largest shape inside a (possibly tuple) result type."""
    sizes = [shape_bytes(m.group(0))
             for m in _SHAPE_RE.finditer(result_str)]
    return max(sizes, default=0)


def _parse_groups(attrs: str) -> list[list[int]]:
    m = _IOTA_GROUPS_RE.search(attrs)
    if m:
        n_groups, group_size = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        perm = ([int(x) for x in m.group(4).split(",")]
                if m.group(4) else list(range(len(dims))))
        ids = np.arange(int(np.prod(dims))).reshape(dims).transpose(perm).ravel()
        return ids.reshape(n_groups, group_size).tolist()
    m = _EXPLICIT_GROUPS_RE.search(attrs)
    if m:
        body = m.group(1)
        groups = []
        for grp in re.findall(r"\{([0-9,\s]*)\}", body):
            members = [int(x) for x in grp.replace(" ", "").split(",") if x]
            if members:
                groups.append(members)
        return groups
    return []


def _full_and_ring(kind: str, result_bytes: int, g: int
                   ) -> tuple[float, float]:
    """(full tensor bytes, per-device ring link bytes)."""
    g = max(g, 1)
    if kind.startswith("all-gather"):
        full = float(result_bytes)
        return full, full * (g - 1) / g
    if kind.startswith("reduce-scatter"):
        full = float(result_bytes) * g
        return full, full * (g - 1) / g
    if kind.startswith("all-reduce"):
        full = float(result_bytes)
        return full, 2.0 * full * (g - 1) / g
    if kind.startswith("all-to-all"):
        full = float(result_bytes)
        return full, full * (g - 1) / g
    if kind.startswith("collective-permute"):
        full = float(result_bytes)
        return full, full
    return float(result_bytes), float(result_bytes)


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    full_bytes: float          # logical tensor size moved
    group_size: int
    crosses_pod: bool
    link_bytes: float          # ring-model per-device bytes on the wire

    @property
    def base_kind(self) -> str:
        return self.kind.replace("-start", "")


@dataclasses.dataclass
class CollectiveStats:
    ops: list[CollectiveOp]

    @property
    def raw_operand_bytes(self) -> float:
        return sum(op.full_bytes for op in self.ops)

    @property
    def ici_link_bytes(self) -> float:
        return sum(op.link_bytes for op in self.ops if not op.crosses_pod)

    @property
    def dci_link_bytes(self) -> float:
        return sum(op.link_bytes for op in self.ops if op.crosses_pod)

    def by_kind(self) -> dict[str, tuple[int, float]]:
        """kind -> (count, link_bytes)."""
        out: dict[str, tuple[int, float]] = defaultdict(lambda: (0, 0.0))
        for op in self.ops:
            c, b = out[op.base_kind]
            out[op.base_kind] = (c + 1, b + op.link_bytes)
        return dict(out)

    def summary(self) -> str:
        parts = [f"{k}:n={c},linkB={b:.3e}" for k, (c, b) in
                 sorted(self.by_kind().items())]
        return (f"ici={self.ici_link_bytes:.3e}B dci={self.dci_link_bytes:.3e}B "
                + " ".join(parts))


def parse_collectives(hlo_text: str, pod_size: int | None = None
                      ) -> CollectiveStats:
    """Extract every collective op with its ring-model link bytes.

    ``pod_size``: number of devices per pod; a replica group containing
    members from different ``device // pod_size`` blocks is classified as
    pod-crossing (DCI)."""
    ops: list[CollectiveOp] = []
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        result_str, kind, attrs = m.group(1), m.group(2), m.group(3)
        if kind.endswith("-done"):
            continue
        n_bytes = _result_bytes(result_str)
        groups = _parse_groups(attrs)
        g = len(groups[0]) if groups else 1
        crosses = False
        if pod_size and groups:
            for grp in groups:
                pods = {d // pod_size for d in grp}
                if len(pods) > 1:
                    crosses = True
                    break
        full, ring = _full_and_ring(kind, n_bytes, g)
        ops.append(CollectiveOp(
            kind=kind, full_bytes=full, group_size=g,
            crosses_pod=crosses, link_bytes=ring))
    return CollectiveStats(ops=ops)


def op_histogram(hlo_text: str, opcodes: Iterable[str] | None = None
                 ) -> dict[str, int]:
    """Count instructions by opcode (for redundancy / remat analysis)."""
    counts: dict[str, int] = defaultdict(int)
    instr = re.compile(r"^\s*(?:ROOT\s+)?%?[\w.\-]*\s*=\s*\S+\s+([a-z][\w\-]*)\(")
    for line in hlo_text.splitlines():
        m = instr.match(line)
        if m:
            op = m.group(1)
            if opcodes is None or op in opcodes:
                counts[op] += 1
    return dict(counts)
