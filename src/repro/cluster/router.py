"""Demand-aware job routing: which machine does an arriving job run on?

The single-machine pool answers "which ops co-run, at what widths" —
routing answers the layer above: against N machines, place each arriving
job where it finishes soonest, without re-deriving what the per-machine
planstores already know.  The policy mirrors the distributed-placement
split in TensorFlow's dataflow scheduler (PAPERS.md): a job is routed
ONCE, by re-estimated cost against per-machine state, and every
finer-grained decision stays with the machine that won it.

``JobRouter`` is deliberately pure decision logic: the ``ClusterPool``
gathers the per-machine facts (loads, demand estimates, cache warmth)
and the router ranks candidates.  Keeping it side-effect-free is what
makes the hypothesis/deterministic-twin properties in
``tests/test_cluster.py`` cheap to state: every job is routed exactly
once, to a machine the facts justify, deterministically.

Two policies:

* ``"demand"`` — bin-pack by planstore-re-estimated demand
  (core-seconds): choose the machine with the smallest projected finish
  ``(load + job demand) / cores``, breaking exact ties toward the
  machine whose ``PlanCache`` fingerprint namespace already holds the
  job's curves (its probes are already paid for) and then toward the
  lowest machine index (determinism).
* ``"round_robin"`` — arrival index modulo N; the baseline
  ``cluster_bench`` measures the demand policy against.
"""

from __future__ import annotations

import dataclasses

POLICIES = ("demand", "round_robin")


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    """Routing policy knobs (frozen: a cluster's routing behavior is
    fixed for its lifetime, like every other config in the stack).

    ``rebalance`` enables the admission-level-eviction move across
    machines: a deadline-critical waiter on a busy machine is withdrawn
    (free — no started work) and resubmitted to an idle one.
    ``split`` enables MovePrice-gated cross-machine splits of
    multi-component graphs; off by default like every other priced move
    in the preemption economics."""

    policy: str = "demand"
    rebalance: bool = True
    split: bool = False
    # a job may be rebalanced at most this many times, so eviction chains
    # across machines terminate by construction
    max_moves: int = 1

    def __post_init__(self):
        if self.policy not in POLICIES:
            raise ValueError(
                f"unknown routing policy {self.policy!r}; "
                f"expected one of {POLICIES}")


@dataclasses.dataclass(frozen=True)
class MachineFacts:
    """Everything the router may consult about one machine at one
    decision instant — assembled by the ClusterPool, consumed here."""

    index: int
    cores: int
    load: float                  # outstanding core-seconds (active+queued)
    demand: float | None         # this JOB's demand here (None = unpriced)
    warm_frac: float             # fraction of the job's op keys already
                                 # cached under this machine's fingerprint

    @property
    def projected_finish(self) -> float:
        """Seconds of work ahead of this machine if the job lands here
        (None-demand machines project their load alone — the OPTIMISTIC
        lower bound the lazy-pricing loop compares against)."""
        return (self.load + (self.demand or 0.0)) / self.cores


class JobRouter:
    """Rank candidate machines for one arriving job."""

    def __init__(self, config: RouterConfig | None = None):
        self.config = config or RouterConfig()
        self._arrivals = 0

    def route(self, facts: list[MachineFacts]) -> int:
        """Choose a machine index.  Every entry in ``facts`` must carry a
        priced ``demand`` (the ClusterPool's lazy-pricing loop decides
        WHICH machines are worth pricing; by the time the router ranks
        them, the comparison is apples-to-apples)."""
        if not facts:
            raise ValueError("route() with no candidate machines")
        self._arrivals += 1
        if self.config.policy == "round_robin":
            # facts carry the live indices; cycle through ALL machines of
            # the cluster, not just the priced subset
            return (self._arrivals - 1) % (max(f.index for f in facts) + 1)
        assert all(f.demand is not None for f in facts)
        best = min(facts, key=lambda f: (f.projected_finish,
                                         -f.warm_frac, f.index))
        return best.index
