"""Multi-machine cluster pool: N per-machine ``RuntimePool``s behind one
demand-aware placement layer.

The cluster model is shared-nothing (``repro.hw.spec.ClusterSpec``):
machines exchange jobs, never memory — so each machine keeps its own
discrete-event sim, its own ``StrategyCore``, its own admission tier,
and the cluster layer is pure routing plus two priced cross-machine
moves:

* **rebalance** — the admission-level eviction made cross-machine: a
  deadline-critical waiter on a busy machine is ``withdraw``n (free by
  construction — no started work) and resubmitted to an idle machine at
  the decision instant;
* **split** (off by default) — a multi-component graph spans two
  machines only when ``split_price`` says the predicted parallel finish
  strictly beats staying put plus the modeled transfer cost.

All member pools share ONE ``PlanCache`` — safe since lookups are
fingerprint-keyed — one jid counter (so jids are cluster-unique and a
rebalanced job can never collide), one correction table, and one trace
sink (``FAM_CLUSTER`` route/rebalance/split events ride beside the
per-machine families).

**Time model**: each pool's sim clock is local wall time on its machine;
all machines share t=0, so cluster makespan is the max of member
makespans, and cross-machine moves resubmit at the source machine's
decision instant (never into another machine's past).  The drive loop
steps the pool with the smallest clock first (ties to the lowest index),
which is deterministic and — for a 1-machine cluster — degenerates to
exactly ``RuntimePool.run``'s loop, giving the bit-for-bit parity leg
(``check_parity`` "cluster-1m") its footing.
"""

from __future__ import annotations

import dataclasses
import itertools

from repro.core.graph import OpGraph
from repro.core.perfmodel import cross_graph_key
from repro.core.planstore import (CorrectionTable, DemandIndex,
                                  TripCountEstimator, make_plan_store,
                                  split_price)
from repro.core.runtime import ConcurrencyRuntime
from repro.core.simmachine import SimMachine
from repro.hw.spec import ClusterSpec
from repro.multitenant.job import Job, jain
from repro.multitenant.plancache import PlanCache
from repro.multitenant.pool import PoolConfig, PoolResult, RuntimePool
from repro.obs.trace import FAM_CLUSTER, TraceEvent

from repro.cluster.router import JobRouter, MachineFacts, RouterConfig


@dataclasses.dataclass
class ClusterJob:
    """One cluster-level submission and where it currently lives.

    ``cjid`` (the first jid minted for it) is the stable identity across
    rebalances and splits: parts and re-placements get fresh jids from
    the shared counter, but the submission itself is this record."""

    cjid: int
    name: str
    submit_time: float
    deadline: float | None
    machine: int                 # current (primary) machine index
    jobs: list[Job]              # live part(s): one, or two when split
    moves: int = 0               # rebalance count
    split: bool = False
    history: list[tuple[int, int]] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return all(j.done for j in self.jobs)

    @property
    def finish_time(self) -> float | None:
        if not self.done:
            return None
        return max(j.finish_time for j in self.jobs)

    @property
    def latency(self) -> float | None:
        f = self.finish_time
        return None if f is None else f - self.submit_time


@dataclasses.dataclass
class ClusterResult:
    """Per-machine ``PoolResult``s plus the cluster-level accounting."""

    machines: list[PoolResult]
    cluster_jobs: list[ClusterJob]
    assignment: dict[int, int]           # jid -> machine index
    n_rebalances: int = 0
    n_splits: int = 0
    demand_index_stats: dict | None = None
    metrics: dict | None = None

    @property
    def makespan(self) -> float:
        """Machines run in parallel wall time: the cluster is done when
        the LAST machine is."""
        return max((r.makespan for r in self.machines), default=0.0)

    @property
    def jobs(self):
        """Every per-machine Job, cluster-wide (the ``PoolResult.jobs``
        surface, so code written against one pool reads a cluster too)."""
        return [j for r in self.machines for j in r.jobs]

    @property
    def total_ops(self) -> int:
        return sum(r.total_ops for r in self.machines)

    @property
    def aggregate_throughput(self) -> float:
        return self.total_ops / self.makespan if self.makespan else 0.0

    def per_job_schedule(self, jid: int):
        """Delegate to the owning machine's result (same contract as
        ``PoolResult.per_job_schedule`` — the parity harness uses it)."""
        return self.machines[self.assignment[jid]].per_job_schedule(jid)

    def latencies(self) -> dict[int, float]:
        """cjid -> cluster-level latency (finish of the LAST part minus
        the ORIGINAL submit time — a rebalanced job's queue wait on its
        first machine is not forgiven)."""
        return {cj.cjid: cj.latency for cj in self.cluster_jobs
                if cj.latency is not None}

    def slowdowns(self, solo_makespans: dict[int, float]) -> dict[int, float]:
        """cjid -> latency / solo makespan (the fairness currency;
        ``solo_makespans`` keyed by cjid)."""
        lats = self.latencies()
        return {cjid: lats[cjid] / solo_makespans[cjid]
                for cjid in lats if solo_makespans.get(cjid)}

    def slowdown_fairness(self, solo_makespans: dict[int, float]) -> float:
        return jain(list(self.slowdowns(solo_makespans).values()))


class ClusterPool:
    """The placement layer: owns one ``RuntimePool`` per machine plus a
    ``JobRouter``; see the module docstring for the model."""

    def __init__(self, cluster: ClusterSpec | None = None, *,
                 config: PoolConfig | None = None,
                 plan_cache: PlanCache | None = None,
                 router: RouterConfig | JobRouter | None = None,
                 machines: list[SimMachine] | None = None,
                 corrections: CorrectionTable | None = None,
                 trip_counts: TripCountEstimator | None = None,
                 seed: int = 0):
        self.cluster = cluster if cluster is not None else ClusterSpec()
        self.config = config or PoolConfig()
        self.plan_cache = (plan_cache if plan_cache is not None
                           else PlanCache())
        if isinstance(router, JobRouter):
            self.router = router
        else:
            self.router = JobRouter(router)
        if machines is None:
            machines = [SimMachine(spec=spec, seed=seed)
                        for spec in self.cluster.machines]
        elif len(machines) != len(self.cluster.machines):
            raise ValueError("machines list must match the ClusterSpec")
        strat = self.config.strategy_config()
        self.sink = strat.sink
        self.feedback = strat.feedback
        # shared learned state, exactly as one RuntimePool shares it
        # across tenants: corrections/trip counts span machines too
        # (ratios are machine-relative to each machine's own curves)
        if self.feedback != "off":
            corrections = (corrections if corrections is not None
                           else CorrectionTable())
            trip_counts = (trip_counts if trip_counts is not None
                           else TripCountEstimator())
        self.corrections = corrections if self.feedback != "off" else None
        self.trip_counts = trip_counts if self.feedback != "off" else None
        self._jid = itertools.count()
        self.pools = [RuntimePool(machine=m, config=self.config,
                                  plan_cache=self.plan_cache,
                                  corrections=corrections,
                                  trip_counts=trip_counts,
                                  jid_counter=self._jid)
                      for m in machines]
        self.demand_index = DemandIndex()
        self.cluster_jobs: list[ClusterJob] = []
        self._by_jid: dict[int, ClusterJob] = {}
        self.assignment: dict[int, int] = {}
        # old jid -> replacement jid, maintained by rebalance so callers
        # holding a pre-move jid (the service daemon's job store) can
        # still find the job
        self.jid_alias: dict[int, int] = {}
        self.n_rebalances = 0
        self.n_splits = 0
        # mirrored onto every member pool at begin() (the daemon's
        # payload-execution seam)
        self.observer = None

    @property
    def jobs(self) -> list[Job]:
        """Every live per-machine Job, cluster-wide (jids are unique
        across machines — the shared counter — so lookups by jid are
        unambiguous)."""
        return [j for p in self.pools for j in p.jobs]

    def current_jid(self, jid: int) -> int:
        """Resolve a possibly-stale jid through the rebalance alias
        chain (a moved job gets a fresh jid on its new machine)."""
        while jid in self.jid_alias:
            jid = self.jid_alias[jid]
        return jid

    # ---- per-machine facts ----------------------------------------------
    def _fingerprint(self, m: int):
        """The SAME (machine fingerprint, probe interval) context the
        ``PlanCache`` namespaces curves under and ``DemandIndex`` keys
        demand under — one definition of "the same machine" everywhere."""
        return (self.pools[m].machine.fingerprint,
                self.config.runtime.interval)

    def _load(self, m: int) -> float:
        """Outstanding core-seconds on machine ``m``: remaining demand of
        active jobs (completed uids excluded) plus queued demand."""
        pool = self.pools[m]
        total = 0.0
        sim = pool._sim
        if sim is not None:
            for j in pool._active:
                if j.store is not None and j.plan is not None:
                    total += j.store.remaining_demand(
                        j.graph, j.plan, sim.completed.get(j.jid, set()))
                else:
                    total += j.demand or 0.0
        for j in pool.queue.waiting_jobs():
            total += j.demand or 0.0
        return total

    @staticmethod
    def _op_keys(graph) -> set:
        view = graph.profile_view() if hasattr(graph, "profile_view") \
            else graph
        return {cross_graph_key(op) for op in view.ops.values()}

    def _warm_frac(self, m: int, graph) -> float:
        keys = self._op_keys(graph)
        if not keys:
            return 0.0
        warm = self.plan_cache.warm_keys(self._fingerprint(m))
        return len(keys & warm) / len(keys)

    def _estimate_demand(self, graph, m: int) -> float:
        """Planstore-re-estimated demand (core-seconds) of ``graph`` on
        machine ``m`` — memoized per (fingerprint, workload shape).  The
        first estimate profiles through the SHARED fingerprint-keyed
        PlanCache, so the probes it pays are exactly the probes the
        winning machine's own submit-time profile then reuses: pricing a
        machine warms it, and never pollutes any other machine."""
        pool = self.pools[m]

        def compute() -> float:
            rt = ConcurrencyRuntime(machine=pool.profile_machine,
                                    config=self.config.runtime,
                                    plan_cache=self.plan_cache)
            rt.profile(graph)
            store = make_plan_store(self.feedback, rt.controller,
                                    corrections=self.corrections,
                                    trip_counts=self.trip_counts)
            return store.remaining_demand(graph, rt.plan)

        return self.demand_index.query(self._fingerprint(m), graph, compute)

    # ---- routing ---------------------------------------------------------
    def _route(self, graph) -> tuple[int, float | None]:
        """Choose a machine for ``graph``; returns (index, demand
        estimate on it — None under round_robin, which never prices)."""
        n = len(self.pools)
        loads = [self._load(m) for m in range(n)]
        cores = [p.machine.spec.cores for p in self.pools]
        if self.router.config.policy == "round_robin":
            facts = [MachineFacts(m, cores[m], loads[m], None, 0.0)
                     for m in range(n)]
            return self.router.route(facts), None
        warm = [self._warm_frac(m, graph) for m in range(n)]
        fps = [self._fingerprint(m) for m in range(n)]
        known = {m for m in range(n)
                 if self.demand_index.peek(fps[m], graph) is not None}
        if not known:
            # brand-new workload shape: price it ONCE, on the machine
            # with the least work ahead (machines sharing that
            # fingerprint become known for free)
            m0 = min(range(n), key=lambda m: (loads[m] / cores[m], m))
            self._estimate_demand(graph, m0)
            known = {m for m in range(n)
                     if self.demand_index.peek(fps[m], graph) is not None}
        facts = [MachineFacts(m, cores[m], loads[m],
                              self.demand_index.peek(fps[m], graph,
                                                     count=True), warm[m])
                 for m in sorted(known)]
        # lazy pricing: a machine with unknown demand (a different
        # fingerprint, never priced for this shape) is worth paying
        # probes for ONLY if its load alone — the optimistic bound —
        # already beats the best fully-priced projection ("route a job
        # where its curves are already paid for", unless a cold machine
        # is idle enough to win anyway)
        for m in range(n):
            if m in known:
                continue
            best = min(f.projected_finish for f in facts)
            if loads[m] / cores[m] < best:
                demand = self._estimate_demand(graph, m)
                facts.append(MachineFacts(m, cores[m], loads[m],
                                          demand, warm[m]))
        chosen = self.router.route(facts)
        est = next(f.demand for f in facts if f.index == chosen)
        return chosen, est

    # ---- submission ------------------------------------------------------
    def submit(self, graph: OpGraph, *, priority: float = 1.0,
               name: str | None = None, submit_time: float = 0.0,
               deadline: float | None = None,
               machine: int | None = None) -> Job:
        """Route ``graph`` to a machine and submit it there.  Returns the
        underlying per-machine ``Job`` (same surface as
        ``RuntimePool.submit``, so ``submit_spec`` and the daemon drive a
        cluster unchanged).  ``machine`` forces the placement — the
        daemon's recovery path, which must restore a checkpointed
        assignment rather than re-route."""
        if machine is not None:
            m, est = machine, None
        else:
            split = self._try_split(graph, priority=priority, name=name,
                                    submit_time=submit_time,
                                    deadline=deadline)
            if split is not None:
                return split
            m, est = self._route(graph)
        job = self.pools[m].submit(graph, priority=priority, name=name,
                                   submit_time=submit_time,
                                   deadline=deadline)
        cj = ClusterJob(cjid=job.jid, name=job.name,
                        submit_time=submit_time, deadline=deadline,
                        machine=m, jobs=[job])
        self.cluster_jobs.append(cj)
        self._by_jid[job.jid] = cj
        self.assignment[job.jid] = m
        if self.sink.enabled:
            self.sink.emit(TraceEvent(
                ts=submit_time, family=FAM_CLUSTER, kind="route",
                key=job.jid,
                data={"job": job.name, "machine": m,
                      "demand": est if est is not None else job.demand,
                      "policy": self.router.config.policy,
                      "forced": machine is not None,
                      "loads": [round(self._load(i), 9)
                                for i in range(len(self.pools))]}))
        return job

    # ---- cross-machine splits (priced, off by default) -------------------
    def _try_split(self, graph, *, priority, name, submit_time,
                   deadline) -> Job | None:
        """Span two machines with one wide tenant — only when the plan
        says the split pays (``split_price``, strict).  Only static
        multi-component graphs qualify: a component is closed under
        deps, so partitioning components never cuts an edge, and the
        per-part demand is approximated by each part's flops share of
        the whole-graph estimate (components execute independently, so
        the share is exact up to width effects)."""
        if not self.router.config.split or len(self.pools) < 2:
            return None
        if type(graph) is not OpGraph or not graph.ops:
            return None
        comps = self._components(graph)
        if len(comps) < 2:
            return None
        m_whole, demand = self._route(graph)
        loads = [self._load(m) for m in range(len(self.pools))]
        cores = [p.machine.spec.cores for p in self.pools]
        whole_time = (loads[m_whole] + demand) / cores[m_whole]
        # two-bin greedy partition by flops weight, heaviest first
        weight = {i: sum(graph.ops[u].flops + graph.ops[u].bytes_moved
                         for u in comp) for i, comp in enumerate(comps)}
        bins: list[list[int]] = [[], []]
        bin_w = [0.0, 0.0]
        for i in sorted(weight, key=lambda i: (-weight[i], i)):
            b = 0 if bin_w[0] <= bin_w[1] else 1
            bins[b].append(i)
            bin_w[b] += weight[i]
        if not bins[0] or not bins[1]:
            return None
        total_w = sum(bin_w) or 1.0
        # the two least-loaded machines host the parts
        m1, m2 = sorted(range(len(self.pools)),
                        key=lambda m: (loads[m] / cores[m], m))[:2]
        shares = [bin_w[0] / total_w, bin_w[1] / total_w]
        split_time = max((loads[mm] + demand * s) / cores[mm]
                         for mm, s in zip((m1, m2), shares))
        price = split_price(whole_time, split_time,
                            self.cluster.transfer_cost_s)
        if not price.worth_it:
            return None
        parts = []
        for part_idx, (mm, bin_comps) in enumerate(zip((m1, m2), bins)):
            ops = {u: graph.ops[u] for ci in bin_comps for u in comps[ci]}
            sub = OpGraph(name=f"{name or graph.name}/part{part_idx}",
                          ops=ops)
            parts.append(self.pools[mm].submit(
                sub, priority=priority, submit_time=submit_time,
                deadline=deadline))
        cj = ClusterJob(cjid=parts[0].jid, name=name or graph.name,
                        submit_time=submit_time, deadline=deadline,
                        machine=m1, jobs=parts, split=True)
        self.cluster_jobs.append(cj)
        self.n_splits += 1
        for job, mm in zip(parts, (m1, m2)):
            self._by_jid[job.jid] = cj
            self.assignment[job.jid] = mm
        if self.sink.enabled:
            self.sink.emit(TraceEvent(
                ts=submit_time, family=FAM_CLUSTER, kind="split",
                key=cj.cjid,
                data={"job": cj.name, "machines": [m1, m2],
                      "jids": [j.jid for j in parts],
                      "gain": price.gain, "cost": price.cost,
                      "whole_time": whole_time,
                      "split_time": split_time}))
        return parts[0]

    @staticmethod
    def _components(graph: OpGraph) -> list[list[int]]:
        """Weakly-connected components (sorted uids, sorted by first
        uid) — union by deps edges."""
        parent = {u: u for u in graph.ops}

        def find(u):
            while parent[u] != u:
                parent[u] = parent[parent[u]]
                u = parent[u]
            return u

        for op in graph.ops.values():
            for d in op.deps:
                parent[find(d)] = find(op.uid)
        groups: dict[int, list[int]] = {}
        for u in graph.ops:
            groups.setdefault(find(u), []).append(u)
        return sorted((sorted(g) for g in groups.values()),
                      key=lambda g: g[0])

    # ---- lifecycle -------------------------------------------------------
    def begin(self, *, clock: float = 0.0,
              clocks: list[float] | None = None) -> None:
        """Start every member pool's lifecycle (``clocks`` resumes each
        machine at its own checkpointed instant — the daemon's recovery
        path)."""
        if clocks is None:
            clocks = [clock] * len(self.pools)
        for pool, c in zip(self.pools, clocks):
            pool.observer = self.observer
            pool.begin(clock=c)

    def step(self) -> bool:
        """Advance the cluster by ONE per-machine decision instant: the
        pool with work and the smallest local clock steps (ties to the
        lowest index — deterministic), then the rebalance check runs.
        With one machine this IS ``RuntimePool.step`` (the rebalance
        check needs a second machine to do anything), which is what the
        cluster-1m parity leg pins."""
        busy = [m for m, p in enumerate(self.pools)
                if p._active or len(p.queue)]
        if not busy:
            return False
        m = min(busy, key=lambda m: (self.pools[m].clock, m))
        stepped = self.pools[m].step()
        if self.router.config.rebalance:
            self._maybe_rebalance()
        return stepped

    def result(self) -> ClusterResult:
        results = [p.result() for p in self.pools]
        res = ClusterResult(machines=results,
                            cluster_jobs=list(self.cluster_jobs),
                            assignment=dict(self.assignment),
                            n_rebalances=self.n_rebalances,
                            n_splits=self.n_splits,
                            demand_index_stats={
                                "hits": self.demand_index.hits,
                                "misses": self.demand_index.misses})
        metrics = {"cluster.makespan": res.makespan,
                   "cluster.total_ops": res.total_ops,
                   "cluster.aggregate_throughput": res.aggregate_throughput,
                   "cluster.rebalances": res.n_rebalances,
                   "cluster.splits": res.n_splits,
                   "cluster.demand_index_hits": self.demand_index.hits}
        for m, r in enumerate(results):
            metrics[f"cluster.machine.{m}.makespan"] = r.makespan
            metrics[f"cluster.machine.{m}.ops"] = r.total_ops
        res.metrics = metrics
        return res

    def run(self) -> ClusterResult:
        self.begin()
        while self.step():
            pass
        result = self.result()
        # one-shot mode, like RuntimePool.run: leave every member "not
        # begun" so later submits queue normally
        for pool in self.pools:
            pool._sim = None
            pool._adapter = None
            pool._active = []
        return result

    def cancel(self, jid: int) -> bool:
        """Cancel a cluster job by any of its part jids (a split tenant's
        parts stand and fall together — cancelling half a job would leave
        an orphaned remainder no client asked for)."""
        cj = self._by_jid.get(jid)
        if cj is None:
            return False
        # list() before any(): a bare generator would short-circuit on
        # the first successful cancel and leave later parts running
        return any([self.pools[self.assignment[j.jid]].cancel(j.jid)
                    for j in list(cj.jobs)])

    # ---- rebalance (admission-level eviction, cross-machine) -------------
    def _maybe_rebalance(self) -> None:
        """Move a deadline-critical WAITER from a busy machine to an idle
        one.  Free by construction: only queued (or launch-free) jobs are
        withdrawable, so nothing is discarded or re-billed — this is the
        pool's admission-level eviction with a machine hop at the end.
        The moved job resubmits at the source machine's decision instant
        (never into the target's past) and keeps its ORIGINAL identity in
        the cluster ledger, so latency accounting still starts at first
        submission."""
        if len(self.pools) < 2:
            return
        for src_idx, src in enumerate(self.pools):
            now = src.clock
            for job in list(src.queue.waiting_jobs()):
                if job.submit_time > now or job.deadline is None:
                    continue
                cj = self._by_jid.get(job.jid)
                if cj is None or cj.moves >= self.router.config.max_moves:
                    continue
                slack = src._root_slack(job, now)
                if slack is None or slack > 0.0:
                    continue
                idle = [t for t, p in enumerate(self.pools)
                        if t != src_idx and not p._active
                        and not len(p.queue)]
                if not idle:
                    continue
                target = min(idle, key=lambda t: (self.pools[t].clock, t))
                moved = src.withdraw(job.jid)
                if moved is None:
                    continue
                new_job = self.pools[target].submit(
                    moved.graph, priority=moved.priority, name=moved.name,
                    submit_time=max(moved.submit_time, now),
                    deadline=moved.deadline)
                cj.jobs[cj.jobs.index(job)] = new_job
                cj.machine = target
                cj.moves += 1
                cj.history.append((src_idx, job.jid))
                del self._by_jid[job.jid]
                self.assignment.pop(job.jid, None)
                self.jid_alias[job.jid] = new_job.jid
                self._by_jid[new_job.jid] = cj
                self.assignment[new_job.jid] = target
                self.n_rebalances += 1
                if self.sink.enabled:
                    self.sink.emit(TraceEvent(
                        ts=now, family=FAM_CLUSTER, kind="rebalance",
                        key=new_job.jid,
                        data={"job": moved.name, "from": src_idx,
                              "to": target, "old_jid": job.jid,
                              "slack": slack}))
                return      # one move per decision instant
