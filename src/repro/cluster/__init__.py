"""Multi-machine cluster pool: demand-aware job routing above the
per-machine StrategyCore.

router  -- JobRouter: pure placement policy (demand bin-packing vs
           round-robin) over per-machine MachineFacts
pool    -- ClusterPool: one RuntimePool per ClusterSpec machine behind
           one shared PlanCache/jid-space, plus priced rebalance and
           (off by default) cross-machine splits
"""

from repro.cluster.router import (JobRouter, MachineFacts, POLICIES,
                                  RouterConfig)
from repro.cluster.pool import ClusterJob, ClusterPool, ClusterResult

__all__ = [
    "ClusterJob", "ClusterPool", "ClusterResult",
    "JobRouter", "MachineFacts", "POLICIES", "RouterConfig",
]
