from repro.data.pipeline import (DataConfig, SyntheticLM, MmapTokens,
                                 make_source, Prefetcher)
__all__ = ["DataConfig", "SyntheticLM", "MmapTokens", "make_source",
           "Prefetcher"]
