"""Data pipeline: deterministic synthetic LM stream + mmap token files,
per-host sharding, background prefetch.

Determinism contract: ``SyntheticLM(seed, ...)`` yields the same global
batch sequence regardless of host count; each host materializes only its
slice (``host_id/num_hosts``), so elastic restarts resume bit-identically
from a (seed, step) cursor — the cursor is what the checkpoint stores.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab: int
    seed: int = 0
    kind: str = "synthetic"       # synthetic | mmap
    path: str | None = None       # token file for kind="mmap" (uint16/32)
    frontend_tokens: int = 0      # >0: also emit stub modality embeddings
    d_model: int = 0


class SyntheticLM:
    """Seeded Zipf-ish token stream with enough structure that loss can
    actually decrease (n-gram correlations), generated per (step, host)."""

    def __init__(self, cfg: DataConfig, host_id: int = 0, num_hosts: int = 1):
        assert cfg.global_batch % num_hosts == 0
        self.cfg = cfg
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.local_batch = cfg.global_batch // num_hosts
        # Zipf-like unigram distribution
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        probs = 1.0 / ranks
        self.probs = (probs / probs.sum()).astype(np.float64)

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, self.host_id]))
        shape = (self.local_batch, cfg.seq_len + 1)
        base = rng.choice(cfg.vocab, size=shape, p=self.probs)
        # inject bigram structure: every even position partially predicts
        # the next token (so training has signal)
        follow = (base * 31 + 7) % cfg.vocab
        m = rng.random(shape) < 0.35
        base[:, 1:] = np.where(m[:, 1:], follow[:, :-1], base[:, 1:])
        out = {
            "tokens": base[:, :-1].astype(np.int32),
            "targets": base[:, 1:].astype(np.int32),
        }
        if cfg.frontend_tokens:
            fr = rng.standard_normal(
                (self.local_batch, cfg.frontend_tokens, cfg.d_model)) * 0.02
            out["frontend"] = fr.astype(np.float32)
        return out

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class MmapTokens:
    """Memory-mapped flat token file, strided into (batch, seq+1) windows.

    Window assignment is a seeded permutation over document offsets so
    epochs reshuffle deterministically."""

    def __init__(self, cfg: DataConfig, host_id: int = 0, num_hosts: int = 1):
        assert cfg.path is not None
        self.cfg = cfg
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.local_batch = cfg.global_batch // num_hosts
        self.tokens = np.memmap(cfg.path, dtype=np.uint16, mode="r")
        self.n_windows = (len(self.tokens) - 1) // cfg.seq_len
        if self.n_windows < cfg.global_batch:
            raise ValueError("token file too small for one global batch")

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        epoch = (step * cfg.global_batch) // self.n_windows
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, epoch]))
        perm = rng.permutation(self.n_windows)
        start = (step * cfg.global_batch) % self.n_windows
        idx = perm[(start + np.arange(cfg.global_batch)) % self.n_windows]
        idx = idx[self.host_id::self.num_hosts][:self.local_batch]
        rows = np.stack([
            self.tokens[i * cfg.seq_len: i * cfg.seq_len + cfg.seq_len + 1]
            for i in idx]).astype(np.int32)
        return {"tokens": rows[:, :-1] % cfg.vocab,
                "targets": rows[:, 1:] % cfg.vocab}


def make_source(cfg: DataConfig, host_id: int = 0, num_hosts: int = 1):
    if cfg.kind == "synthetic":
        return SyntheticLM(cfg, host_id, num_hosts)
    if cfg.kind == "mmap":
        return MmapTokens(cfg, host_id, num_hosts)
    raise ValueError(cfg.kind)


class Prefetcher:
    """Background-thread prefetch with a bounded queue.

    ``cursor`` tracks the next step to produce; ``state()`` returns the
    resume cursor to store in checkpoints."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self.cursor = start_step
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self) -> None:
        step = self.cursor
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self) -> dict[str, np.ndarray]:
        step, batch = self._q.get()
        self.cursor = step + 1
        return batch

    def state(self) -> dict:
        return {"cursor": self.cursor}

    def close(self) -> None:
        self._stop.set()
        self._thread.join(timeout=2.0)
