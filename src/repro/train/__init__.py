from repro.train.trainer import (TrainConfig, init_state, abstract_state,
                                 state_specs, make_train_step,
                                 make_eval_step, make_prefill_step,
                                 make_serve_step)
from repro.train.checkpoint import CheckpointManager
from repro.train.fault import (Heartbeat, StragglerMonitor, run_with_recovery,
                               RecoveryStats)
__all__ = ["TrainConfig", "init_state", "abstract_state", "state_specs",
           "make_train_step", "make_eval_step", "make_prefill_step",
           "make_serve_step", "CheckpointManager", "Heartbeat",
           "StragglerMonitor", "run_with_recovery", "RecoveryStats"]
