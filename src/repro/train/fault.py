"""Fault tolerance: heartbeat, straggler detection, checkpoint-retry loop.

Scope note (DESIGN.md §6): in-process mechanisms are fully implemented
and tested — what belongs to the cluster manager (re-scheduling a dead
host, swapping hardware) is exposed as policy decisions
(``StragglerMonitor.decide``) the manager consumes.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable


class Heartbeat:
    """Periodic liveness file: {step, time}.  A watchdog (or another
    host) treats staleness > timeout as failure."""

    def __init__(self, path: str, interval_s: float = 10.0):
        self.path = path
        self.interval_s = interval_s
        self._last = 0.0

    def beat(self, step: int, force: bool = False) -> None:
        now = time.time()
        if not force and now - self._last < self.interval_s:
            return
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"step": step, "time": now}, f)
        os.rename(tmp, self.path)
        self._last = now

    @staticmethod
    def is_stale(path: str, timeout_s: float) -> bool:
        if not os.path.exists(path):
            return True
        with open(path) as f:
            return time.time() - json.load(f)["time"] > timeout_s


@dataclasses.dataclass
class StragglerMonitor:
    """Per-host step-time EMA + z-score flagging.

    observe() ingests per-host step times (from an allgather in real
    deployments); decide() emits the mitigation policy:
      - "exclude": host consistently beyond z_threshold -> re-mesh without it
      - "watch":   transient slowness
    """

    z_threshold: float = 3.0
    ema_alpha: float = 0.2
    min_observations: int = 5
    consecutive_to_exclude: int = 3
    min_relative_excess: float = 0.2   # must also be >20% over median

    def __post_init__(self) -> None:
        self._ema: dict[str, float] = {}
        self._count: dict[str, int] = {}
        self._flags: dict[str, int] = {}

    def observe(self, host_times: dict[str, float]) -> dict[str, str]:
        for h, t in host_times.items():
            prev = self._ema.get(h, t)
            self._ema[h] = (1 - self.ema_alpha) * prev + self.ema_alpha * t
            self._count[h] = self._count.get(h, 0) + 1

        out: dict[str, str] = {}
        # flag on THIS round's raw times (an EMA would keep flagging a
        # host for many rounds after one transient spike); robust
        # median/MAD stats so a single straggler cannot inflate its own
        # detection threshold, plus a relative floor so sub-20% jitter
        # never flags even when MAD ~ 0
        vals = sorted(host_times.values())
        if len(vals) < 2:
            return out
        mid = len(vals) // 2
        median = (vals[mid] if len(vals) % 2
                  else 0.5 * (vals[mid - 1] + vals[mid]))
        devs = sorted(abs(v - median) for v in vals)
        mad = (devs[mid] if len(devs) % 2
               else 0.5 * (devs[mid - 1] + devs[mid]))
        scale = max(1.4826 * mad, 1e-9)
        for h, v in host_times.items():
            if self._count[h] < self.min_observations:
                continue
            z = (v - median) / scale
            if v < median * (1.0 + self.min_relative_excess):
                z = 0.0
            if z > self.z_threshold:
                self._flags[h] = self._flags.get(h, 0) + 1
                out[h] = ("exclude"
                          if self._flags[h] >= self.consecutive_to_exclude
                          else "watch")
            else:
                self._flags[h] = 0
        return out

    def healthy_hosts(self, hosts: list[str]) -> list[str]:
        return [h for h in hosts
                if self._flags.get(h, 0) < self.consecutive_to_exclude]


@dataclasses.dataclass
class RecoveryStats:
    failures: int = 0
    restores: int = 0
    steps_replayed: int = 0


def run_with_recovery(step_fn: Callable, state, *, n_steps: int,
                      save_every: int, manager, data_prefetch=None,
                      max_failures: int = 5,
                      on_metrics: Callable | None = None
                      ) -> tuple[object, RecoveryStats]:
    """Drive (state, batch) -> (state, metrics) with checkpoint/restore.

    Any exception from step_fn triggers restore-from-latest and replay.
    ``data_prefetch`` must expose .next()/.state()/.cursor and a
    ``source.batch_at(step)`` for deterministic replay."""
    stats = RecoveryStats()
    step = 0
    while step < n_steps:
        try:
            if data_prefetch is not None:
                batch = data_prefetch.source.batch_at(step)
            else:
                batch = None
            state, metrics = step_fn(state, batch, step)
            if on_metrics is not None:
                on_metrics(step, metrics)
            step += 1
            if save_every and step % save_every == 0:
                manager.save(step, state,
                             extra={"data_cursor": step})
        except Exception:
            stats.failures += 1
            if stats.failures > max_failures:
                raise
            restored = manager.restore()
            if restored is None:
                # no checkpoint yet: restart from scratch
                stats.steps_replayed += step
                step = 0
                continue
            state, extra, ck_step = restored
            stats.restores += 1
            stats.steps_replayed += max(0, step - ck_step)
            step = ck_step
    manager.wait()
    return state, stats
