"""Checkpointing: async, sharded-logical, elastic-restorable.

Format: one directory per step —
    ckpt_dir/step_000100/
        arrays.npz          (flat path -> np array, LOGICAL/global values)
        manifest.json       (step, tree structure, dtypes, data cursor,
                             mesh shape at save time)
    ckpt_dir/LATEST         (atomic pointer file)

Design decisions for the 1000+-node story (DESIGN.md §6):
* arrays are saved as GLOBAL logical values (gathered via device_get) —
  restore re-shards onto WHATEVER mesh the restarted job has (elastic
  up/down) by device_put with the new NamedSharding;
* writes happen on a background thread (compute continues; ``wait()``
  joins before the next save or at exit);
* the LATEST pointer is renamed atomically only after a fsync'd write, so
  a crash mid-save never corrupts the restore point;
* keep_last_k garbage-collects old steps.

On a real multi-host deployment the gather becomes per-host shard files
keyed by shard index — the manifest already records the mesh; the single-
process container writes one file.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np

SEP = "/"


def _flatten(tree, prefix="") -> dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}{SEP}"))
        return out
    out[prefix.rstrip(SEP)] = np.asarray(tree)
    return out


def _unflatten(flat: dict[str, np.ndarray]) -> dict:
    tree: dict = {}
    for path, val in flat.items():
        node = tree
        parts = path.split(SEP)
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return tree


class CheckpointManager:
    def __init__(self, directory: str, keep_last_k: int = 3):
        self.directory = directory
        self.keep = keep_last_k
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def save(self, step: int, state: dict, extra: dict | None = None,
             block: bool = False) -> None:
        """Async save. Gathers to host synchronously (cheap vs step time),
        writes on a background thread."""
        self.wait()
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                  state)
        extra = dict(extra or {})

        def write():
            tag = f"step_{step:08d}"
            tmp = os.path.join(self.directory, f".tmp_{tag}_{time.time_ns()}")
            os.makedirs(tmp, exist_ok=True)
            flat = _flatten(host_state)
            np.savez(os.path.join(tmp, "arrays.npz"),
                     **{k: v for k, v in flat.items()})
            manifest = {
                "step": step,
                "paths": {k: [list(v.shape), str(v.dtype)]
                          for k, v in flat.items()},
                "extra": extra,
                "time": time.time(),
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            final = os.path.join(self.directory, tag)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            with open(os.path.join(self.directory, ".LATEST_tmp"), "w") as f:
                f.write(tag)
                f.flush()
                os.fsync(f.fileno())
            os.rename(os.path.join(self.directory, ".LATEST_tmp"),
                      os.path.join(self.directory, "LATEST"))
            self._gc()

        self._thread = threading.Thread(target=write, daemon=True)
        self._thread.start()
        if block:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(d for d in os.listdir(self.directory)
                       if d.startswith("step_"))
        for d in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, d),
                          ignore_errors=True)

    # ------------------------------------------------------------------
    def latest_step(self) -> int | None:
        ptr = os.path.join(self.directory, "LATEST")
        if not os.path.exists(ptr):
            return None
        with open(ptr) as f:
            tag = f.read().strip()
        path = os.path.join(self.directory, tag, "manifest.json")
        if not os.path.exists(path):
            return None
        with open(path) as f:
            return json.load(f)["step"]

    def restore(self, step: int | None = None, shardings=None
                ) -> tuple[dict, dict, int] | None:
        """Returns (state, extra, step) or None.  ``shardings``: optional
        pytree of NamedSharding (same structure) — arrays are device_put
        onto it, which is what makes restore elastic across mesh shapes."""
        if step is None:
            step = self.latest_step()
            if step is None:
                return None
        tag = f"step_{step:08d}"
        base = os.path.join(self.directory, tag)
        with open(os.path.join(base, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(base, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        state = _unflatten(flat)
        if shardings is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s), state, shardings)
        return state, manifest.get("extra", {}), manifest["step"]
