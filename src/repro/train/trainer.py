"""Training loop substrate: train-step builder with gradient accumulation,
remat policy, sharded state, and the paper-technique hooks.

``make_train_step`` builds the jittable (state, batch) -> (state, metrics)
function the launcher and the dry-run both lower:

* microbatch gradient accumulation via ``lax.scan`` (the microbatch count
  is one of the autotuner's knobs — it trades activation memory against
  per-step overhead, DESIGN.md A2);
* activation checkpointing via ``jax.checkpoint`` with a configurable
  policy around the per-microbatch loss (applies through the layer scan);
* gradient compression with error feedback before the optimizer (the
  cross-pod wire-byte saving is accounted in the roofline DCI term —
  XLA's in-jit DP reduction itself stays dense; see optim/compression.py);
* AdamW with schedule + global-norm clip.

TrainState is a plain dict {params, opt, error?} so checkpointing stays
structural.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import zoo
from repro.models.common import ModelConfig
from repro.optim import (AdamWConfig, CompressionConfig, adamw_update,
                         compress, init_error_state, init_opt_state,
                         abstract_opt_state)

Params = Any


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    remat: bool = True
    remat_policy: str = "dots"       # nothing | dots | everything
    accum_dtype: str = "float32"     # grad-accumulator dtype (bf16 halves
                                     # the accumulation buffer: needed to
                                     # fit llama3-405b on one pod)
    aux_weight: float = 0.01
    optimizer: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)
    compression: CompressionConfig = dataclasses.field(
        default_factory=CompressionConfig)


def init_state(cfg: ModelConfig, tcfg: TrainConfig, key) -> dict:
    params = zoo.init(cfg, key)
    state = {"params": params,
             "opt": init_opt_state(tcfg.optimizer, params)}
    if tcfg.compression.scheme != "none" and tcfg.compression.ef:
        state["error"] = init_error_state(params)
    return state


def abstract_state(cfg: ModelConfig, tcfg: TrainConfig) -> dict:
    params = zoo.abstract(cfg)
    state = {"params": params,
             "opt": abstract_opt_state(tcfg.optimizer, params)}
    if tcfg.compression.scheme != "none" and tcfg.compression.ef:
        state["error"] = jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params)
    return state


def state_specs(cfg: ModelConfig, tcfg: TrainConfig) -> dict:
    """Logical-axes tree matching init_state's structure."""
    pspecs = zoo.specs(cfg)
    out = {"params": pspecs,
           "opt": {"mu": pspecs, "nu": pspecs, "step": ()}}
    if tcfg.compression.scheme != "none" and tcfg.compression.ef:
        out["error"] = pspecs
    return out


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig,
                    batch_axes: tuple[str, ...] | None = None
                    ) -> Callable[[dict, dict], tuple[dict, dict]]:
    """``batch_axes``: mesh axes the batch dim is sharded over; when set,
    the microbatched tree gets an explicit sharding constraint — the
    (B,) -> (n_micro, B/n) reshape is ambiguous to GSPMD and silently
    de-shards the batch otherwise (found in the first dry-run)."""
    n_micro = tcfg.microbatches
    # per-LAYER remat (jax.checkpoint around the models' scan bodies):
    # checkpointing the whole loss would still stack full per-layer
    # backward residuals inside the layer scan (found in the first
    # dry-run: 128 GiB of stacked attention residuals for olmo-1b)
    if tcfg.remat:
        cfg = dataclasses.replace(cfg, remat="full")

    def micro_loss(params, mb):
        loss, metrics = zoo.loss_fn(cfg, params, mb,
                                    aux_weight=tcfg.aux_weight)
        return loss, metrics

    grad_fn = jax.value_and_grad(micro_loss, has_aux=True)

    def train_step(state: dict, batch: dict) -> tuple[dict, dict]:
        params = state["params"]

        def reshape_micro(x):
            b = x.shape[0]
            assert b % n_micro == 0, (b, n_micro)
            return x.reshape(n_micro, b // n_micro, *x.shape[1:])

        micro = jax.tree.map(reshape_micro, batch)
        if batch_axes:
            from jax.sharding import PartitionSpec as P

            def constrain(x):
                spec = P(None, tuple(batch_axes),
                         *([None] * (x.ndim - 2)))
                return jax.lax.with_sharding_constraint(x, spec)

            micro = jax.tree.map(constrain, micro)

        acc_dt = jnp.dtype(tcfg.accum_dtype)

        def acc_body(carry, mb):
            gsum, lsum = carry
            (loss, metrics), grads = grad_fn(params, mb)
            gsum = jax.tree.map(
                lambda a, g: a + g.astype(acc_dt), gsum, grads)
            return (gsum, lsum + loss), metrics

        gzero = jax.tree.map(
            lambda p: jnp.zeros(p.shape, acc_dt), params)
        (gsum, lsum), _ = jax.lax.scan(acc_body, (gzero, 0.0), micro)
        grads = jax.tree.map(
            lambda g: (g.astype(jnp.float32) / n_micro).astype(acc_dt), gsum)
        loss = lsum / n_micro

        metrics = {"loss": loss}
        if "error" in state:
            grads, new_error, cm = compress(
                tcfg.compression, grads, state["error"])
            metrics.update(cm)
        new_params, new_opt, om = adamw_update(
            tcfg.optimizer, grads, state["opt"], params)
        metrics.update(om)
        new_state = {"params": new_params, "opt": new_opt}
        if "error" in state:
            new_state["error"] = new_error
        return new_state, metrics

    return train_step


def make_eval_step(cfg: ModelConfig, tcfg: TrainConfig):
    def eval_step(state: dict, batch: dict) -> dict:
        loss, metrics = zoo.loss_fn(cfg, state["params"], batch,
                                    aux_weight=tcfg.aux_weight)
        return {"loss": loss, **metrics}
    return eval_step


def make_prefill_step(cfg: ModelConfig, max_len: int):
    def prefill_step(params: dict, batch: dict):
        return zoo.prefill(cfg, params, batch, max_len)
    return prefill_step


def make_serve_step(cfg: ModelConfig):
    """One decode step: (params, cache, token, pos) -> (logits, cache)."""
    def serve_step(params: dict, cache: dict, token, pos):
        return zoo.decode_step(cfg, params, cache, token, pos)
    return serve_step
