"""codeqwen1.5-7b [dense]: 32L d4096 32H (kv=32, MHA-style) ff13440
vocab92416. [hf:Qwen/CodeQwen1.5-7B; hf]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="codeqwen1.5-7b", family="dense", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=32, d_ff=13440, vocab=92416, head_dim=128,
    norm="rms", act="swiglu", rope_theta=1000000.0)

SMOKE = ModelConfig(
    arch_id="codeqwen1.5-7b-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=192, vocab=512, head_dim=16,
    norm="rms", act="swiglu", dtype="float32", param_dtype="float32")
