"""recurrentgemma-2b [hybrid]: 26L d2560 10H (MQA kv=1) ff7680
vocab256000 — RG-LRU + local attention (window 2048), 1 attn per 2
recurrent blocks.  Bounded state => long_500k runs.
[arXiv:2402.19427; hf]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="recurrentgemma-2b", family="hybrid", n_layers=26, d_model=2560,
    n_heads=10, n_kv_heads=1, d_ff=7680, vocab=256000, head_dim=256,
    window=2048, block_pattern=("rglru", "rglru", "attn"), rglru_dim=2560,
    logit_softcap=30.0, tie_embeddings=True)

SMOKE = ModelConfig(
    arch_id="recurrentgemma-smoke", family="hybrid", n_layers=5, d_model=64,
    n_heads=2, n_kv_heads=1, d_ff=128, vocab=512, head_dim=32,
    window=8, block_pattern=("rglru", "rglru", "attn"), rglru_dim=64,
    dtype="float32", param_dtype="float32")
