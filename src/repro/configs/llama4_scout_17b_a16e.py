"""llama4-scout-17b-16e [moe]: 48L d5120 40H (GQA kv=8) ff8192
vocab202048, MoE 16 experts top-1.  Treated as full attention (its iRoPE
chunking is out of scope) => long_500k skipped (DESIGN.md §5).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="llama4-scout-17b-a16e", family="moe", n_layers=48,
    d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192, vocab=202048,
    head_dim=128, moe_experts=16, moe_top_k=1, norm="rms", act="swiglu")

SMOKE = ModelConfig(
    arch_id="llama4-scout-smoke", family="moe", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=96, vocab=512, head_dim=16,
    moe_experts=4, moe_top_k=1, moe_capacity_factor=8.0,
    norm="rms", act="swiglu",
    dtype="float32", param_dtype="float32")
