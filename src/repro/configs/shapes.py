"""Assigned input shapes and the (arch x shape) cell matrix.

Four shapes per arch (task spec):
  train_4k     seq 4096,  global_batch 256   -> train_step
  prefill_32k  seq 32768, global_batch 32    -> prefill_step
  decode_32k   seq 32768, global_batch 128   -> serve_step (1 new token,
                                                seq_len KV cache)
  long_500k    seq 524288, global_batch 1    -> serve_step; ONLY for
                                                sub-quadratic archs

Skips (DESIGN.md §5): long_500k is skipped for pure full-attention archs
(granite, llama3-405b, codeqwen, olmo, llama4-scout, vision, whisper) and
runs for rwkv6 / recurrentgemma / mixtral (SWA-bounded cache).
"""

from __future__ import annotations

import dataclasses

from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int

    def scaled(self, seq: int | None = None, batch: int | None = None
               ) -> "ShapeSpec":
        return ShapeSpec(self.name, self.kind, seq or self.seq_len,
                         batch or self.global_batch)


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def skip_reason(cfg: ModelConfig, shape: ShapeSpec) -> str | None:
    """None if the cell runs; else a one-line reason recorded per cell."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return ("full-attention arch: 500k decode needs sub-quadratic "
                "attention (DESIGN.md §5)")
    return None


def cells(cfg: ModelConfig) -> list[tuple[ShapeSpec, str | None]]:
    return [(s, skip_reason(cfg, s)) for s in SHAPES.values()]
