"""whisper-small [audio]: 12L enc + 12L dec, d768 12H (kv=12) ff3072
vocab51865 — enc-dec; conv/mel frontend is a stub (precomputed frame
embeddings, 1500 frames).  decode_32k exercises the decoder KV cache at
32k synthetically (real whisper caps at 448 tokens — noted, not skipped);
long_500k skipped (full-attention decoder). [arXiv:2212.04356; unverified]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-small", family="audio", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=12, d_ff=3072, vocab=51865, head_dim=64,
    encoder_layers=12, n_frontend_tokens=1500, norm="layernorm", act="gelu")

SMOKE = ModelConfig(
    arch_id="whisper-small-smoke", family="audio", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=128, vocab=512, head_dim=16,
    encoder_layers=2, n_frontend_tokens=12, norm="layernorm", act="gelu",
    dtype="float32", param_dtype="float32")
