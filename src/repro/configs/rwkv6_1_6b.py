"""rwkv6-1.6b "Finch" [ssm]: 24L d2048 ff7168 vocab65536 — attention-free,
data-dependent decay; 32 heads of dim 64.  O(1) state => long_500k runs.
[arXiv:2404.05892; unverified]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="rwkv6-1.6b", family="ssm", n_layers=24, d_model=2048,
    n_heads=32, n_kv_heads=32, d_ff=7168, vocab=65536, head_dim=64)

SMOKE = ModelConfig(
    arch_id="rwkv6-1.6b-smoke", family="ssm", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=128, vocab=512, head_dim=16,
    dtype="float32", param_dtype="float32")
