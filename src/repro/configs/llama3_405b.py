"""llama3-405b [dense]: 126L d16384 128H (GQA kv=8) ff53248 vocab128256.
[arXiv:2407.21783; unverified]

Optimizer-state dtype is bf16 for this arch (m/v moments): fp32 moments
for 405B params exceed 16 GiB/chip HBM on a single 256-chip pod; bf16
moments + fp32 master-free AdamW keeps the train_4k cell resident
(see EXPERIMENTS.md memory analysis)."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="llama3-405b", family="dense", n_layers=126, d_model=16384,
    n_heads=128, n_kv_heads=8, d_ff=53248, vocab=128256, head_dim=128,
    norm="rms", act="swiglu", param_dtype="bfloat16", rope_theta=500000.0)

SMOKE = ModelConfig(
    arch_id="llama3-405b-smoke", family="dense", n_layers=3, d_model=128,
    n_heads=8, n_kv_heads=2, d_ff=256, vocab=512, head_dim=16,
    norm="rms", act="swiglu", dtype="float32", param_dtype="float32")
