"""olmo-1b [dense]: 16L d2048 16H (kv=16) ff8192 vocab50304 — OLMo's
non-parametric LayerNorm, tied embeddings. [arXiv:2402.00838; hf]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="olmo-1b", family="dense", n_layers=16, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=8192, vocab=50304, head_dim=128,
    norm="nonparam", act="swiglu", tie_embeddings=True)

SMOKE = ModelConfig(
    arch_id="olmo-1b-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=256, vocab=512, head_dim=16,
    norm="nonparam", act="swiglu", tie_embeddings=True,
    dtype="float32", param_dtype="float32")
