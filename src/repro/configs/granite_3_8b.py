"""granite-3-8b [dense]: 40L d4096 32H (GQA kv=8) ff12800 vocab49155.
[hf:ibm-granite/granite-3.0-2b-base family; hf]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="granite-3-8b", family="dense", n_layers=40, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=12800, vocab=49155, head_dim=128,
    norm="rms", act="swiglu")

SMOKE = ModelConfig(
    arch_id="granite-3-8b-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=160, vocab=512, head_dim=16,
    norm="rms", act="swiglu", dtype="float32", param_dtype="float32")
