"""Config registry: the 10 assigned architectures (+ smoke variants) and
the paper's own CNN op-graph workloads (resnet50/dcgan/inception_v3 —
exercised by ``repro.core`` and ``benchmarks/``, see core/graph.py)."""

from __future__ import annotations

from repro.configs import (codeqwen1_5_7b, granite_3_8b, llama3_405b,
                           llama4_scout_17b_a16e, llama_3_2_vision_11b,
                           mixtral_8x7b, olmo_1b, recurrentgemma_2b,
                           rwkv6_1_6b, whisper_small)
from repro.configs.shapes import SHAPES, ShapeSpec, cells, skip_reason
from repro.models.common import ModelConfig

_MODULES = {
    "granite-3-8b": granite_3_8b,
    "llama3-405b": llama3_405b,
    "codeqwen1.5-7b": codeqwen1_5_7b,
    "olmo-1b": olmo_1b,
    "llama4-scout-17b-a16e": llama4_scout_17b_a16e,
    "mixtral-8x7b": mixtral_8x7b,
    "rwkv6-1.6b": rwkv6_1_6b,
    "llama-3.2-vision-11b": llama_3_2_vision_11b,
    "recurrentgemma-2b": recurrentgemma_2b,
    "whisper-small": whisper_small,
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str, smoke: bool = False) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = _MODULES[arch_id]
    return mod.SMOKE if smoke else mod.CONFIG


def all_configs(smoke: bool = False) -> dict[str, ModelConfig]:
    return {a: get_config(a, smoke) for a in ARCH_IDS}


__all__ = ["ARCH_IDS", "get_config", "all_configs", "SHAPES", "ShapeSpec",
           "cells", "skip_reason", "ModelConfig"]
