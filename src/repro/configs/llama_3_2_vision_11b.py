"""llama-3.2-vision-11b [vlm]: 40L d4096 32H (GQA kv=8) ff14336
vocab128256 — cross-attention image layers every 5th layer; vision
frontend is a stub (precomputed patch embeddings, 1600 tokens).
Full attention => long_500k skipped.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="llama-3.2-vision-11b", family="vlm", n_layers=40, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab=128256, head_dim=128,
    cross_attn_every=5, n_frontend_tokens=1600, norm="rms", act="swiglu",
    rope_theta=500000.0)

SMOKE = ModelConfig(
    arch_id="llama-3.2-vision-smoke", family="vlm", n_layers=4, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab=512, head_dim=16,
    cross_attn_every=2, n_frontend_tokens=8,
    dtype="float32", param_dtype="float32")
