"""mixtral-8x7b [moe]: 32L d4096 32H (GQA kv=8) ff14336 vocab32000,
8 experts top-2, sliding-window attention (4096).  SWA bounds the KV
cache, so the long_500k decode cell RUNS for this arch.
[arXiv:2401.04088; hf]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="mixtral-8x7b", family="moe", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab=32000, head_dim=128,
    moe_experts=8, moe_top_k=2, window=4096, norm="rms", act="swiglu")

SMOKE = ModelConfig(
    arch_id="mixtral-8x7b-smoke", family="moe", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=96, vocab=512, head_dim=16,
    moe_experts=4, moe_top_k=2, window=8, moe_capacity_factor=8.0,
    norm="rms", act="swiglu",
    dtype="float32", param_dtype="float32")
