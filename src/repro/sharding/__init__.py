from repro.sharding.specs import (OP_CLASS_AXES, named_sharding_tree,
                                  batch_sharding, replicated,
                                  plan_from_degrees, degree_to_axes,
                                  clamp_degree_for_axis, validate_plan)
from repro.sharding.collective_matmul import ring_ag_matmul, reference_ag_matmul

__all__ = ["OP_CLASS_AXES", "named_sharding_tree", "batch_sharding",
           "replicated", "plan_from_degrees", "degree_to_axes",
           "clamp_degree_for_axis", "validate_plan", "ring_ag_matmul",
           "reference_ag_matmul"]
