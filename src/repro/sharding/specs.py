"""ShardingPlan -> concrete NamedShardings; the autotuner's output surface.

The paper-technique integration point (DESIGN.md §4): the autotuner's
per-op-class shard degrees materialize here as a ``ShardingPlan`` whose
rules map logical axes to mesh axes.  ``plan_from_degrees`` converts a
``ShardPlanResult`` (degrees per op class) into rules on a mesh whose
``model`` axis has been factored into sub-axes — degree-8 sharding on a
16-wide model axis is expressed by splitting the axis into ('mdl', 'sub')
and assigning only 'mdl'.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.common import ModelConfig, ShardingPlan

# op classes the tuner knows, and the logical axes each one controls
OP_CLASS_AXES: dict[str, tuple[str, ...]] = {
    "attention": ("heads", "kv"),
    "mlp": ("ff",),
    "moe": ("expert",),
    "embed": ("vocab",),
    "recurrence": ("state",),
}


def named_sharding_tree(plan: ShardingPlan, mesh: Mesh, logical_tree):
    """Map a logical-axes spec tree to NamedShardings on ``mesh``."""
    def leaf(spec: tuple) -> NamedSharding:
        return NamedSharding(mesh, plan.spec_for(spec))
    return jax.tree.map(
        leaf, logical_tree,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x))


def batch_sharding(plan: ShardingPlan, mesh: Mesh, *,
                   seq_dim: int | None = None) -> NamedSharding:
    """(B, S, ...) activation sharding: batch over plan.batch_axes, and
    optionally sequence over plan.seq_axes (sequence parallelism)."""
    parts: list = [tuple(plan.batch_axes) or None]
    if seq_dim is not None:
        parts.append(tuple(plan.seq_axes) or None)
    return NamedSharding(mesh, P(*parts))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def degree_to_axes(degree: int, model_axes: tuple[tuple[str, int], ...]
                   ) -> tuple[str, ...]:
    """Greedily pick mesh sub-axes whose product equals ``degree``.

    model_axes: ((name, size), ...) in preference order (ICI-near first).
    degree must be a product of a prefix of the sizes."""
    axes: list[str] = []
    left = degree
    for name, size in model_axes:
        if left <= 1:
            break
        if left % size == 0:
            axes.append(name)
            left //= size
        elif size % left == 0 and left > 1:
            # would need a partial axis: not expressible -> caller must
            # factor the mesh so degrees are products of sub-axis sizes
            raise ValueError(
                f"degree {degree} not expressible with axes {model_axes}")
    if left != 1:
        raise ValueError(
            f"degree {degree} not expressible with axes {model_axes}")
    return tuple(axes)


def plan_from_degrees(degrees: dict[str, int],
                      model_axes: tuple[tuple[str, int], ...],
                      *, fsdp_axes: tuple[str, ...] = ("data",),
                      batch_axes: tuple[str, ...] = ("data",),
                      ) -> ShardingPlan:
    """Build a ShardingPlan from per-op-class shard degrees (the frozen
    Strategy-1/2 output of the autotuner)."""
    rules: dict[str, tuple[str, ...]] = {
        "embed": tuple(fsdp_axes),
        "layers": (), "conv": (),
    }
    for cls, logical_axes in OP_CLASS_AXES.items():
        deg = degrees.get(cls, 1)
        axes = degree_to_axes(deg, model_axes)
        for la in logical_axes:
            rules[la] = axes
    # kv heads cannot shard beyond their count: the caller clamps the
    # attention degree; here we simply mirror it
    return ShardingPlan(rules=rules, batch_axes=batch_axes)


def clamp_degree_for_axis(degree: int, axis_len: int) -> int:
    """Largest power-of-two divisor of axis_len that is <= degree."""
    d = 1
    while d * 2 <= min(degree, axis_len) and axis_len % (d * 2) == 0:
        d *= 2
    return d


def validate_plan(cfg: ModelConfig, plan: ShardingPlan, mesh: Mesh) -> list[str]:
    """Static divisibility checks: every sharded dim must divide evenly.
    Returns a list of problems (empty = ok)."""
    problems = []
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def axes_size(axes: tuple[str, ...]) -> int:
        n = 1
        for a in axes:
            n *= sizes.get(a, 1)
        return n

    # param dims are stored flattened (heads*hd), so divisibility is on the
    # flattened sizes; head-granularity locality is a perf matter the
    # autotuner discovers through the collective term, not a validity one.
    checks = {
        "heads": cfg.n_heads * cfg.hd, "kv": cfg.n_kv_heads * cfg.hd,
        "ff": cfg.d_ff, "vocab": cfg.vocab, "embed": cfg.d_model,
        "expert": cfg.moe_experts or 1,
    }
    for axis_name, dim in checks.items():
        deg = axes_size(plan.rules.get(axis_name, ()))
        if deg > 1 and dim % deg:
            problems.append(f"{axis_name}={dim} not divisible by {deg}")
    return problems
