"""Chunked collective matmul: overlap weight all-gather with compute.

The Strategy-4 analogue on TPU (DESIGN.md §2): while the MXU multiplies
chunk i of the weight matrix, the ICI "second pipe" gathers chunk i+1.
Expressed with shard_map + lax.ppermute as a ring: each step multiplies
the locally-held shard and rotates it to the neighbor, so after N steps
every device has consumed every shard with the permute hidden under the
dot (XLA schedules collective-permute-start/done around the dot).

This is the classic "collective matmul" / all-gather-matmul overlap
(Wang et al., overlap-friendly GSPMD lowering); the perf pass enables it
for FSDP weight gathering where the dry-run shows serialized
all-gather -> dot chains.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map


def ring_ag_matmul(x: jax.Array, w: jax.Array, *, mesh: Mesh,
                   axis: str = "model") -> jax.Array:
    """y = x @ w with w sharded on its FIRST dim over ``axis``; x sharded
    on its last dim the same way (the typical FSDP/TP boundary).

    x: (..., K) sharded (K/n per device is NOT required — x comes in
    replicated over ``axis`` here and each step consumes the k-slice
    matching the currently-held w shard).  w: (K, N) row-sharded.
    """
    n = mesh.shape[axis]

    def body(x_local, w_shard):
        # x_local: full (..., K); w_shard: (K/n, N)
        k_shard = w_shard.shape[0]
        idx = jax.lax.axis_index(axis)

        def step(i, carry):
            acc, w_cur = carry
            # after i forward rotations, this device holds the shard that
            # started at device (idx - i) mod n
            src = (idx - i) % n
            x_slice = jax.lax.dynamic_slice_in_dim(
                x_local, src * k_shard, k_shard, axis=x_local.ndim - 1)
            acc = acc + jnp.einsum("...k,kn->...n", x_slice, w_cur)
            # rotate the shard around the ring (overlaps with next dot)
            w_nxt = jax.lax.ppermute(
                w_cur, axis, [(j, (j + 1) % n) for j in range(n)])
            return acc, w_nxt

        out_shape = x_local.shape[:-1] + (w_shard.shape[1],)
        acc0 = jnp.zeros(out_shape, x_local.dtype)
        acc, _ = jax.lax.fori_loop(0, n, step, (acc0, w_shard))
        return acc

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(axis, None)),
        out_specs=P(),
        check_rep=False,
    )(x, w)


def reference_ag_matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.einsum("...k,kn->...n", x, w)
