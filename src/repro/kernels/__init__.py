"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel ships as a trio (DESIGN.md S3):
  kernel.py  pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py     jit'd public wrapper (+ CPU fallback to the oracle)
  ref.py     pure-jnp oracle used by the allclose test sweeps

Kernels: flash_attention (GQA/causal/SWA), rwkv6 (chunked WKV6), rglru
(chunked gated linear recurrence).
"""
