"""Jit'd wrapper for the RG-LRU kernel with CPU fallback to the oracle."""

from __future__ import annotations

import functools

import jax

from repro.kernels.rglru.kernel import rglru_kernel
from repro.kernels.rglru.ref import rglru_ref


@functools.partial(jax.jit, static_argnames=("chunk", "interpret", "fallback"))
def rglru(a, x, *, chunk: int = 128, interpret: bool = False,
          fallback: bool = False):
    """a, x: (B,S,R) -> (h (B,S,R), h_last (B,R))."""
    if fallback:
        return rglru_ref(a, x)
    return rglru_kernel(a, x, chunk=chunk, interpret=interpret)


def rglru_auto(a, x, *, chunk: int = 128):
    on_tpu = jax.default_backend() == "tpu"
    return rglru(a, x, chunk=chunk, fallback=not on_tpu)
