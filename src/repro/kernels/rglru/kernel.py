"""RG-LRU Pallas-TPU kernel: chunked gated linear recurrence.

TPU adaptation (DESIGN.md §2): a diagonal RNN has no matmul to feed the
MXU — the right TPU shape (as in the Griffin/recurrentgemma reference
kernels) is a sequential VPU scan over a VMEM-resident chunk: the chunk
of (a, x) is DMA'd HBM->VMEM once, the inner loop does one vector FMA per
step (h = a_t*h + sqrt(1-a_t^2)*x_t) writing rows back to the output
block, and the (1,R) carry persists in VMEM scratch across the sequential
chunk grid dimension.  Exact — no log-space clipping needed (a naive
telescoped-cumsum factorization overflows fp32 under Griffin's strong
decays; see ref.py oracle tests).

Grid: (B, n_chunks), chunks innermost/sequential per batch row.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, x_ref, o_ref, hlast_ref, h_ref, *, chunk: int):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0].astype(jnp.float32)             # (C,R)
    x = x_ref[0].astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * x

    def step(t, h):
        a_t = jax.lax.dynamic_slice_in_dim(a, t, 1, axis=0)   # (1,R)
        b_t = jax.lax.dynamic_slice_in_dim(b, t, 1, axis=0)
        h = a_t * h + b_t
        # all-slice index: an int dim-0 index breaks older pallas
        # NDIndexer handling (idx.indices entries must have .shape)
        pl.store(o_ref, (pl.ds(0, 1), pl.ds(t, 1), slice(None)),
                 h[None].astype(o_ref.dtype))
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_ref[...])
    h_ref[...] = h

    @pl.when(ci == nc - 1)
    def _flush():
        hlast_ref[...] = h.astype(hlast_ref.dtype)


def rglru_kernel(a: jax.Array, x: jax.Array, *, chunk: int = 128,
                 interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """a, x: (B,S,R). Returns (h (B,S,R), h_last (B,R) fp32)."""
    bsz, s, r = a.shape
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    kernel = functools.partial(_rglru_kernel, chunk=chunk)
    h, h_last = pl.pallas_call(
        kernel,
        grid=(bsz, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, r), lambda b_, c_: (b_, c_, 0)),
            pl.BlockSpec((1, chunk, r), lambda b_, c_: (b_, c_, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, chunk, r), lambda b_, c_: (b_, c_, 0)),
            pl.BlockSpec((1, r), lambda b_, c_: (b_, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((bsz, s, r), x.dtype),
            jax.ShapeDtypeStruct((bsz, r), jnp.float32),
        ),
        scratch_shapes=[pltpu.VMEM((1, r), jnp.float32)],
        interpret=interpret,
    )(a, x)
    return h, h_last
