"""Pure-jnp oracle for the RG-LRU recurrence: exact per-step scan.

h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * x_t   (elementwise over channels)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_ref(a: jax.Array, x: jax.Array, h0: jax.Array | None = None
              ) -> tuple[jax.Array, jax.Array]:
    """a, x: (B,S,R), a in (0,1). Returns (h (B,S,R), h_last (B,R))."""
    af = a.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - af * af, 1e-12)) * xf
    h_init = (jnp.zeros((a.shape[0], a.shape[2]), jnp.float32)
              if h0 is None else h0.astype(jnp.float32))

    def step(h, xs):
        a_t, b_t = xs
        h = a_t * h + b_t
        return h, h

    hs_last, hs = jax.lax.scan(step, h_init,
                               (jnp.moveaxis(af, 1, 0), jnp.moveaxis(b, 1, 0)))
    return jnp.moveaxis(hs, 0, 1).astype(x.dtype), hs_last
