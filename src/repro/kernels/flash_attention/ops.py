"""Jit'd public wrapper for the flash attention kernel.

On TPU the Pallas kernel runs compiled; elsewhere (this CPU container)
``interpret=True`` executes the kernel body in Python for validation, and
``flash_attention(..., fallback=True)`` routes to the jnp oracle — which
is also what the models' forward passes use on CPU.
"""

from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret", "fallback"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False,
                    fallback: bool = False) -> jax.Array:
    """q: (B,S,H,D); k/v: (B,S,K,D) -> (B,S,H,D)."""
    if fallback:
        return attention_ref(q, k, v, causal=causal, window=window)
    return flash_attention_kernel(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret)


def flash_attention_auto(q, k, v, *, causal=True, window=None,
                         block_q=128, block_k=128):
    """Kernel on TPU, oracle elsewhere — the model-facing entry point."""
    return flash_attention(q, k, v, causal=causal, window=window,
                           block_q=block_q, block_k=block_k,
                           fallback=not _on_tpu())
