"""Flash attention Pallas-TPU kernel: online-softmax tiling in VMEM.

TPU adaptation notes (DESIGN.md §2): FlashAttention's CUDA formulation
(shared-memory tiles + warp reductions) is re-tiled for the TPU memory
hierarchy — HBM->VMEM block copies driven by BlockSpec index maps, MXU-
aligned (128) q/k tiles, fp32 accumulators in VMEM scratch that persist
across the innermost (k-block) grid dimension.  Fully-masked k-blocks
(above the causal diagonal / outside the sliding window) skip their
compute via ``pl.when``.

Grid: (batch, q_heads, q_blocks, k_blocks), k innermost so the scratch
(m, l, acc) carries the online softmax state for one q tile.
GQA: the k/v BlockSpec index maps fold the q head onto its kv group —
kv tiles are fetched once per group without materializing repeats in HBM.

Scratch follows the TPU convention of lane-broadcast row stats:
m/l are (block_q, 128) with the statistic replicated across lanes.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
LANES = 128


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               block_q: int, block_k: int, seq_len: int, causal: bool,
               window: int | None, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)
    q_start = qi * block_q
    k_start = ki * block_k

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # block-level skip: entirely above causal diagonal or outside window
    run = k_start < seq_len
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + block_q - 1)
    if window is not None:
        run = jnp.logical_and(run,
                              k_start + block_k - 1 > q_start - window)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale      # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)              # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        mask = kpos < seq_len
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window is not None:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        # fully-masked rows: keep p exactly zero (exp(NEG_INF-m) underflows
        # already, but guard the all-masked-row case where m_new == NEG_INF)
        p = jnp.where(m_new[:, None] == NEG_INF, 0.0, p)
        alpha = jnp.where(m_prev == NEG_INF, 0.0, jnp.exp(m_prev - m_new))
        l_ref[...] = (l_ref[:, 0] * alpha + jnp.sum(p, axis=1)
                      )[:, None] * jnp.ones((1, LANES), jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new[:, None] * jnp.ones((1, LANES), jnp.float32)

    @pl.when(ki == nk - 1)
    def _flush():
        l = l_ref[:, 0]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


def flash_attention_kernel(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int | None = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False) -> jax.Array:
    """q: (B,S,H,D); k/v: (B,S,K,D). Returns (B,S,H,D)."""
    b, s, h, d = q.shape
    kh = k.shape[2]
    assert h % kh == 0, (h, kh)
    group = h // kh
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    nq = s // block_q
    nk = s // block_k
    scale = 1.0 / math.sqrt(d)

    qt = jnp.swapaxes(q, 1, 2)          # (B,H,S,D)
    kt = jnp.swapaxes(k, 1, 2)          # (B,K,S,D)
    vt = jnp.swapaxes(v, 1, 2)

    kernel = functools.partial(
        _fa_kernel, block_q=block_q, block_k=block_k, seq_len=s,
        causal=causal, window=window, scale=scale)

    out = pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b_, h_, q_, k_: (b_, h_, q_, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, q_, k_, g=group: (b_, h_ // g, k_, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, q_, k_, g=group: (b_, h_ // g, k_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h_, q_, k_: (b_, h_, q_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, LANES), jnp.float32),   # running max
            pltpu.VMEM((block_q, LANES), jnp.float32),   # running sum
            pltpu.VMEM((block_q, d), jnp.float32),       # accumulator
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.swapaxes(out, 1, 2)
