"""Pure-jnp oracle for the flash attention kernel (GQA, causal, window)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int | None = None
                  ) -> jax.Array:
    """q: (B,S,H,D); k/v: (B,S,K,D), H multiple of K. fp32 math."""
    b, s, h, d = q.shape
    kh = k.shape[2]
    rep = h // kh
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(d)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
