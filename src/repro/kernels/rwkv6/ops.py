"""Jit'd wrapper for the WKV6 kernel with CPU fallback to the oracle."""

from __future__ import annotations

import functools

import jax

from repro.kernels.rwkv6.kernel import wkv6_kernel
from repro.kernels.rwkv6.ref import wkv6_ref


@functools.partial(jax.jit, static_argnames=("chunk", "interpret", "fallback"))
def wkv6(r, k, v, w, u, *, chunk: int = 64, interpret: bool = False,
         fallback: bool = False):
    """r,k,v,w: (B,H,S,D); u: (H,D) -> (out (B,H,S,D), state (B,H,D,D))."""
    if fallback:
        return wkv6_ref(r, k, v, w, u)
    return wkv6_kernel(r, k, v, w, u, chunk=chunk, interpret=interpret)


def wkv6_auto(r, k, v, w, u, *, chunk: int = 64):
    on_tpu = jax.default_backend() == "tpu"
    return wkv6(r, k, v, w, u, chunk=chunk, fallback=not on_tpu)
