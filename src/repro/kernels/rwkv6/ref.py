"""Pure-jnp oracle for the WKV6 recurrence: exact per-step scan.

S_t = diag(w_t) S_{t-1} + k_t v_t^T
out_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
             u: jax.Array, state0: jax.Array | None = None
             ) -> tuple[jax.Array, jax.Array]:
    """r,k,v,w: (B,H,S,D); u: (H,D). Returns (out (B,H,S,D), S (B,H,D,D))."""
    b, h, s, d = r.shape
    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w))
    uf = u.astype(jnp.float32)
    S0 = (jnp.zeros((b, h, d, d), jnp.float32) if state0 is None
          else state0.astype(jnp.float32))

    def step(S, xs):
        r_t, k_t, v_t, w_t = xs            # (B,H,D)
        kv = jnp.einsum("bhd,bhv->bhdv", k_t, v_t)
        out = jnp.einsum("bhd,bhdv->bhv", r_t,
                         S + uf[None, :, :, None] * kv)
        S = S * w_t[..., None] + kv
        return S, out

    xs = tuple(jnp.moveaxis(x, 2, 0) for x in (rf, kf, vf, wf))
    S, outs = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(outs, 0, 2).astype(r.dtype), S
