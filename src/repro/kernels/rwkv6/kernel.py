"""WKV6 chunked Pallas-TPU kernel.

TPU adaptation (DESIGN.md §2): the CUDA RWKV kernels walk the sequence one
step per thread-block with the state in registers/shared memory.  On TPU
we use the chunked matmul factorization instead, so the MXU does the work
and the (D,D) fp32 state lives in VMEM scratch across the sequential
chunk grid dimension:

  scores[t,j] = sum_d r[t,d] k[j,d] e^{ct[t,d]-cum[j,d]}   (t > j)
  out = scores @ V + (r.k*u) * v + (r e^{ct}) @ S
  S   = diag(e^{cum_C}) S + (k e^{cum_C - cum})^T V

with cum = per-chunk cumulative log-decay, ct = cum - logw (cum through
t-1).  Every exponent is a DIFFERENCE <= 0, so the math is exact and
overflow-free even under RWKV6's strongest data-dependent decays
(validated against the exact per-step oracle down to w ~ 1e-4).

Grid: (B, H, n_chunks), chunks innermost/sequential.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, sout_ref, s_ref, *,
                 chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0, 0].astype(jnp.float32)          # (C,D)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)             # (D,)

    logw = jnp.log(jnp.clip(w, 1e-38, 1.0))
    cum = jnp.cumsum(logw, axis=0)               # (C,D)
    ct = cum - logw                              # decay start -> t-1

    r_in = r * jnp.exp(ct)                       # ct <= 0: safe
    # intra-chunk pairwise decay: exponent ct[t]-cum[j] <= 0 for t > j,
    # so computing the DIFFERENCE first is overflow-free and exact (a
    # factorized r*e^{ct} @ (k*e^{-cum})^T matmul overflows fp32 under
    # strong decay; kept as the documented MXU-friendly variant for
    # bounded-decay deployments)
    dm = ct[:, None, :] - cum[None, :, :]        # (C,C,D)
    t_i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    j_i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    causal = (t_i > j_i)[:, :, None]
    att = jnp.where(causal, jnp.exp(jnp.where(causal, dm, -jnp.inf)), 0.0)
    scores = jnp.einsum("td,jd,tjd->tj", r, k, att)

    out = jax.lax.dot(scores, v, preferred_element_type=jnp.float32)
    diag = jnp.sum(r * k * u[None, :], axis=1)
    out = out + diag[:, None] * v
    out = out + jax.lax.dot(r_in, s_ref[...],
                            preferred_element_type=jnp.float32)
    o_ref[0, 0] = out.astype(o_ref.dtype)

    w_all = jnp.exp(cum[-1])                     # (D,)
    k_out = k * jnp.exp(cum[-1][None, :] - cum)  # exponent <= 0: safe
    s_ref[...] = w_all[:, None] * s_ref[...] + jax.lax.dot_general(
        k_out, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ci == nc - 1)
    def _flush():
        sout_ref[0, 0] = s_ref[...]


def wkv6_kernel(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                u: jax.Array, *, chunk: int = 64,
                interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """r,k,v,w: (B,H,S,D); u: (H,D).  Returns (out, final_state)."""
    b, h, s, d = r.shape
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    kernel = functools.partial(_wkv6_kernel, chunk=chunk)
    out, state = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, d), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, 1, chunk, d), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, 1, chunk, d), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, 1, chunk, d), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, d), lambda b_, h_, c_: (h_, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, 1, chunk, d), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, 1, d, d), lambda b_, h_, c_: (b_, h_, 0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((b, h, s, d), r.dtype),
            jax.ShapeDtypeStruct((b, h, d, d), jnp.float32),
        ),
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
    return out, state
