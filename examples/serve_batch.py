"""Batched serving example: random-weight smoke model, 12 requests through
the wave-batched engine; reports tokens/s and slot utilization.

Run:  PYTHONPATH=src python examples/serve_batch.py
"""

import subprocess
import sys


def main() -> None:
    cmd = [sys.executable, "-m", "repro.launch.serve",
           "--arch", "mixtral-8x7b", "--smoke",
           "--requests", "12", "--slots", "4", "--max-new", "16"]
    raise SystemExit(subprocess.call(cmd))


if __name__ == "__main__":
    main()
