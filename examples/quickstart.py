"""Quickstart: the paper's runtime on a CNN step graph, end to end.

Profiles operations with the hill-climbing performance model, freezes the
concurrency plan (Strategies 1-2), schedules with co-running (3-4), and
compares against the TensorFlow-recommended configuration and exhaustive
manual tuning — the paper's Fig 3 in one script.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import (ConcurrencyRuntime, SimMachine, build_paper_graph,
                        manual_best_schedule, uniform_schedule)


def main() -> None:
    machine = SimMachine()
    for model in ("resnet50", "dcgan", "inception_v3"):
        graph = build_paper_graph(model)
        rt = ConcurrencyRuntime()
        summary = rt.train(graph, total_steps=10_000)
        result = rt.execute_step(graph)
        manual, cfg = manual_best_schedule(graph, machine)
        rec = uniform_schedule(graph, machine, intra=68, inter=1)
        print(f"\n=== {model} ({graph.n_ops} ops) ===")
        print(f"  recommendation (1x68): {rec.makespan*1e3:8.2f} ms/step")
        print(f"  manual best {cfg}:     {manual.makespan*1e3:8.2f} ms/step")
        print(f"  our runtime:           {summary.step_time*1e3:8.2f} ms/step"
              f"  (speedup {summary.speedup:.2f}x, "
              f"mean co-run {result.mean_corunning:.2f})")
        print(f"  profiling: {summary.profiling_steps} steps, "
              f"{100*summary.profiling_overhead:.3f}% of training")


if __name__ == "__main__":
    main()
