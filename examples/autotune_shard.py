"""The paper's technique on TPU meshes: hill-climb per-op-class shard
degrees against a roofline cost model, freeze the plan (Strategies 1-2),
then space-share the model axis between independent op classes
(Strategy 3 analogue).  Runs the tuner against an analytic v5e roofline
for a mixtral-style block so it completes in seconds on CPU; the dry-run
path (repro.launch.dryrun) uses the same tuner with real compiled costs.

Run:  PYTHONPATH=src python examples/autotune_shard.py
"""

from repro.core import (RooflineMeasurement, ShardDegreeAutotuner,
                        corun_groups)
from repro.configs import get_config
from repro.hw import V5E

CFG = get_config("mixtral-8x7b")
TOKENS = 4096 * 256 / 256      # tokens per device per step


def measure(op_class: str, degree: int, variant: bool
            ) -> RooflineMeasurement:
    """Analytic v5e roofline for one op class at a given shard degree."""
    d, f, e = CFG.d_model, CFG.d_ff, CFG.moe_experts
    per_tok_flops = {
        "attention": 2 * d * (CFG.n_heads + 2 * CFG.n_kv_heads) * CFG.hd,
        "moe": 6 * d * f * CFG.moe_top_k,
        "embed": 2 * d,
        "unembed": 2 * d * CFG.vocab / CFG.n_layers,
    }[op_class]
    flops = per_tok_flops * TOKENS * CFG.n_layers / degree
    weight_bytes = {
        "attention": 4 * d * d * 2 * CFG.n_layers,
        "moe": 3 * d * f * e * 2 * CFG.n_layers,
        "embed": CFG.vocab * d * 2,
        "unembed": CFG.vocab * d * 2,
    }[op_class] / degree
    act_bytes = TOKENS * d * 2 * CFG.n_layers
    coll = (2 * (degree - 1) / max(degree, 1)) * act_bytes if degree > 1 \
        else 0.0
    return RooflineMeasurement(
        compute_s=flops / V5E.peak_bf16_flops,
        memory_s=(weight_bytes + act_bytes) / V5E.hbm_bandwidth,
        collective_s=coll / V5E.ici_link_bandwidth)


def main() -> None:
    tuner = ShardDegreeAutotuner(measure, max_degree=16)
    classes = ["attention", "moe", "embed", "unembed"]
    plan = tuner.tune(classes)
    print("frozen per-op-class shard degrees (Strategies 1-2):")
    for cls, dec in plan.decisions.items():
        m = dec.predicted
        print(f"  {cls:10s} degree={dec.degree:2d} "
              f"compute={m.compute_s*1e3:7.3f}ms "
              f"coll={m.collective_s*1e3:7.3f}ms  dom={m.bottleneck}")
    print(f"probes used: {plan.probes} (exhaustive would be "
          f"{len(classes) * 5})")
    groups = corun_groups(plan, [["attention", "moe"]], axis_size=16)
    print("co-run groups (Strategy 3 analogue):")
    for g in groups:
        print(f"  {g.members} degrees={g.degrees} "
              f"makespan={g.makespan*1e3:.3f}ms")


if __name__ == "__main__":
    main()
