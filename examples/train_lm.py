"""End-to-end training driver: train a smoke-scale LM for a few hundred
steps on CPU with the full substrate (data pipeline, AdamW, remat,
checkpointing, recovery).  On a TPU pod the same launcher scales out —
only the mesh changes.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""

import argparse
import subprocess
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="olmo-1b")
    args = ap.parse_args()
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", args.arch, "--smoke",
           "--steps", str(args.steps), "--batch", "8", "--seq", "128",
           "--microbatches", "2", "--lr", "3e-3",
           "--save-every", "50", "--ckpt-dir", "/tmp/repro_example_ckpt"]
    raise SystemExit(subprocess.call(cmd))


if __name__ == "__main__":
    main()
