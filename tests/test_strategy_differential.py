"""Differential suite: both schedulers ARE the one StrategyCore.

* single-job pool reproduces CorunScheduler timelines bit-for-bit on
  every paper-zoo model (and under strategy-knob variations);
* committed golden timelines (tests/golden/) pin the schedule of
  resnet50 + dcgan so refactors diff against known-good output — on
  mismatch the divergence report is written to test-artifacts/ for CI to
  upload;
* a blacklisted op-class pair is never co-launched by EITHER scheduler
  (the ROADMAP-noted dead-``excluded``-path risk: only the pool used to
  be tested).
"""

import json
import pathlib

import pytest

from repro.core import (ConcurrencyRuntime, GraphBuilder, RuntimeConfig,
                        SimMachine, build_paper_graph)
from repro.multitenant import (PoolConfig, RuntimePool, check_parity,
                               compare_timelines, corun_timeline,
                               pool_timeline, timeline_rows)

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden"
ARTIFACT_DIR = pathlib.Path(__file__).parent.parent / "test-artifacts"

ZOO = ["resnet50", "dcgan", "inception_v3", "alexnet"]


def _assert_identical(single, pooled):
    divs = compare_timelines(timeline_rows(single), timeline_rows(pooled))
    assert single.makespan == pooled.makespan, (
        f"makespan diverged: corun={single.makespan!r} "
        f"pool={pooled.makespan!r}")
    assert not divs, "timeline diverged:\n" + "\n".join(divs[:20])


# ---------------------------------------------------------------------------
# 1-job pool == CorunScheduler, bit for bit
# ---------------------------------------------------------------------------

class TestSingleJobPoolParity:
    @pytest.mark.parametrize("model", ZOO)
    def test_zoo_model_timelines_identical(self, model):
        graph = build_paper_graph(model)
        single = corun_timeline(graph, SimMachine(seed=0))
        pooled = pool_timeline(graph, SimMachine(seed=0))
        _assert_identical(single, pooled)

    @pytest.mark.parametrize("config", [
        RuntimeConfig(enable_s4=False),
        RuntimeConfig(enable_s3=False, enable_s4=False),
        RuntimeConfig(strategy2=False),
        RuntimeConfig(candidates=1, max_ht_corunners=1),
        RuntimeConfig(min_fallback_cores=34, fallback_slack=0.5),
    ], ids=["no-s4", "serial", "no-s2", "tight", "fallback-knobs"])
    def test_strategy_knobs_preserved_through_both_adapters(self, config):
        graph = build_paper_graph("dcgan")
        single = corun_timeline(graph, SimMachine(seed=0), config)
        pooled = pool_timeline(graph, SimMachine(seed=0), config)
        _assert_identical(single, pooled)

    def test_other_machine_seed(self):
        graph = build_paper_graph("resnet50")
        single = corun_timeline(graph, SimMachine(seed=7))
        pooled = pool_timeline(graph, SimMachine(seed=7))
        _assert_identical(single, pooled)

    @pytest.mark.parametrize("model", ["resnet50", "dcgan"])
    def test_preemption_enabled_but_inert_is_bit_identical(self, model):
        """A preemption-ENABLED pool whose jobs carry no deadlines can
        never accumulate negative slack, so it must reproduce the
        single-graph scheduler bit-for-bit — the knob alone changes
        nothing, only deadline pressure does."""
        from repro.core.strategy import PreemptionPolicy

        graph = build_paper_graph(model)
        single = corun_timeline(graph, SimMachine(seed=0))
        pooled = pool_timeline(
            graph, SimMachine(seed=0),
            pool_config=PoolConfig(
                max_active=1,
                preemption=PreemptionPolicy(enabled=True)))
        _assert_identical(single, pooled)

    def test_check_parity_report_shape(self):
        report = check_parity(["dcgan"])
        assert report["ok"] is True
        assert report["models"]["dcgan"]["divergences"] == []
        assert report["models"]["dcgan"]["makespan"] > 0


# ---------------------------------------------------------------------------
# golden timelines (seeded) — refactors diff against known-good schedules
# ---------------------------------------------------------------------------

class TestGoldenTimelines:
    @pytest.mark.parametrize("model", ["resnet50", "dcgan"])
    def test_matches_committed_golden(self, model):
        golden = json.loads(
            (GOLDEN_DIR / f"strategy_{model}.json").read_text())
        res = corun_timeline(build_paper_graph(model),
                             SimMachine(seed=golden["seed"]))
        rows = timeline_rows(res)
        divs = compare_timelines(golden["records"], rows,
                                 label_a="golden", label_b="current")
        if res.makespan != golden["makespan"]:
            divs.insert(0, f"makespan: golden={golden['makespan']!r} "
                           f"current={res.makespan!r}")
        if divs:
            # leave a machine-readable diff for CI to upload as artifact
            ARTIFACT_DIR.mkdir(exist_ok=True)
            (ARTIFACT_DIR / f"golden_diff_{model}.json").write_text(
                json.dumps({"model": model, "divergences": divs,
                            "current_makespan": res.makespan,
                            "current_records": rows}, indent=1))
        assert not divs, (
            f"{model} schedule drifted from golden fixture "
            f"(diff written to test-artifacts/golden_diff_{model}.json):\n"
            + "\n".join(divs[:20]))


# ---------------------------------------------------------------------------
# interference blacklist respected by BOTH schedulers
# ---------------------------------------------------------------------------

def _two_class_graph(name="g", per_class=2):
    """Independent chains of classes A and B that WOULD co-run freely."""
    b = GraphBuilder(name)
    for cls in ("ClassA", "ClassB"):
        prev = None
        for i in range(per_class):
            prev = b.add(cls, (32, 16, 16, 64), flops=4e8, bytes_moved=2e6,
                         deps=[prev] if prev is not None else [])
    return b.build()


def _overlaps(recs_a, recs_b):
    return any(a.start < b.finish - 1e-15 and b.start < a.finish - 1e-15
               for a in recs_a for b in recs_b)


class TestBlacklistNeverCoLaunched:
    def _split(self, records):
        return ([r for r in records if r.op.op_class == "ClassA"],
                [r for r in records if r.op.op_class == "ClassB"])

    def test_corun_scheduler_would_corun_without_blacklist(self):
        rt = ConcurrencyRuntime(machine=SimMachine())
        res = rt.execute_step(_two_class_graph())
        a, b = self._split(res.records)
        assert _overlaps(a, b), "control: A/B must co-run when compatible"

    def test_corun_scheduler_respects_blacklist(self):
        rt = ConcurrencyRuntime(machine=SimMachine())
        graph = _two_class_graph()
        rt.profile(graph)
        # one observation far above the 1.35x threshold blacklists the pair
        rt.recorder.record("ClassA", "ClassB", 1.0, 10.0)
        assert rt.recorder.blacklisted("ClassA", "ClassB")
        res = rt.execute_step(graph)
        a, b = self._split(res.records)
        assert len(a) and len(b)
        assert not _overlaps(a, b), \
            "blacklisted pair was co-launched by CorunScheduler"

    def test_pool_scheduler_respects_blacklist_across_jobs(self):
        pool = RuntimePool(machine=SimMachine(),
                           config=PoolConfig(max_active=2))
        ga = GraphBuilder("ja")
        prev = None
        for _ in range(3):
            prev = ga.add("ClassA", (32, 16, 16, 64), flops=4e8,
                          bytes_moved=2e6,
                          deps=[prev] if prev is not None else [])
        gb = GraphBuilder("jb")
        prev = None
        for _ in range(3):
            prev = gb.add("ClassB", (32, 16, 16, 64), flops=4e8,
                          bytes_moved=2e6,
                          deps=[prev] if prev is not None else [])
        pool.submit(ga.build(), name="ja")
        pool.submit(gb.build(), name="jb")
        pool.recorder.record("ClassA", "ClassB", 1.0, 10.0)
        assert pool.recorder.blacklisted("ClassA", "ClassB")
        res = pool.run()
        a = [r for recs in res.records.values() for r in recs
             if r.op.op_class == "ClassA"]
        b = [r for recs in res.records.values() for r in recs
             if r.op.op_class == "ClassB"]
        assert len(a) == 3 and len(b) == 3
        assert not _overlaps(a, b), \
            "blacklisted pair was co-launched across pool tenants"
