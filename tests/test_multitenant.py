"""Multi-tenant runtime pool: admission, fairness, co-scheduling, cache,
deadlines, and checkpoint-free preemption."""

import pytest

from repro.core import SimMachine, build_paper_graph
from repro.core.graph import GraphBuilder
from repro.multitenant import (Job, JobQueue, PlanCache, PoolConfig,
                               PreemptionPolicy, RuntimePool, fairness_index)


@pytest.fixture(scope="module")
def machine():
    return SimMachine()


def _mix_pool(machine, *, max_active=3, priorities=(1.0, 1.0, 2.0, 1.0)):
    pool = RuntimePool(machine=machine,
                       config=PoolConfig(max_active=max_active))
    models = ["resnet50", "dcgan", "resnet50", "dcgan"]
    for i, (model, prio) in enumerate(zip(models, priorities)):
        pool.submit(build_paper_graph(model), priority=prio,
                    name=f"{model}-{i}")
    return pool


# ---------------------------------------------------------------------------
# JobQueue admission controller
# ---------------------------------------------------------------------------

class TestJobQueue:
    def _job(self, jid, *, priority=1.0, submit_time=0.0, demand=1.0):
        g = GraphBuilder(f"g{jid}")
        g.add("X", (4, 4), flops=1e6, bytes_moved=1e4)
        job = Job(jid=jid, name=f"j{jid}", graph=g.build(),
                  priority=priority, submit_time=submit_time)
        job.demand = demand
        return job

    def test_priority_order_fifo_within_level(self):
        q = JobQueue(max_active=10)
        a = self._job(0, priority=1.0)
        b = self._job(1, priority=5.0)
        c = self._job(2, priority=5.0)
        for j in (a, b, c):
            q.submit(j)
        assert q.pop_admissible([]) is b       # highest priority first
        assert q.pop_admissible([]) is c       # FIFO within the level
        assert q.pop_admissible([]) is a

    def test_max_active_gate(self):
        q = JobQueue(max_active=1)
        q.submit(self._job(0))
        active = [self._job(9)]
        assert q.pop_admissible(active) is None
        assert q.pop_admissible([]) is not None

    def test_demand_cap_no_overtaking(self):
        q = JobQueue(max_active=4, max_outstanding_demand=10.0)
        big = self._job(0, priority=5.0, demand=9.0)
        small = self._job(1, priority=1.0, demand=1.0)
        q.submit(big)
        q.submit(small)
        active = [self._job(9, demand=5.0)]
        # big doesn't fit; small must NOT overtake it (strict priority)
        assert q.pop_admissible(active) is None
        assert q.pop_admissible([]) is big

    def test_arrival_time_respected(self):
        q = JobQueue(max_active=4)
        late = self._job(0, priority=5.0, submit_time=10.0)
        early = self._job(1, priority=1.0, submit_time=0.0)
        q.submit(late)
        q.submit(early)
        assert q.pop_admissible([], now=0.0) is early
        assert q.pop_admissible([], now=0.0) is None
        assert q.next_arrival(0.0) == 10.0
        assert q.pop_admissible([], now=10.0) is late

    def test_edf_within_priority_level(self):
        """Same priority: earliest deadline first; best-effort jobs keep
        FIFO among themselves and sort after any deadlined peer."""
        q = JobQueue(max_active=10)
        no_dl = self._job(0)
        late_dl = self._job(1)
        late_dl.deadline = 9.0
        early_dl = self._job(2)
        early_dl.deadline = 3.0
        hi = self._job(3, priority=5.0)         # priority still dominates
        for j in (no_dl, late_dl, early_dl, hi):
            q.submit(j)
        assert q.pop_admissible([]) is hi
        assert q.pop_admissible([]) is early_dl
        assert q.pop_admissible([]) is late_dl
        assert q.pop_admissible([]) is no_dl

    def test_admissible_at_mirrors_pop(self):
        """The wakeup predicate must agree with admission: an arrival the
        demand cap bounces is NOT admissible (the old predicate checked
        max_active only — the spurious-wakeup bug)."""
        q = JobQueue(max_active=4, max_outstanding_demand=10.0)
        over = self._job(0, demand=9.0, submit_time=1.0)
        q.submit(over)
        active = [self._job(9, demand=5.0)]
        assert not q.admissible_at(active, 1.0)       # cap: 5+9 > 10
        assert q.admissible_at([], 1.0)               # idle pool waives cap
        assert not q.admissible_at(active, 0.5)       # not arrived yet
        full = [self._job(i + 10) for i in range(4)]
        assert not q.admissible_at(full, 1.0)         # max_active
        # popping agrees in every case
        assert q.pop_admissible(active, now=1.0) is None
        assert q.pop_admissible([], now=1.0) is over

    def test_reservation_holds_last_slot(self):
        """With a strictly-higher-priority deadlined arrival due within
        the window, the last active slot is not handed to best-effort
        work; outside the window (or with slots to spare) it is."""
        q = JobQueue(max_active=2, reservation_window=5.0)
        lo = self._job(0, priority=1.0, submit_time=0.0)
        hi = self._job(1, priority=4.0, submit_time=3.0)
        hi.deadline = 6.0
        q.submit(lo)
        q.submit(hi)
        active = [self._job(9)]
        # one slot left, hi due at t=3 (within window) -> reserve
        assert q.pop_admissible(active, now=0.0) is None
        # two slots free -> no reservation needed
        assert q.admissible_at([], 0.0)
        # hi has arrived: it is the one admitted
        assert q.pop_admissible(active, now=3.0) is hi
        assert q.pop_admissible(active, now=3.0) is lo

    def test_queue_wait_and_latency_none_for_never_admitted(self):
        job = self._job(0, submit_time=2.0)
        assert job.queue_wait is None
        assert job.latency is None
        assert job.run_latency is None
        assert job.waiting_time(5.0) == pytest.approx(3.0)
        job.admit_time = 4.0
        assert job.queue_wait == pytest.approx(2.0)
        assert job.waiting_time(9.0) == pytest.approx(2.0)
        assert job.latency is None               # admitted, not finished

    def test_effective_priority_scales_with_slack(self):
        job = self._job(0, priority=2.0, submit_time=0.0)
        assert job.effective_priority(100.0) == 2.0    # best-effort: static
        job.deadline = 10.0
        assert job.effective_priority(0.0) == pytest.approx(2.0)
        assert job.effective_priority(5.0) == pytest.approx(3.0)
        assert job.effective_priority(10.0) == pytest.approx(4.0)
        assert job.effective_priority(99.0) == pytest.approx(4.0)  # capped


# ---------------------------------------------------------------------------
# PoolScheduler invariants
# ---------------------------------------------------------------------------

class TestPoolScheduler:
    def test_all_ops_execute_exactly_once(self, machine):
        pool = _mix_pool(machine)
        res = pool.run()
        for job in res.jobs:
            recs = res.records[job.jid]
            assert len(recs) == job.graph.n_ops
            assert len({r.op.uid for r in recs}) == job.graph.n_ops
            assert job.done

    def test_dependencies_respected_per_job(self, machine):
        pool = _mix_pool(machine)
        res = pool.run()
        for job in res.jobs:
            start = {r.op.uid: r.start for r in res.records[job.jid]}
            finish = {r.op.uid: r.finish for r in res.records[job.jid]}
            for op in job.graph.ops.values():
                for d in op.deps:
                    assert finish[d] <= start[op.uid] + 1e-12

    def test_core_capacity_never_exceeded(self, machine):
        pool = _mix_pool(machine)
        res = pool.run()
        recs = [r for rs in res.records.values() for r in rs]
        times = sorted({r.start for r in recs} | {r.finish for r in recs})
        for t in times:
            used = sum(r.threads for r in recs
                       if not r.hyper and r.start <= t < r.finish)
            assert used <= machine.spec.cores

    def test_deterministic_under_fixed_seed(self, machine):
        a = _mix_pool(machine).run()
        b = _mix_pool(machine).run()
        assert a.makespan == b.makespan
        assert a.fairness == b.fairness
        for jid in a.records:
            assert ([r.op.uid for r in a.records[jid]]
                    == [r.op.uid for r in b.records[jid]])
            assert ([r.start for r in a.records[jid]]
                    == [r.start for r in b.records[jid]])

    def test_per_job_schedule_events_are_job_local(self, machine):
        """The per-job events timeline must reflect that job's own
        concurrency, not the pool-wide co-running level."""
        pool = _mix_pool(machine)
        res = pool.run()
        for job in res.jobs:
            sched = res.per_job_schedule(job.jid)
            assert sched.events[-1][1] == 0          # all ops finished
            peak = max(n for _, n in sched.events)
            assert peak <= len(sched.records)
        peaks = [max(n for _, n in res.per_job_schedule(j.jid).events)
                 for j in res.jobs]
        assert max(peaks) <= max(n for _, n in res.events)

    def test_empty_graph_job_completes_immediately(self, machine):
        from repro.core import OpGraph
        pool = RuntimePool(machine=machine,
                           config=PoolConfig(max_active=2))
        empty = pool.submit(OpGraph("empty", {}), name="empty")
        pool.submit(build_paper_graph("dcgan"), name="real")
        res = pool.run()                      # must terminate
        assert empty.done and empty.latency == 0.0
        assert all(j.done for j in res.jobs)

    def test_enable_s3_off_serializes_launches(self, machine):
        """Strategies 1-2 only: the pool must not co-run (matching the
        serial baseline's honoring of the same flag)."""
        from repro.core import RuntimeConfig
        pool = RuntimePool(
            machine=machine,
            config=PoolConfig(max_active=3,
                              runtime=RuntimeConfig(enable_s3=False,
                                                    enable_s4=False)))
        pool.submit(build_paper_graph("dcgan"), name="a")
        pool.submit(build_paper_graph("dcgan"), name="b")
        res = pool.run()
        assert max(n for _, n in res.events) == 1
        assert all(j.done for j in res.jobs)

    def test_seed_changes_timings_not_invariants(self):
        res = _mix_pool(SimMachine(seed=7)).run()
        for job in res.jobs:
            assert job.done
            assert len(res.records[job.jid]) == job.graph.n_ops


# ---------------------------------------------------------------------------
# Fairness / starvation
# ---------------------------------------------------------------------------

class TestFairness:
    def test_no_admitted_job_starves(self, machine):
        res = _mix_pool(machine).run()
        for job in res.jobs:
            assert job.done                       # every tenant finishes
            assert job.service > 0.0              # and got real service

    def test_equal_jobs_get_equal_share(self, machine):
        pool = RuntimePool(machine=machine,
                           config=PoolConfig(max_active=4))
        for i in range(4):
            pool.submit(build_paper_graph("dcgan"), name=f"dcgan-{i}")
        res = pool.run()
        assert res.fairness >= 0.8    # Jain: 1.0 = perfectly proportional

    def test_mixed_mix_fairness_bound(self, machine):
        res = _mix_pool(machine).run()
        # heterogeneous sizes/priorities still keep a sane share spread
        assert res.fairness >= 0.5

    def test_priority_cuts_queueing(self, machine):
        """With one active slot, the high-priority tenant is admitted
        before equal-arrival lower-priority ones."""
        pool = RuntimePool(machine=machine,
                           config=PoolConfig(max_active=1))
        lo = [pool.submit(build_paper_graph("dcgan"), priority=1.0,
                          name=f"lo{i}") for i in range(2)]
        hi = pool.submit(build_paper_graph("dcgan"), priority=10.0,
                         name="hi")
        res = pool.run()
        assert res is not None
        assert hi.queue_wait <= min(j.queue_wait for j in lo)

    def test_midrun_arrival_admitted_before_op_completes(self, machine):
        """A tenant arriving while a long op runs must be admitted at its
        arrival time (free slot + idle cores), not at the op boundary."""
        big = GraphBuilder("big")
        big.add("Huge", (512, 512, 64), flops=5e12, bytes_moved=1e9,
                working_set=1e9)
        tiny = GraphBuilder("tiny")
        tiny.add("Tiny", (8, 8), flops=1e6, bytes_moved=1e4,
                 working_set=1e4)
        pool = RuntimePool(machine=machine,
                           config=PoolConfig(max_active=2))
        pool.submit(big.build(), name="big", submit_time=0.0)
        late = pool.submit(tiny.build(), name="late", submit_time=1e-3)
        res = pool.run()
        big_op = res.records[0][0]
        assert late.admit_time == pytest.approx(1e-3)
        assert late.latency < big_op.duration / 10

    def test_fairness_index_edge_cases(self):
        assert fairness_index([]) == 1.0
        g = GraphBuilder("g")
        g.add("X", (2, 2), flops=1.0, bytes_moved=1.0)
        j = Job(jid=0, name="j", graph=g.build())
        j.admit_time = 0.0
        assert fairness_index([j]) == 1.0     # zero service, single job


# ---------------------------------------------------------------------------
# Deadlines + checkpoint-free preemption
# ---------------------------------------------------------------------------

def _big_graph(n=3):
    """Chain of very long ops — the head-of-line blocker."""
    b = GraphBuilder("big")
    prev = None
    for _ in range(n):
        prev = b.add("Huge", (512, 512, 64), flops=5e12, bytes_moved=1e9,
                     working_set=1e9, deps=[prev] if prev is not None else [])
    return b.build()


def _urgent_chain(n=4):
    """Chain of medium ops whose candidates need real cores (cannot sneak
    into one or two idle cores), so a blocked deadline forces preemption."""
    b = GraphBuilder("urgent")
    prev = None
    for _ in range(n):
        prev = b.add("WavePrefill", (32, 128, 64), flops=8e9,
                     bytes_moved=2e7, working_set=2e7,
                     parallel_fraction=0.97,
                     deps=[prev] if prev is not None else [])
    return b.build()


def _preempt_pool(machine, *, enabled=True, deadline=0.1):
    pool = RuntimePool(
        machine=machine,
        config=PoolConfig(
            max_active=4,
            preemption=PreemptionPolicy(enabled=True) if enabled else None))
    big = pool.submit(_big_graph(), name="big")
    urgent = pool.submit(_urgent_chain(), name="urgent", submit_time=0.05,
                         deadline=0.05 + deadline)
    return pool, big, urgent


class TestPreemption:
    def test_preemption_cuts_urgent_latency(self, machine):
        pool_off, _, u_off = _preempt_pool(machine, enabled=False)
        res_off = pool_off.run()
        pool_on, big, u_on = _preempt_pool(machine, enabled=True)
        res_on = pool_on.run()
        assert res_off.n_preemptions == 0
        assert res_on.n_preemptions >= 1
        assert big.preemptions >= 1              # the blocker was revoked
        assert u_on.latency < u_off.latency / 10
        # preemption fires when slack is ALREADY gone, so a hard deadline
        # guarantee is impossible — but the urgent job must finish within
        # a whisker of its own critical path (i.e., near-zero queueing)
        assert u_on.latency <= max(u_on.cp.values()) * 1.5
        assert big.done and u_on.done            # work-conserving: all runs

    def test_preemption_off_with_deadlines_never_revokes(self, machine):
        pool, big, urgent = _preempt_pool(machine, enabled=False)
        res = pool.run()
        assert res.n_preemptions == 0
        assert big.preemptions == 0 and urgent.preemptions == 0
        assert not res.preempted[big.jid]

    def test_victim_completes_exactly_once_after_revoke(self, machine):
        pool, big, urgent = _preempt_pool(machine, enabled=True)
        res = pool.run()
        assert res.n_preemptions >= 1
        # every op of every job completes exactly once, preempted or not
        for job in res.jobs:
            recs = res.records[job.jid]
            assert len(recs) == job.graph.n_ops
            assert len({r.op.uid for r in recs}) == job.graph.n_ops
        # each preempted node's completed run restarts AFTER the revoke
        done_at = {(big.jid, r.op.uid): r for r in res.records[big.jid]}
        for p in res.preempted[big.jid]:
            final = done_at[(big.jid, p.op.uid)]
            assert final.start >= p.finish - 1e-15    # finish = revoke time
            assert p.finish - p.start >= 0.0

    def test_no_oversubscription_across_preemption_instants(self, machine):
        pool, big, urgent = _preempt_pool(machine, enabled=True)
        res = pool.run()
        assert res.n_preemptions >= 1
        # occupancy intervals: completed runs [start, finish) plus revoked
        # partial runs [start, revoke)
        spans = [(r.start, r.finish, r.threads)
                 for recs in res.records.values() for r in recs
                 if not r.hyper]
        spans += [(p.start, p.finish, p.threads)
                  for precs in res.preempted.values() for p in precs
                  if not p.hyper]
        times = sorted({t for s in spans for t in s[:2]})
        for t in times:
            used = sum(th for s0, s1, th in spans if s0 <= t < s1)
            assert used <= machine.spec.cores

    def test_service_accounting_includes_restart_waste(self, machine):
        pool, big, urgent = _preempt_pool(machine, enabled=True)
        res = pool.run()
        assert res.n_preemptions >= 1
        eff = machine.spec.hyper_thread_efficiency
        waste = machine.spec.restart_waste
        for job in (big, urgent):
            granted = sum(
                r.threads * r.duration * (eff if r.hyper else 1.0)
                for r in res.records[job.jid])
            wasted = sum(
                p.threads * (p.finish - p.start) * (eff if p.hyper else 1.0)
                * waste
                for p in res.preempted[job.jid])
            assert job.service == pytest.approx(granted + wasted, rel=1e-9)

    def test_serial_mode_preemption_never_corun(self, machine):
        """enable_s3=False promises serial execution; the deadline path
        must honor it — acting only by REPLACING the sole runner, never
        by co-launching into idle cores."""
        from repro.core import RuntimeConfig
        pool = RuntimePool(
            machine=machine,
            config=PoolConfig(
                max_active=4,
                runtime=RuntimeConfig(enable_s3=False, enable_s4=False),
                preemption=PreemptionPolicy(enabled=True)))
        big = pool.submit(_big_graph(), name="big")
        urgent = pool.submit(_urgent_chain(), name="urgent",
                             submit_time=0.05, deadline=0.1)
        res = pool.run()
        assert max(n for _, n in res.events) == 1      # still serial
        assert res.n_preemptions >= 1                  # served by replacing
        assert urgent.latency < 1.0                    # not 8s-op queued
        assert all(j.done for j in res.jobs)

    def test_deadline_met_without_preemption_when_feasible(self, machine):
        """A generous deadline is met through plain scheduling — the
        preemption path must not fire when slack never runs out."""
        pool, big, urgent = _preempt_pool(machine, enabled=True,
                                          deadline=1e6)
        res = pool.run()
        assert res.n_preemptions == 0

    def test_over_cap_arrival_causes_no_wakeup(self, machine):
        """An arrival blocked by the demand cap must not create a
        scheduling instant (the old predicate woke on max_active alone)."""
        pool = RuntimePool(
            machine=machine,
            config=PoolConfig(max_active=4, max_outstanding_demand=1.0))
        pool.submit(_big_graph(), name="big")
        pool.submit(_urgent_chain(), name="late", submit_time=1.0)
        admit_clocks = []
        orig = pool._admit

        def spy(sim, active):
            admit_clocks.append(sim.clock)
            return orig(sim, active)

        pool._admit = spy
        res = pool.run()
        assert all(j.done for j in res.jobs)
        # op completions are legitimate scheduling instants; the arrival
        # at t=1.0 is not one (the demand cap blocks it), so no _admit —
        # and hence no drain — may run at that clock
        assert 1.0 not in admit_clocks
        # the late job only enters once the pool idles (cap waived)
        late = next(j for j in res.jobs if j.name == "late")
        big_finish = max(r.finish for r in res.records[0])
        assert late.admit_time == pytest.approx(big_finish)

    def test_blocked_arrival_does_not_mask_later_admissible_one(self,
                                                                machine):
        """A cap-blocked early arrival must not swallow the wakeup of an
        admissible arrival right behind it: the wakeup scans to the
        earliest ADMISSIBLE arrival, not just the earliest one."""
        pool = RuntimePool(
            machine=machine,
            config=PoolConfig(max_active=4, max_outstanding_demand=None))
        big = pool.submit(_big_graph(), name="big")
        blocked = pool.submit(_big_graph(), name="blocked",
                              submit_time=1.0)
        nimble = pool.submit(_urgent_chain(1), name="nimble",
                             submit_time=2.0, priority=4.0)
        # cap: big + nimble fit together, a second big does not — so the
        # t=1.0 arrival is inadmissible while the t=2.0 one is fine
        pool.queue.max_outstanding_demand = (big.demand + nimble.demand
                                             + 1e-6)
        res = pool.run()
        assert all(j.done for j in res.jobs)
        # nimble is admitted AT its arrival (mid-op of big), not at the
        # next op boundary; blocked waits for the cap
        assert nimble.admit_time == pytest.approx(2.0)
        assert blocked.admit_time > 2.0

    def test_slowdown_fairness_variants_split_queueing(self, machine):
        """With one active slot, queue wait dominates end-to-end latency:
        the sched variant (admit-to-finish) must report fairer numbers
        than the queue-inclusive e2e variant."""
        pool = RuntimePool(machine=machine, config=PoolConfig(max_active=1))
        for i in range(3):
            pool.submit(build_paper_graph("dcgan"), name=f"j{i}")
        res = pool.run()
        serial = pool.run_serial()
        e2e = res.slowdown_fairness(serial.job_makespans)
        sched = res.slowdown_fairness(serial.job_makespans,
                                      include_queue_wait=False)
        assert sched > e2e
        assert sched == pytest.approx(1.0, abs=0.05)  # serialized pool:
        # every job runs alone once admitted, so scheduler slowdown ~ 1

    def test_serve_waves_carry_deadlines(self, machine):
        import numpy as np

        from repro.models.common import ModelConfig
        from repro.serving import Request, ServeEngine

        cfg = ModelConfig(arch_id="tiny", family="dense", n_layers=2,
                          d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                          vocab=256)
        eng = ServeEngine(cfg, params={}, n_slots=2, max_len=64)
        for i in range(4):
            eng.submit(Request(rid=i,
                               prompt=np.arange(6, dtype=np.int32),
                               max_new_tokens=4))
        pool = RuntimePool(machine=machine, config=PoolConfig(max_active=4))
        jobs = eng.submit_waves_to_pool(pool, priority=3.0,
                                        arrival_gap=0.5,
                                        latency_target=0.25)
        assert [j.deadline for j in jobs] == [0.25, 0.75]
        assert [j.submit_time for j in jobs] == [0.0, 0.5]
        # without a target, waves stay best-effort
        eng2 = ServeEngine(cfg, params={}, n_slots=2, max_len=64)
        eng2.submit(Request(rid=9, prompt=np.arange(6, dtype=np.int32),
                            max_new_tokens=4))
        jobs2 = eng2.submit_waves_to_pool(pool)
        assert jobs2[0].deadline is None


# ---------------------------------------------------------------------------
# Pool vs serial regression + PlanCache amortization (acceptance criteria)
# ---------------------------------------------------------------------------

class TestPoolVsSerial:
    def test_pool_makespan_not_worse_than_serial(self, machine):
        pool = _mix_pool(machine)
        res = pool.run()
        serial = pool.run_serial()
        assert res.makespan <= serial.makespan
        assert res.aggregate_throughput > serial.aggregate_throughput

    def test_single_job_pool_matches_single_runtime_ballpark(self, machine):
        """A pool of one tenant must not regress the paper scheduler."""
        pool = RuntimePool(machine=machine, config=PoolConfig(max_active=1))
        pool.submit(build_paper_graph("resnet50"))
        res = pool.run()
        serial = pool.run_serial()
        assert res.makespan <= serial.makespan * 1.05

    def test_plancache_reduces_probes(self, machine):
        pool = _mix_pool(machine)
        res = pool.run()
        serial = pool.run_serial()     # isolated per-job profiling
        assert res.cache_stats["probes_spent"] < serial.profiling_probes
        assert res.cache_stats["probes_saved"] > 0
        assert res.cache_stats["hits"] > 0

    def test_plancache_no_collision_on_hidden_cost_params(self, machine):
        """Two tenants with the same (op_class, input_shape) but different
        analytic cost (cost hidden outside the shape, as the transformer
        builders do) must NOT share a curve."""
        cache = PlanCache()
        pool = RuntimePool(machine=machine, plan_cache=cache,
                           config=PoolConfig(max_active=2))

        def one_op_graph(flops):
            b = GraphBuilder("g")
            b.add("attention", (4, 8, 16, 16), flops=flops,
                  bytes_moved=1e5, working_set=1e5)
            return b.build()

        a = pool.submit(one_op_graph(1e9), name="shallow")
        b = pool.submit(one_op_graph(4e9), name="deep")
        assert cache.hits == 0                # same shape, different cost
        assert len(cache.curves) == 2
        pa = a.plan.per_instance[("attention", (4, 8, 16, 16))]
        pb = b.plan.per_instance[("attention", (4, 8, 16, 16))]
        assert pa.predicted_time != pb.predicted_time

    def test_plancache_isolates_different_machine(self, machine):
        # lookups are fingerprint-keyed: a second machine sharing the
        # cache never reuses (or pollutes) the first machine's curves —
        # it pays its own probes into its own namespace
        cache = PlanCache()
        pool_a = RuntimePool(machine=machine, plan_cache=cache)
        pool_a.submit(build_paper_graph("dcgan"), name="a")
        spent_a = cache.probes_spent
        assert spent_a > 0
        other = SimMachine(seed=99)
        pool_b = RuntimePool(machine=other, plan_cache=cache)
        saved_before = cache.probes_saved
        pool_b.submit(build_paper_graph("dcgan"), name="b")
        assert cache.probes_saved == saved_before, \
            "machine B must not hit machine A's curves"
        assert cache.probes_spent > spent_a, \
            "machine B pays its own probes"

    def test_plancache_isolates_different_probe_interval(self, machine):
        from repro.core import RuntimeConfig
        from repro.core.runtime import ConcurrencyRuntime
        cache = PlanCache()
        ConcurrencyRuntime(machine=machine,
                           config=RuntimeConfig(interval=4),
                           plan_cache=cache).profile(
                               build_paper_graph("dcgan"))
        spent = cache.probes_spent
        rt = ConcurrencyRuntime(machine=machine,
                                config=RuntimeConfig(interval=8),
                                plan_cache=cache)
        saved_before = cache.probes_saved
        rt.profile(build_paper_graph("dcgan"))
        assert cache.probes_saved == saved_before
        assert cache.probes_spent > spent, \
            "a different probe interval is a different namespace"

    def test_plancache_identical_jobs_profile_once(self, machine):
        cache = PlanCache()
        pool = RuntimePool(machine=machine, plan_cache=cache,
                           config=PoolConfig(max_active=2))
        pool.submit(build_paper_graph("dcgan"), name="a")
        single_job_probes = cache.probes_spent
        pool.submit(build_paper_graph("dcgan"), name="b")
        assert cache.probes_spent == single_job_probes   # second job free
        assert cache.hit_rate > 0.0

    def test_plancache_lru_bound_evicts_oldest(self):
        """The ROADMAP "unbounded today" item: max_entries evicts in LRU
        order (hits refresh recency), counts evictions, and an evicted
        curve simply re-misses — no wrong answers, only re-paid probes."""
        from repro.core import CurveModel
        curve = lambda: CurveModel(samples={False: [(1, 1.0)]},  # noqa: E731
                                   case_lists={False: [1]}, probes=3)
        cache = PlanCache(max_entries=2)
        cache.insert("a", curve())
        cache.insert("b", curve())
        assert cache.lookup("a") is not None        # refreshes a's recency
        cache.insert("c", curve())                  # evicts b (LRU), not a
        assert cache.evictions == 1
        assert cache.lookup("b") is None            # evicted: miss
        assert cache.lookup("a") is not None
        assert cache.lookup("c") is not None
        assert len(cache.curves) == 2
        assert cache.stats()["evictions"] == 1
        # b's probes were really measured: eviction must not make the
        # cache look cheaper, and re-measuring b after the miss counts
        # as a SECOND payment
        assert cache.probes_spent == 9              # a + c + evicted b
        cache.insert("b", curve())                  # re-measured, evicts a
        assert cache.probes_spent == 12

    def test_plancache_unbounded_by_default_never_evicts(self):
        from repro.core import CurveModel
        cache = PlanCache()
        for i in range(256):
            cache.insert(i, CurveModel(samples={False: [(1, 1.0)]},
                                       case_lists={False: [1]}))
        assert cache.evictions == 0
        assert len(cache.curves) == 256

    def test_bounded_plancache_still_reuses_across_mix(self, machine):
        """Regression for the LRU bound: a bound comfortably above the
        mix's working set must not cost ANY amortization — the bench mix
        still reuses curves across tenants and beats isolated profiling."""
        cache = PlanCache(max_entries=64)
        pool = RuntimePool(machine=machine, plan_cache=cache,
                           config=PoolConfig(max_active=3))
        models = ["resnet50", "dcgan", "resnet50", "dcgan"]
        for i, model in enumerate(models):
            pool.submit(build_paper_graph(model), name=f"{model}-{i}")
        res = pool.run()
        serial = pool.run_serial()     # isolated per-job profiling
        assert len(cache.curves) <= 64
        assert cache.evictions == 0
        assert res.cache_stats["hits"] > 0
        assert res.cache_stats["probes_spent"] < serial.profiling_probes


# ---------------------------------------------------------------------------
# Serving-wave integration (analytic wave graph, no JAX execution needed)
# ---------------------------------------------------------------------------

class TestServingWaves:
    @pytest.fixture(scope="class")
    def cfg(self):
        from repro.models.common import ModelConfig
        return ModelConfig(arch_id="tiny", family="dense", n_layers=2,
                           d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                           vocab=256)

    def test_wave_graph_shape(self, cfg):
        import numpy as np

        from repro.serving.engine import Request, wave_op_graph
        wave = [Request(rid=i, prompt=np.arange(8, dtype=np.int32),
                        max_new_tokens=4) for i in range(3)]
        g = wave_op_graph(cfg, wave)
        g.validate()
        # embed + 2 ops/layer + (max_new - 1) decode steps (the first
        # generated token comes from prefill) + unembed
        assert g.n_ops == 1 + 2 * cfg.n_layers + 3 + 1
        classes = g.classes()
        assert "wave_prefill_attn" in classes
        assert len(classes["wave_decode_step"]) == 3

    def test_wave_costs_use_padded_batch(self, cfg):
        """The engine runs full n_slots batches even for partial waves —
        the analytic graph must carry the padded cost."""
        import numpy as np

        from repro.serving.engine import Request, wave_op_graph
        wave = [Request(rid=0, prompt=np.arange(8, dtype=np.int32),
                        max_new_tokens=4)]
        partial = wave_op_graph(cfg, wave)
        padded = wave_op_graph(cfg, wave, n_slots=8)
        assert padded.total_flops() == pytest.approx(
            8 * partial.total_flops())

    def test_wave_co_schedules_with_training(self, cfg, machine):
        import numpy as np

        from repro.serving.engine import Request, wave_op_graph
        wave = [Request(rid=i, prompt=np.arange(8, dtype=np.int32),
                        max_new_tokens=8) for i in range(4)]
        pool = RuntimePool(machine=machine,
                           config=PoolConfig(max_active=2))
        pool.submit(build_paper_graph("dcgan"), name="train")
        serve = pool.submit(wave_op_graph(cfg, wave),
                            priority=4.0, name="serve")
        res = pool.run()
        serial = pool.run_serial()
        assert serve.done
        # the high-priority wave's latency beats its serial queue position
        assert serve.latency <= serial.job_latencies[serve.jid]
