"""Hypothesis property tests on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (ConcurrencyRuntime, CurveModel, GraphBuilder,
                        HillClimbProfiler, Op, OpPlan, Placement,
                        PreemptionPolicy, RuntimeConfig, SimMachine,
                        paper_case_lists, pick_admissible)
from repro.hw.hlo import parse_collectives, shape_bytes
from repro.multitenant import (JobQueue, PoolConfig, RuntimePool,
                               compare_timelines, corun_timeline,
                               pool_timeline, timeline_rows)
from repro.optim import CompressionConfig, compress, init_error_state

SETTINGS = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# perf model invariants
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(flops=st.floats(1e6, 1e11), byts=st.floats(1e4, 1e9),
       f=st.floats(0.5, 0.99), seed=st.integers(0, 100))
def test_hillclimb_best_never_worse_than_probes(flops, byts, f, seed):
    machine = SimMachine(seed=seed)
    op = Op(uid=0, name="t", op_class="X", input_shape=(32, 8, 8, 64),
            flops=flops, bytes_moved=byts, working_set=byts,
            parallel_fraction=f)

    def measure(op_, t, v):
        return machine.op_time(op_, Placement(t, cache_sharing=v))

    curve = HillClimbProfiler(measure, paper_case_lists(),
                              interval=4).profile(op)
    t, v, y = curve.measured_best()
    for variant, pts in curve.samples.items():
        for tt, yy in pts:
            assert y <= yy + 1e-15


@settings(**SETTINGS)
@given(f=st.floats(0.5, 0.99), seed=st.integers(0, 50))
def test_interpolation_between_sample_bounds(f, seed):
    """Predictions between two samples lie between those samples
    (piecewise-linear)."""
    machine = SimMachine(seed=seed, jitter=0.0)
    op = Op(uid=0, name="t", op_class="X", input_shape=(16, 16, 16, 64),
            flops=2e9, bytes_moved=1e7, working_set=1e7,
            parallel_fraction=f)

    def measure(op_, t, v):
        return machine.op_time(op_, Placement(t, cache_sharing=v))

    curve = HillClimbProfiler(measure, paper_case_lists(),
                              interval=4).profile(op)
    for v, pts in curve.samples.items():
        for (t1, y1), (t2, y2) in zip(pts, pts[1:]):
            mid = (t1 + t2) // 2
            pred = curve.predict(mid, v)
            lo, hi = min(y1, y2), max(y1, y2)
            assert lo - 1e-12 <= pred <= hi + 1e-12


@settings(**SETTINGS)
@given(threads=st.integers(1, 68), f=st.floats(0.5, 0.99))
def test_machine_time_positive_monotone_work(threads, f):
    machine = SimMachine(jitter=0.0)
    small = Op(uid=0, name="a", op_class="X", input_shape=(8, 8, 8, 8),
               flops=1e8, bytes_moved=1e6, working_set=1e6,
               parallel_fraction=f)
    big = Op(uid=1, name="b", op_class="X", input_shape=(8, 8, 8, 8),
             flops=2e8, bytes_moved=2e6, working_set=2e6,
             parallel_fraction=f)
    pl = Placement(threads)
    assert machine.op_time(small, pl) > 0
    assert machine.op_time(big, pl) > machine.op_time(small, pl)


# ---------------------------------------------------------------------------
# StrategyCore invariants over random op-graph DAGs
# ---------------------------------------------------------------------------

# per-class cost factors: cost must be a FUNCTION of (op_class, shape) —
# the paper's premise (and the profile-store key), so the generator never
# builds two ops sharing a size_key with different analytic cost
_DAG_CLASSES = {
    # op_class: (flops/elem, bytes/elem, parallel_fraction)
    "Conv2D": (660.0, 200.0, 0.96),
    "MatMul": (400.0, 60.0, 0.96),
    "FusedBatchNorm": (8.0, 12.0, 0.80),
    "Mul": (1.0, 12.0, 0.60),
    "Sum": (1.0, 8.0, 0.65),
}
_DAG_SHAPES = [(32, 8, 8, 64), (16, 16, 16, 32), (64, 4, 4, 128), (8, 8, 8, 8)]


@st.composite
def op_graphs(draw):
    """Random DAGs: each op depends on a subset of earlier ops, so the
    graph is acyclic by construction."""
    n = draw(st.integers(2, 12))
    b = GraphBuilder("rand")
    for i in range(n):
        cls = draw(st.sampled_from(sorted(_DAG_CLASSES)))
        shape = draw(st.sampled_from(_DAG_SHAPES))
        deps = (draw(st.lists(st.sampled_from(range(i)), unique=True,
                              max_size=min(i, 3))) if i else [])
        elems = float(np.prod(shape))
        fpe, bpe, pf = _DAG_CLASSES[cls]
        b.add(cls, shape, flops=elems * fpe, bytes_moved=elems * bpe,
              parallel_fraction=pf, deps=deps)
    return b.build()


DAG_SETTINGS = dict(max_examples=10, deadline=None)


@settings(**DAG_SETTINGS)
@given(graph=op_graphs())
def test_strategy_core_schedule_invariants(graph):
    """Every op exactly once, deps respected, cores never oversubscribed."""
    machine = SimMachine()
    rt = ConcurrencyRuntime(machine=machine)
    res = rt.execute_step(graph)
    assert len(res.records) == graph.n_ops
    assert len({r.op.uid for r in res.records}) == graph.n_ops
    start = {r.op.uid: r.start for r in res.records}
    finish = {r.op.uid: r.finish for r in res.records}
    for op in graph.ops.values():
        for d in op.deps:
            assert finish[d] <= start[op.uid] + 1e-12
    times = sorted(start.values()) + sorted(finish.values())
    for t in times:
        used = sum(r.threads for r in res.records
                   if not r.hyper and r.start <= t < r.finish)
        assert used <= machine.spec.cores


@settings(**DAG_SETTINGS)
@given(graph=op_graphs())
def test_single_job_pool_matches_corun_on_random_dags(graph):
    """The differential property: 1-job pool == CorunScheduler, bitwise,
    on arbitrary DAGs — not just the zoo models."""
    single = corun_timeline(graph, SimMachine(seed=0))
    pooled = pool_timeline(graph, SimMachine(seed=0))
    assert single.makespan == pooled.makespan
    assert not compare_timelines(timeline_rows(single), timeline_rows(pooled))


@settings(**DAG_SETTINGS)
@given(graphs=st.lists(op_graphs(), min_size=2, max_size=3),
       priorities=st.lists(st.floats(0.5, 4.0), min_size=3, max_size=3))
def test_pool_service_accounting_sums(graphs, priorities):
    """Fair-share service charged at launch must equal the core-seconds
    actually granted (threads x duration, hyper lanes at HT efficiency)."""
    machine = SimMachine()
    pool = RuntimePool(machine=machine, config=PoolConfig(max_active=3))
    jobs = [pool.submit(g, priority=p, name=f"j{i}")
            for i, (g, p) in enumerate(zip(graphs, priorities))]
    res = pool.run()
    eff = machine.spec.hyper_thread_efficiency
    for job in jobs:
        granted = sum(r.threads * r.duration * (eff if r.hyper else 1.0)
                      for r in res.records[job.jid])
        assert job.service == pytest.approx(granted, rel=1e-9)


@settings(**DAG_SETTINGS)
@given(graph=op_graphs(), a=st.sampled_from(sorted(_DAG_CLASSES)),
       b=st.sampled_from(sorted(_DAG_CLASSES)))
def test_blacklisted_pair_never_overlaps_on_random_dags(graph, a, b):
    """A pair blacklisted before the step starts is never co-launched,
    whatever the DAG shape — on any launch path (S3, fallback, S4)."""
    rt = ConcurrencyRuntime(machine=SimMachine())
    rt.profile(graph)
    rt.recorder.record(a, b, 1.0, 10.0)      # far above the 1.35 threshold
    res = rt.execute_step(graph)
    ra = [r for r in res.records if r.op.op_class == a]
    rb = [r for r in res.records if r.op.op_class == b]
    for x in ra:
        for y in rb:
            if x.op.uid == y.op.uid:
                continue
            assert not (x.start < y.finish - 1e-15
                        and y.start < x.finish - 1e-15), \
                f"blacklisted pair ({a}, {b}) co-launched"


# ---------------------------------------------------------------------------
# preemption invariants (deadline-driven revocation, random DAG mixes)
# ---------------------------------------------------------------------------

def _blocker_graph():
    """Chain of very long wide ops — guarantees the random tenants behind
    it actually experience head-of-line blocking, so the deadline path
    (including revocation) is exercised, not just defined."""
    b = GraphBuilder("blocker")
    prev = None
    for _ in range(3):
        prev = b.add("Huge", (512, 512, 64), flops=5e12, bytes_moved=1e9,
                     working_set=1e9, deps=[prev] if prev is not None else [])
    return b.build()


def _preempting_pool(graphs, deadline_scale, topology=None, feedback=None):
    """A long-op blocker tenant plus random DAG tenants arriving staggered
    with deadlines tight enough (a fraction of each job's own critical
    path) that slack pressure — and usually preemption — occurs."""
    machine = SimMachine()
    pool = RuntimePool(machine=machine,
                       config=PoolConfig(
                           max_active=4, topology=topology,
                           feedback=feedback,
                           preemption=PreemptionPolicy(enabled=True)))
    jobs = [pool.submit(_blocker_graph(), name="blocker")]
    for i, g in enumerate(graphs, start=1):
        # the deadline is priced from the job's own critical path, which
        # only exists after profiling — set it post-submit (the admission
        # queue saw it as best-effort; slack/preemption read it live)
        t = 1e-4 * i
        job = pool.submit(g, name=f"j{i}", submit_time=t)
        cp = max(job.cp.values(), default=0.0)
        job.deadline = t + cp * deadline_scale
        jobs.append(job)
    return machine, pool, jobs


@settings(**DAG_SETTINGS)
@given(graphs=st.lists(op_graphs(), min_size=2, max_size=3),
       scale=st.floats(0.1, 1.5))
def test_preemption_every_op_completes_exactly_once(graphs, scale):
    """Work conservation: a revoked victim returns to the ready frontier
    exactly once and its op still completes exactly once; deps hold."""
    machine, pool, jobs = _preempting_pool(graphs, scale)
    res = pool.run()
    for job in jobs:
        recs = res.records[job.jid]
        assert len(recs) == job.graph.n_ops
        assert len({r.op.uid for r in recs}) == job.graph.n_ops
        start = {r.op.uid: r.start for r in recs}
        finish = {r.op.uid: r.finish for r in recs}
        for op in job.graph.ops.values():
            for d in op.deps:
                assert finish[d] <= start[op.uid] + 1e-12
        # a preempted node's final (completed) run starts at or after the
        # instant it was revoked
        for p in res.preempted[job.jid]:
            assert start[p.op.uid] >= p.finish - 1e-15


@settings(**DAG_SETTINGS)
@given(graphs=st.lists(op_graphs(), min_size=2, max_size=3),
       scale=st.floats(0.1, 1.5))
def test_preemption_never_oversubscribes_cores(graphs, scale):
    """Across every instant — including preemption instants — physical
    core occupancy (completed runs plus revoked partial runs) stays
    within the machine."""
    machine, pool, jobs = _preempting_pool(graphs, scale)
    res = pool.run()
    spans = [(r.start, r.finish, r.threads)
             for recs in res.records.values() for r in recs if not r.hyper]
    spans += [(p.start, p.finish, p.threads)
              for precs in res.preempted.values() for p in precs
              if not p.hyper]
    for t in sorted({t for s in spans for t in s[:2]}):
        used = sum(th for s0, s1, th in spans if s0 <= t < s1)
        assert used <= machine.spec.cores


@settings(**DAG_SETTINGS)
@given(graphs=st.lists(op_graphs(), min_size=2, max_size=3),
       scale=st.floats(0.1, 1.5))
def test_preemption_service_accounting_sums(graphs, scale):
    """Launch-time charging stays consistent under revocation: service ==
    completed core-seconds + revoked partials at the restart-waste rate."""
    machine, pool, jobs = _preempting_pool(graphs, scale)
    res = pool.run()
    eff = machine.spec.hyper_thread_efficiency
    waste = machine.spec.restart_waste
    for job in jobs:
        granted = sum(r.threads * r.duration * (eff if r.hyper else 1.0)
                      for r in res.records[job.jid])
        wasted = sum(
            p.threads * (p.finish - p.start) * (eff if p.hyper else 1.0)
            * waste for p in res.preempted[job.jid])
        assert job.service == pytest.approx(granted + wasted, rel=1e-9)


@settings(**DAG_SETTINGS)
@given(graph=op_graphs())
def test_preemption_enabled_without_deadlines_matches_corun(graph):
    """The differential property survives the preemption KNOB: enabled
    but with no deadline anywhere, a 1-job pool is still bit-identical to
    CorunScheduler on arbitrary DAGs (nothing can go overdue)."""
    single = corun_timeline(graph, SimMachine(seed=0))
    pooled = pool_timeline(
        graph, SimMachine(seed=0),
        pool_config=PoolConfig(max_active=1,
                               preemption=PreemptionPolicy(enabled=True)))
    assert single.makespan == pooled.makespan
    assert not compare_timelines(timeline_rows(single), timeline_rows(pooled))


# ---------------------------------------------------------------------------
# preemption-economics invariants (multi-victim / eviction / migration)
# ---------------------------------------------------------------------------

# every economics move armed at once: the invariants below must hold with
# victim sets revoked atomically, admitted jobs bounced back to the queue,
# and running ops re-seated at new widths mid-flight
_ECON_POLICY = PreemptionPolicy(enabled=True, max_victims=4,
                                evict_admitted=True, migration=True)


def _economics_pool(graphs, deadline_scale):
    """_preempting_pool with the full economics policy armed (a tighter
    max_active so admission-level eviction has queue pressure to act on)."""
    machine = SimMachine()
    pool = RuntimePool(machine=machine,
                       config=PoolConfig(max_active=2,
                                         preemption=_ECON_POLICY))
    jobs = [pool.submit(_blocker_graph(), name="blocker")]
    for i, g in enumerate(graphs, start=1):
        t = 1e-4 * i
        job = pool.submit(g, name=f"j{i}", submit_time=t)
        cp = max(job.cp.values(), default=0.0)
        job.deadline = t + cp * deadline_scale
        jobs.append(job)
    return machine, pool, jobs


@settings(**DAG_SETTINGS)
@given(graphs=st.lists(op_graphs(), min_size=2, max_size=3),
       scale=st.floats(0.1, 1.5))
def test_economics_every_op_completes_exactly_once(graphs, scale):
    """Work conservation under the full economics policy: victim-set
    revokes, admission evictions, and width migrations all return work to
    a frontier it leaves exactly once — every op still completes exactly
    once and dependencies hold."""
    machine, pool, jobs = _economics_pool(graphs, scale)
    res = pool.run()
    for job in jobs:
        recs = res.records[job.jid]
        assert len(recs) == job.graph.n_ops
        assert len({r.op.uid for r in recs}) == job.graph.n_ops
        start = {r.op.uid: r.start for r in recs}
        finish = {r.op.uid: r.finish for r in recs}
        for op in job.graph.ops.values():
            for d in op.deps:
                assert finish[d] <= start[op.uid] + 1e-12
        for p in res.preempted[job.jid]:
            assert start[p.op.uid] >= p.finish - 1e-15


@settings(**DAG_SETTINGS)
@given(graphs=st.lists(op_graphs(), min_size=2, max_size=3),
       scale=st.floats(0.1, 1.5))
def test_economics_never_oversubscribes_cores(graphs, scale):
    """Core occupancy stays within the machine across every instant —
    including multi-victim revoke instants (several launches cancelled at
    once) and migration instants (revoke + relaunch at the same clock)."""
    machine, pool, jobs = _economics_pool(graphs, scale)
    res = pool.run()
    spans = [(r.start, r.finish, r.threads)
             for recs in res.records.values() for r in recs if not r.hyper]
    spans += [(p.start, p.finish, p.threads)
              for precs in res.preempted.values() for p in precs
              if not p.hyper]
    for t in sorted({t for s in spans for t in s[:2]}):
        used = sum(th for s0, s1, th in spans if s0 <= t < s1)
        assert used <= machine.spec.cores


@settings(**DAG_SETTINGS)
@given(graphs=st.lists(op_graphs(), min_size=2, max_size=3),
       scale=st.floats(0.1, 1.5))
def test_economics_service_accounting_sums(graphs, scale):
    """Charging stays exact under every economics move: service equals
    completed core-seconds plus revoked partials at the restart-waste
    rate.  Admission-level eviction appears in NEITHER term — the free
    move never charges waste."""
    machine, pool, jobs = _economics_pool(graphs, scale)
    res = pool.run()
    eff = machine.spec.hyper_thread_efficiency
    waste = machine.spec.restart_waste
    for job in jobs:
        granted = sum(r.threads * r.duration * (eff if r.hyper else 1.0)
                      for r in res.records[job.jid])
        wasted = sum(
            p.threads * (p.finish - p.start) * (eff if p.hyper else 1.0)
            * waste for p in res.preempted[job.jid])
        assert job.service == pytest.approx(granted + wasted, rel=1e-9)


@settings(**DAG_SETTINGS)
@given(graph=op_graphs())
def test_economics_armed_without_deadlines_matches_corun(graph):
    """Multi-victim and eviction both require an OVERDUE waiter: with no
    deadline anywhere a 1-job pool with those knobs armed must still be
    bit-identical to CorunScheduler on arbitrary DAGs.  Migration is
    deliberately left off — it prices moves without deadlines by design
    (its inertness lock is the off-default, see check_parity)."""
    single = corun_timeline(graph, SimMachine(seed=0))
    pooled = pool_timeline(
        graph, SimMachine(seed=0),
        pool_config=PoolConfig(
            max_active=1,
            preemption=PreemptionPolicy(enabled=True, max_victims=4,
                                        evict_admitted=True)))
    assert single.makespan == pooled.makespan
    assert not compare_timelines(timeline_rows(single), timeline_rows(pooled))


# ---------------------------------------------------------------------------
# topology-aware placement invariants (quadrant core booking)
# ---------------------------------------------------------------------------

@settings(**DAG_SETTINGS)
@given(graphs=st.lists(op_graphs(), min_size=2, max_size=3),
       scale=st.floats(0.1, 1.5))
def test_quadrant_no_core_double_booked_across_preemption(graphs, scale):
    """Under topology="quadrant" every non-hyper launch books concrete
    core ids; at every instant — including preemption instants, where a
    revoked partial run occupies [start, revoke) — no core hosts two
    launches, and a launch books exactly its width in unique cores."""
    machine, pool, jobs = _preempting_pool(graphs, scale,
                                           topology="quadrant")
    res = pool.run()
    spans = [(r.start, r.finish, r) for recs in res.records.values()
             for r in recs if not r.hyper]
    spans += [(p.start, p.finish, p) for precs in res.preempted.values()
              for p in precs if not p.hyper]
    for _, _, r in spans:
        assert len(r.cores) == r.threads
        assert len(set(r.cores)) == len(r.cores)
        assert all(0 <= c < machine.spec.cores for c in r.cores)
    for t in sorted({t for s in spans for t in s[:2]}):
        booked = [c for s0, s1, r in spans if s0 <= t < s1
                  for c in r.cores]
        assert len(booked) == len(set(booked))


@settings(**DAG_SETTINGS)
@given(graphs=st.lists(op_graphs(), min_size=2, max_size=3),
       scale=st.floats(0.1, 1.5))
def test_quadrant_launches_never_exceed_quadrant_capacity(graphs, scale):
    """A launch's per-quadrant core bookings stay within each quadrant's
    physical capacity (and hyper launches book no cores at all)."""
    machine, pool, jobs = _preempting_pool(graphs, scale,
                                           topology="quadrant")
    res = pool.run()
    spec = machine.spec
    cap = {q: len(spec.quadrant_cores(q)) for q in range(spec.quadrants)}
    recs = [r for rs in res.records.values() for r in rs]
    recs += [p for ps in res.preempted.values() for p in ps]
    for r in recs:
        if r.hyper:
            assert r.cores == ()
            continue
        per_q: dict[int, int] = {}
        for c in r.cores:
            q = spec.quadrant_of_core(c)
            per_q[q] = per_q.get(q, 0) + 1
        for q, n in per_q.items():
            assert n <= cap[q]


@settings(**DAG_SETTINGS)
@given(graph=op_graphs())
def test_flat_topology_pool_matches_corun_on_random_dags(graph):
    """topology="flat" spelled out (not defaulted) keeps the differential
    property: a 1-job flat pool is bit-identical to CorunScheduler — the
    topology feature sits behind the same parity lock as Strategies 2-4."""
    from repro.core import RuntimeConfig
    single = corun_timeline(graph, SimMachine(seed=0))
    pooled = pool_timeline(
        graph, SimMachine(seed=0),
        pool_config=PoolConfig(max_active=1, topology="flat"))
    assert single.makespan == pooled.makespan
    assert not compare_timelines(timeline_rows(single), timeline_rows(pooled))
    quad_single = corun_timeline(graph, SimMachine(seed=0),
                                 RuntimeConfig(topology="quadrant"))
    quad_pooled = pool_timeline(graph, SimMachine(seed=0),
                                RuntimeConfig(topology="quadrant"))
    assert quad_single.makespan == quad_pooled.makespan
    assert not compare_timelines(timeline_rows(quad_single),
                                 timeline_rows(quad_pooled))


# ---------------------------------------------------------------------------
# closed-loop plan store invariants (feedback="ewma")
# ---------------------------------------------------------------------------

@settings(**DAG_SETTINGS)
@given(graph=op_graphs())
def test_feedback_zero_error_matches_off_on_random_dags(graph):
    """The blend-math lock on arbitrary DAGs: feedback="ewma" fed a
    zero-error observation stream (every observation exactly matches its
    prediction) is bit-identical to feedback="off" — both through the
    single-graph scheduler and through a 1-job pool."""
    off = corun_timeline(graph, SimMachine(seed=0))
    fb = RuntimeConfig(feedback="ewma")
    for leg in (corun_timeline(graph, SimMachine(seed=0), fb,
                               zero_error=True),
                pool_timeline(graph, SimMachine(seed=0), fb,
                              zero_error=True)):
        assert off.makespan == leg.makespan
        assert not compare_timelines(timeline_rows(off),
                                     timeline_rows(leg))


@settings(**DAG_SETTINGS)
@given(graphs=st.lists(op_graphs(), min_size=2, max_size=3),
       scale=st.floats(0.1, 1.5))
def test_feedback_service_accounting_sums(graphs, scale):
    """Service accounting stays exact under feedback + preemption: what
    a job was charged equals completed core-seconds plus revoked partial
    runs at the restart-waste rate — re-estimated predictions change
    DECISIONS, never the price of granted cores."""
    machine, pool, jobs = _preempting_pool(graphs, scale, feedback="ewma")
    res = pool.run()
    eff = machine.spec.hyper_thread_efficiency
    waste = machine.spec.restart_waste
    for job in jobs:
        granted = sum(r.threads * r.duration * (eff if r.hyper else 1.0)
                      for r in res.records[job.jid])
        wasted = sum(
            p.threads * (p.finish - p.start) * (eff if p.hyper else 1.0)
            * waste for p in res.preempted[job.jid])
        assert job.service == pytest.approx(granted + wasted, rel=1e-9)


# ---------------------------------------------------------------------------
# observability invariants (tracing must be bit-for-bit inert)
# ---------------------------------------------------------------------------

@settings(**DAG_SETTINGS)
@given(graphs=st.lists(op_graphs(), min_size=2, max_size=3),
       scale=st.floats(0.1, 1.5))
def test_tracing_is_inert_on_random_preempting_mixes(graphs, scale):
    """A live RecordingSink never changes a schedule: the fully-armed pool
    (quadrant placement + ewma feedback + deadline preemption) produces
    the bit-identical timeline traced and untraced, on arbitrary DAG
    mixes — and the traced run must actually record events, so the
    property can't pass vacuously with a disconnected sink."""
    from repro.obs import RecordingSink

    sink = RecordingSink()
    _, pool_a, jobs_a = _traced_preempting_pool(graphs, scale, sink)
    _, pool_b, jobs_b = _traced_preempting_pool(graphs, scale, None)
    res_a, res_b = pool_a.run(), pool_b.run()
    assert sink.events
    assert res_a.makespan == res_b.makespan
    assert res_a.n_preemptions == res_b.n_preemptions
    for ja, jb in zip(jobs_a, jobs_b):
        divs = compare_timelines(
            timeline_rows(res_b.per_job_schedule(jb.jid)),
            timeline_rows(res_a.per_job_schedule(ja.jid)),
            label_a="untraced", label_b="traced")
        assert not divs, divs[:5]


def _traced_preempting_pool(graphs, deadline_scale, sink):
    """_preempting_pool with a trace sink wired into the pool config."""
    machine = SimMachine()
    pool = RuntimePool(machine=machine,
                       config=PoolConfig(
                           max_active=4, topology="quadrant",
                           feedback="ewma", sink=sink,
                           preemption=PreemptionPolicy(enabled=True)))
    jobs = [pool.submit(_blocker_graph(), name="blocker")]
    for i, g in enumerate(graphs, start=1):
        t = 1e-4 * i
        job = pool.submit(g, name=f"j{i}", submit_time=t)
        cp = max(job.cp.values(), default=0.0)
        job.deadline = t + cp * deadline_scale
        jobs.append(job)
    return machine, pool, jobs


@settings(**DAG_SETTINGS)
@given(graphs=st.lists(op_graphs(), min_size=2, max_size=3),
       scale=st.floats(0.1, 1.5))
def test_event_metrics_match_pool_accounting_on_random_mixes(graphs, scale):
    """metrics_from_events over the decision stream alone reproduces the
    pool's service and restart-waste accounting on arbitrary mixes."""
    from repro.obs import RecordingSink, metrics_from_events

    sink = RecordingSink()
    _, pool, jobs = _traced_preempting_pool(graphs, scale, sink)
    res = pool.run()
    ev = metrics_from_events(sink.events)
    assert ev.value("pool.service_core_s") == \
        sum(j.service for j in res.jobs)
    assert ev.value("pool.total_ops") == res.total_ops
    assert ev.value("pool.restart_waste_core_s") == \
        res.metrics["pool.restart_waste_core_s"]


class _CapAssertingQueue(JobQueue):
    """JobQueue that proves the admission-cap invariant at every pop
    (deterministic twin: tests/test_planstore.py::_AssertingQueue)."""

    def pop_admissible(self, active, now=float("inf")):
        job = super().pop_admissible(active, now)
        if (job is not None and self.max_outstanding_demand is not None
                and active):
            outstanding = sum(j.demand for j in active)
            assert outstanding + job.demand \
                <= self.max_outstanding_demand + 1e-9
        return job


@settings(**DAG_SETTINGS)
@given(graphs=st.lists(op_graphs(), min_size=3, max_size=4),
       feedback=st.sampled_from([None, "ewma"]))
def test_feedback_demand_within_admission_cap(graphs, feedback):
    """Re-estimated Job.demand must keep satisfying the admission-cap
    invariant: at every pop, outstanding live demand plus the admitted
    job's fits under the cap (checked inside the asserting queue), and
    every job still runs to completion."""
    pool = RuntimePool(machine=SimMachine(),
                       config=PoolConfig(max_active=3, feedback=feedback))
    pool.queue = _CapAssertingQueue(max_active=3)
    jobs = [pool.submit(g, name=f"j{i}", submit_time=i * 1e-4)
            for i, g in enumerate(graphs)]
    pool.queue.max_outstanding_demand = 1.5 * max(j.demand for j in jobs)
    res = pool.run()
    assert all(j.done for j in jobs)
    assert res.total_ops == sum(g.n_ops for g in graphs)


@settings(**SETTINGS)
@given(threads=st.lists(st.integers(1, 68), min_size=1, max_size=6),
       times=st.lists(st.floats(1e-5, 1.0), min_size=6, max_size=6),
       free=st.integers(0, 68), extra=st.integers(0, 34),
       horizon=st.floats(1e-4, 2.0))
def test_pick_admissible_monotone_in_free_cores(threads, times, free,
                                                extra, horizon):
    """Strategy-3 admission: the pick never exceeds the idle cores or the
    horizon, and admission is monotone — growing the idle-core budget
    never loses admissibility and never picks MORE threads (the admissible
    set only grows, and the rule takes the minimum)."""
    cands = [OpPlan(t, False, y) for t, y in zip(threads, times)]
    pick = pick_admissible(cands, free, horizon)
    if pick is not None:
        assert pick.threads <= free
        assert pick.predicted_time <= horizon
    wider = pick_admissible(cands, free + extra, horizon)
    if pick is not None:
        assert wider is not None
        assert wider.threads <= pick.threads


# ---------------------------------------------------------------------------
# compression invariants
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(seed=st.integers(0, 1000), ratio=st.floats(0.01, 0.9),
       scheme=st.sampled_from(["topk", "int8"]))
def test_error_feedback_conserves_signal(seed, ratio, scheme):
    cfg = CompressionConfig(scheme=scheme, topk_ratio=ratio)
    g = {"w": jax.random.normal(jax.random.PRNGKey(seed), (128,))}
    err = init_error_state(g)
    wire, new_err, _ = compress(cfg, g, err)
    lhs = wire["w"].astype(jnp.float32) + new_err["w"]
    rhs = g["w"].astype(jnp.float32) + err["w"]
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), atol=1e-5)


# ---------------------------------------------------------------------------
# MoE invariants
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100), top_k=st.sampled_from([1, 2]))
def test_moe_dispatch_capacity_respected(seed, top_k):
    from repro.models.layers import moe_block
    key = jax.random.PRNGKey(seed)
    e, d, fdim = 4, 16, 32
    p = {
        "router": jax.random.normal(key, (d, e)) * 0.1,
        "w_gate": jax.random.normal(key, (e, d, fdim)) * 0.1,
        "w_up": jax.random.normal(key, (e, d, fdim)) * 0.1,
        "w_down": jax.random.normal(key, (e, fdim, d)) * 0.1,
    }
    x = jax.random.normal(key, (2, 8, d))
    out, aux = moe_block(p, x, n_experts=e, top_k=top_k,
                         capacity_factor=1.0)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all())
    assert float(aux) >= 0.99   # aux >= 1 at balance (E * sum f*p >= 1)


# ---------------------------------------------------------------------------
# HLO parsing invariants
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(dims=st.lists(st.integers(1, 64), min_size=1, max_size=4))
def test_shape_bytes(dims):
    s = f"f32[{','.join(map(str, dims))}]"
    assert shape_bytes(s) == int(np.prod(dims)) * 4


def test_parse_collectives_ring_formulas():
    hlo = """
  %ag = f32[16,128]{1,0} all-gather(%x), replica_groups={{0,1,2,3}}, dimensions={0}
  %ar = f32[16,128]{1,0} all-reduce(%y), replica_groups={{0,1},{2,3}}, to_apply=%sum
  %rs = f32[4,128]{1,0} reduce-scatter(%z), replica_groups={{0,1,2,3}}, dimensions={0}
  %cp = f32[8,128]{1,0} collective-permute(%w), source_target_pairs={{0,1},{1,0}}
"""
    stats = parse_collectives(hlo, pod_size=2)
    by = stats.by_kind()
    full_ag = 16 * 128 * 4
    assert by["all-gather"][1] == full_ag * 3 / 4
    full_ar = 16 * 128 * 4
    assert by["all-reduce"][1] == 2 * full_ar * 1 / 2
    full_rs = 4 * 128 * 4 * 4
    assert by["reduce-scatter"][1] == full_rs * 3 / 4
    assert by["collective-permute"][1] == 8 * 128 * 4
    # groups {0,1,2,3} cross pod boundary at pod_size=2
    assert stats.dci_link_bytes > 0
    assert stats.ici_link_bytes > 0    # {0,1} stays in pod


def test_parse_collectives_iota_groups():
    hlo = ("  %ar = bf16[256]{0} all-reduce(%x), "
           "replica_groups=[2,2]<=[4], to_apply=%s\n")
    stats = parse_collectives(hlo, pod_size=4)
    assert stats.ops[0].group_size == 2
    assert not stats.ops[0].crosses_pod


# ---------------------------------------------------------------------------
# data determinism
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), step=st.integers(0, 50))
def test_data_step_determinism(seed, step):
    from repro.data import DataConfig, SyntheticLM
    cfg = DataConfig(seq_len=16, global_batch=4, vocab=53, seed=seed)
    a = SyntheticLM(cfg).batch_at(step)
    b = SyntheticLM(cfg).batch_at(step)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].min() >= 0 and a["tokens"].max() < 53
