"""Hypothesis property tests on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (CurveModel, HillClimbProfiler, Op, Placement,
                        SimMachine, paper_case_lists)
from repro.hw.hlo import parse_collectives, shape_bytes
from repro.optim import CompressionConfig, compress, init_error_state

SETTINGS = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# perf model invariants
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(flops=st.floats(1e6, 1e11), byts=st.floats(1e4, 1e9),
       f=st.floats(0.5, 0.99), seed=st.integers(0, 100))
def test_hillclimb_best_never_worse_than_probes(flops, byts, f, seed):
    machine = SimMachine(seed=seed)
    op = Op(uid=0, name="t", op_class="X", input_shape=(32, 8, 8, 64),
            flops=flops, bytes_moved=byts, working_set=byts,
            parallel_fraction=f)

    def measure(op_, t, v):
        return machine.op_time(op_, Placement(t, cache_sharing=v))

    curve = HillClimbProfiler(measure, paper_case_lists(),
                              interval=4).profile(op)
    t, v, y = curve.measured_best()
    for variant, pts in curve.samples.items():
        for tt, yy in pts:
            assert y <= yy + 1e-15


@settings(**SETTINGS)
@given(f=st.floats(0.5, 0.99), seed=st.integers(0, 50))
def test_interpolation_between_sample_bounds(f, seed):
    """Predictions between two samples lie between those samples
    (piecewise-linear)."""
    machine = SimMachine(seed=seed, jitter=0.0)
    op = Op(uid=0, name="t", op_class="X", input_shape=(16, 16, 16, 64),
            flops=2e9, bytes_moved=1e7, working_set=1e7,
            parallel_fraction=f)

    def measure(op_, t, v):
        return machine.op_time(op_, Placement(t, cache_sharing=v))

    curve = HillClimbProfiler(measure, paper_case_lists(),
                              interval=4).profile(op)
    for v, pts in curve.samples.items():
        for (t1, y1), (t2, y2) in zip(pts, pts[1:]):
            mid = (t1 + t2) // 2
            pred = curve.predict(mid, v)
            lo, hi = min(y1, y2), max(y1, y2)
            assert lo - 1e-12 <= pred <= hi + 1e-12


@settings(**SETTINGS)
@given(threads=st.integers(1, 68), f=st.floats(0.5, 0.99))
def test_machine_time_positive_monotone_work(threads, f):
    machine = SimMachine(jitter=0.0)
    small = Op(uid=0, name="a", op_class="X", input_shape=(8, 8, 8, 8),
               flops=1e8, bytes_moved=1e6, working_set=1e6,
               parallel_fraction=f)
    big = Op(uid=1, name="b", op_class="X", input_shape=(8, 8, 8, 8),
             flops=2e8, bytes_moved=2e6, working_set=2e6,
             parallel_fraction=f)
    pl = Placement(threads)
    assert machine.op_time(small, pl) > 0
    assert machine.op_time(big, pl) > machine.op_time(small, pl)


# ---------------------------------------------------------------------------
# compression invariants
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(seed=st.integers(0, 1000), ratio=st.floats(0.01, 0.9),
       scheme=st.sampled_from(["topk", "int8"]))
def test_error_feedback_conserves_signal(seed, ratio, scheme):
    cfg = CompressionConfig(scheme=scheme, topk_ratio=ratio)
    g = {"w": jax.random.normal(jax.random.PRNGKey(seed), (128,))}
    err = init_error_state(g)
    wire, new_err, _ = compress(cfg, g, err)
    lhs = wire["w"].astype(jnp.float32) + new_err["w"]
    rhs = g["w"].astype(jnp.float32) + err["w"]
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), atol=1e-5)


# ---------------------------------------------------------------------------
# MoE invariants
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100), top_k=st.sampled_from([1, 2]))
def test_moe_dispatch_capacity_respected(seed, top_k):
    from repro.models.layers import moe_block
    key = jax.random.PRNGKey(seed)
    e, d, fdim = 4, 16, 32
    p = {
        "router": jax.random.normal(key, (d, e)) * 0.1,
        "w_gate": jax.random.normal(key, (e, d, fdim)) * 0.1,
        "w_up": jax.random.normal(key, (e, d, fdim)) * 0.1,
        "w_down": jax.random.normal(key, (e, fdim, d)) * 0.1,
    }
    x = jax.random.normal(key, (2, 8, d))
    out, aux = moe_block(p, x, n_experts=e, top_k=top_k,
                         capacity_factor=1.0)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all())
    assert float(aux) >= 0.99   # aux >= 1 at balance (E * sum f*p >= 1)


# ---------------------------------------------------------------------------
# HLO parsing invariants
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(dims=st.lists(st.integers(1, 64), min_size=1, max_size=4))
def test_shape_bytes(dims):
    s = f"f32[{','.join(map(str, dims))}]"
    assert shape_bytes(s) == int(np.prod(dims)) * 4


def test_parse_collectives_ring_formulas():
    hlo = """
  %ag = f32[16,128]{1,0} all-gather(%x), replica_groups={{0,1,2,3}}, dimensions={0}
  %ar = f32[16,128]{1,0} all-reduce(%y), replica_groups={{0,1},{2,3}}, to_apply=%sum
  %rs = f32[4,128]{1,0} reduce-scatter(%z), replica_groups={{0,1,2,3}}, dimensions={0}
  %cp = f32[8,128]{1,0} collective-permute(%w), source_target_pairs={{0,1},{1,0}}
"""
    stats = parse_collectives(hlo, pod_size=2)
    by = stats.by_kind()
    full_ag = 16 * 128 * 4
    assert by["all-gather"][1] == full_ag * 3 / 4
    full_ar = 16 * 128 * 4
    assert by["all-reduce"][1] == 2 * full_ar * 1 / 2
    full_rs = 4 * 128 * 4 * 4
    assert by["reduce-scatter"][1] == full_rs * 3 / 4
    assert by["collective-permute"][1] == 8 * 128 * 4
    # groups {0,1,2,3} cross pod boundary at pod_size=2
    assert stats.dci_link_bytes > 0
    assert stats.ici_link_bytes > 0    # {0,1} stays in pod


def test_parse_collectives_iota_groups():
    hlo = ("  %ar = bf16[256]{0} all-reduce(%x), "
           "replica_groups=[2,2]<=[4], to_apply=%s\n")
    stats = parse_collectives(hlo, pod_size=4)
    assert stats.ops[0].group_size == 2
    assert not stats.ops[0].crosses_pod


# ---------------------------------------------------------------------------
# data determinism
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), step=st.integers(0, 50))
def test_data_step_determinism(seed, step):
    from repro.data import DataConfig, SyntheticLM
    cfg = DataConfig(seq_len=16, global_batch=4, vocab=53, seed=seed)
    a = SyntheticLM(cfg).batch_at(step)
    b = SyntheticLM(cfg).batch_at(step)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].min() >= 0 and a["tokens"].max() < 53
