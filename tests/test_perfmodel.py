"""Hill-climbing performance model (paper §III-C) + regression baseline."""

import numpy as np
import pytest

from repro.core import (HillClimbProfiler, Op, ProfileStore, SimMachine,
                        Placement, paper_case_lists, power_of_two_cases,
                        build_paper_graph)


def _op(shape=(32, 8, 8, 384), cls="Conv2DBackpropFilter", f=0.95,
        flops_per=740.0, bytes_per=260.0):
    elems = float(np.prod(shape))
    return Op(uid=0, name="t", op_class=cls, input_shape=shape,
              flops=elems * flops_per, bytes_moved=elems * bytes_per,
              working_set=elems * bytes_per, parallel_fraction=f)


@pytest.fixture
def machine():
    return SimMachine()


def _measure(machine):
    def fn(op, threads, variant):
        return machine.op_time(op, Placement(threads, cache_sharing=variant))
    return fn


class TestHillClimb:
    def test_finds_interior_optimum(self, machine):
        op = _op()
        prof = HillClimbProfiler(_measure(machine), paper_case_lists(),
                                 interval=2)
        curve = prof.profile(op)
        t, v, y = curve.measured_best()
        t_true, pl_true = machine.best_time_exhaustive(op)
        # within 5% of the exhaustive optimum (paper: <2% for x=4)
        assert y <= t_true * 1.05

    def test_probe_budget_bounded(self, machine):
        """N <= C/x * 2 (paper §III-C)."""
        op = _op()
        for x in (2, 4, 8):
            prof = HillClimbProfiler(_measure(machine), paper_case_lists(),
                                     interval=x)
            curve = prof.profile(op)
            assert curve.probes <= (68 // x) * 2 + 4

    def test_stops_on_first_increase(self, machine):
        calls = []

        def spy(op, threads, variant):
            t = _measure(machine)(op, threads, variant)
            calls.append((variant, threads))
            return t

        prof = HillClimbProfiler(spy, paper_case_lists(), interval=4)
        prof.profile(_op())
        # within each variant, threads must be non-decreasing (no backtrack)
        for variant in (False, True):
            seq = [t for v, t in calls if v == variant]
            assert seq == sorted(seq)

    def test_interpolation_accuracy_vs_interval(self, machine):
        """Paper Table V: accuracy degrades as the interval grows, high
        (>=90%) at x in {2, 4}."""
        graph = build_paper_graph("inception_v3")
        oracle = _measure(machine)
        accs = {}
        for x in (2, 4, 8, 16):
            prof = HillClimbProfiler(oracle, paper_case_lists(), interval=x)
            store = prof.profile_graph(graph)
            vals = [store.prediction_accuracy(op, oracle)
                    for op in graph.ops.values()]
            accs[x] = float(np.mean(vals))
        assert accs[2] >= 0.90
        assert accs[4] >= 0.85
        assert accs[2] >= accs[8] >= accs[16]
        assert accs[4] >= accs[16]

    def test_power_of_two_cases(self):
        cases = power_of_two_cases(16)
        assert cases[False] == [1, 2, 4, 8, 16]

    def test_curve_predict_exact_at_samples(self, machine):
        op = _op()
        prof = HillClimbProfiler(_measure(machine), paper_case_lists(),
                                 interval=4)
        curve = prof.profile(op)
        for v, pts in curve.samples.items():
            for t, y in pts:
                assert curve.predict(t, v) == pytest.approx(y, rel=1e-9)


class TestRegressionBaseline:
    def test_regressions_run_and_underperform_hillclimb(self, machine):
        """Paper Table IV vs V: regression accuracy is well below the
        hill-climb model's."""
        from repro.core import RegressionSuite

        train_graph = build_paper_graph("resnet50")
        test_graph = build_paper_graph("alexnet")
        oracle = _measure(machine)
        suite = RegressionSuite(
            feature_fn=machine.counters, oracle=oracle,
            cases=[1, 9, 17, 25, 33])
        train_ops = [op for op in train_graph.ops.values()][:24]
        test_ops = [op for op in test_graph.ops.values()][:12]
        res = suite.evaluate(train_ops, test_ops, n_samples=4,
                             regressor="KNeighbors")
        assert "accuracy" in res and "r2" in res

        prof = HillClimbProfiler(oracle, paper_case_lists(), interval=4)
        store = prof.profile_graph(test_graph)
        hc_acc = float(np.mean([store.prediction_accuracy(op, oracle)
                                for op in test_graph.ops.values()]))
        assert hc_acc > res["accuracy"]
