"""Dynamic control flow: lazily-unrolled while regions, probabilistic cond
regions, expectation pricing, trip-count learning, and the static-parity
lock.

The locks this file owns:

* **Schedule invariants over random dynamic DAGs** — deterministic twins
  of the hypothesis properties (hypothesis is absent in-container): for
  seeded random graphs with random while/cond regions and random resolved
  trip counts, every materialized op completes exactly once, dependencies
  are respected, cores are never oversubscribed at any instant, and every
  region resolves by the end of the run.
* **Zero-unresolved == static, bitwise** — a ``DynamicOpGraph`` with no
  regions must reproduce the plain ``OpGraph`` timeline bit-for-bit (the
  check_parity ``corun-dyn0``/``pool-dyn0`` legs cover the zoo; here the
  same property on random DAGs).
* **Expectation pricing** — ``remaining_demand``/``remaining_critical_path``
  price unresolved regions as expectations (trip prior x body cost), fall
  monotonically as iterations materialize, and collapse to the static sums
  once every region resolves.
* **Trip-count learning** — ``TripCountEstimator`` EWMA semantics, and the
  pool-wide sharing that lets a second tenant running the same loop start
  from the observed count instead of the prior.
* **Events-only accounting** (satellite) — ``metrics_from_events`` agrees
  with the ``PoolResult`` counters for the PR-7 economics kinds
  (``multi_revoke``/``evict``/``migrate``) on armed mixes, and for the
  region counters on a dynamic mix.
* **Decision-instant dedupe** (satellite) — an arrival scheduled after a
  slack expiry must not mask the expiry (``_next_decision_instant``).
"""

import random

import pytest

from repro.core import (ConcurrencyRuntime, GraphBuilder, PreemptionPolicy,
                        RuntimeConfig, SimMachine)
from repro.core.graph import (DynamicGraphBuilder, DynamicOpGraph,
                              build_early_exit_wave,
                              build_recurrent_step_graph)
from repro.core.planstore import TripCountEstimator
from repro.multitenant import (PoolConfig, RuntimePool, compare_timelines,
                               corun_timeline, timeline_rows)
from repro.obs import (FAM_CLUSTER, FAM_REGION, FAM_SERVICE, FAMILIES,
                       RecordingSink,
                       metrics_from_events)


@pytest.fixture(scope="module")
def machine():
    return SimMachine()


# ---------------------------------------------------------------------------
# random dynamic DAGs (seeded: deterministic twins of the hypothesis style)
# ---------------------------------------------------------------------------

_CLASSES = {
    # op_class: (flops_per_elem, bytes_per_elem, parallel_fraction)
    "DynMatMul": (160.0, 16.0, 0.96),
    "DynConv": (90.0, 24.0, 0.92),
    "DynNorm": (6.0, 32.0, 0.75),
    "DynAct": (2.0, 24.0, 0.6),
}
_SHAPES = [(32, 8, 64), (16, 16, 32), (8, 8, 128)]


def _elems(shape):
    n = 1.0
    for d in shape:
        n *= d
    return n


def _add_rand_op(b, rng, deps):
    cls = rng.choice(sorted(_CLASSES))
    fpe, bpe, pf = _CLASSES[cls]
    shape = rng.choice(_SHAPES)
    n = _elems(shape)
    return b.add(cls, shape, flops=n * fpe, bytes_moved=n * bpe,
                 parallel_fraction=pf, deps=deps)


def _rand_body(rng, tag):
    b = GraphBuilder(f"body_{tag}")
    prev = None
    for _ in range(rng.randint(1, 3)):
        prev = _add_rand_op(b, rng, [prev] if prev is not None else [])
    return b.build()


def _rand_dynamic(seed):
    """Random dynamic DAG; returns (graph, expected total op count)."""
    rng = random.Random(seed)
    b = DynamicGraphBuilder(f"dyn{seed}")
    uids = []
    n_static = 0
    n_region_ops = 0
    for _ in range(rng.randint(2, 4)):
        deps = rng.sample(uids, min(len(uids), rng.randint(0, 2)))
        uids.append(_add_rand_op(b, rng, deps))
        n_static += 1
    for r in range(rng.randint(1, 3)):
        deps = rng.sample(uids, min(len(uids), rng.randint(0, 2)))
        if rng.random() < 0.5:
            body = _rand_body(rng, f"w{r}")
            max_trips = rng.randint(1, 4)
            actual = rng.randint(0, max_trips)
            uids.append(b.add_while(
                body, deps=deps, est_trips=rng.uniform(0.5, max_trips),
                max_trips=max_trips, actual_trips=actual))
            n_region_ops += actual * body.n_ops + 1    # + exit op
        else:
            t = _rand_body(rng, f"ct{r}")
            f = _rand_body(rng, f"cf{r}")
            taken = rng.random() < 0.5
            uids.append(b.add_cond(t, f, deps=deps,
                                   p_true=rng.random(), taken=taken))
            n_region_ops += (t if taken else f).n_ops + 1
    for _ in range(rng.randint(1, 2)):
        deps = rng.sample(uids, rng.randint(1, min(len(uids), 3)))
        uids.append(_add_rand_op(b, rng, deps))
        n_static += 1
    return b.build(), n_static + n_region_ops


def _rand_static(seed):
    rng = random.Random(seed)
    b = GraphBuilder(f"stat{seed}")
    uids = []
    for _ in range(rng.randint(3, 8)):
        deps = rng.sample(uids, min(len(uids), rng.randint(0, 3)))
        uids.append(_add_rand_op(b, rng, deps))
    return b.build()


class TestDynamicScheduleInvariants:
    @pytest.mark.parametrize("seed", range(10))
    def test_exactly_once_deps_and_no_oversubscription(self, seed):
        graph, expected = _rand_dynamic(seed)
        machine = SimMachine()
        rt = ConcurrencyRuntime(machine=machine)
        res = rt.execute_step(graph)
        # exactly once: every op the resolved shape materialized, no dupes
        assert len(res.records) == expected
        assert len({r.op.uid for r in res.records}) == expected
        assert graph.unresolved_regions() == ()
        # deps respected (records carry the materialized concrete deps)
        start = {r.op.uid: r.start for r in res.records}
        finish = {r.op.uid: r.finish for r in res.records}
        for r in res.records:
            for d in r.op.deps:
                assert finish[d] <= start[r.op.uid] + 1e-12
        # no core oversubscription at any instant
        for t in sorted(set(start.values()) | set(finish.values())):
            used = sum(r.threads for r in res.records
                       if not r.hyper and r.start <= t < r.finish)
            assert used <= machine.spec.cores

    @pytest.mark.parametrize("seed", range(4))
    def test_deterministic_twin_runs(self, seed):
        ga, _ = _rand_dynamic(seed)
        gb, _ = _rand_dynamic(seed)
        ra = corun_timeline(ga, SimMachine(seed=0))
        rb = corun_timeline(gb, SimMachine(seed=0))
        assert ra.makespan == rb.makespan
        assert not compare_timelines(timeline_rows(ra), timeline_rows(rb),
                                     label_a="run-a", label_b="run-b")

    def test_graph_is_reusable_across_runs(self):
        """reset() restores the initial shape: the same DynamicOpGraph
        object scheduled twice yields the identical timeline."""
        graph, _ = _rand_dynamic(3)
        rt = ConcurrencyRuntime(machine=SimMachine(seed=0))
        rt.profile(graph)
        a = rt.execute_step(graph)
        b = rt.execute_step(graph)
        assert a.makespan == b.makespan
        assert not compare_timelines(timeline_rows(a), timeline_rows(b),
                                     label_a="first", label_b="second")

    @pytest.mark.parametrize("seed", range(4))
    def test_zero_unresolved_regions_is_static_bitwise(self, seed):
        static = _rand_static(seed)
        dyn = DynamicOpGraph(name=static.name, ops=dict(static.ops))
        assert dyn.unresolved_regions() == ()
        assert dyn.profile_view() is dyn
        rs = corun_timeline(static, SimMachine(seed=0))
        rd = corun_timeline(dyn, SimMachine(seed=0))
        assert rs.makespan == rd.makespan
        assert not compare_timelines(timeline_rows(rs), timeline_rows(rd),
                                     label_a="static", label_b="dynamic")


# ---------------------------------------------------------------------------
# expectation pricing
# ---------------------------------------------------------------------------

class TestExpectationPricing:
    @pytest.fixture(scope="class")
    def priced(self):
        g = build_recurrent_step_graph(trips=2, max_trips=8, est_trips=4.0)
        rt = ConcurrencyRuntime(machine=SimMachine(seed=0))
        rt.profile(g)
        return g, rt.planstore, rt.plan

    def test_demand_scales_with_trip_prior(self, priced):
        _, store, plan = priced
        g_opt = build_recurrent_step_graph(trips=2, max_trips=8,
                                           est_trips=1.0)
        g_pess = build_recurrent_step_graph(trips=2, max_trips=8,
                                            est_trips=8.0)
        d_opt = store.remaining_demand(g_opt, plan)
        d_pess = store.remaining_demand(g_pess, plan)
        assert 0.0 < d_opt < d_pess
        # the gap is exactly 7 expected iterations of body demand
        (r_opt,) = g_opt.unresolved_regions()
        body = store._plan_demand(r_opt.body, plan)
        assert d_pess - d_opt == pytest.approx(7 * body, rel=1e-9)

    def test_demand_falls_as_iterations_materialize(self, priced):
        g, store, plan = priced
        g.reset()
        done = set()
        demands = [store.remaining_demand(g, plan, done)]
        frontier = [u for u, op in g.ops.items() if not op.deps]
        while g.unresolved_regions():
            uid = frontier.pop(0)
            done.add(uid)
            for ev in g.advance(uid, done):
                frontier.extend(u for u in ev.new_uids
                                if all(d in done for d in g.ops[u].deps))
            for c in g.consumers(uid):
                if c not in done and c not in frontier and \
                        all(d in done for d in g.ops[c].deps):
                    frontier.append(c)
            demands.append(store.remaining_demand(g, plan, done))
        assert all(b < a for a, b in zip(demands, demands[1:]))
        # resolved: expectation collapses to the exact static remainder
        exact = sum(store._plan_time(op, plan)
                    * plan.per_instance[op.size_key].threads
                    for u, op in g.ops.items() if u not in done)
        assert demands[-1] == pytest.approx(exact, rel=1e-9)
        g.reset()

    def test_critical_path_covers_unresolved_regions(self, priced):
        g, store, plan = priced
        g.reset()
        (region,) = g.unresolved_regions()
        cp = store.remaining_critical_path(g, plan)
        # the virtual exit node is priced and the gate chains through it
        assert region.exit_uid in cp
        tail = store.region_tail(region, plan)
        assert tail > 0.0
        embed = next(u for u, op in g.ops.items() if not op.deps)
        assert cp[embed] >= tail

    def test_cond_demand_is_probability_weighted(self):
        lo = build_early_exit_wave(depth=1, accept=True, p_accept=1.0)
        hi = build_early_exit_wave(depth=1, accept=True, p_accept=0.0)
        rt = ConcurrencyRuntime(machine=SimMachine(seed=0))
        rt.profile(lo)
        store, plan = rt.planstore, rt.plan
        cond_lo = next(r for r in lo.unresolved_regions()
                       if r.kind == "cond")
        cond_hi = next(r for r in hi.unresolved_regions()
                       if r.kind == "cond")
        # p_accept=1.0 prices the cheap verify branch only; 0.0 the
        # expensive correction branch only
        assert store.region_demand(cond_lo, plan) < \
            store.region_demand(cond_hi, plan)


# ---------------------------------------------------------------------------
# trip-count learning
# ---------------------------------------------------------------------------

class TestTripCountLearning:
    def test_estimator_ewma_semantics(self):
        est = TripCountEstimator(alpha=0.5)
        assert est.estimate("k", prior=8.0) == 8.0       # no data: prior
        est.update("k", 3.0)
        assert est.estimate("k", 8.0) == 3.0             # first obs wins
        est.update("k", 4.0)
        assert est.estimate("k", 8.0) == 3.5
        est.update("k", 5.0)
        assert est.estimate("k", 8.0) == 4.25
        assert est.stats() == {"observed": 3, "keys": 1}

    def test_pool_learns_trip_counts_across_tenants(self, machine):
        pool = RuntimePool(machine=machine, config=PoolConfig(
            max_active=2, runtime=RuntimeConfig(feedback="ewma")))
        for i in range(3):
            pool.submit(build_recurrent_step_graph(trips=2, name=f"rnn{i}"),
                        submit_time=i * 0.0005)
        res = pool.run()
        key = ("while", "rnn_cell", (32, 32, 128))
        # every tenant resolved at 2 trips: the EWMA converges there, so
        # a later tenant prices 2 expected trips instead of max_trips=8
        assert pool.trip_counts.values[key] == pytest.approx(2.0)
        g = build_recurrent_step_graph(trips=2, name="next")
        (region,) = g.unresolved_regions()
        assert pool.trip_counts.estimate(region.key, 8.0) == \
            pytest.approx(2.0)
        assert res.n_region_resolves == 3
        assert res.n_region_expands == 6

    def test_frozen_store_ignores_observations(self):
        g = build_recurrent_step_graph(trips=3, est_trips=8.0)
        rt = ConcurrencyRuntime(machine=SimMachine(seed=0))
        rt.profile(g)
        (region,) = g.unresolved_regions()
        before = rt.planstore.region_trips(region)
        rt.planstore.observe_region(region, 3.0)
        assert rt.planstore.region_trips(region) == before == 8.0


# ---------------------------------------------------------------------------
# pool integration: dynamic mixes, tracing, events-only accounting
# ---------------------------------------------------------------------------

def _dynamic_mix_pool(machine, sink=None, **cfg):
    pool = RuntimePool(machine=machine, config=PoolConfig(
        max_active=cfg.pop("max_active", 3),
        runtime=RuntimeConfig(feedback="ewma"), sink=sink, **cfg))
    jobs = [
        pool.submit(build_recurrent_step_graph(trips=3), name="rnn-a"),
        pool.submit(build_recurrent_step_graph(trips=5), name="rnn-b",
                    submit_time=0.0005),
        pool.submit(build_early_exit_wave(depth=2, accept=True),
                    name="ee-a", submit_time=0.001),
        pool.submit(build_early_exit_wave(depth=4, accept=False),
                    name="ee-b", submit_time=0.0015),
    ]
    return pool, jobs


class TestDynamicPool:
    @pytest.fixture(scope="class")
    def traced_dynamic(self, machine):
        sink = RecordingSink()
        pool, jobs = _dynamic_mix_pool(machine, sink)
        res = pool.run()
        return pool, jobs, res, sink

    def test_exactly_once_and_all_jobs_done(self, traced_dynamic):
        _, jobs, res, _ = traced_dynamic
        # 3+5 rnn trips x 3 body ops, 2+4 decoder trips x 2 ops, one
        # verify branch op each, 2 statics + exits per job
        expected = {"rnn-a": 2 + 3 * 3 + 1, "rnn-b": 2 + 5 * 3 + 1,
                    "ee-a": 2 + 2 * 2 + 1 + 1 + 1,
                    "ee-b": 2 + 4 * 2 + 1 + 1 + 1}
        for job in jobs:
            assert job.done
            recs = res.records[job.jid]
            assert len(recs) == expected[job.name]
            assert len({r.op.uid for r in recs}) == expected[job.name]

    def test_no_oversubscription_across_region_instants(self, machine,
                                                        traced_dynamic):
        _, _, res, _ = traced_dynamic
        spans = [(r.start, r.finish, r.threads)
                 for recs in res.records.values()
                 for r in recs if not r.hyper]
        for t in sorted({t for s in spans for t in s[:2]}):
            used = sum(th for s0, s1, th in spans if s0 <= t < s1)
            assert used <= machine.spec.cores

    def test_region_events_trace_expansion_instants(self, traced_dynamic):
        _, _, res, sink = traced_dynamic
        evs = sink.by_family(FAM_REGION)
        expands = [e for e in evs if e.kind == "expand"]
        resolves = [e for e in evs if e.kind == "resolve"]
        assert len(expands) == res.n_region_expands == 3 + 5 + 2 + 4
        # 2 while + (1 while + 1 cond) x 2 early-exit jobs
        assert len(resolves) == res.n_region_resolves == 6
        for e in evs:
            assert e.data["region"] in ("while", "cond")
            assert e.data["new_ops"] >= 1
        for e in resolves:
            assert e.data["outcome"] is not None

    def test_events_only_accounting_matches_region_counters(
            self, traced_dynamic):
        _, _, res, sink = traced_dynamic
        ev = metrics_from_events(sink.events)
        assert ev.value("region.expand") == res.n_region_expands \
            == res.metrics["region.expand"]
        assert ev.value("region.resolve") == res.n_region_resolves \
            == res.metrics["region.resolve"]

    def test_all_six_families_fire_on_armed_dynamic_mix(self, machine):
        sink = RecordingSink()
        pool = RuntimePool(machine=machine, config=PoolConfig(
            max_active=2, topology="quadrant",
            max_outstanding_demand=5000.0,
            preemption=PreemptionPolicy(enabled=True), sink=sink,
            runtime=RuntimeConfig(feedback="ewma")))
        for i in range(3):
            submit = i * 0.0005
            pool.submit(build_recurrent_step_graph(trips=4, name=f"d{i}"),
                        submit_time=submit,
                        deadline=(submit + 0.002 if i % 2 else None))
        pool.run()
        # every family except the daemon-only service lifecycle (fires
        # from PoolDaemon — covered in tests/test_service.py) and the
        # cluster family (needs a second machine — covered in
        # tests/test_cluster.py)
        assert sink.families() == set(FAMILIES) - {FAM_SERVICE,
                                                   FAM_CLUSTER}


# ---------------------------------------------------------------------------
# events-only accounting of the economics kinds (satellite bugfix)
# ---------------------------------------------------------------------------

def _chain(name, cls, shape, flops, bm, ws, pf, n):
    b = GraphBuilder(name)
    prev = None
    for _ in range(n):
        prev = b.add(cls, shape, flops=flops, bytes_moved=bm,
                     working_set=ws, parallel_fraction=pf,
                     deps=[prev] if prev is not None else [])
    return b.build()


def _narrow_runner(n=2, flops=8e11):
    return _chain("runner", "RunnerOp", (48, 96, 64), flops, 4e7, 4e7,
                  0.96, n)


def _wide_chain(n=2, flops=4e11):
    return _chain("wide", "WideStep", (256, 256, 64), flops, 5e7, 5e7,
                  0.99, n)


def _giant_op():
    return _chain("giant", "GiantStep", (256, 256, 64), 4e12, 5e7, 5e7,
                  0.99, 1)


def _blocker(n=2):
    return _chain("blocker", "Huge", (512, 512, 64), 1e12, 1e9, 1e9,
                  0.9, n)


def _assert_economics_agreement(res, sink):
    """The satellite pin: events-only accounting equals the result
    counters for every economics kind."""
    ev = metrics_from_events(sink.events)

    def val(name):
        return ev.counters[name].value if name in ev.counters else 0.0

    assert val("pool.preemptions") == res.n_preemptions
    assert val("pool.evictions") == res.n_evictions
    assert val("pool.migrations") == res.n_migrations


class TestEventsOnlyEconomicsAccounting:
    def test_multi_victim_mix_agrees(self, machine):
        sink = RecordingSink()
        pool = RuntimePool(machine=machine, config=PoolConfig(
            max_active=6, sink=sink,
            preemption=PreemptionPolicy(enabled=True, max_victims=4)))
        for i in range(4):
            pool.submit(_narrow_runner(), name=f"r{i}")
        pool.submit(_wide_chain(), name="wide", submit_time=0.05,
                    deadline=0.15)
        res = pool.run()
        assert res.n_preemptions >= 2       # a victim SET was revoked
        _assert_economics_agreement(res, sink)

    def test_eviction_mix_agrees(self, machine):
        sink = RecordingSink()
        pool = RuntimePool(machine=machine, config=PoolConfig(
            max_active=2, sink=sink,
            preemption=PreemptionPolicy(enabled=True, evict_admitted=True),
            runtime=RuntimeConfig(enable_s4=False)))
        pool.submit(_blocker(), name="blocker")
        pool.submit(_narrow_runner(n=1), name="bystander",
                    submit_time=0.001)
        pool.submit(_wide_chain(n=1), name="urgent", submit_time=0.01,
                    deadline=0.02)
        res = pool.run()
        assert res.n_evictions == 1
        _assert_economics_agreement(res, sink)

    def test_migration_mix_agrees(self, machine):
        sink = RecordingSink()
        pool = RuntimePool(machine=machine, config=PoolConfig(
            max_active=6, sink=sink,
            preemption=PreemptionPolicy(enabled=True, migration=True)))
        for i in range(2):
            pool.submit(_narrow_runner(n=1, flops=2e11), name=f"r{i}")
        pool.submit(_giant_op(), name="urgent", submit_time=0.05,
                    deadline=0.15)
        res = pool.run()
        assert res.n_migrations >= 1
        _assert_economics_agreement(res, sink)


# ---------------------------------------------------------------------------
# decision-instant dedupe (satellite bugfix)
# ---------------------------------------------------------------------------

def test_late_arrival_does_not_mask_earlier_slack_expiry(machine):
    """One shared next-decision-instant helper: with a queued overdue
    waiter whose slack expires at t~=0.02 and another arrival not due
    until t=1.0, the pool must act at the EXPIRY, not the arrival."""
    sink = RecordingSink()
    pool = RuntimePool(machine=machine, config=PoolConfig(
        max_active=2, sink=sink,
        preemption=PreemptionPolicy(enabled=True, evict_admitted=True),
        runtime=RuntimeConfig(enable_s4=False)))
    pool.submit(_blocker(), name="blocker")
    pool.submit(_narrow_runner(n=1), name="bystander", submit_time=0.001)
    urgent = pool.submit(_wide_chain(n=1), name="urgent",
                         submit_time=0.01, deadline=0.02)
    pool.submit(_narrow_runner(n=1), name="late", submit_time=1.0)
    res = pool.run()
    evs = [e for e in sink.events
           if e.family == "preemption" and e.kind == "evict"]
    assert len(evs) == 1
    # the waiter's cp (~0.28s) already exceeds its budget when it arrives
    # at t=0.01, so the expiry instant IS the arrival instant — the evict
    # must fire there, not wait for the t=1.0 arrival wakeup
    assert evs[0].ts == pytest.approx(0.01, abs=1e-6)
    assert urgent.done and res.n_evictions == 1
