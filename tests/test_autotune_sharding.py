"""TPU-side adaptation: shard-degree autotuner, sharding plans, co-run
grouping (DESIGN.md §4)."""

import pytest

from repro.core import (RooflineMeasurement, ShardDegreeAutotuner,
                        corun_groups)
from repro.configs import get_config
from repro.models.common import default_plan
from repro.serving.kvcache import kv_cache_pspec
from repro.sharding import (clamp_degree_for_axis, degree_to_axes,
                            plan_from_degrees, validate_plan)


def synthetic_measure(t_serial: float, comm_coef: float):
    """Convex roofline curve: compute shrinks 1/d, collectives grow with d."""
    def fn(op_class, degree, variant):
        return RooflineMeasurement(
            compute_s=t_serial / degree,
            memory_s=t_serial / (2 * degree),
            collective_s=comm_coef * (degree - 1))
    return fn


class TestShardDegreeAutotuner:
    def test_finds_knee(self):
        # optimum of max(1/d, c(d-1)) is near sqrt(1/c)
        tuner = ShardDegreeAutotuner(synthetic_measure(1.0, 0.02),
                                     max_degree=16)
        plan = tuner.tune(["mlp"])
        d = plan.decisions["mlp"].degree
        # true optimum: max(1/d, 0.02(d-1)): d=8 -> max(0.125, 0.14)=0.14;
        # d=4 -> 0.25; d=16 -> 0.3 -> best is 8
        assert d == 8

    def test_monotone_curve_picks_max(self):
        tuner = ShardDegreeAutotuner(synthetic_measure(1.0, 0.0),
                                     max_degree=16)
        plan = tuner.tune(["attention"])
        assert plan.decisions["attention"].degree == 16

    def test_probe_count_bounded(self):
        tuner = ShardDegreeAutotuner(synthetic_measure(1.0, 0.5),
                                     max_degree=16)
        plan = tuner.tune(["a", "b"])
        # hill climb stops early on the steep-comm curve
        assert plan.probes <= 2 * 5

    def test_measurements_cached(self):
        calls = []

        def spy(cls, d, v):
            calls.append((cls, d))
            return synthetic_measure(1.0, 0.02)(cls, d, v)

        tuner = ShardDegreeAutotuner(spy, max_degree=8)
        tuner.tune(["x"])
        tuner.tune(["x"])
        assert len(calls) == len(set(calls))


class TestCorunGroups:
    def test_balances_independent_classes(self):
        tuner = ShardDegreeAutotuner(synthetic_measure(1.0, 0.001),
                                     max_degree=16)
        plan = tuner.tune(["attn", "mlp"])
        groups = corun_groups(plan, [["attn", "mlp"]], axis_size=16)
        assert groups
        g = groups[0]
        if len(g.members) == 2:
            assert sum(g.degrees) <= 16
            # co-run makespan beats sequential execution of tuned singles
            seq = sum(plan.decisions[m].predicted.time for m in g.members)
            assert g.makespan < seq


class TestShardingPlans:
    def test_degree_to_axes(self):
        axes = (("model", 16),)
        assert degree_to_axes(16, axes) == ("model",)
        assert degree_to_axes(1, axes) == ()
        with pytest.raises(ValueError):
            degree_to_axes(8, axes)      # not a product of sub-axes

    def test_degree_with_factored_axes(self):
        axes = (("mdl", 8), ("sub", 2))
        assert degree_to_axes(16, axes) == ("mdl", "sub")
        assert degree_to_axes(8, axes) == ("mdl",)

    def test_clamp_degree(self):
        assert clamp_degree_for_axis(16, 8) == 8
        assert clamp_degree_for_axis(3, 8) == 2
        assert clamp_degree_for_axis(16, 12) == 4

    def test_plan_from_degrees(self):
        plan = plan_from_degrees({"mlp": 16, "attention": 8},
                                 (("mdl", 8), ("sub", 2)))
        assert plan.rules["ff"] == ("mdl", "sub")
        assert plan.rules["heads"] == ("mdl",)

    def test_validate_plan_catches_indivisible(self):
        from repro.launch.mesh import make_mesh
        cfg = get_config("whisper-small")      # d_model 768
        plan = default_plan()
        mesh = make_mesh((1,), ("model",))
        problems = validate_plan(cfg, plan, mesh)
        assert problems == []                  # degree 1 always fine


class TestKvCachePolicy:
    def test_head_sharded_when_divisible(self):
        cfg = get_config("codeqwen1.5-7b")     # kv=32
        plan = default_plan()
        spec, strategy = kv_cache_pspec(cfg, plan, model_degree=16)
        assert strategy == "head-sharded"

    def test_sequence_sharded_when_not(self):
        cfg = get_config("granite-3-8b")       # kv=8 < 16
        plan = default_plan()
        spec, strategy = kv_cache_pspec(cfg, plan, model_degree=16)
        assert "sequence-sharded" in strategy

    def test_replicated_at_degree_1(self):
        cfg = get_config("olmo-1b")
        plan = default_plan()
        _, strategy = kv_cache_pspec(cfg, plan, model_degree=1)
        assert strategy == "replicated-heads"
