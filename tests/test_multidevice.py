"""Multi-device tests run in a SUBPROCESS with forced host devices, so the
main pytest process keeps seeing exactly 1 device (task-spec requirement:
smoke tests and benches see 1 device)."""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, devices: int = 8, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_main_process_single_device():
    import jax
    assert jax.device_count() == 1


def test_collective_matmul_multidevice():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.sharding import ring_ag_matmul, reference_ag_matmul
        mesh = make_mesh((2, 4), ("data", "model"))
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
        w = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
        with mesh:
            y = ring_ag_matmul(x, w, mesh=mesh, axis="model")
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(reference_ag_matmul(x, w)),
                                   atol=1e-4)
        print("OK")
    """)
    assert "OK" in out


def test_sharded_train_step_matches_single_device():
    """The SAME train step on a 2x4 mesh and on 1 device gives the same
    loss trajectory (SPMD correctness)."""
    code = """
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs import get_config
        from repro.launch.mesh import make_mesh, use_mesh
        from repro.models.common import default_plan
        from repro.sharding import named_sharding_tree
        from repro.train import (TrainConfig, init_state, make_train_step,
                                 state_specs)
        from repro.optim import AdamWConfig

        cfg = get_config("olmo-1b", smoke=True)
        tcfg = TrainConfig(microbatches=2,
                           optimizer=AdamWConfig(lr=1e-2, total_steps=10))
        key = jax.random.PRNGKey(0)
        batch = {"tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab)}
        batch["targets"] = jnp.roll(batch["tokens"], -1, 1)

        # single-logical run (replicated math)
        state = init_state(cfg, tcfg, key)
        step = jax.jit(make_train_step(cfg, tcfg))
        s1, m1 = step(state, batch)
        l_single = float(m1["loss"])

        # sharded run
        mesh = make_mesh((2, 4), ("data", "model"))
        plan = default_plan()
        cfg2 = dataclasses.replace(cfg, batch_axes=("data",))
        with use_mesh(mesh):
            st_sh = named_sharding_tree(plan, mesh, state_specs(cfg2, tcfg))
            state2 = init_state(cfg2, tcfg, key)
            state2 = jax.tree.map(jax.device_put, state2, st_sh)
            step2 = jax.jit(make_train_step(cfg2, tcfg,
                                            batch_axes=("data",)),
                            in_shardings=(st_sh, None),
                            out_shardings=(st_sh, None))
            s2, m2 = step2(state2, batch)
        l_shard = float(m2["loss"])
        assert abs(l_single - l_shard) < 5e-3, (l_single, l_shard)
        print("OK", l_single, l_shard)
    """
    out = run_py(code, devices=8, timeout=420)
    assert "OK" in out


def test_elastic_checkpoint_reshard():
    """Save on a (4,) mesh, restore onto a (2,2) mesh (elastic restart)."""
    code = """
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.train import CheckpointManager

        mesh_a = make_mesh((4,), ("data",))
        sh_a = NamedSharding(mesh_a, P("data"))
        state = {"w": jax.device_put(jnp.arange(16.0), sh_a)}
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d)
            mgr.save(5, state, block=True)
            mesh_b = make_mesh((2, 2), ("x", "y"))
            sh_b = {"w": NamedSharding(mesh_b, P(("x", "y")))}
            restored, _, step = mgr.restore(shardings=sh_b)
            assert step == 5
            np.testing.assert_array_equal(np.asarray(restored["w"]),
                                          np.arange(16.0))
            assert restored["w"].sharding == sh_b["w"]
        print("OK")
    """
    out = run_py(code, devices=8)
    assert "OK" in out


@pytest.mark.slow
def test_tiny_dryrun_cell():
    """The dry-run machinery end-to-end on a small mesh + smoke config."""
    code = """
        import dataclasses, jax
        from repro.configs import get_config, SHAPES
        from repro.launch.dryrun import measure_cell
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((2, 4), ("data", "model"))
        cfg = get_config("olmo-1b", smoke=True)
        cfg = dataclasses.replace(cfg, dtype="bfloat16")
        shape = SHAPES["train_4k"].scaled(seq=128, batch=8)
        rec = measure_cell(cfg, shape, mesh, mesh_name="single",
                           with_cost=True)
        assert rec["fits_hbm"]
        assert rec["flops_per_device"] > 0
        r = rec["roofline"]
        assert r["step_s_overlapped"] > 0
        print("OK", r["dominant"])
    """
    out = run_py(code, devices=8, timeout=420)
    assert "OK" in out


def test_ring_matmul_emits_permutes_between_dots():
    """Strategy-4 analogue structure: the ring collective matmul's HLO
    interleaves collective-permutes with dots (the overlap XLA schedules
    via -start/-done pairs)."""
    code = """
        import jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh
        from repro.sharding import ring_ag_matmul
        mesh = make_mesh((1, 8), ("data", "model"))
        x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
        w = jax.ShapeDtypeStruct((64, 32), jnp.float32)
        with mesh:
            c = jax.jit(lambda x, w: ring_ag_matmul(
                x, w, mesh=mesh, axis="model")).lower(x, w).compile()
        hlo = c.as_text()
        assert "collective-permute" in hlo, "no permute emitted"
        assert "dot(" in hlo or " dot" in hlo
        print("OK")
    """
    out = run_py(code, devices=8)
    assert "OK" in out
