"""Per-arch smoke tests (task requirement): reduced config of the same
family, one forward + one train step on CPU, asserting shapes and no NaNs;
plus prefill/decode consistency against the full forward pass."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, SHAPES, skip_reason
from repro.models import zoo
from repro.optim import AdamWConfig
from repro.train import TrainConfig, init_state, make_train_step

KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def _batch(cfg, key=KEY):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if zoo.needs_frontend(cfg):
        batch["frontend"] = 0.02 * jax.random.normal(
            key, (B, cfg.n_frontend_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_forward_shapes_no_nan(self, arch):
        cfg = get_config(arch, smoke=True)
        params = zoo.init(cfg, KEY)
        logits, aux = jax.jit(
            lambda p, b: zoo.forward(cfg, p, b))(params, _batch(cfg))
        assert logits.shape == (B, S, cfg.vocab)
        assert not bool(jnp.isnan(logits).any())
        assert bool(jnp.isfinite(aux))

    def test_train_step_decreases_loss_and_finite(self, arch):
        cfg = get_config(arch, smoke=True)
        tcfg = TrainConfig(
            microbatches=2, remat=True,
            optimizer=AdamWConfig(lr=5e-3, total_steps=10, warmup_steps=1))
        state = init_state(cfg, tcfg, KEY)
        step = jax.jit(make_train_step(cfg, tcfg))
        losses = []
        for i in range(4):
            b = _batch(cfg, jax.random.PRNGKey(100))   # same batch: memorize
            b["targets"] = jnp.roll(b["tokens"], -1, axis=1)
            state, m = step(state, b)
            losses.append(float(m["loss"]))
        assert all(jnp.isfinite(jnp.asarray(losses)))
        assert losses[-1] < losses[0]

    def test_decode_matches_forward(self, arch):
        cfg = get_config(arch, smoke=True)
        params = zoo.init(cfg, KEY)
        batch = _batch(cfg)
        ml = zoo.cache_max_len(cfg, S + 4)
        last, cache = zoo.prefill(cfg, params, batch, max_len=ml)
        nt = jnp.argmax(last, -1)
        lg, cache2 = zoo.decode_step(cfg, params, cache, nt,
                                     pos=jnp.asarray(S))
        b2 = dict(batch)
        b2["tokens"] = jnp.concatenate([batch["tokens"], nt[:, None]], 1)
        lgf, _ = zoo.forward(cfg, params, b2)
        assert float(jnp.abs(lg - lgf[:, -1]).max()) < 2e-3
        # second decode step stays consistent
        nt2 = jnp.argmax(lg, -1)
        lg2, _ = zoo.decode_step(cfg, params, cache2, nt2,
                                 pos=jnp.asarray(S + 1))
        b3 = dict(batch)
        b3["tokens"] = jnp.concatenate([b2["tokens"], nt2[:, None]], 1)
        lgf2, _ = zoo.forward(cfg, params, b3)
        assert float(jnp.abs(lg2 - lgf2[:, -1]).max()) < 2e-3


def test_shape_skip_rules():
    """long_500k runs only for sub-quadratic archs (DESIGN.md §5)."""
    runs = {a for a in ARCH_IDS
            if skip_reason(get_config(a), SHAPES["long_500k"]) is None}
    assert runs == {"mixtral-8x7b", "rwkv6-1.6b", "recurrentgemma-2b"}
    for a in ARCH_IDS:
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert skip_reason(get_config(a), SHAPES[s]) is None


def test_full_param_counts():
    """Full configs match published sizes (±10%)."""
    expected = {
        "granite-3-8b": 8.4e9, "llama3-405b": 405.9e9,
        "codeqwen1.5-7b": 8.2e9, "olmo-1b": 1.18e9,
        "llama4-scout-17b-a16e": 102e9, "mixtral-8x7b": 46.7e9,
        "rwkv6-1.6b": 1.6e9, "llama-3.2-vision-11b": 9.8e9,
        "recurrentgemma-2b": 2.9e9, "whisper-small": 0.24e9,
    }
    for arch, exp in expected.items():
        n = get_config(arch).param_count()
        assert abs(n - exp) / exp < 0.10, (arch, n, exp)


def test_moe_active_params():
    cfg = get_config("mixtral-8x7b")
    assert cfg.active_param_count() < 0.35 * cfg.param_count()


def test_remat_preserves_values():
    """remat='full' changes memory, not math."""
    cfg = get_config("granite-3-8b", smoke=True)
    params = zoo.init(cfg, KEY)
    batch = _batch(cfg)
    batch["targets"] = jnp.roll(batch["tokens"], -1, axis=1)
    cfg_r = dataclasses.replace(cfg, remat="full")

    def loss(c):
        def f(p):
            return zoo.loss_fn(c, p, batch)[0]
        return f

    l1, g1 = jax.value_and_grad(loss(cfg))(params)
    l2, g2 = jax.value_and_grad(loss(cfg_r))(params)
    assert float(jnp.abs(l1 - l2)) < 1e-5
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        assert float(jnp.abs(a - b).max()) < 1e-4


def test_scan_unroll_preserves_values():
    cfg = get_config("recurrentgemma-2b", smoke=True)
    params = zoo.init(cfg, KEY)
    batch = _batch(cfg)
    cfg_u = dataclasses.replace(cfg, scan_unroll=True)
    l1, _ = zoo.forward(cfg, params, batch)
    l2, _ = zoo.forward(cfg_u, params, batch)
    assert float(jnp.abs(l1 - l2).max()) < 1e-5
