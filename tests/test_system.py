"""End-to-end behaviour tests for the paper's system: the headline claims
(EXPERIMENTS.md cross-references these numbers)."""

from repro.core import (ConcurrencyRuntime, RuntimeConfig, SimMachine,
                        build_paper_graph, uniform_schedule)


def test_headline_mean_speedup():
    """Paper abstract: 33% average (up to 49%) improvement over the
    TensorFlow-recommended configuration across the three models.
    Our simulated-machine reproduction lands in the same band."""
    machine = SimMachine()
    speedups = []
    for model in ("resnet50", "dcgan", "inception_v3"):
        g = build_paper_graph(model)
        base = uniform_schedule(g, machine, intra=68, inter=1).makespan
        rt = ConcurrencyRuntime()
        rt.profile(g)
        ours = rt.execute_step(g).makespan
        speedups.append(base / ours)
    mean_gain = sum(speedups) / len(speedups) - 1.0
    assert 0.15 <= mean_gain <= 0.60, speedups       # paper: 0.33
    assert max(speedups) - 1.0 >= 0.30, speedups     # paper max: 0.49


def test_strategy_ordering_matches_paper():
    """Fig 3: S3 (co-running) is the dominant contribution for ResNet-50;
    each strategy is non-harmful."""
    machine = SimMachine()
    g = build_paper_graph("resnet50")

    def run(s3, s4):
        rt = ConcurrencyRuntime(config=RuntimeConfig(enable_s3=s3,
                                                     enable_s4=s4))
        rt.profile(g)
        return rt.execute_step(g).makespan

    base = uniform_schedule(g, machine, intra=68, inter=1).makespan
    s12, s123, s1234 = run(False, False), run(True, False), run(True, True)
    gain_s12 = base / s12
    gain_s3 = s12 / s123
    gain_s4 = s123 / s1234
    assert gain_s12 > 1.0
    assert gain_s3 > gain_s12 - 1.0 + 1.0 or gain_s3 > 1.15   # S3 dominates
    assert gain_s4 >= 0.999                                    # non-harmful


def test_dynamic_corun_exceeds_static_interop():
    """Fig 4: the runtime's co-run level varies dynamically and its peak
    exceeds the static inter-op parallelism (1) of the recommendation."""
    g = build_paper_graph("inception_v3")
    rt = ConcurrencyRuntime()
    rt.profile(g)
    res = rt.execute_step(g)
    peak = max(n for _, n in res.events)
    assert peak >= 2
    counts = {n for _, n in res.events}
    assert len(counts) >= 3        # genuinely dynamic, not a fixed level
