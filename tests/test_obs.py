"""Observability: trace inertness, event families, metrics agreement,
Perfetto export schema, and the shared logger.

The three locks this file owns:

* tracing is bit-for-bit inert — a pool run with a live ``RecordingSink``
  produces the identical timeline to the untraced run (the deterministic
  twin of the hypothesis property in ``test_property.py``, plus the
  traced leg ``check_parity`` runs on every differential);
* the decision-event stream is a sufficient audit record —
  ``metrics_from_events`` over the events alone reproduces the service,
  restart-waste, op-count, probe, throughput, and fairness numbers that
  ``pool_metrics`` derives from the ``PoolResult``;
* the Perfetto export is loadable — every event carries valid Trace
  Event Format fields and the JSON round-trips through a file.
"""

import json
import logging

import pytest

from repro.core import SimMachine, build_paper_graph
from repro.multitenant import (PoolConfig, PreemptionPolicy, RuntimePool,
                               check_parity, compare_timelines,
                               timeline_rows)
from repro.obs import (FAM_ADMISSION, FAM_CLUSTER, FAM_PLACEMENT,
                       FAM_PLANSTORE,
                       FAM_PREEMPTION, FAM_REGION, FAM_SERVICE, FAM_STRATEGY,
                       FAMILIES, NULL_SINK, MetricsRegistry, NullSink,
                       RecordingSink, TraceEvent, configure_logging,
                       get_logger, metrics_from_events, pool_trace,
                       write_trace)

MIX = [("resnet50", 1.0), ("dcgan", 1.0), ("resnet50", 2.0), ("dcgan", 1.0)]

# every decision family fires under this config: quadrant topology
# (placement), ewma feedback (planstore), staggered arrivals + demand cap
# under max_active=2 (admission defers), deadlines + preemption (revokes)
def _run_mix(sink=None):
    pool = RuntimePool(
        machine=SimMachine(),
        config=PoolConfig(max_active=2, topology="quadrant",
                          feedback="ewma",
                          max_outstanding_demand=5000.0,
                          preemption=PreemptionPolicy(enabled=True),
                          sink=sink))
    for i, (model, prio) in enumerate(MIX):
        submit = i * 0.0005
        pool.submit(build_paper_graph(model), priority=prio,
                    name=f"{model}-{i}", submit_time=submit,
                    deadline=(submit + 0.002 if i % 2 else None))
    return pool, pool.run()


@pytest.fixture(scope="module")
def traced_mix():
    sink = RecordingSink()
    pool, res = _run_mix(sink)
    return pool, res, sink


@pytest.fixture(scope="module")
def untraced_mix():
    return _run_mix(None)


# ---------------------------------------------------------------------------
# the sink seam
# ---------------------------------------------------------------------------

class TestSinkSeam:
    def test_null_sink_is_disabled_and_value_equal(self):
        assert NullSink().enabled is False
        assert NullSink() == NullSink() == NULL_SINK
        assert hash(NullSink()) == hash(NULL_SINK)
        assert NullSink() != RecordingSink()

    def test_recording_sink_collects_and_slices(self):
        sink = RecordingSink()
        assert sink.enabled
        sink.emit(TraceEvent(ts=0.0, family=FAM_ADMISSION, kind="admit"))
        sink.emit(TraceEvent(ts=1.0, family=FAM_STRATEGY, kind="s3_admit",
                             key=(0, 1), data={"threads": 8}))
        assert len(sink) == 2
        assert [e.kind for e in sink.by_family(FAM_STRATEGY)] == ["s3_admit"]
        assert sink.families() == {FAM_ADMISSION, FAM_STRATEGY}

    def test_trace_event_to_json_is_serializable(self):
        e = TraceEvent(ts=0.5, family=FAM_PLACEMENT, kind="book",
                       key=(1, 2), data={"quadrants": (0,), "spill": False})
        d = json.loads(json.dumps(e.to_json()))
        assert d["family"] == FAM_PLACEMENT and d["kind"] == "book"
        assert d["data"]["quadrants"] == [0]


# ---------------------------------------------------------------------------
# trace inertness: traced == untraced, bit for bit
# ---------------------------------------------------------------------------

class TestTraceInertness:
    def test_traced_mix_timeline_bitwise_untraced(self, traced_mix,
                                                  untraced_mix):
        _, traced, sink = traced_mix
        _, ref = untraced_mix
        assert sink.events, "the traced run must actually record events"
        assert traced.makespan == ref.makespan
        assert traced.n_preemptions == ref.n_preemptions
        for jid in ref.records:
            divs = compare_timelines(
                timeline_rows(ref.per_job_schedule(jid)),
                timeline_rows(traced.per_job_schedule(jid)),
                label_a="untraced", label_b="traced")
            assert not divs, divs[:5]

    def test_check_parity_runs_the_traced_leg(self):
        report = check_parity(["dcgan"])
        assert report["ok"], report

    def test_metrics_ride_on_untraced_results_too(self, untraced_mix):
        _, res = untraced_mix
        assert res.metrics["pool.makespan_s"] == res.makespan
        assert res.metrics["pool.preemptions"] == res.n_preemptions
        assert res.metrics["cache.probes_spent"] == \
            res.cache_stats["probes_spent"]


# ---------------------------------------------------------------------------
# the event stream
# ---------------------------------------------------------------------------

class TestEventStream:
    def test_all_static_families_fire_on_the_armed_mix(self, traced_mix):
        # FAM_REGION only fires on dynamic graphs (tests/test_dynamic.py
        # covers that), FAM_SERVICE only from the pool daemon
        # (tests/test_service.py), and FAM_CLUSTER only from a ClusterPool
        # (tests/test_cluster.py); the armed single-machine STATIC mix
        # must fire the remaining five and nothing else
        _, _, sink = traced_mix
        assert sink.families() == set(FAMILIES) - {FAM_REGION, FAM_SERVICE,
                                                   FAM_CLUSTER}

    def test_events_carry_causes_and_inputs(self, traced_mix):
        _, _, sink = traced_mix
        admits = [e for e in sink.by_family(FAM_ADMISSION)
                  if e.kind == "admit"]
        assert admits and all(
            {"demand", "priority", "queue_depth"} <= e.data.keys()
            for e in admits)
        revokes = [e for e in sink.by_family(FAM_PREEMPTION)
                   if e.kind == "revoke"]
        assert revokes and all(
            {"victim", "waiter_slack", "victim_remaining"}
            <= e.data.keys() for e in revokes)
        books = [e for e in sink.by_family(FAM_PLACEMENT)
                 if e.kind in ("book", "spill")]
        assert books and all("quadrants" in e.data for e in books)
        finishes = [e for e in sink.by_family(FAM_PLANSTORE)
                    if e.kind == "finish"]
        assert finishes and all(
            {"predicted", "observed", "correction"} <= e.data.keys()
            for e in finishes)

    def test_every_event_is_json_serializable(self, traced_mix):
        _, _, sink = traced_mix
        dumped = json.dumps([e.to_json() for e in sink.events])
        assert len(json.loads(dumped)) == len(sink.events)


# ---------------------------------------------------------------------------
# metrics: events alone reproduce the PoolResult accounting
# ---------------------------------------------------------------------------

class TestMetricsAgreement:
    def test_event_metrics_match_pool_accounting(self, traced_mix):
        _, res, sink = traced_mix
        ev = metrics_from_events(sink.events)
        assert ev.value("pool.service_core_s") == \
            sum(j.service for j in res.jobs)
        assert ev.value("pool.total_ops") == res.total_ops
        assert ev.value("pool.makespan_s") == res.makespan
        assert ev.value("pool.fairness_jain") == res.fairness
        assert ev.value("preemption.revoke") == res.n_preemptions > 0
        assert ev.value("cache.probes_spent") == \
            res.cache_stats["probes_spent"]

    def test_event_restart_waste_matches_result_metrics(self, traced_mix):
        _, res, _ = traced_mix
        sink = traced_mix[2]
        ev = metrics_from_events(sink.events)
        assert res.metrics["pool.restart_waste_core_s"] > 0.0
        assert ev.value("pool.restart_waste_core_s") == \
            res.metrics["pool.restart_waste_core_s"]

    def test_event_throughput_and_locality_match(self, traced_mix):
        _, res, sink = traced_mix
        ev = metrics_from_events(sink.events)
        assert ev.value("pool.throughput_ops_s") == pytest.approx(
            res.aggregate_throughput, rel=1e-12)
        assert ev.value("placement.local_fraction") == \
            res.metrics["placement.local_fraction"]

    def test_registry_primitives(self):
        reg = MetricsRegistry()
        reg.counter("c").inc()
        reg.counter("c").inc(2.0)
        reg.gauge("g").set(0.5)
        for v in (1.0, 2.0, 3.0, 4.0):
            reg.histogram("h").observe(v)
        snap = reg.snapshot()
        assert snap["c"] == 3.0 and snap["g"] == 0.5
        assert snap["h.count"] == 4 and snap["h.mean"] == 2.5
        assert snap["h.p50"] == 2.0 and snap["h.max"] == 4.0
        assert reg.value("c") == 3.0
        with pytest.raises(KeyError):
            reg.value("renamed.metric")


# ---------------------------------------------------------------------------
# Perfetto export
# ---------------------------------------------------------------------------

VALID_PHASES = {"X", "C", "i", "M", "s", "f"}


class TestPerfettoExport:
    @pytest.fixture(scope="class")
    def trace(self, traced_mix):
        _, res, sink = traced_mix
        return pool_trace(res, sink.events)

    def test_schema_fields_validate(self, trace):
        events = trace["traceEvents"]
        assert events
        for e in events:
            assert e["ph"] in VALID_PHASES
            assert isinstance(e["pid"], int)
            if e["ph"] != "M":
                assert isinstance(e["ts"], float) and e["ts"] >= 0.0
            if e["ph"] == "X":
                assert e["dur"] >= 0.0
            if e["ph"] == "i":
                assert e["s"] == "t"

    def test_all_four_processes_and_families_present(self, trace):
        events = trace["traceEvents"]
        pids = {e["pid"] for e in events}
        assert pids == {1, 2, 3, 4}
        decision_cats = {e["cat"] for e in events
                         if e["ph"] == "i" and e["pid"] == 4}
        assert decision_cats == set(FAMILIES) - {FAM_REGION, FAM_SERVICE,
                                                 FAM_CLUSTER}
        counter_names = {e["name"] for e in events if e["ph"] == "C"}
        assert {"co_running", "queue_depth",
                "bw_share_demand"} <= counter_names

    def test_flow_arrows_pair_revoke_to_relaunch(self, trace):
        starts = [e for e in trace["traceEvents"] if e["ph"] == "s"]
        finishes = [e for e in trace["traceEvents"] if e["ph"] == "f"]
        assert starts and len(starts) == len(finishes)
        by_id = {e["id"]: e for e in finishes}
        for s in starts:
            f = by_id[s["id"]]
            assert f["bp"] == "e" and f["cat"] == s["cat"] == "preempt"
            assert f["ts"] >= s["ts"]

    def test_preempted_slices_appear_on_job_tracks(self, trace, traced_mix):
        _, res, _ = traced_mix
        assert res.n_preemptions > 0
        pre = [e for e in trace["traceEvents"]
               if e["ph"] == "X" and e.get("cat") == "preempted"]
        assert len(pre) == res.n_preemptions
        assert all(e["pid"] == 2 for e in pre)

    def test_trace_round_trips_through_file(self, trace, tmp_path):
        path = tmp_path / "trace.json"
        write_trace(path, trace)
        loaded = json.loads(path.read_text())
        assert loaded == json.loads(json.dumps(trace))
        assert loaded["displayTimeUnit"] == "ms"


# ---------------------------------------------------------------------------
# the shared logger
# ---------------------------------------------------------------------------

class TestLogging:
    def test_get_logger_prefixes_the_root_name(self):
        assert get_logger("repro.obs.test").name == "repro.obs.test"
        assert get_logger("obs.test").name == "repro.obs.test"

    def test_configure_logging_is_idempotent(self):
        configure_logging("info")
        configure_logging("debug")
        root = logging.getLogger("repro")
        assert len(root.handlers) == 1
        assert root.level == logging.DEBUG
