"""Substrate: optimizer, compression, data pipeline, checkpoint, fault
tolerance, serving engine."""

import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import DataConfig, MmapTokens, Prefetcher, SyntheticLM
from repro.optim import (AdamWConfig, CompressionConfig, adamw_update,
                         clip_by_global_norm, compress, init_error_state,
                         init_opt_state, schedule_lr, wire_bytes)
from repro.serving import Request, ServeEngine
from repro.train import (CheckpointManager, Heartbeat, StragglerMonitor,
                         run_with_recovery)


class TestOptimizer:
    def test_adamw_converges_quadratic(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0, total_steps=200,
                          warmup_steps=1, schedule="constant")
        target = jnp.asarray([1.0, -2.0, 3.0])
        params = {"w": jnp.zeros(3)}
        st = init_opt_state(cfg, params)
        for _ in range(200):
            g = {"w": 2 * (params["w"] - target)}
            params, st, _ = adamw_update(cfg, g, st, params)
        assert float(jnp.abs(params["w"] - target).max()) < 0.05

    def test_clip(self):
        g = {"a": jnp.full((4,), 10.0)}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert float(norm) == pytest.approx(20.0)
        import math
        assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0,
                                                                     rel=1e-5)

    def test_schedule_shapes(self):
        cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
        lr0 = float(schedule_lr(cfg, jnp.asarray(0)))
        lr_peak = float(schedule_lr(cfg, jnp.asarray(10)))
        lr_end = float(schedule_lr(cfg, jnp.asarray(100)))
        assert lr0 < lr_peak
        assert lr_end == pytest.approx(0.1, rel=1e-3)

    def test_bf16_moments(self):
        cfg = AdamWConfig(moment_dtype="bfloat16")
        st = init_opt_state(cfg, {"w": jnp.zeros((4,))})
        assert st["mu"]["w"].dtype == jnp.bfloat16


class TestCompression:
    @pytest.mark.parametrize("scheme", ["topk", "int8"])
    def test_error_feedback_identity(self, scheme):
        """wire + residual == grad + old_error (exact EF bookkeeping)."""
        cfg = CompressionConfig(scheme=scheme, topk_ratio=0.25)
        g = {"w": jax.random.normal(jax.random.PRNGKey(0), (64,))}
        err = init_error_state(g)
        wire, new_err, _ = compress(cfg, g, err)
        lhs = wire["w"].astype(jnp.float32) + new_err["w"]
        rhs = g["w"].astype(jnp.float32) + err["w"]
        np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                                   atol=1e-5)

    def test_topk_sparsity(self):
        cfg = CompressionConfig(scheme="topk", topk_ratio=0.1)
        g = {"w": jax.random.normal(jax.random.PRNGKey(1), (1000,))}
        wire, _, _ = compress(cfg, g, init_error_state(g))
        nz = int(jnp.sum(wire["w"] != 0))
        assert nz <= 110

    def test_wire_bytes(self):
        g = {"w": jnp.zeros((1000,), jnp.bfloat16)}
        assert wire_bytes(CompressionConfig("int8"), g) == 1000.0
        assert wire_bytes(CompressionConfig("none"), g) == 2000.0


class TestData:
    def test_synthetic_deterministic_across_hosts(self):
        cfg = DataConfig(seq_len=32, global_batch=8, vocab=101, seed=7)
        whole = SyntheticLM(cfg).batch_at(3)
        parts = [SyntheticLM(cfg, host_id=h, num_hosts=4).batch_at(3)
                 for h in range(4)]
        # every host's rows appear in its own slice deterministically
        for h, p in enumerate(parts):
            assert p["tokens"].shape == (2, 32)
            again = SyntheticLM(cfg, host_id=h, num_hosts=4).batch_at(3)
            np.testing.assert_array_equal(p["tokens"], again["tokens"])

    def test_targets_shifted(self):
        cfg = DataConfig(seq_len=16, global_batch=2, vocab=50, seed=0)
        b = SyntheticLM(cfg).batch_at(0)
        assert b["tokens"].shape == b["targets"].shape

    def test_prefetcher_resume_cursor(self):
        cfg = DataConfig(seq_len=8, global_batch=2, vocab=11, seed=1)
        src = SyntheticLM(cfg)
        pf = Prefetcher(src, start_step=5)
        b5 = pf.next()
        assert pf.state()["cursor"] == 6
        np.testing.assert_array_equal(b5["tokens"], src.batch_at(5)["tokens"])
        pf.close()

    def test_mmap_loader(self):
        with tempfile.NamedTemporaryFile(suffix=".bin", delete=False) as f:
            arr = np.arange(10000, dtype=np.uint16) % 997
            arr.tofile(f.name)
            path = f.name
        cfg = DataConfig(seq_len=64, global_batch=4, vocab=997, seed=0,
                         kind="mmap", path=path)
        src = MmapTokens(cfg)
        b0 = src.batch_at(0)
        b0_again = src.batch_at(0)
        np.testing.assert_array_equal(b0["tokens"], b0_again["tokens"])
        assert b0["tokens"].shape == (4, 64)
        os.unlink(path)


class TestCheckpoint:
    def test_roundtrip_and_gc(self):
        state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
                 "opt": {"step": jnp.asarray(3)}}
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, keep_last_k=2)
            for s in (1, 2, 3):
                mgr.save(s, state, extra={"cursor": s}, block=True)
            dirs = [x for x in os.listdir(d) if x.startswith("step_")]
            assert len(dirs) == 2                      # gc kept last 2
            restored, extra, step = mgr.restore()
            assert step == 3 and extra["cursor"] == 3
            np.testing.assert_array_equal(
                np.asarray(restored["params"]["w"]),
                np.asarray(state["params"]["w"]))

    def test_restore_specific_step(self):
        state = {"w": jnp.ones(3)}
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, keep_last_k=5)
            mgr.save(1, {"w": jnp.ones(3)}, block=True)
            mgr.save(2, {"w": 2 * jnp.ones(3)}, block=True)
            r1, _, _ = mgr.restore(step=1)
            assert float(r1["w"][0]) == 1.0

    def test_latest_pointer_atomic(self):
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d)
            assert mgr.latest_step() is None
            mgr.save(7, {"w": jnp.zeros(1)}, block=True)
            assert mgr.latest_step() == 7


class TestFault:
    def test_straggler_excluded(self):
        mon = StragglerMonitor(min_observations=2, consecutive_to_exclude=2)
        for _ in range(4):
            mon.observe({"h0": 1.0, "h1": 1.02, "h2": 0.99, "h3": 6.0})
        assert mon.healthy_hosts(["h0", "h1", "h2", "h3"]) == \
            ["h0", "h1", "h2"]

    def test_transient_slowness_recovers(self):
        mon = StragglerMonitor(min_observations=1, consecutive_to_exclude=3)
        mon.observe({"h0": 1.0, "h1": 1.0, "h2": 1.0, "h3": 8.0})
        mon.observe({"h0": 1.0, "h1": 1.0, "h2": 1.0, "h3": 1.0})
        for _ in range(8):
            mon.observe({"h0": 1.0, "h1": 1.0, "h2": 1.0, "h3": 1.01})
        assert "h3" in mon.healthy_hosts(["h0", "h1", "h2", "h3"])

    def test_heartbeat_staleness(self):
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "hb.json")
            hb = Heartbeat(path, interval_s=0.0)
            assert Heartbeat.is_stale(path, 1.0)
            hb.beat(1, force=True)
            assert not Heartbeat.is_stale(path, 10.0)

    def test_recovery_replays_from_checkpoint(self):
        from repro.data import DataConfig, SyntheticLM, Prefetcher
        cfg = DataConfig(seq_len=4, global_batch=2, vocab=7, seed=0)
        pf = Prefetcher(SyntheticLM(cfg))
        calls = {"n": 0}

        def step_fn(state, batch, step):
            calls["n"] += 1
            if calls["n"] == 8:
                raise RuntimeError("injected")
            return {"n": state["n"] + 1}, {"loss": 0.0}

        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d)
            state, stats = run_with_recovery(
                step_fn, {"n": jnp.asarray(0)}, n_steps=10, save_every=3,
                manager=mgr, data_prefetch=pf)
        pf.close()
        assert stats.failures == 1 and stats.restores == 1
        # replayed steps re-execute: total applied increments = 10 + replays
        assert int(state["n"]) == 10 + stats.steps_replayed \
            or int(state["n"]) == 10


class TestServing:
    def _engine(self, n_slots=3):
        from repro.configs import get_config
        from repro.models import zoo
        cfg = get_config("olmo-1b", smoke=True)
        params = zoo.init(cfg, jax.random.PRNGKey(0))
        return cfg, ServeEngine(cfg, params, n_slots=n_slots, max_len=64)

    def test_all_requests_served(self):
        cfg, eng = self._engine()
        rng = np.random.default_rng(0)
        for i in range(7):
            eng.submit(Request(
                rid=i, prompt=rng.integers(0, cfg.vocab, 6).astype(np.int32),
                max_new_tokens=5))
        done = eng.run()
        assert len(done) == 7
        assert all(1 <= len(r.output) <= 5 for r in done)
        assert len(eng.stats) == 3                     # ceil(7/3) waves

    def test_eos_stops_generation(self):
        cfg, eng = self._engine(n_slots=1)
        prompt = np.asarray([1, 2, 3], np.int32)
        # pick eos = the model's actual first greedy token
        from repro.models import zoo
        probe_eng = eng
        probe_eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=4))
        first = probe_eng.run()[0].output[0]
        cfg2, eng2 = self._engine(n_slots=1)
        eng2.submit(Request(rid=1, prompt=prompt, max_new_tokens=16,
                            eos_id=first))
        done = eng2.run()
        assert done[0].output[-1] == first and len(done[0].output) <= 16

    def test_utilization_reported(self):
        cfg, eng = self._engine()
        rng = np.random.default_rng(1)
        for i in range(3):
            eng.submit(Request(
                rid=i, prompt=rng.integers(0, cfg.vocab, 4).astype(np.int32),
                max_new_tokens=3 + i))
        eng.run()
        assert 0.0 < eng.mean_slot_utilization <= 1.0

    def test_mixed_length_prefill_matches_unpadded_run(self):
        """The wave prefill left-pads, and the models' causal attention
        has no pad mask — so a shorter request's first generated token
        must come from the per-length exact prefill, identical to running
        that request alone, unpadded."""
        cfg, _ = self._engine()
        rng = np.random.default_rng(7)
        prompts = [rng.integers(1, cfg.vocab, n).astype(np.int32)
                   for n in (3, 6, 10)]
        solo_tokens = []
        for p in prompts:
            _, solo = self._engine(n_slots=1)
            solo.submit(Request(rid=0, prompt=p.copy(), max_new_tokens=1))
            solo_tokens.append(solo.run()[0].output[0])
        _, eng = self._engine(n_slots=3)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p.copy(), max_new_tokens=1))
        done = {r.rid: r for r in eng.run()}
        for i, tok in enumerate(solo_tokens):
            assert done[i].output == [tok], \
                f"prompt {i} (len {len(prompts[i])}) diverged from solo run"

    def test_zero_max_new_tokens_gets_zero_tokens(self):
        cfg, eng = self._engine(n_slots=2)
        rng = np.random.default_rng(2)
        eng.submit(Request(rid=0,
                           prompt=rng.integers(1, cfg.vocab, 5).astype(
                               np.int32),
                           max_new_tokens=0))
        eng.submit(Request(rid=1,
                           prompt=rng.integers(1, cfg.vocab, 8).astype(
                               np.int32),
                           max_new_tokens=3))
        done = {r.rid: r for r in eng.run()}
        assert done[0].output == []            # asked for 0, got 0
        assert len(done[1].output) == 3
        # useful_tokens must not count the suppressed prefill token
        assert eng.stats[0].useful_tokens == 3

    def test_all_zero_wave_spends_no_slot_capacity(self):
        cfg, eng = self._engine(n_slots=2)
        for i in range(2):
            eng.submit(Request(rid=i,
                               prompt=np.arange(1, 5, dtype=np.int32),
                               max_new_tokens=0))
        done = eng.run()
        assert all(r.output == [] for r in done)
        assert eng.stats[0].decode_steps == 0
        assert eng.stats[0].slot_token_capacity == 0
        assert eng.stats[0].useful_tokens == 0
