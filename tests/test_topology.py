"""Topology-aware placement: spec topology, core placement policy,
per-quadrant contention, relation-split interference, and the placement
invariants (deterministic twins of the hypothesis properties in
tests/test_property.py, runnable in hypothesis-less containers)."""

import json
import pathlib

import pytest

from repro.core import (ConcurrencyRuntime, GraphBuilder, PreemptionPolicy,
                        RuntimeConfig, SimMachine, build_paper_graph)
from repro.core.interference import InterferenceRecorder
from repro.core.placement import (REL_ANY, REL_CROSS, REL_LOCAL,
                                  free_cores_by_quadrant, place,
                                  placement_relation, quadrants_of)
from repro.hw.spec import KNL
from repro.multitenant import (PoolConfig, RuntimePool, compare_timelines,
                               corun_timeline, pool_timeline, timeline_rows)

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden"


@pytest.fixture(scope="module")
def machine():
    return SimMachine()


# ---------------------------------------------------------------------------
# KnlLikeSpec topology: tiles -> quadrants, shared-L2 pairs
# ---------------------------------------------------------------------------

class TestSpecTopology:
    def test_quadrants_partition_all_cores_exactly_once(self):
        seen = []
        for q in range(KNL.quadrants):
            seen.extend(KNL.quadrant_cores(q))
        assert sorted(seen) == list(range(KNL.cores))
        assert len(seen) == len(set(seen))

    def test_asymmetric_tile_split(self):
        # 34 tiles over 4 quadrants: 9/9/8/8 tiles = 18/18/16/16 cores
        assert KNL.quadrant_tile_counts == (9, 9, 8, 8)
        assert [len(KNL.quadrant_cores(q)) for q in range(4)] \
            == [18, 18, 16, 16]

    def test_quadrant_of_core_agrees_with_quadrant_cores(self):
        for q in range(KNL.quadrants):
            for c in KNL.quadrant_cores(q):
                assert KNL.quadrant_of_core(c) == q
        with pytest.raises(ValueError):
            KNL.quadrant_of_core(KNL.cores)

    def test_tile_pairs_share_quadrant(self):
        """A shared-L2 tile never straddles a quadrant boundary."""
        for t in range(KNL.tiles):
            a, b = KNL.tile_cores(t)
            assert b == a + 1
            assert KNL.quadrant_of_core(a) == KNL.quadrant_of_core(b)

    def test_quadrant_bandwidth_splits_mcdram(self):
        assert KNL.quadrant_bandwidth * KNL.quadrants \
            == pytest.approx(KNL.mcdram_bandwidth)


# ---------------------------------------------------------------------------
# placement policy: empty quadrant -> local packing -> bounded spill
# ---------------------------------------------------------------------------

class TestPlace:
    def test_prefers_empty_quadrant_best_fit(self):
        # q0 partly busy; q2/q3 empty with 16 cores each, q1 empty with 18:
        # a 10-wide launch takes the SMALLEST adequate empty quadrant
        busy = frozenset(KNL.quadrant_cores(0)[:4])
        cores = place(KNL, 10, busy)
        assert quadrants_of(KNL, cores) == {2}

    def test_packs_quadrant_local_with_fewest_coresidents(self):
        # all quadrants touched; q3 least busy -> 8-wide packs into q3
        busy = set()
        for q, n in ((0, 10), (1, 8), (2, 6), (3, 2)):
            busy.update(KNL.quadrant_cores(q)[:n])
        cores = place(KNL, 8, frozenset(busy))
        assert quadrants_of(KNL, cores) == {3}

    def test_bounded_spill_touches_fewest_quadrants(self):
        # 10 free in each quadrant: a 20-wide launch spills over exactly 2
        busy = set()
        for q in range(4):
            free = len(KNL.quadrant_cores(q)) - 10
            busy.update(KNL.quadrant_cores(q)[:free])
        cores = place(KNL, 20, frozenset(busy))
        assert len(cores) == 20
        assert len(quadrants_of(KNL, cores)) == 2

    def test_prefer_hint_wins_ties(self):
        assert quadrants_of(KNL, place(KNL, 8, frozenset(), prefer=3)) == {3}
        # hint also steers the packing tier
        busy = frozenset(c for q in range(4)
                         for c in KNL.quadrant_cores(q)[:2])
        assert quadrants_of(KNL, place(KNL, 8, busy, prefer=1)) == {1}

    def test_avoid_constraints_respected_or_fail(self):
        cores = place(KNL, 10, frozenset(), avoid=frozenset({0, 1}))
        assert quadrants_of(KNL, cores) <= {2, 3}
        # avoiding everything leaves no cores -> placement fails
        assert place(KNL, 1, frozenset(),
                     avoid=frozenset({0, 1, 2, 3})) is None
        # too few cores outside the avoided quadrants -> fail, not spill
        assert place(KNL, 40, frozenset(), avoid=frozenset({0, 1})) is None

    def test_cache_sharing_takes_whole_tile_pairs(self):
        # odd-numbered busy cores leave singleton tile-mates in q0; a
        # sharing launch prefers the intact pairs of q1 via packing, but
        # when forced into q0 it takes pairs first
        busy = frozenset(c for c in KNL.quadrant_cores(0) if c % 2)
        cores = place(KNL, 6, busy, cache_sharing=True,
                      avoid=frozenset({1, 2, 3}))
        assert cores is not None and len(cores) == 6
        # only singletons remain in q0, so all six are tile-singles here;
        # on an empty quadrant the same launch takes three full pairs
        cores = place(KNL, 6, frozenset(), cache_sharing=True)
        tiles = [c // 2 for c in cores]
        assert len(set(tiles)) == 3          # 3 tiles x 2 cores

    def test_deterministic(self):
        busy = frozenset({0, 1, 20, 21, 40})
        assert place(KNL, 12, busy) == place(KNL, 12, busy)

    def test_free_cores_by_quadrant_accounts_busy(self):
        busy = frozenset(KNL.quadrant_cores(1))
        free = free_cores_by_quadrant(KNL, busy)
        assert free[1] == []
        assert len(free[0]) == 18 and len(free[2]) == 16


# ---------------------------------------------------------------------------
# per-quadrant contention in the cost oracle
# ---------------------------------------------------------------------------

class TestQuadrantBwShare:
    def test_solo_launch_gets_full_bandwidth_like_flat(self, machine):
        cores = KNL.quadrant_cores(0)[:16]
        assert machine.quadrant_bw_share(cores, []) == 1.0

    def test_disjoint_quadrants_beat_flat_corun_share(self, machine):
        a = KNL.quadrant_cores(0)
        b = KNL.quadrant_cores(1)
        flat = machine.corun_bw_share(len(a), [len(b)])
        quad = machine.quadrant_bw_share(a, [(len(b), b)])
        assert quad > flat
        assert quad == pytest.approx(
            max(0.25, len(a) / (len(a) + len(b)))
            * KNL.quadrant_local_boost)

    def test_contested_quadrant_pays_cross_penalty(self, machine):
        mine = KNL.quadrant_cores(0)[:8]
        local = machine.quadrant_bw_share(
            mine, [(8, KNL.quadrant_cores(1)[:8])])
        shared = machine.quadrant_bw_share(
            mine, [(8, KNL.quadrant_cores(0)[8:16])])
        assert shared < local
        base = max(0.25, 8 / 16)
        assert shared == pytest.approx(base * KNL.cross_quadrant_penalty)

    def test_partial_straddle_blends_per_core(self, machine):
        # 18 cores home in q0 + 6 spilled into contested q1
        mine = KNL.quadrant_cores(0) + KNL.quadrant_cores(1)[:6]
        other = KNL.quadrant_cores(1)[6:14]
        share = machine.quadrant_bw_share(mine, [(8, other)])
        base = max(0.25, 24 / 32)
        locality = (18 / 24) * KNL.quadrant_local_boost \
            + (6 / 24) * KNL.cross_quadrant_penalty
        assert share == pytest.approx(min(1.0, base * locality))

    def test_unplaced_hyper_rider_contests_nothing(self, machine):
        mine = KNL.quadrant_cores(0)[:8]
        share = machine.quadrant_bw_share(mine, [(4, ())])
        assert share == pytest.approx(
            min(1.0, max(0.25, 8 / 12) * KNL.quadrant_local_boost))


# ---------------------------------------------------------------------------
# relation-split interference (the op-class-only blacklist bugfix)
# ---------------------------------------------------------------------------

class TestRelationSplitInterference:
    def test_cross_observation_does_not_blacklist_local(self):
        """The regression: one bad cross-quadrant observation used to
        blacklist the pair EVERYWHERE; with the key split by placement
        relation, the quadrant-local relation stays clean."""
        rec = InterferenceRecorder()
        rec.record("A", "B", 1.0, 10.0, relation=REL_CROSS)
        assert rec.blacklisted("A", "B", REL_CROSS)
        assert not rec.blacklisted("A", "B", REL_LOCAL)
        assert not rec.blacklisted("A", "B", REL_ANY)
        assert rec.blacklist() == frozenset({("A", "B", REL_CROSS)})

    def test_flat_any_relation_unchanged(self):
        rec = InterferenceRecorder()
        rec.record("A", "B", 1.0, 10.0)            # default = "any"
        assert rec.blacklisted("A", "B")
        assert rec.blacklisted("B", "A")
        assert not rec.blacklisted("A", "B", REL_LOCAL)

    def test_placement_relation_classification(self):
        a = KNL.quadrant_cores(0)[:4]
        b = KNL.quadrant_cores(1)[:4]
        c = KNL.quadrant_cores(0)[4:8]
        assert placement_relation(KNL, a, b) == REL_LOCAL
        assert placement_relation(KNL, a, c) == REL_CROSS
        assert placement_relation(KNL, a, ()) == REL_CROSS   # hyper rider

    def test_cross_blacklisted_pair_still_coruns_in_disjoint_quadrants(
            self, machine):
        """Quadrant mode re-admits a cross-blacklisted pair as long as
        placement keeps their quadrants disjoint; a LOCAL blacklist (the
        pair interferes even separated) forbids the co-run outright."""
        def two_class_graph():
            b = GraphBuilder("g")
            for cls in ("ClassA", "ClassB"):
                prev = None
                for _ in range(2):
                    prev = b.add(cls, (32, 16, 16, 64), flops=4e8,
                                 bytes_moved=2e6,
                                 deps=[prev] if prev is not None else [])
            return b.build()

        def run(relation):
            rt = ConcurrencyRuntime(
                machine=machine,
                config=RuntimeConfig(topology="quadrant"))
            graph = two_class_graph()
            rt.profile(graph)
            rt.recorder.record("ClassA", "ClassB", 1.0, 10.0,
                               relation=relation)
            res = rt.execute_step(graph)
            a = [r for r in res.records if r.op.op_class == "ClassA"]
            b = [r for r in res.records if r.op.op_class == "ClassB"]
            overlap = [(x, y) for x in a for y in b
                       if x.start < y.finish - 1e-15
                       and y.start < x.finish - 1e-15]
            return overlap

        overlap = run(REL_CROSS)
        assert overlap, "cross-only blacklist must not stop local co-runs"
        for x, y in overlap:
            assert not (quadrants_of(machine.spec, x.cores)
                        & quadrants_of(machine.spec, y.cores)), \
                "cross-blacklisted pair was placed into a shared quadrant"
        assert not run(REL_LOCAL), \
            "local-blacklisted pair co-launched (interferes even apart)"


# ---------------------------------------------------------------------------
# placement invariants — deterministic twins of the hypothesis properties
# ---------------------------------------------------------------------------

def _big_graph(n=3):
    b = GraphBuilder("big")
    prev = None
    for _ in range(n):
        prev = b.add("Huge", (512, 512, 64), flops=5e12, bytes_moved=1e9,
                     working_set=1e9, deps=[prev] if prev is not None else [])
    return b.build()


def _urgent_chain(n=4):
    b = GraphBuilder("urgent")
    prev = None
    for _ in range(n):
        prev = b.add("WavePrefill", (32, 128, 64), flops=8e9,
                     bytes_moved=2e7, working_set=2e7,
                     parallel_fraction=0.97,
                     deps=[prev] if prev is not None else [])
    return b.build()


def _assert_no_core_double_booked(machine, res):
    """At every instant, each core hosts at most one non-hyper launch —
    counting revoked partial runs over [start, revoke)."""
    spans = [(r.start, r.finish, r.cores)
             for recs in res.records.values() for r in recs if not r.hyper]
    spans += [(p.start, p.finish, p.cores)
              for precs in res.preempted.values() for p in precs
              if not p.hyper]
    for t in sorted({t for s in spans for t in s[:2]}):
        live = [s for s in spans if s[0] <= t < s[1]]
        booked: list[int] = []
        for _, _, cores in live:
            booked.extend(cores)
        assert len(booked) == len(set(booked)), \
            f"core double-booked at t={t}"


def _assert_quadrant_capacity(machine, res):
    """A launch's cores are unique, valid, match its width, and never
    exceed any quadrant's capacity."""
    spec = machine.spec
    cap = {q: len(spec.quadrant_cores(q)) for q in range(spec.quadrants)}
    all_recs = [r for recs in res.records.values() for r in recs]
    all_recs += [p for precs in res.preempted.values() for p in precs]
    for r in all_recs:
        if r.hyper:
            assert r.cores == ()
            continue
        assert len(r.cores) == r.threads
        assert len(set(r.cores)) == len(r.cores)
        per_q: dict[int, int] = {}
        for c in r.cores:
            assert 0 <= c < spec.cores
            q = spec.quadrant_of_core(c)
            per_q[q] = per_q.get(q, 0) + 1
        for q, n in per_q.items():
            assert n <= cap[q]


class TestPlacementInvariants:
    def _quadrant_mix(self, machine, *, preempt):
        pool = RuntimePool(
            machine=machine,
            config=PoolConfig(
                max_active=4, topology="quadrant",
                preemption=(PreemptionPolicy(enabled=True)
                            if preempt else None)))
        pool.submit(_big_graph(), name="big")
        pool.submit(build_paper_graph("dcgan"), name="dcgan")
        pool.submit(_urgent_chain(), name="urgent", submit_time=0.05,
                    deadline=0.15 if preempt else None)
        return pool, pool.run()

    def test_no_core_double_booked(self, machine):
        _, res = self._quadrant_mix(machine, preempt=False)
        _assert_no_core_double_booked(machine, res)
        _assert_quadrant_capacity(machine, res)

    def test_no_core_double_booked_across_preemption_revokes(self, machine):
        _, res = self._quadrant_mix(machine, preempt=True)
        assert res.n_preemptions >= 1, \
            "scenario must actually exercise preemption"
        _assert_no_core_double_booked(machine, res)
        _assert_quadrant_capacity(machine, res)
        # a revoked launch's cores are reusable immediately: the victim's
        # relaunch and the preemptor never collide (covered above), and
        # every op still completes exactly once
        for job in res.jobs:
            recs = res.records[job.jid]
            assert len(recs) == job.graph.n_ops
            assert len({r.op.uid for r in recs}) == job.graph.n_ops

    def test_tenant_quadrant_affinity_recorded(self, machine):
        pool, res = self._quadrant_mix(machine, preempt=False)
        for job in res.jobs:
            assert job.last_quadrant is not None
            assert 0 <= job.last_quadrant < machine.spec.quadrants

    def test_flat_pool_records_no_cores(self, machine):
        pool = RuntimePool(machine=machine, config=PoolConfig(max_active=2))
        pool.submit(build_paper_graph("dcgan"), name="a")
        res = pool.run()
        for recs in res.records.values():
            for r in recs:
                assert r.cores == ()


# ---------------------------------------------------------------------------
# flat topology = the pre-topology scheduler, bit for bit
# ---------------------------------------------------------------------------

class TestFlatParityLock:
    @pytest.mark.parametrize("model", ["resnet50", "dcgan"])
    def test_explicit_flat_pool_matches_committed_golden(self, model):
        """topology="flat" (spelled out, not defaulted) reproduces the
        PR-2/PR-3 golden timelines bitwise — the whole topology feature
        sits behind the same parity lock as Strategies 2-4."""
        golden = json.loads(
            (GOLDEN_DIR / f"strategy_{model}.json").read_text())
        res = pool_timeline(
            build_paper_graph(model), SimMachine(seed=golden["seed"]),
            pool_config=PoolConfig(max_active=1, topology="flat"))
        assert res.makespan == golden["makespan"]
        assert not compare_timelines(golden["records"], timeline_rows(res),
                                     label_a="golden", label_b="flat-pool")

    def test_flat_corun_scheduler_matches_explicit_flat_config(self):
        graph = build_paper_graph("dcgan")
        default = corun_timeline(graph, SimMachine(seed=0))
        explicit = corun_timeline(graph, SimMachine(seed=0),
                                  RuntimeConfig(topology="flat"))
        assert default.makespan == explicit.makespan
        assert not compare_timelines(timeline_rows(default),
                                     timeline_rows(explicit))

    def test_quadrant_single_job_pool_matches_quadrant_corun(self):
        """The pool-vs-corun differential holds WITHIN quadrant topology
        too: one core, two adapters, any topology."""
        graph = build_paper_graph("dcgan")
        cfg = RuntimeConfig(topology="quadrant")
        single = corun_timeline(graph, SimMachine(seed=0), cfg)
        pooled = pool_timeline(graph, SimMachine(seed=0), cfg)
        assert single.makespan == pooled.makespan
        assert not compare_timelines(timeline_rows(single),
                                     timeline_rows(pooled))

    def test_quadrant_changes_timings_not_correctness(self, machine):
        pool = RuntimePool(machine=machine,
                           config=PoolConfig(max_active=3,
                                             topology="quadrant"))
        for i, model in enumerate(["resnet50", "dcgan"]):
            pool.submit(build_paper_graph(model), name=f"{model}-{i}")
        res = pool.run()
        for job in res.jobs:
            assert job.done
            recs = res.records[job.jid]
            assert len(recs) == job.graph.n_ops
            start = {r.op.uid: r.start for r in recs}
            finish = {r.op.uid: r.finish for r in recs}
            for op in job.graph.ops.values():
                for d in op.deps:
                    assert finish[d] <= start[op.uid] + 1e-12
