"""Strategies 1-2 (concurrency control) + interference recorder."""

from repro.core import (ConcurrencyController, ConcurrencyRuntime,
                        InterferenceRecorder, SimMachine,
                        build_paper_graph)


class TestStrategies12:
    def setup_method(self):
        self.machine = SimMachine()
        self.graph = build_paper_graph("inception_v3")
        self.rt = ConcurrencyRuntime()
        self.rt.profile(self.graph)

    def test_one_plan_per_class(self):
        plan = self.rt.plan
        for cls in self.graph.classes():
            assert cls in plan.per_class

    def test_class_plan_from_largest_instance(self):
        """Strategy 2: class threads = optimum of the heaviest instance."""
        plan = self.rt.plan
        classes = self.graph.classes()
        for cls, ops in classes.items():
            if not all(o.tunable for o in ops):
                continue
            heaviest = max(ops, key=lambda o: o.weight)
            curve = self.rt.store.curves[heaviest.size_key]
            t, v, _ = curve.best()
            assert plan.per_class[cls].threads == t

    def test_clamp_reverts_large_deviations(self):
        plan = self.rt.plan
        cls = "Conv2DBackpropFilter"
        base = plan.per_class[cls]
        from repro.core import OpPlan
        ops = self.graph.classes()[cls]
        wild = OpPlan(max(1, base.threads - 10 * plan.case_step),
                      base.variant, 1.0)
        clamped = plan.clamp(ops[0], wild)
        assert clamped.threads == base.threads
        mild = OpPlan(base.threads - plan.case_step, base.variant, 1.0)
        assert plan.clamp(ops[0], mild).threads == mild.threads

    def test_non_tunable_pinned_to_default(self):
        """Eigen-style ops keep the session default concurrency."""
        plan = self.rt.plan
        for cls, ops in self.graph.classes().items():
            if all(not o.tunable for o in ops):
                assert plan.per_class[cls].threads == \
                    self.machine.spec.cores

    def test_candidates_sorted_and_bounded(self):
        ctrl: ConcurrencyController = self.rt.controller
        for op in list(self.graph.ops.values())[:10]:
            cands = ctrl.candidates_for(op, k=3)
            assert 1 <= len(cands) <= 3
            times = [c.predicted_time for c in cands]
            assert times == sorted(times)


class TestInterference:
    def test_blacklist_after_repeated_slowdown(self):
        rec = InterferenceRecorder(threshold=1.3)
        for _ in range(5):
            rec.record("A", "B", predicted=1.0, observed=1.6)
        assert rec.blacklisted("A", "B")
        assert rec.blacklisted("B", "A")          # symmetric
        assert not rec.blacklisted("A", "C")
        assert not rec.compatible("A", ["B"])
        assert rec.compatible("A", ["C"])

    def test_fast_corun_not_blacklisted(self):
        rec = InterferenceRecorder(threshold=1.3)
        for _ in range(5):
            rec.record("A", "B", predicted=1.0, observed=1.05)
        assert not rec.blacklisted("A", "B")
