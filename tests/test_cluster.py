"""Cluster pool tests: router properties, cluster-1m parity, rebalance,
priced splits, PlanCache sharing, FAM_CLUSTER observability, and the
cluster-mode daemon.

The three Issue-10 router properties — every job routed exactly once, no
machine over its demand cap at any decision instant, rebalance preserves
exactly-once completion — each have a DETERMINISTIC twin that always
runs; the hypothesis generalizations at the bottom are guarded (the test
image does not ship hypothesis) and exercise the pure ``JobRouter`` over
generated fact tables when the library is available.
"""

from __future__ import annotations

import dataclasses
import os
import random

import pytest

from repro.cluster import (ClusterPool, ClusterResult, JobRouter,
                           MachineFacts, RouterConfig)
from repro.core import SimMachine, StrategyConfig, build_paper_graph
from repro.core.graph import GraphBuilder
from repro.hw import KNL, ClusterSpec
from repro.multitenant import PoolConfig
from repro.multitenant.parity import (cluster_timeline, pool_timeline,
                                      timeline_rows)
from repro.obs import FAM_CLUSTER, RecordingSink, export_cluster_trace
from repro.obs.metrics import metrics_from_events
from repro.obs.perfetto import MACHINE_PID_BASE
from repro.service import JobEntry, JobSpec, PoolDaemon, StoreState

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # deterministic twins below still run
    HAVE_HYPOTHESIS = False


def _recorded_uids(result, jid):
    return sorted(rec.op.uid for rec in result.records[jid])


def _two_component_graph(name: str = "twin", chains: int = 2,
                         depth: int = 5):
    """``chains`` disjoint dependency chains in one static graph — the
    smallest shape the cross-machine split can legally partition."""
    b = GraphBuilder(name)
    for _ in range(chains):
        prev = None
        for _ in range(depth):
            prev = b.add("Conv2D", (32, 32, 32, 64), flops=4e9,
                         bytes_moved=1.5e7,
                         deps=([prev] if prev is not None else []))
    return b.build()


# ---------------------------------------------------------------------------
# ClusterSpec
# ---------------------------------------------------------------------------

class TestClusterSpec:
    def test_homogeneous(self):
        c = ClusterSpec.homogeneous(3)
        assert c.n_machines == len(c) == 3
        assert c.total_cores == 3 * KNL.cores
        assert all(m is KNL for m in c.machines)

    def test_heterogeneous(self):
        small = dataclasses.replace(KNL, cores=34)
        c = ClusterSpec(machines=(KNL, small))
        assert c.total_cores == KNL.cores + 34

    def test_empty_rejected(self):
        with pytest.raises(ValueError):
            ClusterSpec(machines=())

    def test_frozen(self):
        with pytest.raises(dataclasses.FrozenInstanceError):
            ClusterSpec().name = "other"


# ---------------------------------------------------------------------------
# JobRouter (pure decision logic)
# ---------------------------------------------------------------------------

def _facts(rows):
    """rows: (load, demand, warm_frac) per machine, 68 cores each."""
    return [MachineFacts(i, 68, load, demand, warm)
            for i, (load, demand, warm) in enumerate(rows)]


class TestJobRouter:
    def test_unknown_policy_rejected(self):
        with pytest.raises(ValueError):
            RouterConfig(policy="lottery")

    def test_empty_facts_rejected(self):
        with pytest.raises(ValueError):
            JobRouter().route([])

    def test_round_robin_cycles(self):
        r = JobRouter(RouterConfig(policy="round_robin"))
        facts = _facts([(0, None, 0)] * 3)
        assert [r.route(facts) for _ in range(6)] == [0, 1, 2, 0, 1, 2]

    def test_demand_picks_smallest_projected_finish(self):
        r = JobRouter()
        # machine 1 idle, machine 0 loaded: 1 wins despite equal demand
        assert r.route(_facts([(100.0, 5.0, 0.0),
                               (0.0, 5.0, 0.0)])) == 1

    def test_warmth_breaks_exact_ties(self):
        r = JobRouter()
        assert r.route(_facts([(10.0, 5.0, 0.0),
                               (10.0, 5.0, 1.0)])) == 1

    def test_index_breaks_full_ties(self):
        r = JobRouter()
        assert r.route(_facts([(10.0, 5.0, 0.5),
                               (10.0, 5.0, 0.5)])) == 0

    def test_projected_finish_optimistic_when_unpriced(self):
        f = MachineFacts(0, 68, load=68.0, demand=None, warm_frac=0.0)
        assert f.projected_finish == 1.0      # load alone, no demand term


# ---------------------------------------------------------------------------
# Routing properties — deterministic twins (always run)
# ---------------------------------------------------------------------------

class TestRoutingProperties:
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_every_job_routed_exactly_once(self, seed):
        """Assignment covers every submitted jid, each job's ops are
        recorded on EXACTLY the machine the router chose, once each."""
        rng = random.Random(seed)
        n = rng.choice([2, 3])
        models = [rng.choice(["resnet50", "dcgan"]) for _ in range(5)]
        pool = ClusterPool(ClusterSpec.homogeneous(n),
                           config=PoolConfig(max_active=2))
        jobs = [pool.submit(build_paper_graph(m), name=f"{m}.{i}",
                            submit_time=round(rng.uniform(0, 0.005), 6))
                for i, m in enumerate(models)]
        res = pool.run()
        assert sorted(res.assignment) == sorted(j.jid for j in jobs)
        for job in jobs:
            owners = [m for m, r in enumerate(res.machines)
                      if job.jid in r.records]
            assert owners == [res.assignment[job.jid]]
            assert _recorded_uids(res.machines[owners[0]], job.jid) \
                == sorted(job.graph.ops)

    def test_no_machine_over_demand_cap_at_any_instant(self):
        """Per-machine admission honors ``max_outstanding_demand`` at
        every decision instant.  The cap has a deliberate carve-out: a
        SOLO job is always admitted even over the cap (otherwise an
        oversized job could never run), so the invariant is conditional
        on co-running."""
        probe = ClusterPool(ClusterSpec.homogeneous(1))
        big = probe.submit(build_paper_graph("resnet50")).demand
        small = probe.submit(build_paper_graph("dcgan")).demand
        cap = big + 1.5 * small       # big+small co-runs; big+big never
        pool = ClusterPool(
            ClusterSpec.homogeneous(2),
            config=PoolConfig(max_active=4,
                              max_outstanding_demand=cap))
        for i in range(3):
            pool.submit(build_paper_graph("resnet50"), name=f"r{i}")
            pool.submit(build_paper_graph("dcgan"), name=f"d{i}")
        pool.begin()
        saw_corun = False
        while True:
            for p in pool.pools:
                if len(p._active) > 1:
                    saw_corun = True
                    outstanding = sum(j.demand for j in p._active)
                    assert outstanding <= cap + 1e-9
            if not pool.step():
                break
        assert saw_corun, "cap test must actually exercise co-running"
        assert all(cj.done for cj in pool.cluster_jobs)

    def _rebalance_run(self, rebalance: bool):
        pool = ClusterPool(ClusterSpec.homogeneous(2),
                           config=PoolConfig(max_active=1),
                           router=RouterConfig(rebalance=rebalance))
        pool.submit(build_paper_graph("resnet50"), name="hog", machine=0)
        urgent = pool.submit(build_paper_graph("dcgan"), name="urgent",
                             machine=0, submit_time=0.001, deadline=0.04)
        return pool, pool.run(), urgent

    def test_rebalance_preserves_exactly_once_completion(self):
        """The moved job's ops run once, on the target only; the stale
        jid leaves no records anywhere and resolves through the alias."""
        pool, res, urgent = self._rebalance_run(True)
        cj = next(c for c in res.cluster_jobs if c.name == "urgent")
        assert res.n_rebalances == 1 and cj.moves == 1
        assert cj.machine == 1 and cj.history == [(0, urgent.jid)]
        new_jid = cj.jobs[0].jid
        assert new_jid != urgent.jid
        assert pool.current_jid(urgent.jid) == new_jid
        assert urgent.jid not in res.assignment
        for r in res.machines:
            assert urgent.jid not in r.records
        assert _recorded_uids(res.machines[1], new_jid) \
            == sorted(urgent.graph.ops)
        # latency never forgiven: clocked from the ORIGINAL submission
        assert cj.latency == pytest.approx(
            cj.jobs[0].finish_time - 0.001)

    def test_rebalance_disabled_stays_put(self):
        _, res, urgent = self._rebalance_run(False)
        cj = next(c for c in res.cluster_jobs if c.name == "urgent")
        assert res.n_rebalances == 0 and cj.moves == 0
        assert cj.machine == 0 and cj.jobs[0].jid == urgent.jid

    def test_rebalance_beats_staying(self):
        _, moved_res, _ = self._rebalance_run(True)
        _, stay_res, _ = self._rebalance_run(False)
        moved = next(c for c in moved_res.cluster_jobs
                     if c.name == "urgent")
        stayed = next(c for c in stay_res.cluster_jobs
                      if c.name == "urgent")
        assert moved.latency < stayed.latency

    def test_no_deadline_never_rebalances(self):
        pool = ClusterPool(ClusterSpec.homogeneous(2),
                           config=PoolConfig(max_active=1))
        pool.submit(build_paper_graph("resnet50"), machine=0)
        pool.submit(build_paper_graph("dcgan"), machine=0,
                    submit_time=0.001)
        res = pool.run()
        assert res.n_rebalances == 0

    def test_routing_is_deterministic(self):
        def run():
            pool = ClusterPool(ClusterSpec.homogeneous(2),
                               config=PoolConfig(max_active=2))
            for i in range(4):
                m = "resnet50" if i % 2 == 0 else "dcgan"
                pool.submit(build_paper_graph(m), name=f"{m}.{i}")
            return pool.run()

        a, b = run(), run()
        assert a.assignment == b.assignment
        assert a.makespan == b.makespan
        assert a.metrics == b.metrics

    def test_demand_routing_spreads_identical_jobs(self):
        pool = ClusterPool(ClusterSpec.homogeneous(2))
        j0 = pool.submit(build_paper_graph("resnet50"))
        j1 = pool.submit(build_paper_graph("resnet50"))
        assert {pool.assignment[j0.jid], pool.assignment[j1.jid]} == {0, 1}


# ---------------------------------------------------------------------------
# cluster-1m parity: the layering claim
# ---------------------------------------------------------------------------

class TestClusterParity:
    @pytest.mark.parametrize("model", ["resnet50", "dcgan"])
    def test_one_machine_cluster_is_the_pool(self, model):
        a = pool_timeline(build_paper_graph(model), SimMachine(seed=3))
        b = cluster_timeline(build_paper_graph(model), SimMachine(seed=3))
        assert timeline_rows(a) == timeline_rows(b)


# ---------------------------------------------------------------------------
# PlanCache sharing + DemandIndex memoization
# ---------------------------------------------------------------------------

class TestPlanCacheSharing:
    def test_same_fingerprint_pays_probes_once(self):
        """Homogeneous machines share a curve namespace: the second
        machine's submit-time profile is a pure cache hit."""
        pool = ClusterPool(ClusterSpec.homogeneous(2))
        g = build_paper_graph("resnet50")
        pool.submit(build_paper_graph("resnet50"), machine=0)
        spent = pool.plan_cache.stats()["probes_spent"]
        assert pool._warm_frac(1, g) == 1.0
        pool.submit(build_paper_graph("resnet50"), machine=1)
        assert pool.plan_cache.stats()["probes_spent"] == spent

    def test_distinct_fingerprints_pay_separately(self):
        """Machines with different timing identities (here: different
        jitter seeds) must NOT share curves."""
        pool = ClusterPool(ClusterSpec.homogeneous(2),
                           machines=[SimMachine(seed=0),
                                     SimMachine(seed=7)])
        pool.submit(build_paper_graph("dcgan"), machine=0)
        spent = pool.plan_cache.stats()["probes_spent"]
        assert pool._warm_frac(1, build_paper_graph("dcgan")) == 0.0
        pool.submit(build_paper_graph("dcgan"), machine=1)
        assert pool.plan_cache.stats()["probes_spent"] > spent

    def test_demand_index_memoizes_repeat_shapes(self):
        pool = ClusterPool(ClusterSpec.homogeneous(2))
        pool.submit(build_paper_graph("dcgan"))
        misses = pool.demand_index.misses
        pool.submit(build_paper_graph("dcgan"))
        assert pool.demand_index.hits >= 1
        assert pool.demand_index.misses == misses


# ---------------------------------------------------------------------------
# Priced cross-machine splits
# ---------------------------------------------------------------------------

class TestSplit:
    def test_components(self):
        g = _two_component_graph(chains=3, depth=2)
        comps = ClusterPool._components(g)
        assert [len(c) for c in comps] == [2, 2, 2]
        assert sorted(u for c in comps for u in c) == sorted(g.ops)

    def _split_pool(self, transfer_cost_s: float):
        return ClusterPool(
            ClusterSpec(machines=(KNL, KNL),
                        transfer_cost_s=transfer_cost_s),
            router=RouterConfig(split=True))

    def test_cheap_transfer_splits_across_two_machines(self):
        pool = self._split_pool(1e-4)
        pool.submit(_two_component_graph(), name="twin")
        cj = pool.cluster_jobs[-1]
        assert cj.split and len(cj.jobs) == 2
        parts = {pool.assignment[j.jid] for j in cj.jobs}
        assert parts == {0, 1}
        res = pool.run()
        assert res.n_splits == 1
        uids = sorted(u for j in cj.jobs
                      for u in _recorded_uids(
                          res.machines[res.assignment[j.jid]], j.jid))
        assert uids == sorted(_two_component_graph().ops)

    def test_expensive_transfer_refuses_split(self):
        pool = self._split_pool(1e9)
        pool.submit(_two_component_graph(), name="twin")
        res = pool.run()
        assert res.n_splits == 0
        assert not pool.cluster_jobs[-1].split

    def test_split_off_by_default(self):
        pool = ClusterPool(ClusterSpec(machines=(KNL, KNL),
                                       transfer_cost_s=1e-4))
        pool.submit(_two_component_graph(), name="twin")
        assert pool.n_splits == 0

    def test_single_component_never_splits(self):
        pool = self._split_pool(1e-4)
        pool.submit(build_paper_graph("dcgan"))
        assert pool.n_splits == 0

    def test_cancel_takes_all_parts(self):
        """Split parts stand and fall together: cancelling by EITHER
        part's jid removes both halves before any op runs."""
        pool = self._split_pool(1e-4)
        job = pool.submit(_two_component_graph(), name="twin")
        cj = pool.cluster_jobs[-1]
        assert pool.cancel(job.jid) is True
        res = pool.run()
        for part in cj.jobs:
            m = res.assignment[part.jid]
            assert not res.machines[m].records.get(part.jid)


# ---------------------------------------------------------------------------
# FAM_CLUSTER observability (positive coverage — the single-machine
# trace artifact legitimately excludes this family)
# ---------------------------------------------------------------------------

class TestClusterObservability:
    def _traced_run(self, tmp_path):
        sink = RecordingSink()
        pool = ClusterPool(
            ClusterSpec.homogeneous(2),
            config=PoolConfig(max_active=2,
                              strategy=StrategyConfig(sink=sink)))
        for i, m in enumerate(["resnet50", "dcgan", "resnet50", "dcgan"]):
            pool.submit(build_paper_graph(m), name=f"{m}.{i}")
        res = pool.run()
        return sink, res

    def test_route_events_and_metrics(self, tmp_path):
        sink, _ = self._traced_run(tmp_path)
        routes = [e for e in sink.events
                  if e.family == FAM_CLUSTER and e.kind == "route"]
        assert len(routes) == 4
        assert all(e.data["policy"] == "demand"
                   and not e.data["forced"]
                   and e.data["demand"] is not None
                   and len(e.data["loads"]) == 2 for e in routes)
        snap = metrics_from_events(sink.events).snapshot()
        assert snap.get("cluster.route") == 4
        assert sum(snap.get(f"cluster.machine.{m}.routed", 0)
                   for m in range(2)) == 4

    def test_rebalance_event(self):
        sink = RecordingSink()
        pool = ClusterPool(
            ClusterSpec.homogeneous(2),
            config=PoolConfig(max_active=1,
                              strategy=StrategyConfig(sink=sink)))
        pool.submit(build_paper_graph("resnet50"), name="hog", machine=0)
        pool.submit(build_paper_graph("dcgan"), name="urgent", machine=0,
                    submit_time=0.001, deadline=0.04)
        pool.run()
        moves = [e for e in sink.events
                 if e.family == FAM_CLUSTER and e.kind == "rebalance"]
        assert len(moves) == 1
        assert moves[0].data["from"] == 0 and moves[0].data["to"] == 1
        assert moves[0].data["slack"] <= 0.0

    def test_split_event(self):
        sink = RecordingSink()
        pool = ClusterPool(
            ClusterSpec(machines=(KNL, KNL), transfer_cost_s=1e-4),
            config=PoolConfig(strategy=StrategyConfig(sink=sink)),
            router=RouterConfig(split=True))
        pool.submit(_two_component_graph(), name="twin")
        ev = [e for e in sink.events
              if e.family == FAM_CLUSTER and e.kind == "split"]
        assert len(ev) == 1
        assert ev[0].data["machines"] == [0, 1]
        assert ev[0].data["gain"] > ev[0].data["cost"]

    def test_perfetto_export_per_machine_lanes(self, tmp_path):
        sink, res = self._traced_run(tmp_path)
        path = tmp_path / "cluster_trace.json"
        trace = export_cluster_trace(res, str(path), sink.events)
        assert path.exists()
        pids = {e.get("pid") for e in trace["traceEvents"]}
        assert {MACHINE_PID_BASE, MACHINE_PID_BASE + 1} <= pids
        flows = [e for e in trace["traceEvents"]
                 if e.get("cat") == "cluster"
                 and e.get("ph") in ("s", "f")]
        assert flows, "route->launch flow arrows must be emitted"


# ---------------------------------------------------------------------------
# Cluster-mode daemon: placement is state, recovery restores it
# ---------------------------------------------------------------------------

class TestClusterDaemon:
    def test_cluster_xor_machine(self, tmp_path):
        with pytest.raises(ValueError):
            PoolDaemon(tmp_path, cluster=ClusterSpec.homogeneous(2),
                       machine=SimMachine())

    def test_placement_survives_restart_and_drains(self, tmp_path):
        spec = ClusterSpec.homogeneous(2)
        cfg = PoolConfig(max_active=2)
        d1 = PoolDaemon(tmp_path, cluster=spec, config=cfg)
        for i, w in enumerate(["resnet50", "dcgan", "dcgan", "resnet50"]):
            d1.submit(JobSpec(workload=w, name=f"j{i}"))
        st1 = d1.status()
        placement1 = {j["id"]: j["machine"] for j in st1["jobs"]}
        assert st1["machines"] == 2
        assert set(placement1.values()) <= {0, 1}
        d1.checkpoint()
        d1.close()

        d2 = PoolDaemon(tmp_path, cluster=spec, config=cfg)
        st2 = d2.status()
        assert {j["id"]: j["machine"] for j in st2["jobs"]} == placement1
        res = d2.drain()
        assert isinstance(res, ClusterResult)
        assert all(cj.done for cj in d2.pool.cluster_jobs)
        assert len(st2["clocks"]) == 2
        d2.close()

    def test_legacy_store_without_cluster_fields_loads(self):
        entry = JobEntry(spec=JobSpec(workload="dcgan"), order=0)
        d = entry.to_dict()
        d.pop("machine")
        assert JobEntry.from_dict(d).machine is None

        state = StoreState(entries=[entry])
        sd = state.to_dict()
        sd.pop("clocks")
        assert StoreState.from_dict(sd).clocks is None


# ---------------------------------------------------------------------------
# XLA host-device fan-out (executor side of the cluster)
# ---------------------------------------------------------------------------

class TestHostDevices:
    def test_existing_device_count_flag_wins(self, monkeypatch):
        from repro.core.runtime import _request_host_devices
        monkeypatch.setenv(
            "XLA_FLAGS", "--xla_force_host_platform_device_count=4")
        _request_host_devices(2)
        assert os.environ["XLA_FLAGS"].count(
            "xla_force_host_platform_device_count") == 1

    def test_flag_appended_once(self, monkeypatch):
        from repro.core.runtime import _request_host_devices
        monkeypatch.setenv("XLA_FLAGS", "--some_other_flag")
        _request_host_devices(3)
        flags = os.environ["XLA_FLAGS"]
        assert "--some_other_flag" in flags
        assert "--xla_force_host_platform_device_count=3" in flags

    @pytest.mark.slow
    def test_device_for_round_robins(self):
        jax = pytest.importorskip("jax")
        from repro.core.runtime import RealGraphExecutor
        ex = RealGraphExecutor(n_devices=2)
        d0 = ex.device_for(0)
        if d0 is None:          # jax present but no CPU backend
            pytest.skip("no jax CPU devices available")
        n = len(jax.devices("cpu"))
        assert ex.device_for(n) == d0        # wraps modulo the grant
        if n >= 2:
            assert ex.device_for(1) != d0


# ---------------------------------------------------------------------------
# hypothesis generalizations (skipped when hypothesis is absent; the
# deterministic twins above always run)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    _fact_rows = st.lists(
        st.tuples(st.floats(0, 1e4), st.floats(1e-3, 1e3),
                  st.floats(0, 1)),
        min_size=1, max_size=8)

    class TestRouterHypothesis:
        @settings(deadline=None, max_examples=100)
        @given(rows=_fact_rows)
        def test_demand_route_minimizes_projected_finish(self, rows):
            facts = _facts(rows)
            chosen = JobRouter().route(facts)
            picked = next(f for f in facts if f.index == chosen)
            best = min((f.projected_finish, -f.warm_frac, f.index)
                       for f in facts)
            assert (picked.projected_finish, -picked.warm_frac,
                    picked.index) == best

        @settings(deadline=None, max_examples=50)
        @given(rows=_fact_rows, k=st.integers(1, 32))
        def test_round_robin_routes_each_arrival_exactly_once(
                self, rows, k):
            r = JobRouter(RouterConfig(policy="round_robin"))
            facts = _facts(rows)
            n = len(facts)
            choices = [r.route(facts) for _ in range(k)]
            assert all(0 <= c < n for c in choices)
            # arrivals spread one at a time, never skipping a machine
            for m in range(n):
                assert choices.count(m) in (k // n, k // n + 1)
